"""End-to-end driver: train a ~100M-param glm4-family LM for a few hundred
steps on the synthetic ThundeRiNG data pipeline, with periodic async
checkpoints and restart-proof determinism.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

(~100M params; on this CPU container expect ~1-2 s/step. The identical
code path jits under the production mesh on TPU — see repro/launch/train.)
"""
import argparse

from repro.configs import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-param member of the glm4 family (14 layers, d=768, GQA 12/2)
    cfg = get_config("glm4_9b").scaled(
        n_layers=14, d_model=768, n_heads=12, n_kv_heads=2, d_ff=2048,
        vocab=32768, q_chunk=128, loss_chunks=4)
    train(cfg, steps=args.steps, global_batch=4, seq_len=256,
          ckpt_dir=args.ckpt_dir, save_every=100, log_every=10)


if __name__ == "__main__":
    main()
