"""Paper case study 1 (Sec. 6 / Fig. 8): Monte-Carlo estimation of pi.

Sweeps the number of draws like the paper's figure; reports estimate,
error and throughput for the ThundeRiNG-fused path and a jax.random
baseline.  Draw windows come from a ``BlockService`` lease ledger, so
every sweep point consumes fresh, disjoint randomness of one family —
re-spending a window would raise, not silently correlate the estimates.

  PYTHONPATH=src python examples/monte_carlo_pi.py
"""
import time
from math import pi

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.runtime import BlockService


def vendor_pi(n):
    key = jax.random.PRNGKey(0)
    xy = jax.random.uniform(key, (2, n))
    return 4.0 * jnp.sum((xy[0] ** 2 + xy[1] ** 2) < 1.0) / n


def main():
    lanes = 1024
    service = BlockService(seed=7)
    service.open("mc/pi", num_streams=lanes)
    print(f"{'draws':>12} {'estimate':>10} {'|err|':>9} {'Mdraw/s':>9}")
    for draws_per_lane in (256, 1024, 4096):
        n = lanes * draws_per_lane
        lease = service.lease("mc/pi", draws_per_lane)
        f = lambda: ops.estimate_pi(seed=service.seed, num_lanes=lanes,
                                    draws_per_lane=draws_per_lane,
                                    offset=lease.lo, use_kernel=False)
        f()  # compile (replaying a window is recompute, not re-spend)
        t0 = time.perf_counter()
        est = float(f())
        dt = time.perf_counter() - t0
        service.commit(lease)
        print(f"{n:12d} {est:10.6f} {abs(est - pi):9.2e} "
              f"{n / dt / 1e6:9.1f}  (thundering)")
    spent = service.ledger_state()["channels"]["mc/pi"]["committed"]
    print(f"# mc/pi windows consumed: {spent}")
    n = 1024 * 4096
    jax.block_until_ready(vendor_pi(n))
    t0 = time.perf_counter()
    est = float(vendor_pi(n))
    dt = time.perf_counter() - t0
    print(f"{n:12d} {est:10.6f} {abs(est - pi):9.2e} "
          f"{n / dt / 1e6:9.1f}  (jax.random baseline)")


if __name__ == "__main__":
    main()
