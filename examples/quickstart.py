"""Quickstart: the ThundeRiNG MISRN public API in 2 minutes.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, stream
from repro.core.statistics import inter_stream_report
from repro.kernels import ops

# --- 1. splittable streams (the jax.random-style API) ---------------------
root = stream.new_stream(seed=42)
s_dropout, s_init, s_data = stream.split(root, 3)

bits = stream.random_bits(s_init, (4, 8))
print("uint32 bits:\n", np.asarray(bits))
print("uniform:", np.asarray(stream.uniform(s_data, (4,))))
print("normal :", np.asarray(stream.normal(s_data, (4,))))

# --- 2. counter addressing: advance == slicing ----------------------------
a = stream.random_bits(s_data, (10,))
b = stream.random_bits(stream.advance(s_data, 4), (6,))
assert np.array_equal(np.asarray(a)[4:], np.asarray(b))
print("counter addressing OK (advance(k) == [k:])")

# --- 3. bulk MISRN block (the paper's core artifact) -----------------------
blk = ops.thundering_bulk(seed=42, num_streams=256, num_steps=512,
                          mode="ctr")  # (T, S) time-major
print("bulk block:", blk.shape, blk.dtype)

# paper-faithful serial xorshift128 decorrelator mode:
blk_f = ops.thundering_bulk(seed=42, num_streams=128, num_steps=64,
                            mode="faithful")
print("faithful block:", blk_f.shape)

# --- 4. independence across streams (paper Table 3) ------------------------
streams = np.asarray(blk).T[:6]  # 6 streams x 512 steps
rep = inter_stream_report(streams)
print(f"max pairwise |pearson| over 6 streams: {rep['max_pearson']:.5f}")

# --- 5. fused dropout (mask never materializes in HBM) ---------------------
x = jnp.ones((16, 256))
y = ops.fused_dropout(x, s_dropout, rate=0.3)
print("fused dropout kept:", float((np.asarray(y) != 0).mean()))

# --- 6. the engine underneath: one plan, any backend, any mesh --------------
plan = engine.make_plan(seed=42, num_streams=256, num_steps=64)
a = engine.generate(plan, backend="xla")
b = engine.generate(plan, backend="pallas")      # interpret=True on CPU
c = engine.generate_sharded(plan)                # shard_map over all devices
assert np.array_equal(np.asarray(a), np.asarray(b))
assert np.array_equal(np.asarray(a), np.asarray(c))
print(f"engine backends {engine.available_backends()} bit-identical, "
      f"sharded over {len(jax.devices())} device(s)")
