"""Paper case study 2 (Sec. 6 / Fig. 9, Table 7): Black-Scholes Monte-
Carlo option pricing with ThundeRiNG streams, validated against the
closed form.

  PYTHONPATH=src python examples/option_pricing.py
"""
import time
from math import erf, exp, log, sqrt

from repro.kernels import ops


def black_scholes(s0, k, r, sigma, t):
    d1 = (log(s0 / k) + (r + sigma ** 2 / 2) * t) / (sigma * sqrt(t))
    d2 = d1 - sigma * sqrt(t)
    N = lambda x: 0.5 * (1 + erf(x / sqrt(2)))
    return s0 * N(d1) - k * exp(-r * t) * N(d2)


def main():
    params = dict(s0=100.0, strike=100.0, r=0.05, sigma=0.2, t=1.0)
    closed = black_scholes(params["s0"], params["strike"], params["r"],
                           params["sigma"], params["t"])
    print(f"closed-form Black-Scholes call: {closed:.4f}")
    print(f"{'draws':>12} {'MC price':>10} {'rel err':>9} {'Mdraw/s':>9}")
    for draws in (256, 1024, 4096):
        lanes = 1024
        n = lanes * draws
        f = lambda: ops.price_option(seed=3, num_lanes=lanes,
                                     draws_per_lane=draws,
                                     use_kernel=False, **params)
        f()
        t0 = time.perf_counter()
        est = float(f())
        dt = time.perf_counter() - t0
        print(f"{n:12d} {est:10.4f} {abs(est - closed) / closed:9.2e} "
              f"{n / dt / 1e6:9.1f}")


if __name__ == "__main__":
    main()
