"""Fleet transport + failover: framing, fencing, fault injection.

The wire-level acceptance properties as executable tests:

  * framing is robust: torn mid-frame writes and oversize declared
    lengths error cleanly on one connection without wedging the accept
    loop (the next client is still served),
  * arrays survive the wire byte-exactly for every served dtype
    (uint32, float32, bfloat16, bool),
  * the consistent-hash ring is a pure function of the shard count —
    every client derives the same routing with no coordination,
  * scripted faults replay exactly (plan parse/json/seeded round-trips;
    the injector fires each spec exactly once),
  * retries are idempotent: a journaled rid is answered by journal
    replay — bit-identical bytes, never a second counter window,
  * a journal has exactly one writer (flock fencing), and
  * the headline guarantee: a 2-shard burst with a scripted
    kill-mid-burst produces EXACTLY the bytes of the no-fault run —
    the surviving peer fences the dead shard's journal, replays its
    committed windows, and resumes its tenant regions bit-identically.
"""
import os
import signal
import socket
import struct
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.runtime.fault import (FaultInjector, FaultPlan, FaultSpec,
                                 rid_index)
from repro.service import audit, transport
from repro.service.audit import Journal, JournalLockedError
from repro.service.burst import make_requests
from repro.service.fleet import (Fleet, FleetConfig, HashRing,
                                 run_fleet_burst)
from repro.service.frontend import RandRequest
from repro.service.transport import (FrameTooLarge, ShardHost, TornFrame,
                                     decode_array, encode_array,
                                     recv_frame, send_frame)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------

def test_frame_roundtrip():
    a, b = socket.socketpair()
    try:
        msg = {"op": "ping", "nested": {"xs": [1, 2, 3]}}
        send_frame(a, msg)
        assert recv_frame(b) == msg
        # several frames back to back stay in sync
        for i in range(5):
            send_frame(a, {"i": i})
        for i in range(5):
            assert recv_frame(b) == {"i": i}
    finally:
        a.close()
        b.close()


def test_frame_clean_eof_is_none():
    a, b = socket.socketpair()
    a.close()
    try:
        assert recv_frame(b) is None
    finally:
        b.close()


def test_frame_too_large_both_directions():
    a, b = socket.socketpair()
    try:
        with pytest.raises(FrameTooLarge):
            send_frame(a, {"blob": "x" * 256}, max_frame=64)
        # hostile declared length: reader refuses before allocating
        a.sendall(struct.pack("!I", transport.MAX_FRAME + 1))
        with pytest.raises(FrameTooLarge):
            recv_frame(b)
    finally:
        a.close()
        b.close()


def test_torn_frame_mid_body():
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("!I", 100) + b'{"partial": tru')
        a.close()
        with pytest.raises(TornFrame):
            recv_frame(b)
    finally:
        b.close()


def test_torn_frame_mid_header():
    a, b = socket.socketpair()
    try:
        a.sendall(b"\x00\x00")          # 2 of 4 header bytes
        a.close()
        with pytest.raises(TornFrame):
            recv_frame(b)
    finally:
        b.close()


@pytest.mark.parametrize("dtype,maker", [
    ("uint32", lambda: np.arange(12, dtype=np.uint32).reshape(3, 4)),
    ("float32", lambda: np.linspace(-1, 1, 7, dtype=np.float32)),
    ("bool", lambda: np.array([True, False, True])),
    ("bfloat16", lambda: None),          # built below via ml_dtypes
])
def test_array_wire_roundtrip(dtype, maker):
    if dtype == "bfloat16":
        import ml_dtypes
        a = np.arange(6).astype(ml_dtypes.bfloat16).reshape(2, 3)
    else:
        a = maker()
    back = decode_array(encode_array(a))
    assert str(back.dtype) == str(a.dtype)
    assert back.shape == a.shape
    assert back.tobytes() == a.tobytes()


# ---------------------------------------------------------------------------
# Hash ring
# ---------------------------------------------------------------------------

def test_ring_deterministic_and_covering():
    r1, r2 = HashRing(4), HashRing(4)
    tenants = [f"tenant/{i:05d}" for i in range(512)]
    assert [r1.owner(t) for t in tenants] == [r2.owner(t) for t in tenants]
    owners = {r1.owner(t) for t in tenants}
    assert owners == {0, 1, 2, 3}        # every shard gets traffic
    # peer preference: all other shards, no self, deterministic order
    for s in range(4):
        assert r1.peers(s) == [(s + k) % 4 for k in range(1, 4)]
        assert s not in r1.peers(s)


def test_ring_single_shard():
    ring = HashRing(1)
    assert ring.owner("anyone") == 0
    assert ring.peers(0) == []


# ---------------------------------------------------------------------------
# Fault plans
# ---------------------------------------------------------------------------

def test_fault_plan_parse_and_json_roundtrip():
    plan = FaultPlan.parse("kill@512, hang@40#1, slow@600~0.25, drop@7")
    kinds = [s.kind for s in plan.specs]
    assert kinds == ["kill", "hang", "slow", "drop"]
    assert plan.specs[1].shard == 1
    assert plan.specs[2].seconds == 0.25
    assert FaultPlan.from_json(plan.to_json()) == plan
    assert FaultPlan.parse(plan.to_json()) == plan    # JSON form accepted
    assert not FaultPlan.parse("")                     # empty plan
    with pytest.raises(ValueError):
        FaultPlan.parse("explode@3")


def test_fault_plan_seeded_replays():
    p1 = FaultPlan.seeded(7, burst=1024, kinds=("kill", "drop"), count=3)
    p2 = FaultPlan.seeded(7, burst=1024, kinds=("kill", "drop"), count=3)
    assert p1 == p2 and len(p1.specs) == 3
    assert all(256 <= s.index < 768 for s in p1.specs)
    assert FaultPlan.seeded(8, burst=1024, kinds=("kill", "drop"),
                            count=3) != p1


def test_injector_fires_each_spec_once():
    inj = FaultInjector(FaultPlan.parse("kill@24,drop@24#1"))
    assert inj.fire(1, 24).kind == "kill"   # shard-agnostic spec first
    assert inj.fire(1, 24).kind == "drop"
    assert inj.fire(1, 24) is None          # both consumed
    assert inj.fire(0, 99) is None
    assert rid_index("burst/000512") == 512
    assert rid_index("no-digits") is None
    assert rid_index(None) is None


# ---------------------------------------------------------------------------
# ShardHost over real sockets
# ---------------------------------------------------------------------------

def _req_msg(shard, rid, tenant="alice", n=16):
    return {"op": "request", "shard": shard, "rid": rid,
            "tenant": tenant, "shape": [n], "sampler": "bits",
            "dtype": "float32"}


def test_shardhost_serves_and_replays_idempotently(tmp_path):
    with ShardHost(3) as host:
        host.add_shard(0, str(tmp_path / "j.jsonl"))
        first = transport.rpc(host.address, _req_msg(0, "rid/001"))
        assert first["ok"] and first["replayed"] is False
        again = transport.rpc(host.address, _req_msg(0, "rid/001"))
        assert again["ok"] and again["replayed"] is True
        a1, a2 = decode_array(first["array"]), decode_array(again["array"])
        assert a1.tobytes() == a2.tobytes()     # never a second window
        # and a different rid gets different bytes (fresh window)
        other = transport.rpc(host.address, _req_msg(0, "rid/002"))
        assert decode_array(other["array"]).tobytes() != a1.tobytes()


def test_shardhost_not_owner_and_bad_op(tmp_path):
    with ShardHost(3) as host:
        host.add_shard(0, str(tmp_path / "j.jsonl"))
        r = transport.rpc(host.address, _req_msg(5, "rid/001"))
        assert not r["ok"] and r["kind"] == "not_owner"
        r = transport.rpc(host.address, {"op": "frobnicate"})
        assert not r["ok"] and r["kind"] == "bad_request"
        r = transport.rpc(host.address, {"op": "ping"})
        assert r["ok"] and r["shards"] == [0]


def test_shardhost_survives_torn_and_oversize_clients(tmp_path):
    """One client's torn write or hostile length must not wedge the
    accept loop: the NEXT connection is still served normally."""
    with ShardHost(3) as host:
        host.add_shard(0, str(tmp_path / "j.jsonl"))
        # torn mid-body
        s = socket.create_connection(host.address, timeout=10)
        s.sendall(struct.pack("!I", 500) + b'{"op": "requ')
        s.close()
        # torn mid-header
        s = socket.create_connection(host.address, timeout=10)
        s.sendall(b"\x00")
        s.close()
        # oversize declared length: server answers with an error frame
        # (best effort) and closes
        s = socket.create_connection(host.address, timeout=10)
        s.sendall(struct.pack("!I", transport.MAX_FRAME + 7))
        reply = recv_frame(s)
        assert reply is not None and reply["kind"] == "frame_too_large"
        assert recv_frame(s) is None            # then the conn closes
        s.close()
        # the host is unharmed: a well-behaved client is served
        r = transport.rpc(host.address, _req_msg(0, "rid/ok1"))
        assert r["ok"]


def test_shardhost_close_retires_transport_threads(tmp_path):
    """close() must not leak accept/conn threads into the embedding
    process: blocked accept()/recv() are not woken by a plain close(2)
    on Linux, so the host has to poll the listener and shut down idle
    connections explicitly."""
    host = ShardHost(3)
    host.add_shard(0, str(tmp_path / "j.jsonl"))
    assert transport.rpc(host.address, {"op": "ping"})["ok"]
    idle = socket.create_connection(host.address, timeout=10)
    time.sleep(0.3)                 # let the conn thread park in recv
    host.close()
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        left = [t for t in threading.enumerate()
                if t.name.startswith("shardhost") and t.is_alive()]
        if not left:
            break
        time.sleep(0.05)
    assert not left, [t.name for t in left]
    idle.close()


def test_shardhost_drop_fault_retry_is_bit_identical(tmp_path):
    """A drop-frame fault serves+journals but never replies; the retry
    must be answered by replay with exactly the journaled bytes."""
    inj = FaultInjector(FaultPlan.parse("drop@7"))
    with ShardHost(3, injector=inj) as host:
        host.add_shard(0, str(tmp_path / "j.jsonl"))
        s = socket.create_connection(host.address, timeout=30)
        send_frame(s, _req_msg(0, "rid/007"))
        with pytest.raises((TornFrame, OSError)) as _:
            if recv_frame(s) is None:           # clean-EOF variant
                raise TornFrame("dropped")
        s.close()
        retry = transport.rpc(host.address, _req_msg(0, "rid/007"))
        assert retry["ok"] and retry["replayed"] is True
        served = decode_array(retry["array"])
        replayed = audit.replay(str(tmp_path / "j.jsonl"), seed=3)
        assert served.tobytes() == replayed["rid/007"].tobytes()


# ---------------------------------------------------------------------------
# Journal locking (the fencing primitive)
# ---------------------------------------------------------------------------

def test_journal_exclusive_lock(tmp_path):
    path = str(tmp_path / "j.jsonl")
    j1 = Journal(path)
    j1.append_window("c", 0, 8)
    j1.flush()
    # a second writer in another PROCESS is refused while j1 lives
    # (flock is per-open-file, so the check must cross processes)
    code = ("import sys\n"
            "from repro.service.audit import Journal, JournalLockedError\n"
            "try:\n"
            f"    Journal({path!r})\n"
            "except JournalLockedError:\n"
            "    sys.exit(42)\n"
            "sys.exit(0)\n")
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    rc = subprocess.run([sys.executable, "-c", code], cwd=REPO,
                        env=env, timeout=120).returncode
    assert rc == 42, "second writer must raise JournalLockedError"
    # a readonly view is always allowed
    ro = Journal(path, readonly=True)
    assert len(ro.windows()) == 1
    # close releases the lock: the next writer proceeds
    j1.close()
    j2 = Journal(path)
    assert len(j2.windows()) == 1
    j2.close()


def test_adopt_refused_while_owner_lives(tmp_path):
    """Fence-gated hedging: adoption reports ``locked`` while the
    journal's owner still holds the flock (cross-process)."""
    path = str(tmp_path / "j.jsonl")
    code = ("import time, sys\n"
            "from repro.service.audit import Journal\n"
            f"j = Journal({path!r})\n"
            "j.append_window('c', 0, 8)\n"
            "j.flush()\n"
            "print('locked', flush=True)\n"
            "time.sleep(300)\n")
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    owner = subprocess.Popen([sys.executable, "-c", code], cwd=REPO,
                             env=env, stdout=subprocess.PIPE, text=True)
    try:
        assert owner.stdout.readline().strip() == "locked"
        with ShardHost(3) as host:
            reply = host._handle_adopt({"shard": 1, "journal": path})
            assert not reply["ok"] and reply["kind"] == "locked"
            # fence the owner (SIGKILL) -> the flock drops -> adoption
            # succeeds and the journaled window is fenced off
            owner.kill()
            owner.wait(timeout=30)
            reply = host._handle_adopt({"shard": 1, "journal": path})
            assert reply["ok"]
            assert 1 in host.shards()
    finally:
        if owner.poll() is None:
            owner.kill()
            owner.wait(timeout=30)


# ---------------------------------------------------------------------------
# Fleet end-to-end (subprocess shards over TCP)
# ---------------------------------------------------------------------------

BURST, TENANTS, SEED = 64, 16, 0


def _fleet_digest(tmp_path, name, fault_plan, **client_kw):
    cfg = FleetConfig(num_shards=2, seed=SEED,
                      journal_dir=str(tmp_path / name))
    reqs = make_requests(burst=BURST, tenants=TENANTS, seed=SEED)
    with Fleet(cfg, fault_plan) as fleet:
        client = fleet.client(**client_kw)
        responses = run_fleet_burst(client, reqs)
        stats = client.stats()
        client.close()
        journals = fleet.journals()
    assert len(responses) == BURST
    return audit.response_digest(responses), stats, journals


@pytest.mark.slow
def test_fleet_kill_midburst_digest_equality(tmp_path):
    """The headline failover guarantee: kill a shard mid-burst; the
    surviving peer fences its journal, adopts its tenant regions, and
    the full response set is BIT-IDENTICAL to the no-fault run."""
    baseline, base_stats, _ = _fleet_digest(tmp_path, "nofault",
                                            FaultPlan())
    assert base_stats["failovers"] == 0
    killed, kill_stats, journals = _fleet_digest(
        tmp_path, "kill", FaultPlan.parse(f"kill@{BURST // 2}"))
    assert killed == baseline
    assert kill_stats["failovers"] == 1
    assert kill_stats["recovery_ms"] is not None
    # the union of the shard journals replays the whole burst
    replayed = {}
    for path in journals.values():
        replayed.update(audit.replay(path, seed=SEED))
        audit.verify_ledger_disjoint(Journal(path, readonly=True))
    assert len(replayed) == BURST
    assert audit.response_digest(replayed) == baseline


@pytest.mark.slow
def test_fleet_hang_is_fenced_then_adopted(tmp_path):
    """A hung (alive but wedged) shard: adoption is refused while the
    flock is held, the client fences (SIGKILL) the owner, adoption then
    succeeds — and the bytes still match the no-fault run."""
    baseline, _, _ = _fleet_digest(tmp_path, "nofault", FaultPlan())
    hung, stats, _ = _fleet_digest(
        tmp_path, "hang", FaultPlan.parse(f"hang@{BURST // 2}"),
        deadline_s=8.0)
    assert hung == baseline
    assert stats["failovers"] == 1


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-v"]))
