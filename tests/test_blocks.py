"""Block delivery layer: lease accounting (disjoint windows, two-phase
ledger, checkpoint/restore), double-buffered producers, the 2-D
(host, stream) mesh fan-out, and the BlockService-fed training path."""
import json
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, stream as tstream
from repro.kernels import ops
from repro.runtime import BlockProducer, BlockService, Lease, LeaseError
from repro.runtime import blocks as blocks_mod


# ---------------------------------------------------------------------------
# lease accounting
# ---------------------------------------------------------------------------

def test_sequential_leases_are_consecutive_and_disjoint():
    svc = BlockService(seed=1)
    svc.open("a", num_streams=4)
    l1 = svc.lease("a", 10)
    l2 = svc.lease("a", 6)
    assert (l1.lo, l1.hi) == (0, 10)
    assert (l2.lo, l2.hi) == (10, 16)


@pytest.mark.parametrize("at", [0, 5, 9, 15])
def test_overlapping_lease_rejected_reserved_and_committed(at):
    svc = BlockService(seed=1)
    svc.open("a")
    l1 = svc.lease("a", 10)          # [0, 10) reserved
    l2 = svc.lease("a", 6)           # [10, 16) reserved
    svc.commit(l1)                   # [0, 10) committed
    with pytest.raises(LeaseError, match="overlaps"):
        svc.lease("a", 1, at=at)
    # non-overlapping explicit window is fine
    l3 = svc.lease("a", 4, at=100)
    assert (l3.lo, l3.hi) == (100, 104)


def test_release_reopens_window():
    svc = BlockService(seed=1)
    svc.open("a")
    lease = svc.lease("a", 8)
    svc.release(lease)
    again = svc.lease("a", 8, at=0)
    assert (again.lo, again.hi) == (0, 8)


def test_commit_requires_reservation():
    svc = BlockService(seed=1)
    svc.open("a")
    ghost = Lease(channel="a", lo=0, hi=4, service=svc)
    with pytest.raises(LeaseError, match="not reserved"):
        svc.commit(ghost)


def test_lease_validation():
    svc = BlockService(seed=1)
    with pytest.raises(KeyError, match="not open"):
        svc.lease("missing", 4)
    svc.open("a")
    with pytest.raises(ValueError, match="positive"):
        svc.lease("a", 0)


def test_channels_have_independent_ledgers():
    svc = BlockService(seed=1)
    svc.open("a")
    svc.open("b")
    svc.commit(svc.lease("a", 16))
    lb = svc.lease("b", 16)
    assert lb.lo == 0    # channel b unaffected by a's windows


# ---------------------------------------------------------------------------
# ledger checkpoint / restore
# ---------------------------------------------------------------------------

def test_ledger_snapshot_restores_midrun_bit_identically():
    svc = BlockService(seed=5)
    svc.open("a", num_streams=8)
    for _ in range(3):
        svc.commit(svc.lease("a", 16))
    snap = svc.ledger_state()
    # run continues past the snapshot ...
    l4 = svc.lease("a", 16)
    blk4 = np.asarray(svc.generate(l4))
    svc.commit(l4)
    # ... the process dies and restarts from the snapshot: the SAME
    # window is re-leased and regenerates the SAME bits.
    svc2 = BlockService(seed=5)
    svc2.open("a", num_streams=8)
    svc2.restore_ledger(snap)
    l4b = svc2.lease("a", 16)
    assert (l4b.lo, l4b.hi) == (l4.lo, l4.hi)
    assert np.array_equal(np.asarray(svc2.generate(l4b)), blk4)


def test_ledger_snapshot_excludes_reservations():
    svc = BlockService(seed=5)
    svc.open("a")
    svc.commit(svc.lease("a", 8))
    in_flight = svc.lease("a", 8)          # reserved, never committed
    snap = svc.ledger_state()
    assert snap["channels"]["a"]["committed"] == [[0, 8]]
    svc.restore_ledger(snap)
    replay = svc.lease("a", 8)
    assert (replay.lo, replay.hi) == (in_flight.lo, in_flight.hi)


def test_ledger_snapshot_is_json_roundtrippable():
    svc = BlockService(seed=5)
    svc.open("a")
    svc.commit(svc.lease("a", 4))
    snap = json.loads(json.dumps(svc.ledger_state()))
    svc2 = BlockService(seed=5)
    svc2.open("a")
    svc2.restore_ledger(snap)
    assert svc2.lease("a", 4).lo == 4


def test_committed_windows_merge():
    svc = BlockService(seed=5)
    svc.open("a")
    for _ in range(4):
        svc.commit(svc.lease("a", 8))
    assert svc.ledger_state()["channels"]["a"]["committed"] == [[0, 32]]


# ---------------------------------------------------------------------------
# generation parity: traced windows == static plans == stream API
# ---------------------------------------------------------------------------

def test_generate_matches_static_plan_and_stream():
    svc = BlockService(seed=42)
    svc.open("t", num_streams=8)
    lease = svc.lease("t", 16)
    svc.commit(svc.lease("t", 16))  # a second window, out of order is fine
    blk = np.asarray(svc.generate(lease))
    ref = np.asarray(engine.generate(lease.plan(), backend="ref"))
    assert np.array_equal(blk, ref)
    col = np.asarray(tstream.random_bits(lease.stream(3), (16,)))
    assert np.array_equal(col, blk[:, 3])


def test_generate_sampler_override():
    svc = BlockService(seed=42)
    svc.open("u", num_streams=4, sampler="uniform")
    lease = svc.lease("u", 8)
    u = np.asarray(svc.generate(lease))
    assert u.dtype == np.float32 and (u >= 0).all() and (u < 1).all()
    bits = np.asarray(svc.generate(lease, sampler="bits"))
    assert bits.dtype == np.uint32
    ref = np.asarray(engine.generate(lease.plan(sampler="bits"),
                                     backend="ref"))
    assert np.array_equal(bits, ref)


def test_take_commits_and_equal_length_leases_share_one_executable():
    svc = BlockService(seed=9)
    svc.open("t", num_streams=4)
    a = np.asarray(svc.take("t", 8))
    b = np.asarray(svc.take("t", 8))
    assert not np.array_equal(a, b)          # disjoint windows
    assert svc.ledger_state()["channels"]["t"]["committed"] == [[0, 16]]
    # one jitted window fn per (channel, length, sampler, dtype)
    assert len(svc._window_fns) == 1


def test_service_generates_through_mesh():
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()).reshape(1, 1), ("hosts", "streams"))
    svc = BlockService(seed=3, mesh=mesh)
    svc.open("m", num_streams=12)
    blk = np.asarray(svc.take("m", 16))
    plan = engine.make_plan(seed=3, num_streams=12, num_steps=16,
                            purpose=blocks_mod.channel_purpose("m"))
    assert np.array_equal(blk, np.asarray(engine.generate(plan,
                                                          backend="xla")))


# ---------------------------------------------------------------------------
# double-buffered producer
# ---------------------------------------------------------------------------

def test_producer_blocks_match_synchronous_generation():
    svc = BlockService(seed=7)
    svc.open("p", num_streams=8)
    with svc.producer("p", 16, count=4) as prod:
        got = [(lease, np.asarray(block)) for lease, block in prod]
    assert [lease.lo for lease, _ in got] == [0, 16, 32, 48]
    for lease, block in got:
        ref = np.asarray(engine.generate(lease.plan(), backend="xla"))
        assert np.array_equal(block, ref)
    # every handed-out window was committed at handoff
    assert svc.ledger_state()["channels"]["p"]["committed"] == [[0, 64]]


def test_producer_close_releases_prefetched_reservations():
    svc = BlockService(seed=7)
    svc.open("p", num_streams=4)
    prod = svc.producer("p", 8)
    next(prod)            # consume one block; ~depth more are in flight
    prod.close()
    # only the consumed window stays committed; reservations were dropped
    assert svc.ledger_state()["channels"]["p"]["committed"] == [[0, 8]]
    assert svc.lease("p", 8).lo == 8


def test_producer_surfaces_lease_exhaustion():
    svc = BlockService(seed=7)
    svc.open("p")
    svc.commit(svc.lease("p", 8, at=16))   # stale window in the way
    with svc.producer("p", 8, start=8) as prod:
        next(prod)                          # [8, 16) is fine
        with pytest.raises(LeaseError, match="overlaps"):
            for _ in prod:                  # [16, 24) must be refused
                pass


def test_producer_custom_window_fn_channel():
    svc = BlockService(seed=7)
    seen = []

    def window(lo, hi):
        seen.append((lo, hi))
        return jnp.full((hi - lo,), lo, jnp.int32)

    svc.open("custom", window_fn=window)
    with svc.producer("custom", 4, count=3) as prod:
        vals = [int(np.asarray(b)[0]) for _, b in prod]
    assert vals == [0, 4, 8]
    assert seen == [(0, 4), (4, 8), (8, 12)]


# ---------------------------------------------------------------------------
# deep pipelines, donated buffer rings, fused multi-window producers
# ---------------------------------------------------------------------------

needs_donation = pytest.mark.skipif(
    not blocks_mod.donation_supported(),
    reason="jit buffer donation is a no-op on this backend")


def _take_blocks(svc, name, length, n, **kw):
    return [np.array(svc.take(name, length, **kw)) for _ in range(n)]


def test_deep_producer_ordering_and_bit_identity():
    ref_svc = BlockService(seed=13)
    ref_svc.open("p", num_streams=8)
    ref = _take_blocks(ref_svc, "p", 16, 6)
    svc = BlockService(seed=13)
    svc.open("p", num_streams=8)
    with svc.producer("p", 16, count=6, depth=3) as prod:
        got = [(lease.lo, np.array(blk)) for lease, blk in prod]
    assert [lo for lo, _ in got] == [0, 16, 32, 48, 64, 80]
    for (_, blk), expect in zip(got, ref):
        assert np.array_equal(blk, expect)


def test_deep_producer_backpressure_bounds_prefetch():
    """A lagging consumer never lets the producer run away: in-flight
    windows are bounded by queue depth + the block being generated."""
    import time
    depth = 3
    svc = BlockService(seed=13)
    svc.open("p", num_streams=4)
    with svc.producer("p", 8, depth=depth) as prod:
        next(prod)                     # slow consumer: take one, then idle
        time.sleep(0.5)                # let the producer fill the queue
        state = svc.ledger_state()["channels"]["p"]["committed"]
        assert state == [[0, 8]]       # nothing else committed
        # reservations = queue (depth) + at most one being generated +
        # one put-blocked: a fresh lease lands within that bound
        nxt = svc.lease("p", 8)
        assert nxt.lo <= 8 * (1 + depth + 2)


def test_deep_producer_stop_mid_queue_drains_reservations():
    svc = BlockService(seed=13)
    svc.open("p", num_streams=4)
    prod = svc.producer("p", 8, depth=4)
    next(prod)
    next(prod)
    prod.close()                       # queue still holds blocks
    assert svc.ledger_state()["channels"]["p"]["committed"] == [[0, 16]]
    # every undelivered reservation was released: [16, 24) is free again
    lease = svc.lease("p", 8, at=16)
    assert (lease.lo, lease.hi) == (16, 24)


@needs_donation
@pytest.mark.parametrize("depth", [1, 2, 4])
def test_donated_producer_bit_identical_to_plain(depth):
    ref_svc = BlockService(seed=17)
    ref_svc.open("p", num_streams=8, sampler="uniform")
    ref = _take_blocks(ref_svc, "p", 16, 6)
    svc = BlockService(seed=17)
    svc.open("p", num_streams=8, sampler="uniform")
    with svc.producer("p", 16, count=6, depth=depth, donate=True,
                      check_ring=True) as prod:
        # donated contract: a block is valid only until the next pull
        got = [np.array(blk) for _, blk in prod]
    assert len(got) == 6
    for blk, expect in zip(got, ref):
        assert np.array_equal(blk, expect)


@needs_donation
def test_donated_producer_reuses_ring_buffers():
    """Zero-copy steady state: every block the ring yields lives at one
    of depth + 2 pre-allocated addresses."""
    depth, n = 2, 12
    svc = BlockService(seed=17)
    svc.open("p", num_streams=4)
    ptrs = set()
    with svc.producer("p", 8, count=n, depth=depth, donate=True,
                      check_ring=True) as prod:
        for _, blk in prod:
            blk.block_until_ready()
            ptrs.add(blk.unsafe_buffer_pointer())
    assert 1 < len(ptrs) <= depth + 2


def test_donated_producer_refused_where_unsupported(monkeypatch):
    svc = BlockService(seed=17)
    svc.open("p", num_streams=4)
    monkeypatch.setattr(blocks_mod, "donation_supported", lambda: False)
    with pytest.raises(ValueError, match="donation"):
        svc.producer("p", 8, donate=True)


def test_fused_producer_bit_identical_with_per_window_commits():
    ref_svc = BlockService(seed=19)
    ref_svc.open("p", num_streams=8)
    ref = _take_blocks(ref_svc, "p", 12, 6)
    svc = BlockService(seed=19)
    svc.open("p", num_streams=8)
    with svc.producer("p", 12, count=6, fuse=4) as prod:  # 6 = 4 + 2 tail
        got = [(lease, np.array(blk)) for lease, blk in prod]
    assert [lease.lo for lease, _ in got] == [0, 12, 24, 36, 48, 60]
    for (_, blk), expect in zip(got, ref):
        assert np.array_equal(blk, expect)
    assert svc.ledger_state()["channels"]["p"]["committed"] == [[0, 72]]


def test_fused_producer_single_window_tail():
    """count % fuse == 1: the one-lease tail batch must still yield a
    full (L, S) window, not a slice of it."""
    ref_svc = BlockService(seed=19)
    ref_svc.open("p", num_streams=8)
    ref = _take_blocks(ref_svc, "p", 12, 7)
    svc = BlockService(seed=19)
    svc.open("p", num_streams=8)
    with svc.producer("p", 12, count=7, fuse=2) as prod:  # 7 = 3x2 + 1 tail
        got = [np.array(blk) for _, blk in prod]
    assert [g.shape for g in got] == [(12, 8)] * 7
    for blk, expect in zip(got, ref):
        assert np.array_equal(blk, expect)


@needs_donation
def test_fused_donated_producer_bit_identical():
    ref_svc = BlockService(seed=19)
    ref_svc.open("p", num_streams=8, sampler="uniform", out_dtype="bfloat16")
    ref = _take_blocks(ref_svc, "p", 16, 8)
    svc = BlockService(seed=19)
    svc.open("p", num_streams=8, sampler="uniform", out_dtype="bfloat16")
    with svc.producer("p", 16, count=8, fuse=2, donate=True,
                      check_ring=True) as prod:
        got = [np.array(blk) for _, blk in prod]
    for blk, expect in zip(got, ref):
        assert np.array_equal(blk.view(np.uint16), expect.view(np.uint16))


def test_lease_many_contiguous_and_atomic():
    svc = BlockService(seed=23)
    svc.open("a", num_streams=2)
    leases = svc.lease_many("a", 8, 3)
    assert [(l.lo, l.hi) for l in leases] == [(0, 8), (8, 16), (16, 24)]
    svc.commit(svc.lease("a", 8, at=40))   # block the middle of the next run
    with pytest.raises(LeaseError, match="overlaps"):
        svc.lease_many("a", 8, 4, at=24)   # [40, 48) clashes on window 3
    # all-or-nothing: the windows before the clash were rolled back
    ok = svc.lease("a", 16, at=24)
    assert (ok.lo, ok.hi) == (24, 40)


def test_generate_many_matches_per_lease_generate():
    svc = BlockService(seed=23)
    svc.open("a", num_streams=8)
    leases = svc.lease_many("a", 16, 3)
    stack = np.asarray(svc.generate_many(leases))
    assert stack.shape == (3, 16, 8)
    for w, lease in enumerate(leases):
        assert np.array_equal(stack[w], np.asarray(svc.generate(lease)))
    solo = svc.lease("a", 16)
    one = np.asarray(svc.generate_many([solo]))
    assert one.shape == (1, 16, 8)
    assert np.array_equal(one[0], np.asarray(svc.generate(solo)))
    with pytest.raises(ValueError, match="single-window"):
        svc.generate_many([solo], retired=jnp.zeros((1, 16, 8), jnp.uint32))


def test_generate_many_rejects_gaps_and_mixed_lengths():
    svc = BlockService(seed=23)
    svc.open("a", num_streams=4)
    l1 = svc.lease("a", 8)
    svc.lease("a", 8)                       # consumed to create a gap
    l3 = svc.lease("a", 8)
    with pytest.raises(ValueError, match="contiguous"):
        svc.generate_many([l1, l3])
    l4 = svc.lease("a", 4)
    with pytest.raises(ValueError, match="contiguous"):
        svc.generate_many([l3, l4])


def test_donate_and_fuse_require_meshless_service():
    devs = np.array(jax.devices())
    mesh = jax.sharding.Mesh(devs, ("streams",))
    svc = BlockService(seed=23, mesh=mesh)
    svc.open("a", num_streams=4)
    with pytest.raises(ValueError, match="mesh"):
        svc.producer("a", 8, fuse=2)
    with pytest.raises(ValueError, match="mesh"):
        svc.producer("a", 8, donate=True)


# ---------------------------------------------------------------------------
# BlockService-fed training: bit-identity + mid-epoch resume
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_cfg():
    from repro.configs import get_config
    from repro.launch.train import smoke_config
    return smoke_config(get_config("glm4_9b"))


@pytest.mark.slow
def test_train_service_path_bit_identical_to_fused(smoke_cfg):
    """The acceptance bar: BlockService-fed training produces bit-identical
    losses (and params) to the pre-refactor fused per-step derive path."""
    from repro.launch.train import train
    runs = {}
    for use_service in (True, False):
        with tempfile.TemporaryDirectory() as d:
            runs[use_service] = train(
                smoke_cfg, steps=4, global_batch=2, seq_len=32, ckpt_dir=d,
                save_every=2, log_every=1, use_service=use_service)
    p1, _, l1 = runs[True]
    p2, _, l2 = runs[False]
    assert l1 == l2
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_train_resumes_bit_identically_after_failure(smoke_cfg):
    """Lease-ledger checkpoint/restore: a SimulatedFailure mid-epoch
    (between checkpoints) restarts from the ledger snapshot and converges
    to the exact params of an uninterrupted run."""
    from repro.launch.train import train
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        p1, _, _ = train(smoke_cfg, steps=5, global_batch=2, seq_len=32,
                         ckpt_dir=d1, save_every=2, log_every=10,
                         use_service=True, fail_at=3)
        p2, _, _ = train(smoke_cfg, steps=5, global_batch=2, seq_len=32,
                         ckpt_dir=d2, save_every=2, log_every=10,
                         use_service=True)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# leased app consumers
# ---------------------------------------------------------------------------

def test_leased_mc_apps_consume_disjoint_windows():
    svc = BlockService(seed=11)
    e1 = float(blocks_mod.estimate_pi(svc, num_lanes=128,
                                      draws_per_lane=64))
    e2 = float(blocks_mod.estimate_pi(svc, num_lanes=128,
                                      draws_per_lane=64))
    assert abs(e1 - np.pi) < 0.2 and abs(e2 - np.pi) < 0.2
    assert e1 != e2          # fresh randomness per call
    assert svc.ledger_state()["channels"]["mc/pi"]["committed"] == [[0, 128]]
    # the second call is the offset window of the same family
    direct = float(ops.estimate_pi(seed=11, num_lanes=128, draws_per_lane=64,
                                   offset=64))
    assert e2 == direct


def test_mc_offset_window_matches_tail_of_longer_run():
    """offset is real counter addressing: a [64, 128) window equals the
    second half of a 128-draw run (partial sums of the same samples)."""
    full = float(ops.estimate_pi(seed=13, num_lanes=64, draws_per_lane=128,
                                 use_kernel=False))
    head = float(ops.estimate_pi(seed=13, num_lanes=64, draws_per_lane=64,
                                 use_kernel=False))
    tail = float(ops.estimate_pi(seed=13, num_lanes=64, draws_per_lane=64,
                                 offset=64, use_kernel=False))
    total = 64 * 128
    assert abs((head * 64 * 64 + tail * 64 * 64) - full * total) < 1e-3


def test_leased_dropout_matches_stream_and_rejects_short_window():
    svc = BlockService(seed=17)
    svc.open("drop")
    x = jnp.ones((16, 256))
    lease = svc.lease("drop", x.size)
    a = np.asarray(ops.fused_dropout(x, lease, 0.3))
    b = np.asarray(ops.fused_dropout(x, lease.stream(), 0.3))
    assert np.array_equal(a, b)
    with pytest.raises(ValueError, match="smaller than"):
        ops.fused_dropout(x, svc.lease("drop", 16), 0.3)


# ---------------------------------------------------------------------------
# 2-D (host, stream) mesh fan-out — forced 8-device subprocess
# ---------------------------------------------------------------------------

MESH_2D_SUBPROCESS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np
from repro.core import engine
from repro.launch.mesh import make_host_mesh, rng_axes
from repro.runtime import BlockService

assert len(jax.devices()) == 8
mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(2, 4),
                         ("hosts", "streams"))
ok = {}
for mode in ("ctr", "faithful"):
    plan = engine.make_plan(seed=29, num_streams=64, num_steps=16, mode=mode)
    single = np.asarray(engine.generate(plan, backend="xla"))
    two_d = np.asarray(engine.generate_sharded(
        plan, mesh=mesh, axis_names=("hosts", "streams")))
    ok[mode] = bool(np.array_equal(single, two_d))
# fmix32 ctr hash + uneven S (50 pads to 56 on 8 devices, sliced back)
plan = engine.make_plan(seed=31, num_streams=50, num_steps=12, deco="fmix32")
ok["fmix32_uneven"] = bool(np.array_equal(
    np.asarray(engine.generate(plan, backend="xla")),
    np.asarray(engine.generate_sharded(plan, mesh=mesh,
                                       axis_names=("hosts", "streams")))))
# a production-style mesh via make_host_mesh + rng_axes
hm = make_host_mesh(model=2)
plan = engine.make_plan(seed=33, num_streams=24, num_steps=8)
ok["host_mesh"] = bool(np.array_equal(
    np.asarray(engine.generate(plan, backend="xla")),
    np.asarray(engine.generate_sharded(plan, mesh=hm,
                                       axis_names=rng_axes(hm)))))
# BlockService riding the 2-D mesh: leased windows == single-device engine
svc = BlockService(seed=35, mesh=mesh)
svc.open("c", num_streams=48)
lease = svc.lease("c", 16)
blk = np.asarray(svc.generate(lease))
ok["service_2d"] = bool(np.array_equal(
    blk, np.asarray(engine.generate(lease.plan(), backend="xla"))))
# make_host_mesh guard: 8 devices cannot split with model=3
try:
    make_host_mesh(model=3)
    ok["mesh_guard"] = False
except ValueError as e:
    ok["mesh_guard"] = "cannot split" in str(e)
print(json.dumps({"devices": len(jax.devices()), **ok}))
"""


def test_mesh_2d_bit_exact_subprocess():
    """Real (2, 4) = (hosts, streams) device grid: the 2-D fan-out is
    bit-exact vs single-device generate for both decorrelator modes, the
    fmix32 hash, uneven S, make_host_mesh production axes, and the
    BlockService window path."""
    out = subprocess.run([sys.executable, "-c", MESH_2D_SUBPROCESS],
                         capture_output=True, text=True, timeout=300,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    assert rep["devices"] == 8
    for key in ("ctr", "faithful", "fmix32_uneven", "host_mesh",
                "service_2d", "mesh_guard"):
        assert rep[key], key


def test_make_host_mesh_guard_single_device():
    """In this 1-device process any model > 1 must raise, not build a
    (0, model) mesh."""
    from repro.launch.mesh import make_host_mesh
    with pytest.raises(ValueError, match="cannot split"):
        make_host_mesh(model=2)
    with pytest.raises(ValueError, match="cannot split"):
        make_host_mesh(model=0)
