"""Distribution stages end to end: every (dist x backend x mode x dtype)
cell bit-exact vs the ref oracle, parse-grammar errors, edge cases
(rate -> 0, k = 1 gamma, single-outcome categorical), open-interval /
support guards, PIT correctness, and hypothesis-driven moment/KS checks
at S = 4096."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import engine, sampler as sampler_mod
from repro.quality import pit

BACKENDS = ("ref", "xla", "pallas")
MODES = ("ctr", "faithful")
DTYPES = ("float32", "bfloat16")
DIST_SAMPLERS = ("exponential(1.5)", "poisson(3.5)", "gamma(2.5)",
                 "categorical[0.5,0.25,0.125,0.125]")


def _raw(a):
    a = np.asarray(a)
    return a.view(np.uint16) if a.dtype == jnp.bfloat16 else a.view(np.uint32)


def _bits(n, salt=0x9E3779B9):
    """Deterministic well-mixed uint32 test words."""
    return sampler_mod.remix_bits(
        jnp.arange(n, dtype=jnp.uint32) * np.uint32(salt), 7)


def _ulp_diff(a, b):
    """Max ULP distance between equal-dtype float arrays (f32 only here;
    bf16 comparisons in this file are all exact)."""
    ai = np.asarray(a).view(np.int32).astype(np.int64)
    bi = np.asarray(b).view(np.int32).astype(np.int64)
    return int(np.abs(ai - bi).max()) if ai.size else 0


def _assert_dist_matches(out, base, spec, ctx, pallas=False):
    """ref and xla are BIT-exact for every distribution stage (gamma's
    multiply-add chains are pinned with ``sampler.fma_guard`` so XLA's
    shape-dependent FMA contraction cannot split executables — the
    property journal replay relies on).  The pallas interpreter matches
    bit-exactly for the transcendental-free stages (poisson,
    categorical) and to a few ULP for the log-based ones (exponential,
    gamma): at tile-padded shapes the log of an element can take the
    SIMD-vs-remainder libm path the other backend didn't — the same
    documented slack as the "normal" stage."""
    assert out.shape == base.shape and out.dtype == base.dtype, ctx
    log_based = spec.startswith(("exponential", "gamma"))
    if pallas and log_based and np.asarray(out).dtype == np.float32:
        assert _ulp_diff(out, base) <= 8, ctx
    else:
        assert np.array_equal(_raw(out), _raw(base)), ctx


# ---------------------------------------------------------------------------
# parity matrix: every cell bit-exact vs the ref oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS[1:])
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("spec", DIST_SAMPLERS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_distribution_backend_parity(backend, mode, spec, dtype):
    plan = engine.make_plan(seed=91, num_streams=36, num_steps=12, offset=4,
                            mode=mode, sampler=spec, out_dtype=dtype)
    base = engine.generate(plan, backend="ref")
    out = engine.generate(plan, backend=backend)
    _assert_dist_matches(out, base, spec, (backend, mode, spec, dtype),
                         pallas=(backend == "pallas"))


@pytest.mark.parametrize("T,S", [(10, 4), (40, 257), (256, 130)])
def test_distribution_awkward_shapes_pallas(T, S):
    """Pallas tiling/padding never leaks into real rows; (256, 130) is
    the shape where the padded last tile provably shifts libm lane
    positions, exercising the ULP-slack branch of the contract."""
    for spec in DIST_SAMPLERS:
        plan = engine.make_plan(seed=17, num_streams=S, num_steps=T,
                                sampler=spec)
        _assert_dist_matches(engine.generate(plan, backend="pallas"),
                             engine.generate(plan, backend="ref"),
                             spec, (T, S, spec), pallas=True)


@pytest.mark.parametrize("spec", DIST_SAMPLERS)
def test_distribution_shape_invariant_under_jit(spec):
    """The same words transform to the same bytes at ANY batch shape,
    eager or jitted — the property journal replay depends on (the
    coalescer serves padded batches, the auditor replays per-request
    shapes)."""
    import jax
    parsed = sampler_mod.parse(spec)
    flat = _bits(1792)
    base = np.asarray(sampler_mod.apply(flat, parsed, "float32"))
    for shape in [(1792,), (64, 28), (7, 256), (1792, 1)]:
        out = jax.jit(
            lambda b: sampler_mod.apply(b, parsed, "float32"))(
                flat.reshape(shape))
        assert np.array_equal(
            base, np.asarray(out).ravel()), (spec, shape)


@pytest.mark.parametrize("spec,dtype", [("exponential(0.5)", "bfloat16"),
                                        ("gamma(4.0)", "float32"),
                                        ("poisson(10.0)", "float32")])
def test_generate_sharded_distribution(spec, dtype):
    plan = engine.make_plan(seed=13, num_streams=22, num_steps=16,
                            sampler=spec, out_dtype=dtype)
    assert np.array_equal(_raw(engine.generate(plan, backend="xla")),
                          _raw(engine.generate_sharded(plan)))


# ---------------------------------------------------------------------------
# spec grammar: parse acceptance and rejection tables
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("text,expect", [
    ("exponential(1.5)", ("exponential", 1.5)),
    ("poisson(0.0)", ("poisson", 0.0)),
    ("gamma(2.5)", ("gamma", 2.5)),
    ("categorical[1,1,2]", ("categorical", (1.0, 1.0, 2.0))),
    ("categorical[ 0.5 , 0.5 ]", ("categorical", (0.5, 0.5))),
])
def test_parse_accepts(text, expect):
    assert sampler_mod.parse(text) == expect


@pytest.mark.parametrize("bad", [
    "gamma",                       # bare name: parens required
    "gamma()",                     # empty param
    "gamma(0.5)",                  # shape < 1 unsupported (M-T needs k>=1)
    "gamma(nan)",                  # non-finite
    "exponential(0)",              # rate must be > 0
    "exponential(-1)",
    "poisson(-0.5)",               # rate must be >= 0
    "poisson(33)",                 # above POISSON_MAX_RATE ladder bound
    "poisson(two)",                # not a float
    "categorical[]",               # no outcomes
    "categorical[1,-2]",           # negative weight
    "categorical[0,0]",            # zero total mass
    "categorical[" + ",".join(["1"] * 65) + "]",   # > max outcomes
    "exponential[1.5]",            # wrong bracket style
    "weibull(2.0)",                # unknown distribution
])
def test_parse_rejects_with_grammar(bad):
    """Every rejection names the spec grammar so callers can self-serve
    (bare names like "gamma" must still carry the historical "unknown
    sampler" prefix relied on by engine error paths)."""
    with pytest.raises(ValueError) as ei:
        sampler_mod.parse(bad)
    msg = str(ei.value)
    assert "grammar" in msg or "must" in msg, bad
    if bad in ("gamma", "weibull(2.0)", "exponential[1.5]"):
        assert "unknown sampler" in msg
        assert sampler_mod.SPEC_GRAMMAR.split("|")[0].strip() in msg


def test_spec_grammar_names_every_stage():
    for kind in sampler_mod.DISTRIBUTION_KINDS:
        assert kind in sampler_mod.SPEC_GRAMMAR


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------

def test_poisson_rate_zero_is_all_zeros():
    """lambda -> 0: the threshold ladder is empty, every count is 0 on
    every backend (and the spec is still a valid request class)."""
    assert sampler_mod.poisson_thresholds(0.0) == ()
    plan = engine.make_plan(seed=3, num_streams=8, num_steps=16,
                            sampler="poisson(0.0)")
    for backend in BACKENDS:
        out = np.asarray(engine.generate(plan, backend=backend))
        assert out.dtype == np.float32 and np.all(out == 0.0), backend


def test_poisson_tiny_rate_mostly_zero():
    plan = engine.make_plan(seed=3, num_streams=64, num_steps=64,
                            sampler="poisson(0.001)")
    out = np.asarray(engine.generate(plan, backend="xla"))
    assert out.mean() < 0.01 and out.min() == 0.0


def test_gamma_shape_one_is_exact_exponential():
    """k = 1 short-circuits to the exponential inversion — bit-identical,
    not approximately equal (Gamma(1) IS Exponential(1))."""
    kw = dict(seed=7, num_streams=32, num_steps=64)
    g = engine.generate(engine.make_plan(sampler="gamma(1.0)", **kw),
                        backend="xla")
    e = engine.generate(engine.make_plan(sampler="exponential(1.0)", **kw),
                        backend="xla")
    assert np.array_equal(_raw(g), _raw(e))


def test_single_outcome_categorical_is_zero():
    assert sampler_mod.alias_table((3.0,)) == ((1.0, 0),)
    plan = engine.make_plan(seed=5, num_streams=8, num_steps=8,
                            sampler="categorical[3.0]")
    for backend in BACKENDS:
        out = np.asarray(engine.generate(plan, backend=backend))
        assert np.all(out == 0.0), backend


def test_categorical_zero_weight_outcome_never_drawn():
    plan = engine.make_plan(seed=5, num_streams=64, num_steps=256,
                            sampler="categorical[1.0,0.0,1.0]")
    out = np.asarray(engine.generate(plan, backend="xla"))
    assert not np.any(out == 1.0)
    assert set(np.unique(out)) <= {0.0, 2.0}


# ---------------------------------------------------------------------------
# support / open-interval guards
# ---------------------------------------------------------------------------

def test_exponential_finite_on_extreme_bits():
    """All-zero and all-one words map to finite, strictly positive
    exponentials on every backend: uniform_from_bits never returns 1.0
    (no log(0)) and the u = 0 word maps to -log(1) = 0 exactly."""
    bits = jnp.array([[0, 0xFFFFFFFF], [0xFFFFFFFF, 0]], jnp.uint32)
    for spec in ("exponential(1.5)", "gamma(2.5)"):
        x = np.asarray(sampler_mod.apply(bits, sampler_mod.parse(spec),
                                         "float32"))
        assert np.all(np.isfinite(x)), spec
        assert np.all(x >= 0.0), spec


def test_exponential_nonnegative_and_moments():
    plan = engine.make_plan(seed=1234, num_streams=4096, num_steps=64,
                            sampler="exponential(1.5)")
    x = np.asarray(engine.generate(plan, backend="xla"), dtype=np.float64)
    n = x.size
    assert np.all(x >= 0.0) and np.all(np.isfinite(x))
    assert abs(x.mean() - 1 / 1.5) < 4 * (1 / 1.5) / np.sqrt(n)
    assert abs(x.var() - 1 / 1.5 ** 2) < 6 * (1 / 1.5 ** 2) / np.sqrt(n)


def test_poisson_counts_in_truncated_support():
    rate = 3.5
    kmax = len(sampler_mod.poisson_thresholds(rate))
    plan = engine.make_plan(seed=99, num_streams=1024, num_steps=64,
                            sampler=f"poisson({rate})")
    out = np.asarray(engine.generate(plan, backend="xla"))
    assert out.min() >= 0 and out.max() <= kmax
    assert np.array_equal(out, np.rint(out))  # float-coded exact integers


def test_categorical_indices_in_range():
    plan = engine.make_plan(seed=99, num_streams=1024, num_steps=16,
                            sampler="categorical[1,2,3,4,5]")
    out = np.asarray(engine.generate(plan, backend="xla"))
    assert out.min() >= 0 and out.max() <= 4
    assert np.array_equal(out, np.rint(out))


def test_gamma_fallback_bounds_support():
    """Even adversarial words stay on (0, inf): every retry row rejecting
    falls back to the central value d, never to garbage."""
    x = np.asarray(sampler_mod.apply(
        _bits(1 << 16), sampler_mod.parse("gamma(5.0)"), "float32"))
    assert np.all(x > 0.0) and np.all(np.isfinite(x))


# ---------------------------------------------------------------------------
# hypothesis: moment/KS battery at S = 4096 over parameters and seeds
# ---------------------------------------------------------------------------

def _draw_block(spec, seed, S=4096, T=16):
    plan = engine.make_plan(seed=seed, num_streams=S, num_steps=T,
                            sampler=spec)
    return np.asarray(engine.generate(plan, backend="xla"),
                      dtype=np.float64), S * T


@settings(max_examples=8, deadline=None)
@given(st.floats(0.25, 8.0), st.integers(0, 2 ** 31 - 1))
def test_exponential_moments_hypothesis(rate, seed):
    x, n = _draw_block(f"exponential({rate!r})", seed)
    assert abs(x.mean() - 1 / rate) < 5 * (1 / rate) / np.sqrt(n)


@settings(max_examples=8, deadline=None)
@given(st.floats(0.25, 16.0), st.integers(0, 2 ** 31 - 1))
def test_poisson_moments_hypothesis(rate, seed):
    x, n = _draw_block(f"poisson({rate!r})", seed)
    sd = np.sqrt(rate / n)
    assert abs(x.mean() - rate) < 5 * sd + 1e-6
    assert abs(x.var() - rate) < 6 * rate / np.sqrt(n) + 1e-6


@settings(max_examples=8, deadline=None)
@given(st.floats(1.0, 16.0), st.integers(0, 2 ** 31 - 1))
def test_gamma_moments_hypothesis(shape, seed):
    x, n = _draw_block(f"gamma({shape!r})", seed)
    assert abs(x.mean() - shape) < 5 * np.sqrt(shape / n)
    assert abs(x.var() - shape) < 8 * shape / np.sqrt(n)


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
def test_categorical_frequencies_hypothesis(k, seed):
    w = tuple(float(i + 1) for i in range(k))
    total = sum(w)
    x, n = _draw_block("categorical[" + ",".join(map(str, w)) + "]", seed)
    for i, wi in enumerate(w):
        p = wi / total
        assert abs((x == i).mean() - p) < 5 * np.sqrt(p * (1 - p) / n)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_exponential_pit_ks_uniform(seed):
    """The PIT reduction of a correct exponential draw is KS-uniform —
    the property the quality battery's dist generators rely on."""
    from repro.core import statistics as stats
    x, _ = _draw_block("exponential(1.5)", seed, S=512, T=8)
    u = -np.expm1(-1.5 * x)
    assert stats.ks_uniform_pvalue(u.ravel()) > 1e-4


# ---------------------------------------------------------------------------
# PIT reduction unit behavior
# ---------------------------------------------------------------------------

def test_regularized_gamma_p_against_closed_forms():
    x = np.linspace(0.01, 40.0, 4001)
    # P(1, x) = 1 - exp(-x)
    assert np.allclose(pit.regularized_gamma_p(1.0, x), -np.expm1(-x),
                       atol=1e-13)
    # P(2, x) = 1 - (1 + x) exp(-x)
    assert np.allclose(pit.regularized_gamma_p(2.0, x),
                       1.0 - (1.0 + x) * np.exp(-x), atol=1e-13)
    # P(0.5, x) = erf(sqrt(x))
    erf = np.vectorize(math.erf)
    assert np.allclose(pit.regularized_gamma_p(0.5, x), erf(np.sqrt(x)),
                       atol=1e-12)
    assert pit.regularized_gamma_p(3.0, np.array([0.0, -1.0])).tolist() \
        == [0.0, 0.0]


def test_pit_words_rejects_bad_inputs():
    x = np.ones(4, np.float32)
    v = np.zeros(4, np.uint32)
    with pytest.raises(ValueError, match="not a distribution stage"):
        pit.pit_words(x, "uniform", v)
    with pytest.raises(ValueError, match="v_bits"):
        pit.pit_words(x, "exponential(1.0)", np.zeros(3, np.uint32))
    with pytest.raises(ValueError, match="v_bits"):
        pit.pit_words(x, "exponential(1.0)", np.zeros(4, np.uint64))


def test_pit_discrete_randomization_spans_cells():
    """With V = 0 the word sits at the cell floor F(k-1); with V -> 1 it
    approaches F(k): the randomized PIT fills each pmf cell."""
    x = np.array([0.0, 1.0, 2.0], np.float32)
    lo = pit.pit_words(x, "poisson(3.5)",
                       np.zeros(3, np.uint32)).astype(np.float64) * 2.0 ** -32
    hi = pit.pit_words(x, "poisson(3.5)",
                       np.full(3, 0xFFFFFFFF, np.uint32)
                       ).astype(np.float64) * 2.0 ** -32
    cdf = pit.discrete_cdf_table("poisson", 3.5)
    for k in range(3):
        f_lo = 0.0 if k == 0 else cdf[k - 1]
        assert abs(lo[k] - f_lo) < 1e-9
        assert abs(hi[k] - cdf[k]) < 1e-6


# ---------------------------------------------------------------------------
# gamma(shape, scale) sugar + the gumbel stage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("text,expect", [
    ("gamma(2.5, 0.5)", ("gamma", (2.5, 0.5))),
    ("gamma( 3.0 ,2.0 )", ("gamma", (3.0, 2.0))),
    ("gumbel", ("gumbel", None)),
])
def test_parse_accepts_gamma_scale_and_gumbel(text, expect):
    assert sampler_mod.parse(text) == expect


@pytest.mark.parametrize("bad", [
    "gamma(2.5, 0)",               # scale must be > 0
    "gamma(2.5, -1.0)",
    "gamma(0.5, 2.0)",             # shape < 1 still unsupported
    "gamma(2.5, two)",             # scale not a float
    "gumbel(1.0)",                 # gumbel takes no parameter
])
def test_parse_rejects_gamma_scale_and_gumbel(bad):
    with pytest.raises(ValueError):
        sampler_mod.parse(bad)


def test_gamma_scale_is_pure_multiply():
    """gamma(k, theta) == gamma(k) * theta BIT-exactly (the sugar is one
    f32 multiply after the unit-scale transform — same words, same
    Marsaglia-Tsang chain, nothing re-derived), and theta = 1 is the
    identity (no multiply at all)."""
    kw = dict(seed=7, num_streams=32, num_steps=64)
    unit = np.asarray(engine.generate(
        engine.make_plan(sampler="gamma(2.5)", **kw), backend="xla"))
    scaled = np.asarray(engine.generate(
        engine.make_plan(sampler="gamma(2.5, 0.5)", **kw), backend="xla"))
    assert np.array_equal(_raw(scaled),
                          _raw(unit * np.float32(0.5)))
    one = np.asarray(engine.generate(
        engine.make_plan(sampler="gamma(2.5, 1.0)", **kw), backend="xla"))
    assert np.array_equal(_raw(one), _raw(unit))


def test_gamma_scale_one_param_backcompat():
    """Single-arg gamma(k) still parses to a scalar param (not a 1-tuple)
    — journaled request records from earlier runs replay unchanged."""
    assert sampler_mod.parse("gamma(2.5)") == ("gamma", 2.5)
    assert isinstance(sampler_mod.parse("gamma(2.5)")[1], float)


def test_gumbel_backends_match():
    """gumbel is log-based: ref == xla bit-exact, pallas within the same
    documented ULP slack as exponential/normal."""
    plan = engine.make_plan(seed=11, num_streams=256, num_steps=32,
                            sampler="gumbel")
    base = np.asarray(engine.generate(plan, backend="ref"))
    assert np.array_equal(
        _raw(base), _raw(engine.generate(plan, backend="xla")))
    assert _ulp_diff(base, engine.generate(plan, backend="pallas")) <= 8


def test_gumbel_stage_matches_formula():
    """The stage is -log(-log(u)) over the open-interval uniform of the
    same words (TINY clamp included).  The oracle runs in float64 (the
    f32 chain's inner-log rounding amplifies near the zero crossing at
    u = 1/e, so this is a tolerance check) — cross-backend BIT-exactness
    is test_gumbel_backends_match's job."""
    bits = _bits(4096)
    u = np.asarray(sampler_mod.uniform_from_bits(bits)).astype(np.float64)
    want = -np.log(-np.log(np.maximum(u, sampler_mod.TINY_F32)))
    got = np.asarray(sampler_mod.apply(bits, ("gumbel", None), "float32"))
    assert np.allclose(got, want, rtol=2e-5, atol=1e-6)
    # standard Gumbel: mean ~ Euler-Mascheroni, all finite
    assert np.isfinite(got).all()
    assert abs(got.mean() - 0.5772) < 0.05


def test_gumbel_and_gamma_scale_pit_uniform():
    """PIT through the new CDFs is uniform: the quality harness can
    battery-test both new stages without special cases."""
    kw = dict(seed=23, num_streams=64, num_steps=64)
    for spec in ("gumbel", "gamma(2.5, 0.5)"):
        x = np.asarray(engine.generate(
            engine.make_plan(sampler=spec, **kw), backend="xla")).ravel()
        p = pit.pit_words(x, spec, _bits(x.size)).astype(np.float64) \
            * 2.0 ** -32
        # coarse KS bound at n = 4096: D_n < 0.035 ~ alpha >> 1e-3
        d = np.abs(np.sort(p) - (np.arange(p.size) + 0.5) / p.size).max()
        assert d < 0.035, (spec, d)


def test_gamma_tuple_cdf_is_scaled_regularized_p():
    x = np.linspace(0.01, 8.0, 64)
    got = pit.continuous_cdf("gamma", (2.5, 0.5), x)
    want = pit.regularized_gamma_p(2.5, x / 0.5)
    assert np.allclose(got, want, atol=1e-12)
    g = pit.continuous_cdf("gumbel", None, np.array([0.0]))
    assert abs(float(g[0]) - np.exp(-1.0)) < 1e-7
