"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only launch/dryrun.py (and the engine's sharded subprocess test) force a
multi-device host platform.

If ``hypothesis`` is not installed (some validation containers cannot pip
install), a deterministic fallback shim is registered so the property
tests still collect and run over boundary + seeded-random examples.
"""
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback
    _hypothesis_fallback.install()


# Every cached XLA executable pins a handful of memory mappings, and a
# full-suite process accumulates ~200 of them per test: around the
# ~310-test mark the process crosses vm.max_map_count (65530 on stock
# Linux) and the next mmap() inside LLVM fails — jaxlib takes that as a
# SIGSEGV mid-compile, killing the whole run. Dropping the jit caches
# every batch of tests keeps the map count bounded (clearing releases
# ~90% of the accumulated mappings); the only cost is recompiles.
_CLEAR_EVERY = 25
_test_counter = {"n": 0}


@pytest.fixture(autouse=True)
def _bound_xla_map_count():
    yield
    _test_counter["n"] += 1
    if _test_counter["n"] % _CLEAR_EVERY == 0:
        try:
            import jax
            jax.clear_caches()
        except ImportError:
            pass


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)
