"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only launch/dryrun.py (and the engine's sharded subprocess test) force a
multi-device host platform.

If ``hypothesis`` is not installed (some validation containers cannot pip
install), a deterministic fallback shim is registered so the property
tests still collect and run over boundary + seeded-random examples.
"""
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback
    _hypothesis_fallback.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)
