"""Dry-run machinery tests.

The full 512-device dry-run is exercised via ``python -m
repro.launch.dryrun`` (EXPERIMENTS.md §Dry-run); here we unit-test the
pieces: HLO collective parsing, pspec resolution, mesh construction, and
a tiny end-to-end lower+compile on a subprocess-forced 8-device host
platform (keeping THIS process at 1 device).
"""
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.analysis import (_DTYPE_BYTES, _shape_bytes,
                                   collective_bytes)
from repro.launch.mesh import make_mesh_auto
from repro.models import sharding


def test_shape_bytes():
    assert _shape_bytes("f32[2,3]") == 24
    assert _shape_bytes("bf16[128]") == 256
    assert _shape_bytes("(f32[2], u32[4])") == 24
    assert _shape_bytes("pred[8]") == 8
    assert _shape_bytes("f32[]") == 4


def test_collective_bytes_parsing():
    hlo = textwrap.dedent("""\
        %ag = f32[64,128] all-gather(%x), replica_groups={}
        %ar.1 = bf16[32] all-reduce(%y), to_apply=%add
        %ars = bf16[32] all-reduce-start(%y)
        %ard = bf16[32] all-reduce-done(%ars)
        %rs = f32[16] reduce-scatter(%z)
        %cp = u32[8,8] collective-permute(%w)
        %dot = f32[9999] dot(%a, %b)
    """)
    got = collective_bytes(hlo)
    assert got["all-gather"] == 64 * 128 * 4
    assert got["all-reduce"] == 64 + 64   # plain + start (done skipped)
    assert got["reduce-scatter"] == 64
    assert got["collective-permute"] == 256
    assert got["total"] == sum(got[k] for k in (
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute"))


@pytest.fixture(scope="module")
def mesh44():
    return make_mesh_auto((1, 1), ("data", "model"))


def test_param_pspec_tp_priority(mesh44):
    # kv_heads divisible -> model on kv; FSDP puts embed on data
    spec = sharding.param_pspec(("embed", "kv_heads", "q_rep", "head"),
                                (64, 1, 4, 16), mesh44)
    assert spec == P("data", "model", None, None)


def test_param_pspec_vocab_tables_tp_only():
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    spec = sharding.param_pspec(("vocab", "embed"), (1024, 64), mesh,
                                mode="train")
    assert spec == P("model", None)  # no FSDP on table d_model


def test_cache_pspec_mqa_falls_back_to_ctx():
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    # kv=1 not divisible by model>1 would shard ctx; with model=1 all fine
    spec = sharding._cache_kv_pspec(mesh, (4, 8, 128, 1, 64), kv_idx=3,
                                    ctx_idx=2)
    assert spec[3] == "model"


SUBPROCESS_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, input_specs, SHAPES
from repro.launch import steps as steps_mod
from repro.launch import analysis as dr
from repro.models import registry
from repro.optim import adamw_init

from repro.launch.mesh import make_mesh_auto
mesh = make_mesh_auto((4, 2), ("data", "model"))
cfg = get_config("glm4_9b").scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    q_chunk=16, loss_chunks=2)
model = registry.build(cfg)
holder = {}
def initf():
    p, s = model.init(0)
    holder["specs"] = s
    return p
params = jax.eval_shape(initf)
pshard, _ = steps_mod.param_sharding_tree(model, params, holder["specs"],
                                          mesh, "train")
batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
bshard = steps_mod.batch_sharding(cfg, batch, mesh)
opt = jax.eval_shape(adamw_init, params)
oshard = steps_mod.opt_sharding_like(pshard, mesh)
ts = steps_mod.make_train_step(model, microbatches=2)
with mesh:
    lowered = jax.jit(ts, in_shardings=(pshard, oshard, bshard,
                                        NamedSharding(mesh, P())),
                      out_shardings=(pshard, oshard, None)).lower(
        params, opt, batch, jax.ShapeDtypeStruct((), jnp.int32))
    compiled = lowered.compile()
ma = compiled.memory_analysis()
coll = dr.collective_bytes(compiled.as_text())
print(json.dumps({"devices": len(jax.devices()),
                  "temp": ma.temp_size_in_bytes,
                  "coll_total": coll["total"]}))
"""


@pytest.mark.slow
def test_end_to_end_dryrun_small_mesh():
    """Real lower+compile on an 8-device forced host platform, with the
    production sharding machinery, in a subprocess."""
    # JAX_PLATFORMS=cpu: without it, an installed libtpu spends minutes
    # retrying GCP metadata fetches before falling back to CPU.
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_PROG],
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    assert rep["devices"] == 8
    assert rep["coll_total"] > 0   # FSDP/TP emitted real collectives


def test_this_process_sees_one_device():
    assert len(jax.devices()) == 1
