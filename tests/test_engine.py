"""Unified engine: backend parity (ref/xla/pallas vs numpy golden) on
awkward shapes, dispatch, leaf-derivation dedup, and shard_map fan-out."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, golden, stream as stream_mod, u64
from repro.kernels import ops

BACKENDS = ("ref", "xla", "pallas")


def _golden_block(seed, num_streams, num_steps, mode, offset=0,
                  purpose=0):
    """(T, S) numpy golden for the family make_plan builds."""
    x0p, h_fam = engine.family_from_seed(seed, purpose)
    x0 = u64.join64(np.asarray(x0p[0]), np.asarray(x0p[1]))
    hh, hl = engine.leaf_table(h_fam, num_streams)
    h = np.array([u64.join64(a, b) for a, b in
                  zip(np.asarray(hh), np.asarray(hl))], dtype=object)
    return golden.thundering_block(x0, h, num_steps, mode=mode,
                                   offset=offset).T  # (T, S)


# ---------------------------------------------------------------------------
# backend parity on awkward shapes (non-multiples of (8, 128), offsets)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("T,S,offset", [
    (10, 4, 0),      # tiny, nothing tile-aligned
    (7, 130, 0),     # S just over one lane tile
    (40, 257, 0),    # both dims awkward
    (12, 36, 37),    # awkward + nonzero offset
    (8, 128, 5),     # tile-exact + offset
])
def test_ctr_backend_matches_golden(backend, T, S, offset):
    plan = engine.make_plan(seed=91, num_streams=S, num_steps=T,
                            offset=offset, mode="ctr")
    out = np.asarray(engine.generate(plan, backend=backend))
    assert out.shape == (T, S) and out.dtype == np.uint32
    assert np.array_equal(out, _golden_block(91, S, T, "ctr", offset))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("T,S,offset", [
    (10, 4, 0),
    (7, 130, 0),
    (12, 36, 37),
])
def test_faithful_backend_matches_golden(backend, T, S, offset):
    plan = engine.make_plan(seed=93, num_streams=S, num_steps=T,
                            offset=offset, mode="faithful")
    out = np.asarray(engine.generate(plan, backend=backend))
    assert np.array_equal(out, _golden_block(93, S, T, "faithful", offset))


@pytest.mark.parametrize("backend", BACKENDS)
def test_fmix32_deco_backend_parity(backend):
    plan = engine.make_plan(seed=95, num_streams=36, num_steps=12,
                            mode="ctr", deco="fmix32")
    base = np.asarray(engine.generate(plan, backend="ref"))
    assert np.array_equal(np.asarray(engine.generate(plan, backend=backend)),
                          base)


def test_faithful_traced_ctr_matches_static_offset():
    """A plan whose counter is only known at trace time (offset=None, the
    stream-API case) must equal the host-jumped static plan bit-exactly."""
    static = engine.make_plan(seed=97, num_streams=20, num_steps=16,
                              offset=100, mode="faithful")
    ch, cl = (jnp.asarray(v, jnp.uint32) for v in u64.split64(100))
    traced = engine.GenPlan(x0=static.x0, h=static.h, num_steps=16,
                            ctr=(ch, cl), offset=None, mode="faithful")
    for backend in ("ref", "xla", "pallas"):
        assert np.array_equal(
            np.asarray(engine.generate(traced, backend=backend)),
            np.asarray(engine.generate(static, backend=backend))), backend


# ---------------------------------------------------------------------------
# dispatch / registry
# ---------------------------------------------------------------------------

def test_registry_lists_all_backends():
    assert set(BACKENDS) <= set(engine.available_backends())


def test_unknown_backend_raises():
    plan = engine.make_plan(seed=1, num_streams=4, num_steps=8)
    with pytest.raises(ValueError, match="unknown backend"):
        engine.generate(plan, backend="cuda")


def test_select_backend_cpu_is_xla():
    plan = engine.make_plan(seed=1, num_streams=512, num_steps=256)
    assert engine.select_backend(plan) == "xla"  # no TPU in this container


def test_generate_flat_requires_single_stream():
    plan = engine.make_plan(seed=1, num_streams=4, num_steps=8)
    with pytest.raises(ValueError, match="S=1"):
        engine.generate_flat(plan)


# ---------------------------------------------------------------------------
# leaf derivation dedup: one helper behind derive(), h_table() and plans
# ---------------------------------------------------------------------------

def test_h_table_matches_stream_derive():
    """ops.h_table[s] == derive(family, s).h — both are engine.derive_leaf."""
    fam = stream_mod.new_stream(77, 0)
    hh, hl = ops.h_table(77, 16)
    for s in range(16):
        child = stream_mod.derive(fam, s)
        assert u64.join64(np.asarray(hh[s]), np.asarray(hl[s])) == \
            u64.join64(np.asarray(child.h_hi), np.asarray(child.h_lo))


@pytest.mark.parametrize("backend", BACKENDS)
def test_bulk_columns_equal_stream_random_bits(backend):
    """Column s of an engine block == per-stream random_bits with leaf h_s
    (the parity the shared derivation helper guarantees)."""
    T, S = 24, 8
    plan = engine.make_plan(seed=55, num_streams=S, num_steps=T)
    blk = np.asarray(engine.generate(plan, backend=backend))
    fam = stream_mod.new_stream(55, 0)
    for s in (0, 3, 7):
        st = fam._replace(h_hi=plan.h[0][s], h_lo=plan.h[1][s])
        assert np.array_equal(blk[:, s],
                              np.asarray(stream_mod.random_bits(st, (T,))))


def test_generate_flat_equals_random_bits():
    s = stream_mod.advance(stream_mod.new_stream(42, 3), 17)
    plan = engine.plan_for_stream(s, 50)
    flat = np.asarray(engine.generate_flat(plan))
    assert np.array_equal(flat, np.asarray(stream_mod.random_bits(s, (50,))))


# ---------------------------------------------------------------------------
# multi-device fan-out
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ctr", "faithful"])
def test_generate_sharded_single_device_bitexact(mode):
    """shard_map path on the (1-device) test mesh == plain generate."""
    plan = engine.make_plan(seed=13, num_streams=24, num_steps=16, mode=mode)
    a = np.asarray(engine.generate(plan, backend="xla"))
    b = np.asarray(engine.generate_sharded(plan))
    assert np.array_equal(a, b)


def test_generate_sharded_pads_uneven_streams():
    # S not a multiple of the mesh size still returns exactly (T, S)
    plan = engine.make_plan(seed=15, num_streams=7, num_steps=8)
    out = np.asarray(engine.generate_sharded(plan))
    assert out.shape == (8, 7)
    assert np.array_equal(out, np.asarray(engine.generate(plan,
                                                          backend="xla")))


@pytest.mark.parametrize("mode", ["ctr", "faithful"])
def test_generate_sharded_2d_axes_bitexact(mode):
    """2-D (hosts, streams) fan-out on a (1, 1) mesh == plain generate
    (the real multi-device grid is covered by the 8-device subprocess
    test in test_blocks.py)."""
    mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(1, 1),
                             ("hosts", "streams"))
    plan = engine.make_plan(seed=17, num_streams=24, num_steps=16, mode=mode)
    a = np.asarray(engine.generate(plan, backend="xla"))
    b = np.asarray(engine.generate_sharded(plan, mesh=mesh,
                                           axis_names=("hosts", "streams")))
    assert np.array_equal(a, b)


def test_generate_sharded_axis_validation():
    plan = engine.make_plan(seed=17, num_streams=8, num_steps=4)
    mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(1, 1),
                             ("hosts", "streams"))
    with pytest.raises(ValueError, match="no axis"):
        engine.generate_sharded(plan, mesh=mesh, axis_names=("hosts", "bogus"))
    with pytest.raises(ValueError, match="requires an explicit mesh"):
        engine.generate_sharded(plan, axis_names=("hosts", "streams"))


SHARDED_SUBPROCESS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
import numpy as np
from repro.core import engine

assert len(jax.devices()) == 4
ok = {}
for mode in ("ctr", "faithful"):
    plan = engine.make_plan(seed=29, num_streams=64, num_steps=16, mode=mode)
    single = np.asarray(engine.generate(plan, backend="xla"))
    sharded = np.asarray(engine.generate_sharded(plan))
    ok[mode] = bool(np.array_equal(single, sharded))
# uneven split: 4 devices, 26 streams -> padded to 28, sliced back
plan = engine.make_plan(seed=31, num_streams=26, num_steps=8)
ok["uneven"] = bool(np.array_equal(
    np.asarray(engine.generate(plan, backend="xla")),
    np.asarray(engine.generate_sharded(plan))))
# pallas backend inside the sharded path: faithful mode must consume the
# global-index xs0 states, not rebuild the lane table per shard
plan = engine.make_plan(seed=29, num_streams=64, num_steps=16,
                        mode="faithful")
ok["pallas_faithful"] = bool(np.array_equal(
    np.asarray(engine.generate(plan, backend="xla")),
    np.asarray(engine.generate_sharded(plan, backend="pallas"))))
# sampler stage rides through the shard_map fan-out (uneven split, bf16)
plan = engine.make_plan(seed=37, num_streams=26, num_steps=16,
                        sampler="uniform", out_dtype="bfloat16")
ok["sampler"] = bool(np.array_equal(
    np.asarray(engine.generate(plan, backend="xla")).view(np.uint16),
    np.asarray(engine.generate_sharded(plan)).view(np.uint16)))
print(json.dumps({"devices": len(jax.devices()), **ok}))
"""


def test_generate_sharded_multi_device_subprocess():
    """Real >= 2 host devices (forced CPU platform): sharded block equals
    the single-device block bit-exactly, zero cross-device communication
    required by construction (counter addressing)."""
    # JAX_PLATFORMS=cpu: without it, an installed libtpu spends minutes
    # retrying GCP metadata fetches before falling back to CPU.
    out = subprocess.run([sys.executable, "-c", SHARDED_SUBPROCESS],
                         capture_output=True, text=True, timeout=300,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    assert rep["devices"] == 4
    assert rep["ctr"] and rep["faithful"] and rep["uneven"]
    assert rep["pallas_faithful"]
    assert rep["sampler"]
