"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite property-tests the u64/LCG/xorshift cores with
hypothesis; some environments (including the container this repo is
validated in) cannot pip-install it.  This module provides just enough of
the API surface the tests use — ``given``, ``settings`` and the
``integers`` / ``floats`` / ``sampled_from`` / ``tuples`` strategies — running each
test over the strategy's boundary values plus seeded-random draws.  It is
NOT a property-testing framework (no shrinking, no coverage-guided
search); when the real hypothesis is importable, ``conftest.py`` never
installs this shim.
"""
from __future__ import annotations

import itertools
import random
import sys
import types
import zlib

_MAX_EXAMPLES_CAP = 64  # keep the degraded suite fast


class _Strategy:
    def __init__(self, draw, edges=()):
        self._draw = draw          # fn(rng) -> value
        self.edges = tuple(edges)  # deterministic boundary examples

    def draw(self, rng):
        return self._draw(rng)


def integers(min_value=None, max_value=None):
    lo = 0 if min_value is None else int(min_value)
    hi = (1 << 64) if max_value is None else int(max_value)
    edges = sorted({lo, hi, min(lo + 1, hi), max(hi - 1, lo)})
    return _Strategy(lambda r: r.randint(lo, hi), edges)


def floats(min_value, max_value, **_ignored):
    """Bounded floats only (the shim has no NaN/inf generation): edges
    are the two endpoints and the midpoint, random draws uniform."""
    lo, hi = float(min_value), float(max_value)
    edges = sorted({lo, hi, (lo + hi) / 2.0})
    return _Strategy(lambda r: r.uniform(lo, hi), edges)


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda r: r.choice(seq), seq[: min(len(seq), 4)])


def tuples(*strategies):
    edges = []
    for k in range(min((len(s.edges) for s in strategies), default=0)):
        edges.append(tuple(s.edges[k] for s in strategies))
    return _Strategy(lambda r: tuple(s.draw(r) for s in strategies), edges)


def _cases(strategies, n, seed):
    rng = random.Random(seed)
    # all-edges cross product first (capped), then independent random draws
    for combo in itertools.islice(itertools.product(
            *(s.edges or (s.draw(rng),) for s in strategies)), n // 2):
        yield combo
    while True:
        yield tuple(s.draw(rng) for s in strategies)


def given(*strategies):
    def decorate(fn):
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_max_examples", 25), _MAX_EXAMPLES_CAP)
            seed = 0xC0FFEE ^ zlib.crc32(fn.__qualname__.encode())
            for case in itertools.islice(_cases(strategies, n, seed), n):
                fn(*args, *case, **kwargs)
        # no functools.wraps: pytest must see the (*args, **kwargs)
        # signature, not the original one (whose params would look like
        # fixtures).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis_fallback = True
        return wrapper
    return decorate


class settings:
    def __init__(self, max_examples=None, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            fn._max_examples = self.max_examples
        return fn


def install() -> None:
    """Register this module as ``hypothesis`` in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    st_mod.tuples = tuples
    mod.strategies = st_mod
    mod.__is_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
