"""xorshift128 decorrelator: step, GF(2) jump-ahead, substream spacing."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import golden, xorshift

words = st.tuples(*[st.integers(min_value=0, max_value=(1 << 32) - 1)] * 4)


def test_step_matches_host(rng):
    states = rng.integers(0, 1 << 32, (128, 4), dtype=np.uint32)
    stepped = np.asarray(xorshift.step(jnp.asarray(states)))
    for i in range(128):
        exp = xorshift.step_words(*(int(w) for w in states[i]))
        assert tuple(int(w) for w in stepped[i]) == exp


def test_step_xyzw_matches_step(rng):
    s = rng.integers(0, 1 << 32, (64, 4), dtype=np.uint32)
    a = np.asarray(xorshift.step(jnp.asarray(s)))
    x, y, z, w = xorshift.step_xyzw(*(jnp.asarray(s[:, i]) for i in range(4)))
    b = np.stack([np.asarray(x), np.asarray(y), np.asarray(z), np.asarray(w)], -1)
    assert np.array_equal(a, b)


@settings(max_examples=20, deadline=None)
@given(words, st.integers(min_value=0, max_value=4000))
def test_jump_matches_sequential(state, n):
    seq = state
    for _ in range(n):
        seq = xorshift.step_words(*seq)
    assert xorshift.jump(state, n) == seq


def test_jump_composes():
    st0 = xorshift.DEFAULT_SEED
    a = xorshift.jump(xorshift.jump(st0, 1 << 20), 1 << 21)
    b = xorshift.jump(st0, (1 << 20) + (1 << 21))
    assert a == b


def test_jump_large_no_collision():
    """Substream starts spaced 2**64 apart must all differ (first 16)."""
    tbl = xorshift.lane_table(16)
    assert len({tuple(r) for r in tbl.tolist()}) == 16


def test_lane_table_matches_substream_state():
    tbl = xorshift.lane_table(4)
    for i in range(4):
        assert tuple(int(w) for w in tbl[i]) == xorshift.substream_state(
            xorshift.DEFAULT_SEED, i)


def test_jump_traced_matches_host(rng):
    states = rng.integers(0, 1 << 32, (8, 4), dtype=np.uint32)
    for n in [0, 1, 5, 1000, (1 << 33) + 7]:
        jumped = np.asarray(xorshift.jump_traced(
            jnp.asarray(states),
            jnp.uint32(n >> 32), jnp.uint32(n & 0xFFFFFFFF)))
        for i in range(8):
            exp = xorshift.jump(tuple(int(w) for w in states[i]), n)
            assert tuple(int(w) for w in jumped[i]) == exp, (i, n)


def test_xorshift_seq_golden_consistency():
    out = golden.xorshift_seq(xorshift.DEFAULT_SEED, 5)
    s = xorshift.DEFAULT_SEED
    exp = []
    for _ in range(5):
        s = xorshift.step_words(*s)
        exp.append(s[3])
    assert out.tolist() == exp


def test_substream_outputs_differ():
    """First 64 outputs of substreams 0..7 are pairwise distinct sequences."""
    outs = [golden.xorshift_seq(xorshift.substream_state(xorshift.DEFAULT_SEED, i), 64)
            for i in range(8)]
    for i in range(8):
        for j in range(i + 1, 8):
            assert not np.array_equal(outs[i], outs[j])


def test_jump_batch_matches_per_state_jump():
    """Vectorized whole-table GF(2) jump == python-int jump per state."""
    tbl = xorshift.lane_table(9)
    for n in (0, 1, 7, 256, 1 << 20, (1 << 40) + 12345):
        batched = xorshift.jump_batch(tbl, n)
        for s in range(9):
            exp = xorshift.jump(tuple(int(w) for w in tbl[s]), n)
            assert tuple(int(w) for w in batched[s]) == exp, (s, n)


def test_jump_batch_does_not_mutate_input():
    tbl = xorshift.lane_table(4)
    snapshot = tbl.copy()
    xorshift.jump_batch(tbl, 123456)
    assert np.array_equal(tbl, snapshot)
