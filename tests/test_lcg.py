"""LCG core: jump-ahead algebra, leaf transitions, XSH-RR permutation."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import golden, lcg, u64

M64 = (1 << 64) - 1


def lcg_n_steps(x0, n, a=lcg.MULTIPLIER, c=lcg.DEFAULT_INCREMENT):
    x = x0 & M64
    for _ in range(n):
        x = (a * x + c) & M64
    return x


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=0, max_value=M64),
       st.integers(min_value=0, max_value=5000))
def test_lcg_skip_matches_sequential(x0, n):
    A, C = lcg.lcg_skip(n)
    assert (A * x0 + C) & M64 == lcg_n_steps(x0, n)


def test_lcg_skip_zero_is_identity():
    assert lcg.lcg_skip(0) == (1, 0)


def test_lcg_skip_composes():
    # skip(m) . skip(n) == skip(m + n)
    Am, Cm = lcg.lcg_skip(123)
    An, Cn = lcg.lcg_skip(456)
    A, C = lcg.lcg_skip(579)
    assert (An * Am) & M64 == A
    assert (An * Cm + Cn) & M64 == C


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=M64),
       st.integers(min_value=0, max_value=(1 << 40)))
def test_lcg_skip_traced_matches_host(x0, n):
    A_exp, C_exp = lcg.lcg_skip(n)
    n_pair = u64.const64(n)
    A, C = lcg.lcg_skip_traced(n_pair)
    assert u64.join64(np.asarray(A[0]), np.asarray(A[1])) == A_exp
    assert u64.join64(np.asarray(C[0]), np.asarray(C[1])) == C_exp


def test_block_affine_constants_match_skip():
    A_hi, A_lo, C_hi, C_lo = lcg.block_affine_constants(32)
    for t in range(32):
        A, C = lcg.lcg_skip(t)
        assert u64.join64(A_hi[t], A_lo[t]) == A
        assert u64.join64(C_hi[t], C_lo[t]) == C


def test_leaf_effective_increment_is_lcg():
    """Leaf stream w_n = x_n + h must equal the LCG with increment c_eff (Eq. 21/22)."""
    x0, h = 0xDEADBEEF12345678, 0x1234567890ABCDE0  # h even
    a, c = lcg.MULTIPLIER, lcg.DEFAULT_INCREMENT
    c_eff = lcg.effective_increment(a, c, h)
    assert c_eff % 2 == 1, "Hull-Dobell: effective increment must be odd"
    w = (x0 + h) & M64
    x = x0
    for _ in range(100):
        x = (a * x + c) & M64
        w = (a * w + c_eff) & M64
        assert w == (x + h) & M64


def test_even_h_preserves_full_period_condition():
    """For odd a, odd c: any even h gives odd effective increment."""
    a, c = lcg.MULTIPLIER, lcg.DEFAULT_INCREMENT
    for h in range(0, 64, 2):
        assert lcg.effective_increment(a, c, h) % 2 == 1


def test_xsh_rr_vs_golden(rng):
    states = rng.integers(0, 1 << 64, 1024, dtype=np.uint64)
    pair = (jnp.asarray((states >> 32).astype(np.uint32)),
            jnp.asarray(states.astype(np.uint32)))
    got = np.asarray(lcg.xsh_rr(pair))
    exp = golden.xsh_rr(states)
    assert np.array_equal(got, exp)


def test_pcg32_known_answers():
    """Cross-check LCG+XSH-RR against O'Neill's published pcg32 demo output
    (seed 42, seq 54) — proves the pipeline implements the real algorithm."""
    seq = golden.pcg32_seq(42, 54, 6)
    assert [hex(int(x)) for x in seq] == [
        "0xa15c02b7", "0x7b47f409", "0xba1d3330",
        "0x83d2f293", "0xbfa4784b", "0xcbed606e"]


def test_lcg_step_matches_host(rng):
    xs = rng.integers(0, 1 << 64, 64, dtype=np.uint64)
    a = u64.const64(lcg.MULTIPLIER)
    c = u64.const64(lcg.DEFAULT_INCREMENT)
    pair = (jnp.asarray((xs >> 32).astype(np.uint32)), jnp.asarray(xs.astype(np.uint32)))
    nh, nl = lcg.lcg_step(pair, (jnp.broadcast_to(a[0], xs.shape), jnp.broadcast_to(a[1], xs.shape)),
                          (jnp.broadcast_to(c[0], xs.shape), jnp.broadcast_to(c[1], xs.shape)))
    got = (np.asarray(nh).astype(np.uint64) << np.uint64(32)) | np.asarray(nl).astype(np.uint64)
    exp = (np.uint64(lcg.MULTIPLIER) * xs + np.uint64(lcg.DEFAULT_INCREMENT))
    assert np.array_equal(got, exp)
