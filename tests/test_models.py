"""Model-stack tests: per-arch reduced smoke, decode/forward consistency,
SSD-vs-naive-recurrence oracle, MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import stream as tstream
from repro.models import layers as L
from repro.models import mamba2, registry

SMOKE_OVERRIDES = {
    "gemma_7b": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                     head_dim=16, d_ff=128, vocab=256, q_chunk=8),
    "glm4_9b": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    d_ff=128, vocab=256, q_chunk=8),
    "qwen15_32b": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                       d_ff=128, vocab=256, q_chunk=8),
    "granite_34b": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                        d_ff=128, vocab=256, q_chunk=8),
    "qwen2_vl_72b": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab=256, vision_prefix=4, q_chunk=8),
    "granite_moe_3b": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                           d_ff=32, vocab=256, n_experts=4, top_k=2,
                           q_chunk=8),
    "olmoe_1b_7b": dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=32, vocab=256, n_experts=8, top_k=2, q_chunk=8),
    "mamba2_2p7b": dict(n_layers=2, d_model=64, vocab=256, ssm_state=16,
                        ssm_head_dim=8),
    "zamba2_7b": dict(n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=128, vocab=256, ssm_state=16,
                      ssm_head_dim=8, attn_every=2, q_chunk=8),
    "whisper_small": dict(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=4, d_ff=128, vocab=256, enc_ctx=24,
                          q_chunk=8),
}


def smoke_cfg(arch):
    return get_config(arch).scaled(**SMOKE_OVERRIDES[arch])


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.vision_prefix, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.enc_ctx, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list(SMOKE_OVERRIDES))
def test_arch_smoke_train_step(arch):
    """One forward + grad step on the reduced config: shapes + no NaNs."""
    cfg = smoke_cfg(arch)
    m = registry.build(cfg)
    params, specs = m.init(0)
    batch = make_batch(cfg)
    rng = tstream.new_stream(7, 0)

    loss, metrics = m.loss(params, batch, rng)
    assert np.isfinite(float(loss))
    assert 0 < float(loss) < 3 * np.log(cfg.vocab)

    grads = jax.grad(lambda p: m.loss(p, batch, rng)[0])(params)
    for path, g in zip(jax.tree_util.tree_leaves_with_path(grads),
                       jax.tree.leaves(grads)):
        assert np.isfinite(np.asarray(g, np.float32)).all(), path[0]

    logits, aux = m.forward(params, batch, rng)
    assert logits.shape == (*batch["tokens"].shape, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", list(SMOKE_OVERRIDES))
def test_arch_smoke_serve_path(arch):
    """prefill + a few decode steps: shapes, finiteness."""
    cfg = smoke_cfg(arch)
    m = registry.build(cfg)
    params, _ = m.init(0)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits, cache = m.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    c = m.init_cache(B, S)
    tok = jnp.ones((B, 1), jnp.int32)
    for pos in range(3):
        logits, c = m.decode(params, c, tok, jnp.int32(pos))
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["glm4_9b", "granite_34b", "olmoe_1b_7b",
                                  "mamba2_2p7b", "zamba2_7b"])
def test_decode_matches_forward(arch):
    """Greedy incremental decode logits == full-forward logits (bf16 tol)."""
    cfg = smoke_cfg(arch)
    m = registry.build(cfg)
    params, _ = m.init(3)
    B, S = 2, 8
    batch = make_batch(cfg, B, S, seed=5)
    full_logits, _ = m.forward(params, batch)

    cache = m.init_cache(B, S)
    outs = []
    for pos in range(S):
        tok = batch["tokens"][:, pos:pos + 1]
        lg, cache = m.decode(params, cache, tok, jnp.int32(pos))
        outs.append(np.asarray(lg))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits), atol=0.15,
                               rtol=0.05)


def test_whisper_decode_matches_forward():
    cfg = smoke_cfg("whisper_small")
    m = registry.build(cfg)
    params, _ = m.init(3)
    B, S = 2, 8
    batch = make_batch(cfg, B, S, seed=5)
    full_logits, _ = m.forward(params, batch)
    _, cache = m.prefill(params, batch)  # warm path exercise
    cache = m.init_cache(B, S)
    # encdec decode needs the cross-attn cache from prefill of 1 token
    logits0, cache_pf = m.prefill(
        params, {**batch, "tokens": batch["tokens"][:, :1]})
    # rebuild a full-size self cache, keep cross from prefill
    sk, sv, ck_, cv_ = cache
    cache = (sk, sv, cache_pf[2], cache_pf[3])
    outs = []
    for pos in range(S):
        tok = batch["tokens"][:, pos:pos + 1]
        lg, cache = m.decode(params, cache, tok, jnp.int32(pos))
        outs.append(np.asarray(lg))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits), atol=0.15,
                               rtol=0.05)


def test_prefill_matches_forward_last_position():
    cfg = smoke_cfg("glm4_9b")
    m = registry.build(cfg)
    params, _ = m.init(4)
    batch = make_batch(cfg, 2, 16, seed=9)
    full, _ = m.forward(params, batch)
    last, _ = m.prefill(params, batch)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full)[:, -1],
                               atol=0.1, rtol=0.05)


# ---------------------------------------------------------------------------
# SSD oracle
# ---------------------------------------------------------------------------

def _naive_ssm(x, dt, A, B_, C_):
    """Token-by-token recurrence oracle (fp64-ish fp32)."""
    B, S, H, P = x.shape
    N = B_.shape[-1]
    h = np.zeros((B, H, N, P), np.float32)
    ys = np.zeros((B, S, H, P), np.float32)
    for t in range(S):
        dA = np.exp(dt[:, t] * A)                      # (B, H)
        contrib = np.einsum("bn,bh,bhp->bhnp", B_[:, t], dt[:, t], x[:, t])
        h = dA[..., None, None] * h + contrib
        ys[:, t] = np.einsum("bn,bhnp->bhp", C_[:, t], h)
    return ys, h


@pytest.mark.parametrize("S,chunk", [(16, 4), (24, 8), (8, 16)])
def test_ssd_chunked_matches_naive(S, chunk):
    rng = np.random.default_rng(11)
    B, H, P, N = 2, 3, 4, 5
    x = rng.normal(0, 1, (B, S, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (B, S, H)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (H,)).astype(np.float32)
    B_ = rng.normal(0, 1, (B, S, N)).astype(np.float32)
    C_ = rng.normal(0, 1, (B, S, N)).astype(np.float32)
    y, final = mamba2._ssd_chunked(jnp.asarray(x), jnp.asarray(dt),
                                   jnp.asarray(A), jnp.asarray(B_),
                                   jnp.asarray(C_), chunk=chunk)
    y_ref, h_ref = _naive_ssm(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final), h_ref, atol=1e-3,
                               rtol=1e-3)


def test_ssd_chunk_invariance():
    rng = np.random.default_rng(12)
    B, S, H, P, N = 1, 32, 2, 4, 3
    x = rng.normal(0, 1, (B, S, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (B, S, H)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (H,)).astype(np.float32)
    B_ = rng.normal(0, 1, (B, S, N)).astype(np.float32)
    C_ = rng.normal(0, 1, (B, S, N)).astype(np.float32)
    args = (jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
            jnp.asarray(B_), jnp.asarray(C_))
    y1, f1 = mamba2._ssd_chunked(*args, chunk=4)
    y2, f2 = mamba2._ssd_chunked(*args, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# attention / layers
# ---------------------------------------------------------------------------

def test_attention_chunk_invariance():
    rng = np.random.default_rng(13)
    B, S, K, R, d = 2, 32, 2, 3, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, K, R, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, K, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, d)), jnp.float32)
    full = L.attention(q, k, v, causal=True, q_chunk=32)
    chunked = L.attention(q, k, v, causal=True, q_chunk=8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-5, rtol=1e-5)


def test_attention_causality():
    """Changing future tokens must not affect past outputs."""
    rng = np.random.default_rng(14)
    B, S, K, R, d = 1, 16, 1, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, K, R, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, K, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, d)), jnp.float32)
    base = np.asarray(L.attention(q, k, v, causal=True, q_chunk=4))
    k2 = k.at[:, 10:].set(99.0)
    v2 = v.at[:, 10:].set(-99.0)
    pert = np.asarray(L.attention(q, k2, v2, causal=True, q_chunk=4))
    np.testing.assert_allclose(base[:, :10], pert[:, :10], atol=1e-5)


def test_decode_attention_matches_full():
    rng = np.random.default_rng(15)
    B, T, K, R, d = 2, 12, 2, 2, 8
    q_all = jnp.asarray(rng.normal(0, 1, (B, T, K, R, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, T, K, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, K, d)), jnp.float32)
    full = np.asarray(L.attention(q_all, k, v, causal=True, q_chunk=T))
    for pos in [0, 5, 11]:
        dec = np.asarray(L.decode_attention(
            q_all[:, pos:pos + 1], k, v, jnp.int32(pos)))
        np.testing.assert_allclose(dec[:, 0], full[:, pos], atol=1e-5,
                                   rtol=1e-5)


def test_rope_orthogonality():
    """RoPE preserves norms and relative-position inner products."""
    rng = np.random.default_rng(16)
    x = jnp.asarray(rng.normal(0, 1, (1, 8, 1, 16)), jnp.float32)
    pos = jnp.arange(8)[None]
    rot = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(rot), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # shift invariance: <rope(a,p), rope(b,q)> depends only on p-q
    a = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 16)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 16)), jnp.float32)
    def ip(p, q):
        ra = L.apply_rope(a, jnp.asarray([[p]]), 10000.0)
        rb = L.apply_rope(b, jnp.asarray([[q]]), 10000.0)
        return float(jnp.sum(ra * rb))
    assert abs(ip(3, 5) - ip(10, 12)) < 1e-3


def test_layer_dropout_deterministic():
    s = tstream.new_stream(5, 0)
    x = jnp.ones((4, 8, 16), jnp.float32)
    a = np.asarray(L.dropout(x, s, 0.5))
    b = np.asarray(L.dropout(x, s, 0.5))
    assert np.array_equal(a, b)
    frac = (a != 0).mean()
    assert 0.3 < frac < 0.7


def test_moe_capacity_and_combine():
    from repro.models import moe as moe_mod
    cfg = smoke_cfg("olmoe_1b_7b")
    m = registry.build(cfg)
    params, _ = m.init(0)
    batch = make_batch(cfg, 2, 16)
    # aux loss should be near 1 (balanced) at random init, definitely finite
    loss, mets = m.loss(params, batch)
    assert np.isfinite(float(mets["aux"]))
    assert float(mets["aux"]) > 0.5


def test_init_deterministic_across_calls():
    cfg = smoke_cfg("glm4_9b")
    m = registry.build(cfg)
    p1, _ = m.init(0)
    p2, _ = m.init(0)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    p3, _ = m.init(1)
    diff = any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)))
    assert diff
