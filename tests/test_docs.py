"""The public API surface is documented and its examples actually run.

Two guarantees:

  1. every public symbol carries a substantive docstring (the audit
     list below IS the public surface — extending the API means
     extending the list), and
  2. the ``Example:`` doctest blocks in those docstrings execute
     cleanly, so the documentation can never show code that no longer
     works.
"""
import doctest

import pytest

from repro.core import engine, sampler, stream
from repro.quality import battery, cross, pit
from repro.runtime import blocks
from repro.service import audit, frontend, server, tenants

#: the audited public surface: (symbol, minimum docstring length)
PUBLIC_SYMBOLS = [
    engine.GenPlan,
    engine.make_plan,
    engine.plan_for_stream,
    engine.generate,
    engine.generate_flat,
    engine.generate_sharded,
    engine.generate_windows,
    engine.shift_plan,
    engine.sample,
    engine.family_from_seed,
    engine.derive_leaf,
    engine.leaf_table,
    engine.select_backend,
    stream.ThunderStream,
    stream.new_stream,
    stream.derive,
    stream.split,
    stream.advance,
    stream.random_bits,
    stream.uniforms,
    stream.normals,
    stream.uniform,
    stream.normal,
    stream.bernoulli,
    stream.gumbel,
    stream.categorical,
    sampler.parse,
    sampler.apply,
    sampler.result_dtype,
    sampler.fma_guard,
    sampler.remix_bits,
    sampler.poisson_thresholds,
    sampler.gamma_mt_constants,
    sampler.alias_table,
    sampler.exponential_from_bits,
    sampler.gamma_from_bits,
    sampler.categorical_from_bits,
    pit.regularized_gamma_p,
    pit.continuous_cdf,
    pit.discrete_cdf_table,
    pit.pit_words,
    cross.pairwise_sweep,
    blocks.BlockService,
    blocks.BlockService.open,
    blocks.BlockService.lease,
    blocks.BlockService.lease_many,
    blocks.BlockService.commit,
    blocks.BlockService.release,
    blocks.BlockService.ledger_state,
    blocks.BlockService.restore_ledger,
    blocks.BlockService.generate,
    blocks.BlockService.generate_many,
    blocks.BlockService.take,
    blocks.BlockService.producer,
    blocks.Lease,
    blocks.BlockProducer,
    battery.run_battery,
    tenants.tenant_region,
    tenants.TenantRegistry,
    frontend.RandRequest,
    frontend.Coalescer,
    frontend.class_channel,
    server.ServerConfig,
    server.RandServer,
    server.RandServer.submit,
    server.RandServer.request,
    server.RandServer.stats,
    audit.Journal,
    audit.replay,
    audit.verify_ledger_disjoint,
]

#: symbols whose docstring must include a runnable ``>>>`` example
EXAMPLE_BEARING = [
    engine.GenPlan, engine.generate, engine.generate_sharded,
    engine.generate_windows,
    engine.sample,
    stream.ThunderStream, stream.new_stream, stream.derive, stream.split,
    stream.advance, stream.random_bits, stream.uniforms, stream.normals,
    stream.uniform, stream.normal, stream.bernoulli, stream.gumbel,
    stream.categorical,
    sampler.parse, sampler.apply, sampler.result_dtype,
    sampler.poisson_thresholds, sampler.alias_table,
    pit.regularized_gamma_p, pit.discrete_cdf_table, pit.pit_words,
    blocks.BlockService, blocks.Lease, blocks.BlockProducer,
    battery.run_battery,
    tenants.tenant_region, tenants.TenantRegistry,
    frontend.RandRequest, server.RandServer, audit.Journal, audit.replay,
]


@pytest.mark.parametrize("symbol", PUBLIC_SYMBOLS,
                         ids=lambda s: getattr(s, "__qualname__",
                                               getattr(s, "__name__", str(s))))
def test_public_symbol_has_docstring(symbol):
    doc = symbol.__doc__
    assert doc is not None and len(doc.strip()) >= 40, (
        f"{symbol!r} needs a substantive docstring (the public surface is "
        f"documentation-audited; see README / docs/)")


@pytest.mark.parametrize("symbol", EXAMPLE_BEARING,
                         ids=lambda s: getattr(s, "__qualname__",
                                               getattr(s, "__name__", str(s))))
def test_public_symbol_has_example(symbol):
    assert ">>>" in symbol.__doc__, (
        f"{symbol!r} must carry a runnable Example: doctest block")


@pytest.mark.parametrize("module", [engine, sampler, stream, blocks,
                                    tenants, frontend, server, audit, pit],
                         ids=lambda m: m.__name__)
def test_doctests_run_clean(module):
    results = doctest.testmod(module, verbose=False)
    assert results.failed == 0, (
        f"{results.failed}/{results.attempted} doctests failed in "
        f"{module.__name__}")
    assert results.attempted > 0, f"no doctests collected in {module.__name__}"


def test_quality_battery_doctest():
    """run_battery's example runs a real tiny battery (ref backend +
    raw-LCG ablation) — slowest doctest, kept in its own test node."""
    results = doctest.testmod(battery, verbose=False)
    assert results.failed == 0, f"{results.failed} doctests failed"
    assert results.attempted > 0
