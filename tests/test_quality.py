"""The Crush-lite battery: calibrated, discriminating, deterministic.

Three properties make the battery trustworthy documentation:

  1. **Calibration** — under a known-good reference generator (numpy's
     Philox), every first-level test produces uniform p-values (checked
     by KS) and every counting test's summed statistic sits in the
     Poisson body.  A miscalibrated test would fail good generators or
     pass bad ones.
  2. **Discrimination** — the inter-stream cross-battery rejects the
     paper's Table 3/4 ablations (shared-root LCG streams without
     decorrelation) decisively while passing thundering, at sizes far
     below the committed profile.
  3. **Determinism** — the committed QUALITY_report.json is a pure
     function of (profile, seed): its verdicts are asserted here, its
     canonical serialization round-trips, and the rendered docs match
     it byte-for-byte (CI additionally regenerates the whole report).
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import statistics as st
from repro.quality import battery, cross, crush, render

REPO = pathlib.Path(__file__).resolve().parent.parent

N_CAL_BLOCKS = 150
CAL_WORDS = 1024


@pytest.fixture(scope="module")
def philox_blocks():
    rng = np.random.Generator(np.random.Philox(0xC0FFEE))
    return rng.integers(0, 2 ** 32, size=(N_CAL_BLOCKS, CAL_WORDS),
                        dtype=np.uint32)


# ---------------------------------------------------------------------------
# calibration under a known-good reference (numpy Philox)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(crush.CHI2_TESTS))
def test_chi2_family_pvalues_uniform_under_philox(philox_blocks, name):
    fn = crush.CHI2_TESTS[name]
    ps = np.array([fn(b) for b in philox_blocks])
    assert st.ks_uniform_pvalue(ps) > 1e-3, (
        f"{name} p-values are not uniform under Philox — the test is "
        f"miscalibrated")
    assert 0.25 < ps.mean() < 0.75


@pytest.mark.parametrize("name", sorted(crush.POISSON_TESTS))
def test_poisson_family_calibrated_under_philox(philox_blocks, name):
    fn = crush.POISSON_TESTS[name]
    results = [fn(b) for b in philox_blocks]
    total = sum(c for c, _ in results)
    lam = sum(l for _, l in results)
    p = st.poisson_two_sided(total, lam)
    assert p > 1e-3, (f"{name}: {total} observed vs Poisson({lam:.1f}) — "
                      f"miscalibrated")


def test_intra_battery_passes_on_philox(philox_blocks):
    """End-to-end two-level aggregation on a known-good (T, S) block."""
    block = philox_blocks[:32].T.copy()  # (1024, 32)
    rep = battery.run_intra(block)
    assert rep["ok"], {n: t for n, t in rep["tests"].items() if not t["ok"]}


def test_cross_battery_passes_on_philox():
    rng = np.random.Generator(np.random.Philox(7))
    streams = rng.integers(0, 2 ** 32, size=(64, 1024), dtype=np.uint32)
    rep = cross.run_cross(streams)
    assert rep["ok"], rep["tests"]


# ---------------------------------------------------------------------------
# discrimination: the paper's Table 3/4 ordering at small size
# ---------------------------------------------------------------------------

def test_cross_battery_rejects_raw_lcg():
    blk = battery._ablation_block(777, 512, 64, "raw_lcg")
    rep = cross.run_cross(np.ascontiguousarray(blk.T))
    assert not rep["ok"]
    assert not rep["tests"]["pairwise_sweep"]["ok"]  # Pearson ~1


def test_cross_battery_rejects_permutation_only():
    """Permutation without decorrelation: the sweep alone is not enough —
    the interleaved HWD detector must reject (paper Table 4's point)."""
    blk = battery._ablation_block(777, 512, 64, "no_deco")
    rep = cross.run_cross(np.ascontiguousarray(blk.T))
    assert not rep["ok"]
    assert not rep["tests"]["interleaved/hwd"]["ok"]


def test_cross_battery_passes_thundering():
    blk = battery._engine_block(777, 1024, 64, "ctr", "splitmix64", "xla")
    rep = cross.run_cross(np.ascontiguousarray(blk.T))
    assert rep["ok"], rep["tests"]


def test_cross_battery_rejects_raw_lcg_through_pit():
    """Pushing raw-LCG words through a distribution stage and back to
    uniforms via the PIT must NOT launder the inter-stream correlation:
    the PIT-reduced words still fail the cross-battery decisively."""
    blk = battery._ablation_pit_block(777, 512, 64)
    rep = cross.run_cross(np.ascontiguousarray(blk.T))
    assert not rep["ok"]
    assert not rep["tests"]["pairwise_sweep"]["ok"]


def test_dist_pit_block_passes_cross_battery():
    """The same PIT reduction applied to the real engine's exponential
    draws keeps inter-stream independence (discrimination cuts one way)."""
    blk = battery._dist_block(777, 512, 64, "exponential(1.5)", "ctr", "xla")
    rep = cross.run_cross(np.ascontiguousarray(blk.T))
    assert rep["ok"], rep["tests"]


def test_pairwise_sweep_blocked_equals_unblocked():
    """The blocked Gram path (full profile, S=2^14) must cover the same
    pair set and agree with one unblocked Gram on the whole stream set
    to BLAS rounding (GEMM accumulation order differs across tile
    shapes, so exact bit-identity across block sizes is not promised)."""
    rng = np.random.Generator(np.random.Philox(11))
    streams = rng.integers(0, 2 ** 32, size=(64, 256), dtype=np.uint32)
    whole = cross.pairwise_sweep(streams)            # one 64-row block
    tiled = cross.pairwise_sweep(streams, block=16)  # 4x4 block triangle
    assert tiled["max_abs_r"] == pytest.approx(whole["max_abs_r"],
                                               rel=1e-12)
    assert tiled["p"] == pytest.approx(whole["p"], rel=1e-9)
    assert tiled["n_pairs"] == whole["n_pairs"] == 64 * 63 // 2


def test_matrix_rank_detects_rank_deficiency():
    """The rank test is the battery's F2-linearity detector (Bakiri et
    al.): forcing one GF(2)-dependent row per 32x32 matrix (the
    signature of undecorrelated F2-linear output) must be rejected
    decisively, while the same words unmodified are fine."""
    rng = np.random.Generator(np.random.Philox(3))
    words = rng.integers(0, 2 ** 32, size=2048, dtype=np.uint32)
    assert crush.matrix_rank(words) > 1e-3
    mats = words.reshape(-1, 32).copy()
    mats[:, 31] = mats[:, 0] ^ mats[:, 1]  # every matrix rank <= 31
    assert crush.matrix_rank(mats.reshape(-1)) < 1e-4


def test_gf2_rank32_exact_values():
    eye = np.uint32(1) << np.arange(32, dtype=np.uint32)
    assert crush.gf2_rank32(eye) == 32
    assert crush.gf2_rank32(np.zeros(32, np.uint32)) == 0
    two = np.zeros(32, np.uint32)
    two[0], two[1], two[2] = 5, 3, 6  # 6 = 5 ^ 3: dependent third row
    assert crush.gf2_rank32(two) == 2


# ---------------------------------------------------------------------------
# the committed report: verdicts, coverage, canonical serialization
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def committed_report():
    with open(REPO / "QUALITY_report.json") as f:
        return json.load(f)


def test_committed_report_is_ok(committed_report):
    assert committed_report["schema"] == 1
    assert committed_report["profile"] == "fast"
    assert committed_report["ok"] is True
    for g in committed_report["generators"]:
        assert g["as_expected"], g["name"]


def test_committed_report_covers_acceptance_matrix(committed_report):
    """Both decorrelator modes x all three backends pass; both ablations
    fail on the cross-battery (the PR's acceptance criterion)."""
    by_name = {g["name"]: g for g in committed_report["generators"]}
    for mode in ("ctr", "faithful"):
        for backend in ("ref", "xla", "pallas"):
            g = by_name[f"thundering/{mode}/{backend}"]
            assert g["ok"] and g["intra"]["ok"], g["name"]
        assert by_name[f"thundering/{mode}/sharded"]["cross"]["ok"]
    for kind in ("raw_lcg", "no_deco"):
        g = by_name[f"ablation/{kind}"]
        assert not g["ok"]
        rank_fail = (g["intra"] is not None
                     and not g["intra"]["tests"]["matrix_rank"]["ok"])
        cross_fail = g["cross"] is not None and not g["cross"]["ok"]
        assert rank_fail or cross_fail, g["name"]


def test_committed_report_covers_distribution_stages(committed_report):
    """Every distribution stage passes Crush-lite via the PIT on all
    three backends, and the raw-LCG-through-PIT ablation still fails —
    the reduction neither breaks good samplers nor launders bad bits."""
    by_name = {g["name"]: g for g in committed_report["generators"]}
    for spec in battery.DIST_SPECS:
        dist = spec.split("(")[0].split("[")[0]
        for backend in ("ref", "xla", "pallas"):
            g = by_name[f"dist/{dist}/{backend}"]
            assert g["ok"] and g["intra"]["ok"], g["name"]
            assert g["sampler"] == spec
    pit_g = by_name["ablation/raw_lcg_pit"]
    assert not pit_g["ok"]
    assert pit_g["cross"] is not None and not pit_g["cross"]["ok"]
    assert pit_g["sampler"] == "exponential(1.0)"


def test_committed_report_serialization_is_canonical(committed_report):
    """File bytes == report_json(parsed file): no hand edits possible."""
    on_disk = (REPO / "QUALITY_report.json").read_text()
    assert battery.report_json(committed_report) == on_disk


def test_rendered_docs_match_committed_report(committed_report):
    assert render.render_quality_md(committed_report) == \
        (REPO / "docs" / "quality.md").read_text()
    exp = (REPO / "EXPERIMENTS.md").read_text()
    assert render.patch_experiments(exp, committed_report) == exp


def test_run_battery_rejects_unknown_generator():
    with pytest.raises(ValueError, match="unknown generators"):
        battery.run_battery("tiny", generators=["nope"])


def test_round_floats_is_stable():
    r = battery._round_floats({"a": 0.1234567890123456789,
                               "b": [1e-300, 3], "c": "x"})
    assert r == {"a": 0.123456789, "b": [1e-300, 3], "c": "x"}


# ---------------------------------------------------------------------------
# full regeneration (slow): the CI docs job's check as a pytest node
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fast_profile_regenerates_byte_identically(committed_report):
    regen = battery.run_battery("fast", seed=committed_report["seed"])
    assert battery.report_json(regen) == \
        (REPO / "QUALITY_report.json").read_text()
