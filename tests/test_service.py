"""RandService: tenancy, coalescing, journal replay, drain.

The acceptance properties as executable tests:

  * tenant -> region derivation is injective and regions are pairwise
    disjoint across >= 10^4 sampled ids including adversarial
    near-collisions (property-tested),
  * a concurrent mixed burst (>= 512 requests, >= 10^3 tenants) is
    served with ZERO counter-window overlap (ledger-verified on both
    the live service and the raw journal), with the coalescer issuing
    <= 10% as many engine/lease calls as requests,
  * journal replay after a restart — including a simulated mid-request
    crash (torn journal tail) — reproduces every served byte
    bit-identically, and the restarted service's new windows stay
    disjoint from everything replayed,
  * shutdown is a graceful drain: queued requests are served, late
    submissions are refused, SIGINT or SIGTERM on
    ``python -m repro.service`` drains and exits cleanly, and
    ``drain(timeout=None)`` waits for completion rather than bailing.
"""
import os
import signal
import subprocess
import sys
import threading
import time
from typing import Tuple

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime import BlockService
from repro.service import (Coalescer, Journal, RandRequest, RandServer,
                           ServerConfig, ServiceClosed, TenantRegistry,
                           replay, tenant_region, verify_ledger_disjoint)
from repro.service.audit import response_digest
from repro.service.burst import make_requests, run_burst
from repro.service.frontend import (DEFAULT_MAX_ROWS as DEFAULT_ROWS,
                                    class_channel, request_rows)
from repro.service.tenants import REGION_BITS, QuotaExceeded

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bytes_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and str(a.dtype) == str(b.dtype) \
        and a.tobytes() == b.tobytes()


# ---------------------------------------------------------------------------
# tenants: injective derivation, disjoint regions, quotas
# ---------------------------------------------------------------------------

def _adversarial_ids():
    """Near-collision ids: shared prefixes/suffixes, whitespace, case,
    separator and unicode perturbations of the same stem."""
    stems = ["tenant-0", "user/42", ""]
    ids = set()
    for stem in stems:
        ids.update({stem, stem + " ", " " + stem, stem + "\x00",
                    stem + "0", "0" + stem, stem.upper(), stem * 2,
                    stem + "é", stem[::-1]})
    ids.update(f"tenant-{i:04d}" for i in range(64))   # one-digit deltas
    ids.update("x" * 200 + str(i) for i in range(64))  # long shared prefix
    return sorted(ids)


def test_tenant_region_injective_over_10k_ids():
    ids = [f"tenant/{i}" for i in range(10_000)] + _adversarial_ids()
    bases = [tenant_region(i) for i in ids]
    assert len(set(bases)) == len(ids), "region base collision"
    size = 1 << REGION_BITS
    assert all(b % size == 0 for b in bases)
    # disjointness: bases are distinct multiples of the region size, so
    # sorted regions [b, b + size) cannot overlap
    srt = sorted(bases)
    assert all(srt[k] + size <= srt[k + 1] for k in range(len(srt) - 1))


def test_registry_rejects_region_collision_detectably():
    reg = TenantRegistry(region_bits=0)  # every hash value is a region
    reg.register("a")
    # region_bits=0 makes collisions FINDABLE, not likely; simulate one
    reg._by_region[tenant_region("b", 0)] = "other"
    from repro.service.tenants import TenantCollisionError
    with pytest.raises(TenantCollisionError):
        reg.register("b")


@settings(max_examples=64, deadline=None)
@given(st.integers(0, 2 ** 64 - 1), st.integers(0, 2 ** 64 - 1))
def test_tenant_region_property(a, b):
    """Distinct ids -> disjoint regions; same id -> same region (pure)."""
    ia, ib = f"t{a}", f"t{b}"
    ra, rb = tenant_region(ia), tenant_region(ib)
    assert ra == tenant_region(ia)
    size = 1 << REGION_BITS
    if ia == ib:
        assert ra == rb
    elif ra != rb:
        lo, hi = min(ra, rb), max(ra, rb)
        assert lo + size <= hi  # bases are multiples of size -> disjoint


@settings(max_examples=32, deadline=None)
@given(st.integers(1, 100), st.integers(1, 100))
def test_quota_accounting_property(quota, ask):
    reg = TenantRegistry(default_quota=quota)
    if ask <= quota:
        assert reg.charge("t", ask).served == ask
        left = quota - ask
        with pytest.raises(QuotaExceeded):
            reg.charge("t", left + 1)
        assert reg.get("t").served == ask  # failed charge consumed nothing
    else:
        with pytest.raises(QuotaExceeded):
            reg.charge("t", ask)


def test_request_rows_quantization():
    assert request_rows(1) == 8
    assert request_rows(8) == 8
    assert request_rows(9) == 16
    assert request_rows(2048) == 2048
    assert request_rows(10 ** 9) == 2048          # clamped to max_rows
    assert request_rows(4096, max_rows=4096) == 4096
    with pytest.raises(ValueError):
        request_rows(0)


# ---------------------------------------------------------------------------
# coalescer: determinism, replay parity, mixed classes
# ---------------------------------------------------------------------------

def _mixed_requests(n=24):
    cases = [("bits", "float32"), ("uniform", "float32"),
             ("uniform", "bfloat16"), ("normal", "float32"),
             ("bernoulli(0.25)", "float32")]
    reqs = []
    for i in range(n):
        sampler, dtype = cases[i % len(cases)]
        shape = (3 + i,) if i % 2 else (2 + i % 5, 7 + i)
        reqs.append(RandRequest(f"t{i % 7}", shape, sampler, dtype,
                                rid=f"r{i:03d}"))
    return reqs


def _flush_once(seed=13):
    journal = Journal()
    svc = BlockService(seed, backend="xla")
    co = Coalescer(svc, TenantRegistry(), journal=journal, backend="xla")
    got, asgs, errs = co.flush(_mixed_requests())
    assert not errs
    return got, asgs, journal, svc, co


def test_coalescer_deterministic_and_replay_parity():
    got1, _, journal, svc, co = _flush_once()
    got2, _, _, _, _ = _flush_once()
    assert response_digest(got1) == response_digest(got2)
    # replay regenerates per-request stand-alone plans: a gathered-column
    # slice of the fused batch must equal the request's own plan
    rep = replay(journal, seed=13, backend="xla")
    assert set(rep) == set(got1)
    for rid in rep:
        assert _bytes_equal(got1[rid], rep[rid]), rid
    verify_ledger_disjoint(svc)
    verify_ledger_disjoint(journal)
    # one lease + one engine call per (class) microbatch
    s = co.stats()
    assert s["engine_calls"] == s["lease_calls"] == 5


def test_coalescer_response_shapes_and_dtypes():
    got, asgs, _, _, _ = _flush_once()
    by_rid = {a.rid: a for a in asgs}
    for req in _mixed_requests():
        a = np.asarray(got[req.rid])
        assert a.shape == req.shape
        if req.sampler == "bits":
            assert a.dtype == np.uint32
        elif req.sampler.startswith("bernoulli"):
            assert a.dtype == np.bool_
        elif req.out_dtype == "float32":
            assert a.dtype == np.float32
        asg = by_rid[req.rid]
        assert len(asg.tags) == -(-req.num_samples // asg.rows)


def test_coalescer_tags_disjoint_within_flush():
    _, asgs, _, _, _ = _flush_once()
    per_channel = {}
    for a in asgs:
        seen = per_channel.setdefault((a.channel, a.lo), set())
        for t in a.tags:
            assert t not in seen, "column tag double-assigned"
            seen.add(t)


def test_successive_flushes_get_fresh_windows():
    journal = Journal()
    svc = BlockService(5, backend="xla")
    co = Coalescer(svc, TenantRegistry(), journal=journal, backend="xla")
    reqs = [RandRequest("t", (32,), rid="a")]
    got1, asg1, _ = co.flush(reqs)
    got2, asg2, _ = co.flush([RandRequest("t", (32,), rid="b")])
    assert asg1[0].lo != asg2[0].lo
    assert not _bytes_equal(got1["a"], got2["b"])
    verify_ledger_disjoint(journal)


def test_rejected_request_consumes_no_quota():
    """Admission checks run before charge(): a request refused for
    region capacity must leave the tenant's meter untouched."""
    reg = TenantRegistry(region_bits=2)       # 4 slots per tenant
    svc = BlockService(5, backend="xla")
    co = Coalescer(svc, reg, backend="xla")
    too_big = 5 * DEFAULT_ROWS + 1            # needs 6 columns > 4 slots
    got, _, errs = co.flush([
        RandRequest("t", (too_big,), rid="big"),
        RandRequest("t", (16,), rid="small")])
    assert isinstance(errs["big"], QuotaExceeded)
    assert reg.get("t").served == 16          # only the served request
    assert got["small"].shape == (16,)


def test_registry_refund_restores_quota():
    reg = TenantRegistry(default_quota=100)
    reg.charge("t", 80)
    reg.refund("t", 80)
    assert reg.get("t").served == 0
    assert reg.charge("t", 100).served == 100


def test_deferred_start_is_count_deterministic():
    """start=False + enqueue-all + start(): batch composition is pure
    chunks of max_batch, so two runs agree byte-for-byte even with a
    watermark deadline of ~0."""
    digests = []
    for _ in range(2):
        srv = RandServer(53, config=ServerConfig(max_batch=5,
                                                 max_delay_s=0.0001),
                         start=False)
        reqs = _mixed_requests(17)
        futs = [srv.submit(r) for r in reqs]
        srv.start()
        got = {r.rid: f.result(timeout=60) for r, f in zip(reqs, futs)}
        srv.shutdown()
        digests.append(response_digest(got))
    assert digests[0] == digests[1]


def test_journal_newline_less_tail_survives_reopen(tmp_path):
    """Crash after the closing brace but before the newline: the record
    is kept AND the next append starts on a fresh line."""
    path = str(tmp_path / "j.jsonl")
    j = Journal(path)
    j.append_window("c", 0, 8)
    j.flush()
    j.close()
    with open(path, "r+b") as f:              # chop the trailing newline
        f.truncate(os.path.getsize(path) - 1)
    j2 = Journal(path)
    assert len(j2.windows()) == 1
    j2.append_window("c", 8, 16)
    j2.flush()
    j2.close()
    j3 = Journal(path)                        # both records, two lines
    assert [w["lo"] for w in j3.windows()] == [0, 8]


def test_bench_json_filtered_merge(tmp_path):
    from benchmarks.throughput import write_bench_json
    path = tmp_path / "bench.json"
    write_bench_json([{"name": "a", "variant": "x", "v": 1},
                      {"name": "b", "variant": "x", "v": 2}], path)
    write_bench_json([{"name": "b", "variant": "x", "v": 9}], path,
                     merge=True)
    import json
    rows = json.loads(path.read_text())["rows"]
    assert {(r["name"], r["v"]) for r in rows} == {("a", 1), ("b", 9)}


def test_server_honors_caller_supplied_empty_registry():
    """An empty registry is falsy (__len__) — the server must keep the
    caller's instance anyway, or quotas silently stop applying."""
    reg = TenantRegistry(default_quota=32)
    with RandServer(7, config=ServerConfig(max_batch=1),
                    registry=reg) as srv:
        assert srv.registry is reg
        srv.request("q", (16,))
        with pytest.raises(QuotaExceeded):
            srv.request("q", (32,))
        assert reg.get("q").served == 16


def test_restarted_server_does_not_reuse_journaled_rids(tmp_path):
    path = str(tmp_path / "j.jsonl")
    srv = RandServer(7, config=ServerConfig(max_batch=1),
                     journal=Journal(path))
    a = srv.request("t", (24,))            # auto-rid r00000001
    srv.shutdown()
    j2 = Journal(path)
    srv2 = RandServer(7, config=ServerConfig(max_batch=1), journal=j2)
    b = srv2.request("t", (24,))           # must NOT collide with run 1
    srv2.shutdown()
    rep = replay(Journal(path), seed=7)
    assert len(rep) == 2
    assert any(_bytes_equal(v, a) for v in rep.values())
    assert any(_bytes_equal(v, b) for v in rep.values())


def test_quota_rejection_is_isolated():
    svc = BlockService(5, backend="xla")
    co = Coalescer(svc, TenantRegistry(default_quota=100), backend="xla")
    reqs = [RandRequest("small", (64,), rid="ok"),
            RandRequest("big", (101,), rid="over"),
            RandRequest("small2", (64,), rid="ok2")]
    got, _, errs = co.flush(reqs)
    assert set(got) == {"ok", "ok2"}
    assert isinstance(errs["over"], QuotaExceeded)


# ---------------------------------------------------------------------------
# server: the acceptance burst, pools, crash replay, drain
# ---------------------------------------------------------------------------

def test_acceptance_burst_1024_tenants(tmp_path):
    """>= 512 concurrent mixed requests from >= 10^3 distinct tenants:
    zero window overlap (ledger-verified), <= 10% calls/request,
    bit-identical replay after restart."""
    burst, tenants = 1024, 1024
    path = str(tmp_path / "journal.jsonl")
    cfg = ServerConfig(max_batch=256, max_delay_s=0.25,
                       hot_classes=(("uniform", "float32"),))
    srv = RandServer(17, config=cfg, journal=Journal(path))
    reqs = make_requests(burst=burst, tenants=tenants, seed=17)
    got = run_burst(srv, reqs, submit_threads=16)
    assert len(got) == burst
    assert len(srv.registry) >= 1000
    stats = srv.stats()
    assert stats["requests_failed"] == 0
    assert stats["calls_per_request"] <= 0.10, stats
    verify_ledger_disjoint(srv.block_service)
    verify_ledger_disjoint(srv.journal)
    srv.shutdown()

    # restart: replay the journal in a fresh context -> bit-identical
    j2 = Journal(path)
    rep = replay(j2, seed=17)
    assert set(rep) == set(got)
    for rid in rep:
        assert _bytes_equal(got[rid], rep[rid]), rid
    # ...and a restarted server leases strictly disjoint new windows
    srv2 = RandServer(17, config=cfg, journal=j2)
    run_burst(srv2, make_requests(burst=32, tenants=16, seed=99,
                                  rid_prefix="post-restart"))
    verify_ledger_disjoint(srv2.journal)
    srv2.shutdown()


def test_pool_serves_hot_class_with_replay_parity():
    cfg = ServerConfig(max_batch=16, max_delay_s=0.1, pool_rows=128,
                       pool_cols=8, hot_classes=(("uniform", "float32"),))
    journal = Journal()
    with RandServer(23, config=cfg, journal=journal) as srv:
        reqs = [RandRequest("t/pool", (50 + i,), "uniform", "float32",
                            rid=f"p{i}") for i in range(12)]
        got = run_burst(srv, reqs)
        stats = srv.stats()
        assert stats["pool_requests"] == 12
        verify_ledger_disjoint(srv.block_service)
    rep = replay(journal, seed=23)
    for rid in got:
        assert _bytes_equal(got[rid], rep[rid]), rid
    pool_wins = [w for w in journal.windows()
                 if w["channel"].startswith("service/pool/")]
    assert pool_wins, "pool windows must be journaled"


def test_pool_donation_serves_identical_responses():
    """The donated standing pool (and its fused variant) must be
    response-for-response byte-identical to the plain pool: donation
    only changes WHERE blocks are written, never what they hold."""
    def burst(donate, fuse=1):
        cfg = ServerConfig(max_batch=8, max_delay_s=0.05, pool_rows=64,
                           pool_cols=8, pool_depth=2, pool_donate=donate,
                           pool_fuse=fuse,
                           hot_classes=(("uniform", "float32"),))
        with RandServer(41, config=cfg) as srv:
            reqs = [RandRequest("t/don", (40 + i,), "uniform", "float32",
                                rid=f"d{i}") for i in range(24)]
            got = run_burst(srv, reqs)
            assert srv.stats()["pool_requests"] == 24
            verify_ledger_disjoint(srv.block_service)
        return got

    plain = burst(donate=False)
    for tag, got in (("donated", burst(donate=True)),
                     ("donated+fused", burst(donate=True, fuse=2))):
        for rid in plain:
            assert _bytes_equal(plain[rid], got[rid]), (tag, rid)


def test_mid_request_crash_torn_journal_replays(tmp_path):
    """Kill mid-write: truncate the journal to a torn final line — every
    COMPLETE record must still replay bit-identically."""
    path = str(tmp_path / "journal.jsonl")
    srv = RandServer(31, config=ServerConfig(max_batch=8, max_delay_s=0.05),
                     journal=Journal(path))
    got = run_burst(srv, _mixed_requests())
    srv.shutdown()
    raw = open(path, "rb").read()
    lines = raw.splitlines(keepends=True)
    keep = len(lines) * 2 // 3
    torn = b"".join(lines[:keep]) + lines[keep][: len(lines[keep]) // 2]
    with open(path, "wb") as f:
        f.write(torn)
    j = Journal(path)          # torn trailing line is dropped, not fatal
    rep = replay(j, seed=31)
    assert 0 < len(rep) < len(got)
    for rid in rep:
        assert _bytes_equal(got[rid], rep[rid]), rid
    # restart on the torn journal: new windows disjoint from replayed
    srv2 = RandServer(31, config=ServerConfig(max_batch=8), journal=j)
    run_burst(srv2, [RandRequest("t9", (64,), rid="post-crash")])
    verify_ledger_disjoint(srv2.journal)
    srv2.shutdown()


def test_restart_reserves_journaled_windows(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    srv = RandServer(41, config=ServerConfig(max_batch=4),
                     journal=Journal(path))
    run_burst(srv, [RandRequest("t", (256,), rid="one")])
    state1 = srv.ledger_state()["channels"]
    srv.shutdown()
    srv2 = RandServer(41, config=ServerConfig(max_batch=4),
                      journal=Journal(path))
    chan = class_channel("bits", "float32")
    restored = srv2.ledger_state()["channels"][chan]["committed"]
    assert restored == state1[chan]["committed"]
    srv2.shutdown()


def test_graceful_drain_serves_queued_then_refuses():
    srv = RandServer(7, config=ServerConfig(max_batch=64, max_delay_s=5.0))
    futs = [srv.submit(RandRequest("t", (16,), rid=f"d{i}"))
            for i in range(8)]
    srv.shutdown()             # drain must flush the deadline-waiting batch
    assert all(f.result(timeout=30).shape == (16,) for f in futs)
    with pytest.raises(ServiceClosed):
        srv.submit(RandRequest("t", (16,)))


def test_duplicate_rid_in_one_batch_fails_cleanly():
    with RandServer(7, config=ServerConfig(max_batch=4,
                                           max_delay_s=0.5)) as srv:
        f1 = srv.submit(RandRequest("t", (8,), rid="dup"))
        f2 = srv.submit(RandRequest("t", (8,), rid="dup"))
        ok, bad = ((f1, f2) if f2.exception(timeout=30) is not None
                   else (f2, f1))
        assert ok.result(timeout=30).shape == (8,)
        assert isinstance(bad.exception(timeout=30), ValueError)


def test_server_rejects_invalid_sampler_at_submit():
    with RandServer(7, config=ServerConfig(max_batch=1)) as srv:
        with pytest.raises(ValueError):
            srv.submit(RandRequest("t", (8,), sampler="nonsense"))


def test_journaled_rid_reuse_refused_at_submit():
    with RandServer(7, config=ServerConfig(max_batch=1),
                    journal=Journal()) as srv:
        assert srv.submit(RandRequest("t", (8,), rid="x")).result(30) \
            .shape == (8,)
        with pytest.raises(ValueError, match="already used"):
            srv.submit(RandRequest("t", (8,), rid="x"))


def test_partial_class_failure_preserves_other_classes():
    """One class's engine failure fails ITS requests only; the other
    class is served and its tenants are the only ones billed."""
    reg = TenantRegistry()
    svc = BlockService(5, backend="xla")
    co = Coalescer(svc, reg, backend="xla")
    boom = RuntimeError("backend down")

    def broken(*a, **k):
        raise boom
    good = [RandRequest("a", (16,), "bits", rid="ok")]
    bad = [RandRequest("b", (16,), "uniform", rid="bad")]
    orig = co._window_fn

    def selective(purpose, rows, cols, sampler, dtype):
        return broken if sampler == "uniform" else orig(
            purpose, rows, cols, sampler, dtype)
    co._window_fn = selective
    got, _, errs = co.flush(good + bad)
    assert got["ok"].shape == (16,)
    assert errs["bad"] is boom
    assert reg.get("a").served == 16
    assert reg.get("b").served == 0          # refunded on failure


def test_submit_backpressure_does_not_deadlock_drain():
    """A full queue on a never-started server must not wedge drain()."""
    srv = RandServer(7, config=ServerConfig(max_batch=1, queue_depth=2),
                     start=False)
    futs = [srv.submit(RandRequest("t", (8,), rid=f"q{i}"))
            for i in range(2)]                  # queue now full
    blocked = {}

    def third():
        try:
            blocked["fut"] = srv.submit(RandRequest("t", (8,), rid="q2"))
        except ServiceClosed as e:
            blocked["err"] = e
    th = threading.Thread(target=third, daemon=True)
    th.start()
    time.sleep(0.1)                             # let it hit the full queue
    srv.shutdown(timeout=30)                    # must not deadlock
    th.join(timeout=30)
    assert not th.is_alive()
    assert all(f.result(30).shape == (8,) for f in futs)
    assert "err" in blocked or blocked["fut"].result(30).shape == (8,)


def _drain_via_signal(sig) -> Tuple[int, str]:
    """Run ``python -m repro.service --linger``, deliver ``sig`` once
    ready, return (returncode, output)."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.service", "--burst", "16",
         "--tenants", "4", "--linger", "120"],
        cwd=REPO, env=env, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    try:
        deadline = time.time() + 180
        ready = False
        for line in proc.stdout:
            if "ready (SIGINT/SIGTERM to drain)" in line:
                ready = True
                break
            assert time.time() < deadline, "server never became ready"
        assert ready
        proc.send_signal(sig)
        out, _ = proc.communicate(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
    return proc.returncode, out


def test_sigint_graceful_drain():
    """``python -m repro.service --linger``: SIGINT drains and exits 0."""
    rc, out = _drain_via_signal(signal.SIGINT)
    assert rc == 0, out
    assert "drained" in out


def test_sigterm_graceful_drain():
    """SIGTERM (what supervisors and ``fleet.Fleet.stop`` send) takes
    the same graceful-drain path as SIGINT."""
    rc, out = _drain_via_signal(signal.SIGTERM)
    assert rc == 0, out
    assert "drained" in out


def test_drain_timeout_none_waits_forever():
    """``drain(timeout=None)`` means "wait until drained", not "give
    up immediately" — it must return True with all work served."""
    srv = RandServer(11, config=ServerConfig(max_batch=4), start=False)
    futs = [srv.submit(RandRequest("t", (64,), rid=f"n{i}"))
            for i in range(8)]
    assert srv.drain(timeout=None) is True
    assert all(f.result(30).shape == (64,) for f in futs)
    # a second drain is idempotent and still reports drained
    assert srv.drain(timeout=None) is True


# ---------------------------------------------------------------------------
# scale: million-tenant churn, bounded class-keyed caching, dist classes
# ---------------------------------------------------------------------------

def test_million_tenant_churn_regions_disjoint_and_serving():
    """10**6 tenant registrations (idempotent churn included), sampled
    region disjointness at scale, then live mixed-distribution serving
    from tenants spread across the whole population — registration cost
    must stay O(1) per id and the region map collision-free."""
    reg = TenantRegistry()
    n = 1_000_000
    step = 997  # co-prime stride: re-register every ~1000th id (churn)
    for i in range(n):
        reg.register(f"churn/{i:07d}")
        if i % step == 0:
            reg.register(f"churn/{i % 4096:07d}")  # idempotent re-touch
    assert len(reg) == n
    # sampled disjointness: 20k evenly-spaced ids -> sorted region bases
    # must be distinct multiples of the region size with no overlap
    size = 1 << REGION_BITS
    bases = sorted(tenant_region(f"churn/{i:07d}")
                   for i in range(0, n, n // 20_000))
    assert all(b % size == 0 for b in bases)
    assert all(bases[k] + size <= bases[k + 1]
               for k in range(len(bases) - 1))
    # the registry still serves: mixed distribution classes from tenants
    # sampled across the population, replay-parity checked
    journal = Journal()
    svc = BlockService(29, backend="xla")
    co = Coalescer(svc, reg, journal=journal, backend="xla")
    classes = [("exponential(1.5)", "float32"), ("poisson(3.5)", "bfloat16"),
               ("gamma(2.5)", "float32"),
               ("categorical[0.5,0.25,0.125,0.125]", "float32")]
    reqs = [RandRequest(f"churn/{(j * 77777) % n:07d}", (9 + j,),
                        *classes[j % 4], rid=f"m{j:03d}")
            for j in range(16)]
    got, _, errs = co.flush(reqs)
    assert not errs
    rep = replay(journal, seed=29)
    for rid in got:
        assert _bytes_equal(got[rid], rep[rid]), rid
    verify_ledger_disjoint(journal)


def test_window_fn_cache_bounded_under_class_churn():
    """Every distinct (rows, sampler, dtype) request class keys a jitted
    window fn; unbounded churn (e.g. per-tenant categorical weights)
    must not grow the cache without limit — the coalescer's LRU keeps it
    at ``window_fn_cache_size`` while staying byte-deterministic across
    evict/recompile cycles."""
    journal = Journal()
    svc = BlockService(31, backend="xla")
    co = Coalescer(svc, TenantRegistry(), journal=journal, backend="xla",
                   window_fn_cache_size=4)
    # 12 distinct classes > 4 cache slots, flushed twice (second pass
    # re-derives evicted fns)
    reqs = [RandRequest("t/cache", (8,), f"exponential({1.0 + 0.25 * k})",
                        "float32", rid=f"c{k:02d}")
            for k in range(12)]
    got1, _, errs = co.flush(reqs)
    assert not errs
    assert co.stats()["window_fn_cache"] <= 4
    assert co.stats()["window_fn_cache_max"] == 4
    # replay sees every class, including ones whose fn was evicted
    rep = replay(journal, seed=31)
    for rid in got1:
        assert _bytes_equal(got1[rid], rep[rid]), rid
    with pytest.raises(ValueError, match="window_fn_cache_size"):
        Coalescer(svc, TenantRegistry(), window_fn_cache_size=0)


def test_burst_mixed_distribution_classes_replay(tmp_path):
    """The PR's acceptance criterion in miniature: a burst spanning all
    four distribution classes (plus bf16 poisson) journals and replays
    bit-identically — the shaped-sampler transforms must be stable
    between the coalescer's batched executables and the auditor's
    per-request ones."""
    path = str(tmp_path / "dist.jsonl")
    srv = RandServer(37, config=ServerConfig(max_batch=64, max_delay_s=0.2),
                     journal=Journal(path))
    classes = [("exponential(0.75)", "float32"), ("poisson(7.25)", "bfloat16"),
               ("gamma(3.5)", "float32"), ("gamma(1.0)", "bfloat16"),
               ("categorical[3,1,1,3]", "float32"),
               ("poisson(0.0)", "float32")]
    reqs = [RandRequest(f"d/{i % 13:02d}",
                        (i % 3 + 1, 11 + i) if i % 2 else (23 + 7 * i,),
                        *classes[i % len(classes)], rid=f"x{i:03d}")
            for i in range(96)]
    got = run_burst(srv, reqs, submit_threads=8)
    assert srv.stats()["requests_failed"] == 0
    srv.shutdown()
    rep = replay(Journal(path), seed=37)
    assert set(rep) == set(got)
    for rid in rep:
        assert _bytes_equal(got[rid], rep[rid]), rid
    # shaped responses stay in-domain through the service path
    for r in reqs:
        a = np.asarray(got[r.rid], dtype=np.float64)
        if r.sampler.startswith("poisson(0.0"):
            assert np.all(a == 0.0)
        elif r.sampler.startswith("categorical"):
            assert a.min() >= 0 and a.max() <= 3
        else:
            assert np.all(np.isfinite(a)) and a.min() >= 0.0
