"""Fused sampler pipeline: every sampler x backend x mode bit/value-exact
vs the ref oracle, fusion (single pallas_call, no uint32 intermediate),
open-interval / exact-threshold guarantees, and distribution moments."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, sampler as sampler_mod, stream as stream_mod

BACKENDS = ("ref", "xla", "pallas")
SAMPLERS = ("uniform", "normal", "bernoulli(0.3)")
DTYPES = ("float32", "bfloat16")


def _raw(a):
    """Bit view for exact comparison (bf16/bool-safe)."""
    a = np.asarray(a)
    return a.view(np.uint16) if a.dtype == jnp.bfloat16 else a


def _ulp_diff(a, b):
    """Max ULP distance between two equal-dtype float arrays."""
    a, b = np.asarray(a), np.asarray(b)
    itype = np.int16 if a.dtype == jnp.bfloat16 else np.int32
    ai = a.view(itype).astype(np.int64)
    bi = b.view(itype).astype(np.int64)
    # map the sign-magnitude float ordering onto monotone integers
    sign_bit = np.int64(1) << (8 * itype(0).itemsize - 1)
    ai = np.where(ai < 0, (sign_bit - 1) - ai, ai)
    bi = np.where(bi < 0, (sign_bit - 1) - bi, bi)
    return int(np.abs(ai - bi).max()) if a.size else 0


def _assert_matches(out, base, sampler, ctx):
    """Bit-exact for bits/uniform/bernoulli (pure integer/multiply
    pipelines); exact to 2 ULP for normal, whose log and cos/sin may each
    take SIMD-vs-remainder libm paths that differ in the last bit when
    the backends' padded shapes differ (XLA:CPU vectorization)."""
    assert out.shape == base.shape and out.dtype == base.dtype, ctx
    if sampler.startswith("normal"):
        assert _ulp_diff(out, base) <= 2, ctx
    else:
        assert np.array_equal(_raw(out), _raw(base)), ctx


# ---------------------------------------------------------------------------
# backend parity: value-exact vs the ref oracle on awkward shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS[1:])
@pytest.mark.parametrize("mode", ["ctr", "faithful"])
@pytest.mark.parametrize("sampler", SAMPLERS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sampler_backend_parity(backend, mode, sampler, dtype):
    plan = engine.make_plan(seed=91, num_streams=36, num_steps=12, offset=4,
                            mode=mode, sampler=sampler, out_dtype=dtype)
    base = engine.generate(plan, backend="ref")
    out = engine.generate(plan, backend=backend)
    _assert_matches(out, base, sampler, (backend, mode, sampler, dtype))


@pytest.mark.parametrize("T,S", [(10, 4), (40, 257), (8, 128), (256, 130)])
def test_sampler_awkward_shapes_pallas(T, S):
    """Pallas tiling/padding never leaks into real rows, any sampler."""
    for sampler in SAMPLERS:
        plan = engine.make_plan(seed=17, num_streams=S, num_steps=T,
                                sampler=sampler)
        _assert_matches(engine.generate(plan, backend="pallas"),
                        engine.generate(plan, backend="ref"),
                        sampler, (T, S, sampler))


def test_sampler_block_shape_invariance():
    """Box-Muller pairing is tiling-independent (bt even by construction)."""
    plan = engine.make_plan(seed=19, num_streams=256, num_steps=64,
                            sampler="normal")
    base = np.asarray(engine.generate(plan, backend="pallas"))
    for bt, bs in [(8, 128), (16, 128), (32, 256)]:
        out = np.asarray(engine.generate(plan, backend="pallas",
                                         block_t=bt, block_s=bs))
        assert np.array_equal(out, base), (bt, bs)


def test_normal_odd_block_t_rounded_to_sublane():
    """A raw odd block_t must not flip Box-Muller pairing parity across
    tiles: tile_t rounds it down to the dtype's sublane multiple."""
    from repro.kernels import thundering_block as tb
    assert tb.tile_t(9, 64, jnp.float32) == 8
    assert tb.tile_t(24, 64, jnp.bfloat16) == 16
    assert tb.tile_t(8, 64, jnp.bool_) == 32
    for mode in ("ctr", "faithful"):
        plan = engine.make_plan(seed=7, num_streams=8, num_steps=32,
                                mode=mode, sampler="normal")
        _assert_matches(engine.generate(plan, backend="pallas", block_t=9),
                        engine.generate(plan, backend="ref"),
                        "normal", mode)


def test_sample_override_and_fmix32():
    plan = engine.make_plan(seed=23, num_streams=36, num_steps=12,
                            deco="fmix32")
    for backend in BACKENDS:
        out = engine.sample(plan, sampler="uniform", backend=backend)
        assert out.dtype == jnp.float32
        assert np.array_equal(
            np.asarray(out),
            np.asarray(engine.sample(plan, sampler="uniform",
                                     backend="ref")))


# ---------------------------------------------------------------------------
# fusion: one pallas_call, no (T, S) uint32 block in the outer jaxpr
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sampler", ["uniform", "normal"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_pallas_sampler_is_fused(sampler, dtype):
    T, S = 64, 256
    plan = engine.make_plan(seed=3, num_streams=S, num_steps=T,
                            sampler=sampler, out_dtype=dtype)
    jaxpr = jax.make_jaxpr(
        lambda: engine.generate(plan, backend="pallas"))()
    calls = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "pallas_call"]
    assert len(calls) == 1, [e.primitive.name for e in jaxpr.jaxpr.eqns]
    # No intermediate the size of the bit block may exist outside the
    # kernel: the uint32 (T, S) block must live and die in VMEM.
    for eqn in jaxpr.jaxpr.eqns:
        for var in eqn.outvars:
            aval = var.aval
            assert not (aval.dtype == jnp.uint32 and aval.size >= T * S), \
                f"uint32 intermediate {aval.shape} escapes the kernel"


# ---------------------------------------------------------------------------
# transform guarantees
# ---------------------------------------------------------------------------

def test_normal_open_interval_no_log0():
    """All-zero and all-one bits map to finite normals (log(0) guarded)."""
    bits = jnp.array([[0, 0xFFFFFFFF], [0xFFFFFFFF, 0]], jnp.uint32)
    z = np.asarray(sampler_mod.apply(bits, ("normal", None)))
    assert np.all(np.isfinite(z))
    u = np.asarray(sampler_mod.apply(bits, ("uniform", None)))
    assert np.all((u >= 0.0) & (u < 1.0))


def test_normal_odd_t_raises():
    plan = engine.make_plan(seed=3, num_streams=4, num_steps=7,
                            sampler="normal")
    with pytest.raises(ValueError, match="even T"):
        engine.generate(plan)


def test_unknown_sampler_and_dtype_raise():
    with pytest.raises(ValueError, match="unknown sampler"):
        engine.generate(engine.make_plan(seed=1, num_streams=4, num_steps=8,
                                         sampler="gamma"))
    with pytest.raises(ValueError, match="unknown out_dtype"):
        engine.generate(engine.make_plan(seed=1, num_streams=4, num_steps=8,
                                         sampler="uniform",
                                         out_dtype="float64"))


def test_uniform_matches_stream_transform():
    """sampler='uniform' == uniform_from_bits(sampler='bits') elementwise."""
    plan = engine.make_plan(seed=7, num_streams=12, num_steps=10)
    bits = engine.generate(plan, backend="xla")
    u = engine.sample(plan, sampler="uniform", backend="xla")
    assert np.array_equal(np.asarray(u),
                          np.asarray(sampler_mod.uniform_from_bits(bits)))


def test_bernoulli_threshold_exact_near_one():
    """p near 1 keeps the exact host-int threshold (no float32 wrap)."""
    p = 1.0 - 2.0 ** -33  # rounds to 2**32 - 1, not 2**32
    assert sampler_mod.bernoulli_threshold(p) == (1 << 32) - 1
    plan = engine.make_plan(seed=9, num_streams=8, num_steps=16,
                            sampler=f"bernoulli({p!r})")
    bits = np.asarray(engine.sample(plan, sampler="bits", backend="xla"))
    mask = np.asarray(engine.generate(plan, backend="xla"))
    assert np.array_equal(mask, bits != 0xFFFFFFFF)


def test_bernoulli_endpoints_constant():
    for p, want in [(0.0, False), (1.0, True), (-2.0, False), (3.0, True)]:
        plan = engine.make_plan(seed=9, num_streams=4, num_steps=8,
                                sampler=f"bernoulli({p})")
        for backend in BACKENDS:
            out = np.asarray(engine.generate(plan, backend=backend))
            assert out.dtype == bool and np.all(out == want), (p, backend)


def test_bernoulli_matches_stream_api():
    """Column s of a bernoulli block == stream.bernoulli of the derived
    stream (same bits, same exact threshold)."""
    T, S, p = 24, 8, 0.37
    plan = engine.make_plan(seed=55, num_streams=S, num_steps=T,
                            sampler=f"bernoulli({p})")
    blk = np.asarray(engine.generate(plan, backend="xla"))
    fam = stream_mod.new_stream(55, 0)
    for s in (0, 5):
        st = fam._replace(h_hi=plan.h[0][s], h_lo=plan.h[1][s])
        assert np.array_equal(blk[:, s],
                              np.asarray(stream_mod.bernoulli(st, p, (T,))))


def test_stream_uniforms_normals_match_engine():
    st = stream_mod.advance(stream_mod.new_stream(42, 1), 6)
    u = stream_mod.uniforms(st, (5, 4))
    assert np.array_equal(
        np.asarray(u).ravel(),
        np.asarray(engine.sample(engine.plan_for_stream(st, 20),
                                 sampler="uniform"))[:, 0])
    # odd count: one pair tail generated and dropped
    z = stream_mod.normals(st, (7,))
    z8 = stream_mod.normals(st, (8,))
    assert np.array_equal(np.asarray(z), np.asarray(z8)[:7])
    assert stream_mod.normals(st, (6,), jnp.bfloat16).dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# sharded fan-out carries the sampler stage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sampler,dtype", [
    ("uniform", "bfloat16"), ("normal", "float32"), ("bernoulli(0.6)",
                                                     "float32")])
def test_generate_sharded_sampler(sampler, dtype):
    plan = engine.make_plan(seed=13, num_streams=22, num_steps=16,
                            sampler=sampler, out_dtype=dtype)
    a = engine.generate(plan, backend="xla")
    b = engine.generate_sharded(plan)
    _assert_matches(b, a, sampler, (sampler, dtype))


# ---------------------------------------------------------------------------
# moments (S = 4096): mean/var within 4 sigma of the distribution
# ---------------------------------------------------------------------------

def _moment_block(sampler, T=64, S=4096):
    plan = engine.make_plan(seed=1234, num_streams=S, num_steps=T,
                            sampler=sampler)
    return np.asarray(engine.generate(plan, backend="xla"),
                      dtype=np.float64), T * S


def test_uniform_moments():
    u, n = _moment_block("uniform")
    assert abs(u.mean() - 0.5) < 4 * np.sqrt(1 / 12 / n)
    # var of the sample variance of U(0,1): (E[x^4]-var^2)/n with x
    # centered -> 1/180n; 4 sigma
    assert abs(u.var() - 1 / 12) < 4 * np.sqrt(1 / 180 / n)


def test_normal_moments():
    z, n = _moment_block("normal")
    assert abs(z.mean()) < 4 / np.sqrt(n)
    assert abs(z.var() - 1.0) < 4 * np.sqrt(2.0 / n)
    # Box-Muller pair rows must not correlate: lag-1 correlation along T
    c = np.corrcoef(z[:-1].ravel(), z[1:].ravel())[0, 1]
    assert abs(c) < 4 / np.sqrt(n)


def test_bernoulli_moments():
    p = 0.3
    m, n = _moment_block(f"bernoulli({p})")
    assert abs(m.mean() - p) < 4 * np.sqrt(p * (1 - p) / n)
