"""ThunderStream API: golden equivalence, counter addressing, samplers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import golden, splitmix, stream, u64


def _h_int(s):
    return u64.join64(np.asarray(s.h_hi), np.asarray(s.h_lo))


def _x0_int(s):
    return u64.join64(np.asarray(s.x0_hi), np.asarray(s.x0_lo))


def test_random_bits_matches_golden_ctr():
    s = stream.new_stream(2024, 3)
    got = np.asarray(stream.random_bits(s, (300,)))
    exp = golden.thundering_block(_x0_int(s), np.array([_h_int(s)], dtype=object),
                                  300, mode="ctr")[0]
    assert np.array_equal(got, exp)


def test_random_bits_offset_matches_golden():
    s = stream.advance(stream.new_stream(7, 0), 1000)
    got = np.asarray(stream.random_bits(s, (64,)))
    exp = golden.thundering_block(_x0_int(s), np.array([_h_int(s)], dtype=object),
                                  64, mode="ctr", offset=1000)[0]
    assert np.array_equal(got, exp)


_FULL = None


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=768),
       st.sampled_from([1, 17, 256]))  # few shapes -> few recompiles
def test_counter_addressing_property(offset, n):
    """bits(advance(s, k))[i] == bits(s)[k + i]  — the pure-map property."""
    global _FULL
    s = stream.new_stream(99, 1)
    if _FULL is None:
        _FULL = np.asarray(stream.random_bits(s, (1024,)))
    part = np.asarray(stream.random_bits(stream.advance(s, offset), (n,)))
    assert np.array_equal(_FULL[offset:offset + n], part)


def test_block_boundary_continuity():
    """Cross the 256-element internal block boundary."""
    s = stream.new_stream(5, 5)
    a = np.asarray(stream.random_bits(s, (1024,)))
    b = np.concatenate([np.asarray(stream.random_bits(stream.advance(s, i), (128,)))
                        for i in range(0, 1024, 128)])
    assert np.array_equal(a, b)


def test_derive_changes_h_keeps_root():
    s = stream.new_stream(11, 0)
    c = stream.derive(s, 42)
    assert _x0_int(c) == _x0_int(s)
    assert _h_int(c) != _h_int(s)
    assert _h_int(c) % 2 == 0, "leaf offsets must stay even (Hull-Dobell)"


def test_derive_distinct_tags_distinct_streams():
    s = stream.new_stream(11, 0)
    hs = {_h_int(stream.derive(s, t)) for t in range(64)}
    assert len(hs) == 64


def test_split_disjoint_outputs():
    s = stream.new_stream(13, 0)
    children = stream.split(s, 8)
    outs = [np.asarray(stream.random_bits(c, (256,))) for c in children]
    for i in range(8):
        for j in range(i + 1, 8):
            assert not np.array_equal(outs[i], outs[j])


def test_derive_traced_tag_matches_static():
    s = stream.new_stream(17, 0)
    c_static = stream.derive(s, 5)
    c_traced = jax.jit(lambda t: stream.derive(s, t))(jnp.uint32(5))
    assert _h_int(c_traced) == _h_int(c_static)


def test_stream_is_pytree():
    s = stream.new_stream(1, 0)
    leaves = jax.tree.leaves(s)
    assert len(leaves) == 6
    mapped = jax.tree.map(lambda x: x, s)
    assert isinstance(mapped, stream.ThunderStream)


def test_random_bits_jit_and_shapes():
    s = stream.new_stream(3, 0)
    out = jax.jit(lambda s: stream.random_bits(s, (4, 8, 2)))(s)
    assert out.shape == (4, 8, 2) and out.dtype == jnp.uint32
    flat = stream.random_bits(s, (64,))
    assert np.array_equal(np.asarray(out).reshape(-1), np.asarray(flat))


def test_uniform_range_and_determinism():
    s = stream.new_stream(21, 0)
    u = np.asarray(stream.uniform(s, (10_000,)))
    assert (u >= 0).all() and (u < 1).all()
    assert abs(u.mean() - 0.5) < 0.02
    assert np.array_equal(u, np.asarray(stream.uniform(s, (10_000,))))


def test_uniform_bounds_scaling():
    s = stream.new_stream(22, 0)
    u = np.asarray(stream.uniform(s, (4096,), minval=-2.0, maxval=3.0))
    assert (u >= -2).all() and (u < 3).all()
    assert abs(u.mean() - 0.5) < 0.2


def test_normal_moments():
    s = stream.new_stream(23, 0)
    x = np.asarray(stream.normal(s, (50_000,)))
    assert abs(x.mean()) < 0.02
    assert abs(x.std() - 1.0) < 0.02
    assert np.isfinite(x).all()


def test_bernoulli_rate():
    s = stream.new_stream(24, 0)
    for p in [0.1, 0.5, 0.9]:
        m = np.asarray(stream.bernoulli(stream.derive(s, int(p * 10)), p, (20_000,)))
        assert abs(m.mean() - p) < 0.02


def test_bernoulli_endpoints_exact():
    """p=1 must be all True, p=0 all False (float32 threshold used to wrap)."""
    s = stream.new_stream(27, 0)
    assert np.asarray(stream.bernoulli(s, 1.0, (4096,))).all()
    assert not np.asarray(stream.bernoulli(s, 0.0, (4096,))).any()
    assert np.asarray(stream.bernoulli(s, 1, (16,))).all()      # int p
    assert not np.asarray(stream.bernoulli(s, 0, (16,))).any()


def test_bernoulli_near_one_threshold_exact():
    """Host threshold is exact 64-bit: round(p * 2**32), not float32."""
    s = stream.new_stream(28, 0)
    p = 1.0 - 2.0 ** -20   # float32 p*2**32 would round up to 2**32 and wrap
    m = np.asarray(stream.bernoulli(s, p, (50_000,)))
    assert m.mean() > 0.999
    # exact threshold semantics: mask == (bits < round(p * 2**32))
    bits = np.asarray(stream.random_bits(s, (50_000,)))
    assert np.array_equal(m, bits < np.uint32(round(p * 2 ** 32)))


def test_bernoulli_traced_p_clamped():
    s = stream.new_stream(29, 0)
    f = jax.jit(lambda p: stream.bernoulli(s, p, (1024,)))
    assert np.asarray(f(jnp.float32(1.0))).all()
    assert not np.asarray(f(jnp.float32(0.0))).any()
    assert np.asarray(f(jnp.float32(1.5))).all()    # clamped
    m = np.asarray(f(jnp.float32(0.5)))
    assert abs(m.mean() - 0.5) < 0.05


def test_categorical_distribution():
    s = stream.new_stream(25, 0)
    logits = jnp.log(jnp.asarray([[0.1, 0.2, 0.7]] * 8192))
    draws = np.asarray(stream.categorical(s, logits))
    freq = np.bincount(draws, minlength=3) / draws.size
    assert np.allclose(freq, [0.1, 0.2, 0.7], atol=0.03)


def test_gumbel_finite():
    s = stream.new_stream(26, 0)
    g = np.asarray(stream.gumbel(s, (10_000,)))
    assert np.isfinite(g).all()
    assert abs(g.mean() - 0.5772) < 0.05  # Euler-Mascheroni


def test_independent_streams_uncorrelated():
    s = stream.new_stream(31, 0)
    a, b = stream.split(s, 2)
    xa = np.asarray(stream.uniform(a, (100_000,)))
    xb = np.asarray(stream.uniform(b, (100_000,)))
    rho = np.corrcoef(xa, xb)[0, 1]
    assert abs(rho) < 0.01


def test_vmap_over_streams():
    s = stream.new_stream(41, 0)
    children = stream.split(s, 4)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *children)
    outs = jax.vmap(lambda st: stream.random_bits(st, (32,)))(stacked)
    for i, c in enumerate(children):
        assert np.array_equal(np.asarray(outs[i]), np.asarray(stream.random_bits(c, (32,))))
