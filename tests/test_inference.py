"""The inference tier end to end: fused gumbel-max kernel parity vs the
two-pass oracle (temperature/top-k matrix, padded shapes), the
jaxpr-level fusion contract (one pallas_call, no uint32 bit block in
HBM), slot-pool churn with ledger-proved non-overlap of reused regions,
tenant retire, scheduler determinism across runs and sampling paths,
kill-and-replay transcript-digest identity (subprocess), and the serve
driver's greedy bit-compat."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, sampler as sampler_mod
from repro.inference import (ActiveSeq, ContinuousBatcher, GumbelMaxSampler,
                             SamplingSpec, ScheduleConfig, SlotPool,
                             SyntheticLogitModel, run_offline,
                             transcript_digest)
from repro.inference.kernels import (argmax_first, fused_argmax,
                                     gumbel_scores, twopass_argmax)
from repro.inference import sampling as sampling_mod
from repro.inference import slots as slots_mod
from repro.runtime import blocks, fault
from repro.service import audit, tenants

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _scored_setup(seed, V, B, *, deco="splitmix64"):
    """(logits_t, h, roots, ctr_rows) for a direct kernel-level call."""
    rng = np.random.default_rng(seed)
    logits_t = jnp.asarray(rng.normal(size=(V, B)).astype(np.float32))
    x0, h_fam = engine.family_from_seed(seed, 0xD0)
    tags = jnp.arange(B, dtype=jnp.uint32)
    h = engine.derive_leaf(
        (jnp.broadcast_to(h_fam[0], tags.shape),
         jnp.broadcast_to(h_fam[1], tags.shape)),
        (jnp.zeros_like(tags), tags))
    from repro.core import u64
    c = tuple(map(jnp.asarray, u64.const64(977)))
    roots, ctr_rows = engine.root_and_ctr_rows(x0, c, V)
    plan = engine.GenPlan(x0=x0, h=h, num_steps=V, ctr=c, offset=None,
                         mode="ctr", deco=deco, sampler="gumbel",
                         out_dtype="float32")
    noise = engine.generate(plan, backend="ref")
    return logits_t, h, roots, ctr_rows, noise


# ---------------------------------------------------------------------------
# kernel: fused vs two-pass oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("V,B", [(512, 256), (512, 128), (64, 8),
                                 (300, 20), (1000, 130)])
@pytest.mark.parametrize("inv_temp,top_k", [(1.0, 0), (1.25, 0),
                                            (1.0, 16), (2.0, 4)])
def test_fused_matches_twopass_oracle(V, B, inv_temp, top_k):
    """Token-exact parity at tile-multiple AND padded shapes, with and
    without temperature scaling and top-k masking.  The oracle's noise
    is engine-generated (ref backend) — disagreement isolates the
    kernel's tiling, not the math (both share gumbel_scores)."""
    logits_t, h, roots, ctr_rows, noise = _scored_setup(9, V, B)
    if top_k:
        thresh = jax.lax.top_k(logits_t.T, top_k)[0][:, -1]
    else:
        thresh = jnp.full((B,), -jnp.inf, jnp.float32)
    it = np.float32(inv_temp)
    fused = np.asarray(fused_argmax(logits_t, h, roots, ctr_rows, thresh,
                                    inv_temp=it, interpret=True))
    ref = np.asarray(twopass_argmax(logits_t, noise, thresh, inv_temp=it))
    assert fused.dtype == np.int32 and fused.shape == (B,)
    assert np.array_equal(fused, ref)
    if top_k:
        # every sampled token is inside its sequence's top-k set
        keep = np.asarray(logits_t).T >= np.asarray(thresh)[:, None]
        assert keep[np.arange(B), fused].all()


def test_fused_small_blocks_internal_carry():
    """Tiny tile sizes force many vocab tiles per column — the
    strictly-greater scratch carry must still match the full-column
    first-argmax."""
    V, B = 192, 16
    logits_t, h, roots, ctr_rows, noise = _scored_setup(3, V, B)
    thresh = jnp.full((B,), -jnp.inf, jnp.float32)
    fused = np.asarray(fused_argmax(
        logits_t, h, roots, ctr_rows, thresh, inv_temp=np.float32(1.0),
        block_v=16, block_b=128, interpret=True))
    ref = np.asarray(twopass_argmax(logits_t, noise, thresh,
                                    inv_temp=np.float32(1.0)))
    assert np.array_equal(fused, ref)


def test_argmax_first_matches_jnp_argmax_and_breaks_ties_low():
    rng = np.random.default_rng(5)
    s = rng.normal(size=(64, 32)).astype(np.float32)
    assert np.array_equal(np.asarray(argmax_first(jnp.asarray(s))),
                          np.argmax(s, axis=0))
    # explicit ties: first index must win (jnp.argmax semantics)
    t = np.zeros((8, 4), np.float32)
    t[2, :] = 7.0
    t[5, :] = 7.0
    assert np.asarray(argmax_first(jnp.asarray(t))).tolist() == [2] * 4


def test_gumbel_scores_shared_transform():
    """The kernel body and the oracle share ONE scoring transform; its
    noise term is exactly the sampler grammar's gumbel stage."""
    bits = sampler_mod.remix_bits(
        jnp.arange(256, dtype=jnp.uint32) * np.uint32(0x9E3779B9), 7)
    logits = jnp.linspace(-2.0, 2.0, 256).astype(jnp.float32)
    got = gumbel_scores(bits, logits, np.float32(0.5))
    want = (sampler_mod.fma_guard(logits * np.float32(0.5))
            + sampler_mod.gumbel_from_bits(bits))
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# jaxpr fusion contract
# ---------------------------------------------------------------------------

def _all_eqns(jaxpr):
    for e in jaxpr.eqns:
        yield e
        for v in e.params.values():
            if hasattr(v, "jaxpr"):
                yield from _all_eqns(v.jaxpr)


def _u32_block_outvars(fn, args, min_size):
    jaxpr = jax.make_jaxpr(fn)(*args)
    pallas_calls = 0
    u32_blocks = 0
    for e in _all_eqns(jaxpr.jaxpr):
        if e.primitive.name == "pallas_call":
            pallas_calls += 1
        for var in e.outvars:
            aval = var.aval
            if aval.dtype == jnp.uint32 and aval.size >= min_size:
                u32_blocks += 1
    return pallas_calls, u32_blocks


def test_fused_path_jaxpr_no_uint32_block_one_pallas_call():
    """The in-kernel bits-to-token contract, asserted on the jaxpr: the
    fused step function contains exactly ONE pallas_call and NO uint32
    intermediate of the (vocab, batch) bit-block size — the raw bits
    never exist outside VMEM.  The two-pass path over the same shapes
    DOES materialize that block (the contrast proving the assertion has
    teeth)."""
    V, B = 512, 256
    s = GumbelMaxSampler.standalone(seed=2, vocab=V, capacity=B,
                                    spec=SamplingSpec(temperature=0.7,
                                                      top_k=8))
    logits = jnp.zeros((B, V), jnp.float32)
    tags = jnp.zeros((B,), jnp.uint32)
    from repro.core import u64
    c = tuple(map(jnp.asarray, u64.const64(0)))
    args = (logits, tags, tags, c[0], c[1])
    calls, u32 = _u32_block_outvars(s.jitted("fused"), args, V * B)
    assert calls == 1, f"expected exactly 1 pallas_call, saw {calls}"
    assert u32 == 0, f"uint32 bit block reached HBM ({u32} outvars)"
    _, u32_twopass = _u32_block_outvars(s.jitted("xla"), args, V * B)
    assert u32_twopass >= 1, "oracle path should materialize the bits"


# ---------------------------------------------------------------------------
# sampler: greedy, metering, journaling, replay
# ---------------------------------------------------------------------------

def _mk_active(registry, n):
    out = []
    for slot in range(n):
        sid = f"seq/{slot}"
        t = registry.register(sid)
        out.append(ActiveSeq(slot=slot, seq_id=sid, tenant_id=sid,
                             tag=t.tag(0), position=0))
    return out


def test_sampler_greedy_is_pure_argmax_no_randomness():
    s = GumbelMaxSampler.standalone(seed=1, vocab=32, capacity=4,
                                    spec=SamplingSpec(temperature=0.0))
    logits = np.random.default_rng(0).normal(size=(4, 32)).astype(np.float32)
    toks = s.sample_step(0, logits, _mk_active(s.registry, 4))
    assert np.array_equal(toks, np.argmax(logits, -1))
    st = s.stats()
    assert st["engine_calls"] == 0 and st["greedy"]
    # no leases either: the class channel ledger is untouched
    led = s.service.ledger_state()["channels"][s.channel]
    assert led["committed"] == []


def test_sampler_journals_one_batch_per_step_and_replays(tmp_path):
    """Each stochastic step journals ONE atomic batch record (window +
    per-sequence assignments); a second sampler over the restored
    journal regenerates the SAME tokens through lease-or-regenerate
    (replayed_steps meters the regenerated prefix)."""
    path = str(tmp_path / "j.jsonl")
    V, cap = 64, 4
    rng = np.random.default_rng(7)
    logits = rng.normal(size=(cap, V)).astype(np.float32)

    def step_batch(active, t):
        return [ActiveSeq(slot=a.slot, seq_id=a.seq_id,
                          tenant_id=a.tenant_id, tag=a.tag, position=t)
                for a in active]

    j = audit.Journal(path)
    s = GumbelMaxSampler.standalone(seed=5, vocab=V, capacity=cap,
                                    journal=j)
    active = _mk_active(s.registry, cap)
    first = [s.sample_step(t, logits, step_batch(active, t))
             for t in range(3)]
    j.close()

    j2 = audit.Journal(path)
    batches = [e for e in j2.entries if e["kind"] == "batch"]
    assert len(batches) == 3
    assert batches[0]["windows"] == [
        {"channel": sampling_mod.class_channel(), "lo": 0, "hi": V}]
    assert len(batches[0]["requests"]) == cap
    # journal replay regenerates each sequence's noise independently
    rep = audit.replay(j2, seed=5)
    assert sorted(rep) == sorted(r["rid"] for b in batches
                                 for r in b["requests"])

    svc = blocks.BlockService(seed=5)
    j2.restore_into(svc, fence=True)
    s2 = GumbelMaxSampler(svc, tenants.TenantRegistry(), vocab=V,
                          capacity=cap, journal=j2)
    active2 = _mk_active(s2.registry, cap)
    again = [s2.sample_step(t, logits, step_batch(active2, t))
             for t in range(3)]
    j2.close()
    for a, b in zip(first, again):
        assert np.array_equal(a, b)
    assert s2.stats()["replayed_steps"] == 3
    assert s.stats()["calls_per_step"] == 1.0


def test_sampler_rejects_bad_config():
    with pytest.raises(ValueError, match="unknown sampling path"):
        GumbelMaxSampler.standalone(seed=0, vocab=8, capacity=2,
                                    path="cuda")
    with pytest.raises(ValueError, match="top_k"):
        GumbelMaxSampler.standalone(seed=0, vocab=8, capacity=2,
                                    spec=SamplingSpec(top_k=9))
    with pytest.raises(ValueError, match="top_k must be >= 0"):
        SamplingSpec(top_k=-1)


# ---------------------------------------------------------------------------
# blocks.release(name): channel retire + floor fence
# ---------------------------------------------------------------------------

def test_release_channel_fences_floor_against_reuse():
    """A retired-and-reused channel can NEVER re-lease a window its
    previous occupant consumed: release() fences the floor at the
    high-water mark, open() preserves the retired ledger, and the
    ledger stays verifiably disjoint across the reuse."""
    svc = blocks.BlockService(seed=1)
    svc.open("churn/x", num_streams=1)
    svc.take("churn/x", 8)                      # occupant 0 consumes [0, 8)
    floor = svc.release("churn/x")
    assert floor == 8
    with pytest.raises(KeyError):
        svc.lease("churn/x", 8)                 # channel is gone
    svc.open("churn/x", num_streams=1)          # occupant 1 re-opens
    assert svc.lease("churn/x", 8).lo == 8      # strictly beyond
    with pytest.raises(blocks.LeaseError, match="floor"):
        svc.lease("churn/x", 4, at=0)           # explicit reuse refused
    with pytest.raises(blocks.LeaseError, match="floor"):
        svc.lease("churn/x", 4, at=6)           # even straddling
    audit.verify_ledger_disjoint(svc)


def test_release_channel_refuses_live_reservations():
    svc = blocks.BlockService(seed=1)
    svc.open("churn/y", num_streams=1)
    lease = svc.lease("churn/y", 4)
    with pytest.raises(blocks.LeaseError, match="live reservation"):
        svc.release("churn/y")
    lease.release()
    svc.release("churn/y")
    with pytest.raises(KeyError):
        svc.release("churn/y")                  # already retired


def test_tenant_retire_frees_row_same_region_on_return():
    reg = tenants.TenantRegistry()
    t = reg.register("seq/42")
    snap = reg.retire("seq/42")
    assert snap is not None and snap.region_lo == t.region_lo
    assert "seq/42" not in reg and len(reg) == 0
    assert reg.retire("seq/42") is None         # idempotent
    t2 = reg.register("seq/42")                 # pure hash: same region
    assert (t2.region_lo, t2.region_hi) == (t.region_lo, t.region_hi)
    assert t2.served == 0                       # fresh meters


# ---------------------------------------------------------------------------
# slot pool churn
# ---------------------------------------------------------------------------

def test_slot_pool_admit_retire_reuse_ledger_disjoint():
    svc = blocks.BlockService(seed=3)
    reg = tenants.TenantRegistry()
    pool = SlotPool(svc, reg, capacity=2, min_len=2, len_spread=5)
    a = pool.admit("seq/a", 0)
    b = pool.admit("seq/b", 0)
    assert (a.slot, b.slot) == (0, 1) and not pool.has_free()
    assert 2 <= a.target_len <= 7
    with pytest.raises(RuntimeError, match="no free slot"):
        pool.admit("seq/c", 1)
    gone = pool.retire(0)
    assert gone.seq_id == "seq/a" and "seq/a" not in reg
    c = pool.admit("seq/c", 3)
    assert c.slot == 0 and c.occupant == 1      # ordinal advanced
    # occupant windows are disjoint ON THE LEDGER, floor-fenced between
    led = svc.ledger_state()["channels"][slots_mod.slot_channel(0)]
    assert led["committed"] == [[0, 16]]        # [0,8) + [8,16) merged
    assert led["floor"] == 8                    # fenced at retire
    audit.verify_ledger_disjoint(svc)
    pool.retire(0)                              # frees seq/c
    with pytest.raises(ValueError, match="not occupied"):
        pool.retire(0)                          # empty slot refuses
    assert pool.num_active() == 1               # seq/b still live


def test_slot_pool_admission_draw_replays_bit_identically(tmp_path):
    """Same (slot, occupant) coordinates => same target_len, across a
    journal-restored service (the admission half of crash-replay)."""
    path = str(tmp_path / "j.jsonl")
    j = audit.Journal(path)
    svc = blocks.BlockService(seed=9)
    pool = SlotPool(svc, tenants.TenantRegistry(), capacity=1, journal=j)
    s0 = pool.admit("seq/0", 0)
    pool.retire(0)
    s1 = pool.admit("seq/1", 5)
    j.close()

    j2 = audit.Journal(path)
    svc2 = blocks.BlockService(seed=9)
    j2.restore_into(svc2, fence=True)
    pool2 = SlotPool(svc2, tenants.TenantRegistry(), capacity=1, journal=j2)
    r0 = pool2.admit("seq/0", 0)
    pool2.retire(0)
    r1 = pool2.admit("seq/1", 5)
    j2.close()
    assert (r0.target_len, r1.target_len) == (s0.target_len, s1.target_len)


# ---------------------------------------------------------------------------
# scheduler determinism + path parity
# ---------------------------------------------------------------------------

SMALL = ScheduleConfig(capacity=4, vocab=64, sequences=8, rate=1.0, seed=5)


def test_batcher_rerun_and_xla_path_same_digest():
    r1 = ContinuousBatcher(SMALL).run()
    r2 = ContinuousBatcher(SMALL).run()
    assert r1.digest == r2.digest
    assert r1.digest == transcript_digest(r1.transcripts)
    rx = ContinuousBatcher(
        ScheduleConfig(**{**SMALL.__dict__, "path": "xla"})).run()
    assert rx.digest == r1.digest
    assert r1.admitted == r1.retired == SMALL.sequences
    assert r1.sampler_stats["calls_per_step"] == 1.0
    assert 0.0 < r1.occupancy <= 1.0
    for sid, toks in r1.transcripts.items():
        assert len(toks) >= SMALL.min_len
        assert all(0 <= t < SMALL.vocab for t in toks)


def test_batcher_seed_changes_tokens():
    r1 = ContinuousBatcher(SMALL).run()
    r2 = ContinuousBatcher(
        ScheduleConfig(**{**SMALL.__dict__, "seed": 6})).run()
    assert r1.digest != r2.digest


def test_synthetic_logit_model_pure_and_bounded():
    m = SyntheticLogitModel(4, 32, scale=6.0)
    h = np.asarray([m.seq_hash(f"s{i}") for i in range(4)], np.uint32)
    p = np.arange(4, dtype=np.uint32)
    a, b = np.asarray(m(h, p)), np.asarray(m(h, p))
    assert np.array_equal(a, b) and a.shape == (4, 32)
    assert float(a.min()) >= 0.0 and float(a.max()) < 6.0
    assert not np.array_equal(a, np.asarray(m(h, p + 1)))


# ---------------------------------------------------------------------------
# kill-and-replay under churn (subprocess: real os._exit crash)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_kill_and_replay_transcript_digest_identical(tmp_path):
    """The acceptance check: an offline run killed mid-flight (scripted
    FaultPlan, SIGKILL semantics at decode step 6) and restarted from
    its journal produces the EXACT transcript digest of a fault-free
    run — slot churn, admissions, arrivals and decode noise all replay
    bit-identically."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    args = [sys.executable, "-m", "repro.inference", "--batch", "4",
            "--vocab", "64", "--sequences", "8", "--rate", "1",
            "--seed", "5"]
    base = tmp_path / "base.digest"
    ok = subprocess.run(args + ["--digest-out", str(base)], cwd=REPO,
                        env=env, timeout=300)
    assert ok.returncode == 0
    journal = str(tmp_path / "run.jsonl")
    killed = subprocess.run(
        args + ["--journal", journal, "--fault-plan", "kill@6"],
        cwd=REPO, env=env, timeout=300)
    assert killed.returncode == 1, "kill fault must take the process down"
    assert os.path.exists(journal)
    replay = tmp_path / "replay.digest"
    again = subprocess.run(
        args + ["--journal", journal, "--digest-out", str(replay)],
        cwd=REPO, env=env, timeout=300)
    assert again.returncode == 0
    assert base.read_text() == replay.read_text()
    # and the journal's windows stayed disjoint across both owners
    audit.verify_ledger_disjoint(audit.Journal(journal, readonly=True))


def test_run_offline_parity_flag_in_process(tmp_path):
    report = run_offline(SMALL, journal_path=str(tmp_path / "j.jsonl"),
                         parity=True)
    j = report.to_json()
    assert j["parity_digest"] == j["digest"]
    assert j["calls_per_step"] == 1.0
    assert j["retired"] == SMALL.sequences


# ---------------------------------------------------------------------------
# serve driver: greedy bit-compat with the retired ad-hoc picker
# ---------------------------------------------------------------------------

def test_serve_picker_greedy_bit_identical_to_old_pick():
    """The retired serve._pick greedy path was
    ``jnp.argmax(logits, -1)[:, None].astype(int32)``; the TokenPicker
    must reproduce it bit-for-bit (same expression, asserted — greedy
    decode token streams are unchanged by the rewiring)."""
    from repro.launch.serve import TokenPicker
    rng = np.random.default_rng(11)
    logits = jnp.asarray(rng.normal(size=(4, 97)).astype(np.float32))
    picker = TokenPicker(seed=0, batch=4, vocab=97, temperature=0.0)
    for step in range(3):
        old = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        assert np.array_equal(np.asarray(picker.pick(step, logits)),
                              np.asarray(old))
    assert picker.sampler is None               # no service, no leases


def test_serve_picker_stochastic_delegates_to_inference_tier():
    from repro.launch.serve import TokenPicker
    rng = np.random.default_rng(13)
    logits = rng.normal(size=(4, 64)).astype(np.float32)
    p1 = TokenPicker(seed=3, batch=4, vocab=64, temperature=0.8)
    p2 = TokenPicker(seed=3, batch=4, vocab=64, temperature=0.8,
                     path="xla")
    for step in range(3):
        t1 = np.asarray(p1.pick(step, jnp.asarray(logits)))
        t2 = np.asarray(p2.pick(step, jnp.asarray(logits)))
        assert t1.shape == (4, 1)
        assert np.array_equal(t1, t2)           # fused == two-pass tokens
    assert p1.sampler.stats()["calls_per_step"] == 1.0
    # every draw is tenant-attributed to the serve sequence rows
    assert "launch/serve/seq/0" in p1.sampler.registry
