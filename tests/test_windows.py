"""Multi-window fusion: ``engine.generate_windows`` emits W consecutive
counter windows in one dispatch, bit-identical to W stacked ``generate``
calls on every backend, both decorrelator modes, every sampler stage,
and awkward (non-tile-multiple) window lengths — and the pallas path
compiles to exactly ONE pallas_call."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine

BACKENDS = ("ref", "xla", "pallas")


def _stacked(plan, W, backend="ref"):
    """The oracle: W independent single-window generate calls."""
    T = plan.num_steps
    return np.stack([
        np.asarray(engine.generate(engine.shift_plan(plan, w * T),
                                   backend=backend))
        for w in range(W)])


def _raw(a):
    return a.view(np.uint16) if a.dtype == jnp.bfloat16 else a


# ---------------------------------------------------------------------------
# parity: backend x mode x sampler x awkward window length
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,deco", [("ctr", "splitmix64"),
                                       ("ctr", "fmix32"),
                                       ("faithful", "splitmix64"),
                                       ("faithful", "fmix32")])
@pytest.mark.parametrize("backend", BACKENDS)
def test_windows_match_stacked_generate(backend, mode, deco):
    T, S, W = 12, 70, 3                 # T far off the 8-row tile multiple
    plan = engine.make_plan(seed=42, num_streams=S, num_steps=T,
                            mode=mode, deco=deco)
    expect = _stacked(plan, W)
    got = np.asarray(engine.generate_windows(plan, W, backend=backend,
                                             block_t=8, block_s=16))
    assert got.shape == (W, T, S)
    assert np.array_equal(got, expect)


@pytest.mark.parametrize("sampler,dtype", [("bits", "float32"),
                                           ("uniform", "float32"),
                                           ("uniform", "bfloat16"),
                                           ("normal", "float32"),
                                           ("normal", "bfloat16"),
                                           ("bernoulli(0.3)", "float32")])
@pytest.mark.parametrize("backend", BACKENDS)
def test_windows_sampler_parity(backend, sampler, dtype):
    T, S, W = 20, 33, 3                 # awkward rows AND lanes
    plan = engine.make_plan(seed=9, num_streams=S, num_steps=T,
                            sampler=sampler, out_dtype=dtype)
    expect = _stacked(plan, W)
    got = np.asarray(engine.generate_windows(plan, W, backend=backend,
                                             block_t=8, block_s=16))
    assert np.array_equal(_raw(got), _raw(expect))


@pytest.mark.parametrize("backend", BACKENDS)
def test_single_window_equals_generate(backend):
    plan = engine.make_plan(seed=5, num_streams=16, num_steps=8)
    got = np.asarray(engine.generate_windows(plan, 1, backend=backend))
    assert np.array_equal(got[0],
                          np.asarray(engine.generate(plan, backend=backend)))


def test_windows_from_nonzero_counter():
    """Windows lease mid-stream exactly like shifted plans do."""
    T, S, W = 12, 24, 4
    plan = engine.make_plan(seed=13, num_streams=S, num_steps=T, offset=37)
    for backend in BACKENDS:
        got = np.asarray(engine.generate_windows(plan, W, backend=backend,
                                                 block_t=8, block_s=16))
        assert np.array_equal(got, _stacked(plan, W))


def test_windows_traced_counter_matches_static():
    """The producer path: counter traced through jit, offset=None."""
    T, S, W = 8, 16, 3
    plan = engine.make_plan(seed=3, num_streams=S, num_steps=T)
    traced = dataclasses.replace(plan, offset=None)

    @jax.jit
    def fn(hi, lo):
        p = dataclasses.replace(traced, ctr=(hi, lo))
        return engine.generate_windows(p, W, backend="xla")

    hi, lo = plan.ctr
    got = np.asarray(fn(jnp.asarray(hi), jnp.asarray(lo)))
    assert np.array_equal(got, _stacked(plan, W))


def test_shift_plan_matches_offset_lease():
    plan = engine.make_plan(seed=21, num_streams=8, num_steps=16)
    direct = engine.make_plan(seed=21, num_streams=8, num_steps=16,
                              offset=48)
    a = np.asarray(engine.generate(engine.shift_plan(plan, 48)))
    assert np.array_equal(a, np.asarray(engine.generate(direct)))


def test_invalid_window_count_raises():
    plan = engine.make_plan(seed=1, num_streams=8, num_steps=8)
    for bad in (0, -2):
        with pytest.raises(ValueError, match="num_windows"):
            engine.generate_windows(plan, bad)
    with pytest.raises(ValueError, match="backend"):
        engine.generate_windows(plan, 2, backend="nope")


# ---------------------------------------------------------------------------
# fusion: the pallas path is ONE kernel launch for all W windows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ctr", "faithful"])
def test_pallas_windows_is_one_pallas_call(mode):
    T, S, W = 64, 256, 4
    plan = engine.make_plan(seed=3, num_streams=S, num_steps=T, mode=mode,
                            sampler="uniform")
    jaxpr = jax.make_jaxpr(
        lambda: engine.generate_windows(plan, W, backend="pallas"))()
    calls = [e for e in jaxpr.jaxpr.eqns
             if e.primitive.name == "pallas_call"]
    assert len(calls) == 1, [e.primitive.name for e in jaxpr.jaxpr.eqns]
