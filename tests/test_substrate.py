"""Substrate tests: data pipeline determinism, checkpoint atomicity +
elastic restore, fault-tolerant restart equivalence, optimizer, schedule."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import SyntheticLMPipeline
from repro.launch.train import smoke_config, train
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.optim.adamw import global_norm


def test_pipeline_deterministic_and_seekable():
    p1 = SyntheticLMPipeline(7, 512, 4, 32)
    p2 = SyntheticLMPipeline(7, 512, 4, 32)
    b5a = p1.batch_at(5)
    b5b = p2.batch_at(5)   # fresh pipeline, direct seek
    assert np.array_equal(np.asarray(b5a["tokens"]), np.asarray(b5b["tokens"]))
    b6 = p1.batch_at(6)
    assert not np.array_equal(np.asarray(b5a["tokens"]), np.asarray(b6["tokens"]))


def test_pipeline_labels_are_shifted_tokens():
    p = SyntheticLMPipeline(3, 128, 2, 16)
    b = p.batch_at(0)
    assert b["tokens"].shape == (2, 16)
    assert b["labels"].shape == (2, 16)
    # tokens/labels are windows of the same stream shifted by 1
    full = p.batch_at(0)
    assert np.array_equal(np.asarray(full["tokens"][:, 1:]),
                          np.asarray(full["labels"][:, :-1]))


def test_pipeline_tokens_in_range_and_zipf():
    p = SyntheticLMPipeline(9, 1000, 8, 128)
    t = np.asarray(p.batch_at(0)["tokens"])
    assert t.min() >= 0 and t.max() < 1000
    # Zipf: low ids much more frequent
    low = (t < 10).mean()
    high = (t > 900).mean()
    assert low > high


def test_pipeline_extras():
    p = SyntheticLMPipeline(1, 64, 2, 8, extras={"patches": (4, 16)})
    b = p.batch_at(0)
    assert b["patches"].shape == (2, 4, 16)
    assert b["patches"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 3, tree)
    loaded, step, extra = load_checkpoint(str(tmp_path))
    assert step == 3
    assert np.array_equal(np.asarray(loaded["a"]), np.arange(6).reshape(2, 3))
    assert loaded["b"]["c"].dtype == np.dtype("bfloat16") or \
        str(loaded["b"]["c"].dtype) == "bfloat16"


def test_checkpoint_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.asarray([s])})
    assert mgr.latest() == 4
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_00000003", "step_00000004"]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(7, {"x": jnp.arange(10)})
    mgr.wait()
    tree, step, _ = mgr.restore()
    assert step == 7


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(3)})
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    lr = cosine_schedule(0.1, 5, 200)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = adamw_update(grads, opt, params, lr=lr,
                                   weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_clipping():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    grads = {"w": jnp.asarray([1e6, 1e6, 1e6])}
    p2, opt = adamw_update(grads, opt, params, lr=0.001, clip_norm=1.0,
                           weight_decay=0.0)
    # first step with clip: |update| <= lr (adam normalizes) — just finite
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_bf16_gradient_compression_changes_little():
    params = {"w": jnp.ones(64)}
    opt = adamw_init(params)
    g = {"w": jnp.linspace(0.1, 1.0, 64)}
    p1, _ = adamw_update(g, opt, params, lr=0.01, compress=None,
                         weight_decay=0.0)
    p2, _ = adamw_update(g, adamw_init(params), params, lr=0.01,
                         compress="bf16", weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               atol=1e-3)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-5)


# ---------------------------------------------------------------------------
# fault tolerance: crash/restart is bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_restart_bitexact(tmp_path):
    """Inject a failure mid-run; restarted run must produce identical
    params as an uninterrupted run (counter-addressable RNG + seekable
    data + atomic checkpoints)."""
    cfg = smoke_config(get_config("glm4_9b")).scaled(
        n_layers=1, d_model=32, d_ff=64, vocab=64, n_heads=2, n_kv_heads=2,
        head_dim=16, loss_chunks=2)
    kw = dict(steps=8, global_batch=2, seq_len=16, save_every=2, seed=1)

    p_fail, _, _ = train(cfg, ckpt_dir=str(tmp_path / "a"), fail_at=5, **kw)
    p_clean, _, _ = train(cfg, ckpt_dir=str(tmp_path / "b"), **kw)
    for a, b in zip(jax.tree.leaves(p_fail), jax.tree.leaves(p_clean)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_elastic_restore_resharding(tmp_path):
    """Checkpoint written under one 'mesh', restored as plain host arrays
    (any target sharding): values identical."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 1, tree)
    loaded, _, _ = load_checkpoint(str(tmp_path))
    assert np.array_equal(np.asarray(loaded["w"]),
                          np.arange(64, dtype=np.float32).reshape(8, 8))
