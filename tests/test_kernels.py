"""Per-kernel validation: Pallas (interpret=True on CPU) vs ref.py oracle
vs numpy golden, swept over shapes, block sizes and modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import golden, stream as stream_mod, u64, xorshift
from repro.kernels import ops, ref


def _golden_block(seed, num_streams, num_steps, mode, offset=0):
    """(T, S) golden block matching ops.thundering_bulk's stream family."""
    fam = stream_mod.new_stream(seed, 0)
    x0 = u64.join64(np.asarray(fam.x0_hi), np.asarray(fam.x0_lo))
    hh, hl = ops.h_table(seed, num_streams)
    h = np.array([u64.join64(a, b) for a, b in
                  zip(np.asarray(hh), np.asarray(hl))], dtype=object)
    return golden.thundering_block(x0, h, num_steps, mode=mode,
                                   offset=offset).T  # (T, S)


@pytest.mark.parametrize("T,S", [(8, 128), (32, 128), (64, 256), (96, 384)])
def test_ctr_kernel_matches_golden(T, S):
    out = np.asarray(ops.thundering_bulk(seed=11, num_streams=S,
                                         num_steps=T, mode="ctr"))
    exp = _golden_block(11, S, T, "ctr")
    assert np.array_equal(out, exp)


@pytest.mark.parametrize("T,S", [(8, 128), (24, 256)])
def test_faithful_kernel_matches_golden(T, S):
    out = np.asarray(ops.thundering_bulk(seed=13, num_streams=S,
                                         num_steps=T, mode="faithful"))
    exp = _golden_block(13, S, T, "faithful")
    assert np.array_equal(out, exp)


@pytest.mark.parametrize("mode", ["ctr", "faithful"])
def test_kernel_matches_ref(mode):
    """Pallas kernel == pure-jnp reference bit-for-bit."""
    a = np.asarray(ops.thundering_bulk(seed=7, num_streams=128, num_steps=32,
                                       mode=mode, use_kernel=True))
    b = np.asarray(ops.thundering_bulk(seed=7, num_streams=128, num_steps=32,
                                       mode=mode, use_kernel=False))
    assert np.array_equal(a, b)


@pytest.mark.parametrize("bt,bs", [(8, 128), (16, 128), (32, 256)])
def test_ctr_kernel_block_shape_invariance(bt, bs):
    """Output independent of the BlockSpec tiling."""
    base = np.asarray(ops.thundering_bulk(seed=3, num_streams=256,
                                          num_steps=64, mode="ctr"))
    tiled = np.asarray(ops.thundering_bulk(seed=3, num_streams=256,
                                           num_steps=64, mode="ctr",
                                           block_t=bt, block_s=bs))
    assert np.array_equal(base, tiled)


def test_faithful_kernel_tile_boundary():
    """Multi-tile T: xorshift states must chain across row tiles."""
    out = np.asarray(ops.thundering_bulk(seed=5, num_streams=128,
                                         num_steps=32, mode="faithful",
                                         block_t=8))
    exp = _golden_block(5, 128, 32, "faithful")
    assert np.array_equal(out, exp)


def test_ctr_kernel_offset():
    full = np.asarray(ops.thundering_bulk(seed=9, num_streams=128,
                                          num_steps=64, mode="ctr"))
    tail = np.asarray(ops.thundering_bulk(seed=9, num_streams=128,
                                          num_steps=32, mode="ctr",
                                          offset=32))
    assert np.array_equal(full[32:], tail)


def test_faithful_kernel_offset():
    full = np.asarray(ops.thundering_bulk(seed=9, num_streams=128,
                                          num_steps=48, mode="faithful"))
    tail = np.asarray(ops.thundering_bulk(seed=9, num_streams=128,
                                          num_steps=16, mode="faithful",
                                          offset=32))
    assert np.array_equal(full[32:], tail)


def test_bulk_matches_stream_api():
    """Column s of the ctr bulk block == ThunderStream with the same h."""
    S, T = 128, 32
    blk = np.asarray(ops.thundering_bulk(seed=21, num_streams=S,
                                         num_steps=T, mode="ctr"))
    fam = stream_mod.new_stream(21, 0)
    hh, hl = ops.h_table(21, S)
    for s in [0, 7, 127]:
        st = fam._replace(h_hi=hh[s], h_lo=hl[s])
        col = np.asarray(stream_mod.random_bits(st, (T,)))
        assert np.array_equal(blk[:, s], col)


# ---------------------------------------------------------------------------
# fused dropout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (16, 256), (4, 8, 128)])
@pytest.mark.parametrize("rate", [0.1, 0.5])
def test_fused_dropout_matches_ref(shape, rate):
    s = stream_mod.new_stream(31, 0)
    x = jnp.ones(shape, jnp.float32)
    a = np.asarray(ops.fused_dropout(x, s, rate, use_kernel=True))
    b = np.asarray(ops.fused_dropout(x, s, rate, use_kernel=False))
    assert np.array_equal(a, b)


def test_fused_dropout_rate_and_scale():
    s = stream_mod.new_stream(33, 0)
    x = jnp.ones((64, 512), jnp.float32)
    rate = 0.25
    out = np.asarray(ops.fused_dropout(x, s, rate))
    kept = out != 0
    assert abs(kept.mean() - 0.75) < 0.02
    assert np.allclose(out[kept], 1.0 / 0.75, rtol=1e-6)


def test_fused_dropout_tiling_invariance():
    """Mask depends only on (stream, element index), not on block_m."""
    s = stream_mod.new_stream(35, 0)
    x = jnp.ones((32, 128), jnp.float32)
    a = np.asarray(ops.fused_dropout(x, s, 0.3, block_m=8))
    b = np.asarray(ops.fused_dropout(x, s, 0.3, block_m=16))
    assert np.array_equal(a, b)


def test_fused_dropout_counter_advance():
    """Advancing the stream by one row's worth shifts the mask by a row."""
    s = stream_mod.new_stream(37, 0)
    x = jnp.ones((16, 128), jnp.float32)
    a = np.asarray(ops.fused_dropout(x, s, 0.4))
    b = np.asarray(ops.fused_dropout(x[:8], stream_mod.advance(s, 8 * 128), 0.4))
    assert np.array_equal(a[8:], b)


def test_fused_dropout_zero_rate_identity():
    s = stream_mod.new_stream(39, 0)
    x = jnp.arange(8 * 128, dtype=jnp.float32).reshape(8, 128)
    out = np.asarray(ops.fused_dropout(x, s, 0.0))
    assert np.array_equal(out, np.asarray(x))


def test_fused_dropout_bf16():
    s = stream_mod.new_stream(41, 0)
    x = jnp.ones((8, 256), jnp.bfloat16)
    a = np.asarray(ops.fused_dropout(x, s, 0.5, use_kernel=True).astype(jnp.float32))
    b = np.asarray(ops.fused_dropout(x, s, 0.5, use_kernel=False).astype(jnp.float32))
    assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# Monte-Carlo kernels (paper Sec. 6 case studies)
# ---------------------------------------------------------------------------

def test_pi_kernel_matches_ref():
    a = float(ops.estimate_pi(seed=1, num_lanes=128, draws_per_lane=256,
                              use_kernel=True))
    b = float(ops.estimate_pi(seed=1, num_lanes=128, draws_per_lane=256,
                              use_kernel=False))
    assert a == pytest.approx(b, abs=1e-12)


def test_pi_estimate_accuracy():
    est = float(ops.estimate_pi(seed=2, num_lanes=256, draws_per_lane=1024))
    assert abs(est - np.pi) < 0.02


def test_option_kernel_matches_ref():
    a = float(ops.price_option(seed=1, num_lanes=128, draws_per_lane=256,
                               use_kernel=True))
    b = float(ops.price_option(seed=1, num_lanes=128, draws_per_lane=256,
                               use_kernel=False))
    assert a == pytest.approx(b, rel=1e-6)


def test_option_price_matches_black_scholes():
    """MC price ~ closed-form Black-Scholes for the default params."""
    from math import erf, exp, log, sqrt

    s0, k, r, sigma, t = 100.0, 100.0, 0.05, 0.2, 1.0
    d1 = (log(s0 / k) + (r + sigma ** 2 / 2) * t) / (sigma * sqrt(t))
    d2 = d1 - sigma * sqrt(t)
    N = lambda x: 0.5 * (1 + erf(x / sqrt(2)))
    bs = s0 * N(d1) - k * exp(-r * t) * N(d2)
    mc = float(ops.price_option(seed=3, num_lanes=512, draws_per_lane=512))
    assert abs(mc - bs) / bs < 0.02


def test_pi_block_shape_invariance():
    a = float(ops.estimate_pi(seed=4, num_lanes=256, draws_per_lane=256))
    b = float(ops.estimate_pi(seed=4, num_lanes=256, draws_per_lane=256,
                              block_t=128, block_s=128))
    assert a == pytest.approx(b, abs=1e-12)


@pytest.mark.parametrize("draws", [37, 200, 777])
def test_pi_kernel_awkward_draw_count(draws):
    """T need not be a tile multiple: padded rows are masked out of the
    partial reductions (would previously assert)."""
    a = float(ops.estimate_pi(seed=6, num_lanes=130, draws_per_lane=draws,
                              use_kernel=True))
    b = float(ops.estimate_pi(seed=6, num_lanes=130, draws_per_lane=draws,
                              use_kernel=False))
    assert a == pytest.approx(b, abs=1e-12)


def test_pi_kernel_awkward_draws_multi_tile():
    """Masking composes with a multi-tile T grid (only the LAST tile has
    padded rows)."""
    a = float(ops.estimate_pi(seed=6, num_lanes=130, draws_per_lane=37,
                              use_kernel=True, block_t=8))
    b = float(ops.estimate_pi(seed=6, num_lanes=130, draws_per_lane=37,
                              use_kernel=False))
    assert a == pytest.approx(b, abs=1e-12)


def test_option_kernel_awkward_draw_count():
    a = float(ops.price_option(seed=6, num_lanes=130, draws_per_lane=37,
                               use_kernel=True))
    b = float(ops.price_option(seed=6, num_lanes=130, draws_per_lane=37,
                               use_kernel=False))
    assert a == pytest.approx(b, rel=1e-6)


# ---------------------------------------------------------------------------
# fmix32 decorrelator variant (beyond-paper §Perf/H3)
# ---------------------------------------------------------------------------

def test_ctr32_kernel_matches_ref():
    a = np.asarray(ops.thundering_bulk(seed=7, num_streams=128, num_steps=32,
                                       mode="ctr", deco="fmix32",
                                       use_kernel=True))
    b = np.asarray(ops.thundering_bulk(seed=7, num_streams=128, num_steps=32,
                                       mode="ctr", deco="fmix32",
                                       use_kernel=False))
    assert np.array_equal(a, b)


def test_ctr32_differs_from_ctr64():
    a = np.asarray(ops.thundering_bulk(seed=7, num_streams=128, num_steps=32,
                                       mode="ctr", deco="fmix32"))
    b = np.asarray(ops.thundering_bulk(seed=7, num_streams=128, num_steps=32,
                                       mode="ctr", deco="splitmix64"))
    assert not np.array_equal(a, b)


def test_ctr32_matches_host_mirror():
    from repro.core import splitmix as sm
    from repro.core import stream as stream_mod, u64 as u64m
    blk = np.asarray(ops.thundering_bulk(seed=21, num_streams=4, num_steps=8,
                                         mode="ctr", deco="fmix32"))
    blk64 = np.asarray(ops.thundering_bulk(seed=21, num_streams=4, num_steps=8,
                                           mode="ctr", deco="splitmix64"))
    hh, hl = ops.h_table(21, 4)
    for s in range(4):
        h = u64m.join64(np.asarray(hh[s]), np.asarray(hl[s]))
        for t in range(8):
            d32 = sm.ctr_decorrelator32_host(h, t)
            d64 = sm.ctr_decorrelator_host(h, t)
            # perm ^ deco relation: blk ^ deco recovers the permuted leaf
            assert (int(blk[t, s]) ^ d32) == (int(blk64[t, s]) ^ d64)


def test_ctr32_quality_battery():
    from repro.core import statistics
    blk = np.asarray(ops.thundering_bulk(seed=33, num_streams=128,
                                         num_steps=4096, mode="ctr",
                                         deco="fmix32", use_kernel=False))
    streams = blk.T[:4]
    rep = statistics.inter_stream_report(streams)
    assert rep["max_pearson"] < 4.0 / np.sqrt(4096)
    intra = statistics.intra_stream_report(streams[0])
    assert abs(intra["monobit"] - 0.5) < 0.01
    assert abs(intra["hwd"]) < 0.05
