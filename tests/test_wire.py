"""Wire v2 + pipelined-client tests: framing, negotiation, faults.

Covers the fast-path contracts the fleet rides on:

  * v1 <-> v2 cross-version roundtrip (incl. bfloat16) — byte-exact,
  * torn/oversize BINARY frame containment (same guarantees as v1),
  * hello version negotiation (max common version, v1-only fallback),
  * pipelined client against drop/slow faults: per-tenant delivery
    order preserved and response digests bit-identical to a fault-free
    run, with coalescing (max_batch>1) and standing pools enabled,
  * duplicate resubmission of an in-flight rid attaches (dedup) rather
    than re-entering the gate,
  * atomic batch journal records: a torn tail drops the WHOLE last
    microbatch, never a partial one.
"""
import socket
import struct

import numpy as np
import pytest

from repro.runtime.fault import FaultInjector, FaultPlan
from repro.service import audit, transport
from repro.service.burst import make_requests
from repro.service.fleet import FleetClient, FleetConfig
from repro.service.frontend import RandRequest
from repro.service.server import RandServer, ServerConfig


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------

def _pair():
    a, b = socket.socketpair()
    a.settimeout(10.0)
    b.settimeout(10.0)
    return a, b


def _sample_msg():
    import ml_dtypes
    return {
        "ok": True, "rid": "r/0", "n": 3,
        "f32": np.arange(12, dtype=np.float32).reshape(3, 4),
        "u32": np.arange(7, dtype=np.uint32),
        "bf16": np.arange(6).astype(ml_dtypes.bfloat16).reshape(2, 3),
    }


@pytest.mark.parametrize("version", [transport.WIRE_V1, transport.WIRE_V2])
def test_wire_roundtrip_both_versions(version):
    a, b = _pair()
    msg = _sample_msg()
    transport.send_wire(a, msg, version=version)
    got, ver = transport.recv_wire(b)
    assert ver == version
    for k in ("f32", "u32", "bf16"):
        want = msg[k]
        have = got[k] if isinstance(got[k], np.ndarray) \
            else transport.decode_array(got[k])
        assert have.dtype == want.dtype
        assert have.shape == want.shape
        assert have.tobytes() == want.tobytes()
    assert got["ok"] is True and got["rid"] == "r/0" and got["n"] == 3
    a.close(); b.close()


def test_wire_cross_version_payloads_identical():
    """The SAME message sent v1 and v2 decodes to identical bytes —
    the payload-transparency the binary/json digest pair relies on."""
    a, b = _pair()
    msg = _sample_msg()
    transport.send_wire(a, msg, version=transport.WIRE_V1)
    transport.send_wire(a, msg, version=transport.WIRE_V2)
    got1, _ = transport.recv_wire(b)
    got2, _ = transport.recv_wire(b)
    for k in ("f32", "u32", "bf16"):
        a1 = transport.decode_array(got1[k])
        a2 = got2[k]
        assert a1.dtype == a2.dtype and a1.tobytes() == a2.tobytes()
    a.close(); b.close()


def test_wire_v2_is_zero_copy_view():
    a, b = _pair()
    transport.send_wire(a, {"x": np.arange(64, dtype=np.uint32)},
                        version=transport.WIRE_V2)
    got, _ = transport.recv_wire(b)
    x = got["x"]
    assert isinstance(x, np.ndarray)
    assert x.base is not None          # a view over the recv buffer
    assert not x.flags.writeable       # frombuffer over bytes: read-only
    a.close(); b.close()


def test_wire_v2_smaller_than_v1_for_arrays():
    a, b = _pair()
    msg = {"array": np.zeros(8192, dtype=np.float32), "ok": True}
    n2 = transport.send_wire(a, msg, version=transport.WIRE_V2)
    transport.recv_wire(b)              # drain between sends: the pair's
    n1 = transport.send_wire(a, msg, version=transport.WIRE_V1)
    transport.recv_wire(b)              # kernel buffer is small

    # base64 alone is 4/3 the payload; v2 is payload + tiny header
    assert n2 < 0.80 * n1
    a.close(); b.close()


def test_wire_v2_torn_header_contained():
    a, b = _pair()
    a.sendall(bytes([transport.WIRE_MAGIC]))     # magic alone, then EOF
    a.close()
    with pytest.raises(transport.TornFrame):
        transport.recv_wire(b)
    b.close()


def test_wire_v2_torn_payload_contained():
    a, b = _pair()
    msg = {"x": np.arange(1024, dtype=np.float32)}
    # encode a full frame into a buffer, then send only a prefix
    class _Buf:
        def __init__(self): self.data = b""
        def sendall(self, d): self.data += bytes(d)
    buf = _Buf()
    transport.send_wire(buf, msg, version=transport.WIRE_V2)
    a.sendall(buf.data[:len(buf.data) - 100])
    a.close()
    with pytest.raises(transport.TornFrame):
        transport.recv_wire(b)
    b.close()


def test_wire_v2_oversize_declared_length_contained():
    a, b = _pair()
    huge = transport.MAX_FRAME + 1
    a.sendall(bytes((transport.WIRE_MAGIC, transport.WIRE_V2))
              + struct.pack("<II", 16, huge))
    with pytest.raises(transport.FrameTooLarge):
        transport.recv_wire(b)
    a.close(); b.close()


def test_wire_unknown_version_rejected():
    a, b = _pair()
    a.sendall(bytes((transport.WIRE_MAGIC, 9)) + struct.pack("<II", 0, 0))
    with pytest.raises(transport.TransportError):
        transport.recv_wire(b)
    a.close(); b.close()


# ---------------------------------------------------------------------------
# Negotiation + serving
# ---------------------------------------------------------------------------

def _host(tmp_path, *, max_batch=4, injector=None, hot=()):
    cfg = ServerConfig(max_batch=max_batch, max_delay_s=0.0,
                       hot_classes=tuple(hot))
    host = transport.ShardHost(0, config=cfg, injector=injector)
    host.add_shard(0, str(tmp_path / "shard0.jsonl"))
    return host


def _hello(addr, versions):
    with socket.create_connection(addr, timeout=10.0) as s:
        s.settimeout(10.0)
        transport.send_wire(s, {"op": "hello", "versions": versions},
                            version=transport.WIRE_V1)
        got = transport.recv_wire(s)
    assert got is not None
    return got[0]


def test_hello_negotiates_max_common_version(tmp_path):
    host = _host(tmp_path, max_batch=4)
    try:
        r = _hello(host.address, [1, 2])
        assert r["ok"] and r["version"] == transport.WIRE_V2
        assert r["max_batch"] == 4
        r = _hello(host.address, [1])
        assert r["ok"] and r["version"] == transport.WIRE_V1
        r = _hello(host.address, [99])
        assert not r["ok"]
    finally:
        host.close()


def test_shardhost_survives_torn_v2_client(tmp_path):
    host = _host(tmp_path)
    try:
        with socket.create_connection(host.address, timeout=10.0) as s:
            s.sendall(bytes([transport.WIRE_MAGIC]))   # torn v2 header
        # host must still answer on a fresh connection
        reply = transport.rpc(host.address, {"op": "ping"}, timeout=10.0)
        assert reply["ok"]
    finally:
        host.close()


def _client(host, tmp_path, **kw):
    return FleetClient(
        {0: host.address}, {0: str(tmp_path / "shard0.jsonl")},
        config=FleetConfig(num_shards=1, journal_dir=str(tmp_path)), **kw)


def test_pipelined_client_in_order_delivery(tmp_path):
    host = _host(tmp_path, hot=(("bits", "float32"),))
    try:
        reqs = make_requests(burst=48, tenants=12, seed=5)
        client = _client(host, tmp_path)
        out = client.run_shard(0, reqs)
        assert set(out) == {r.rid for r in reqs}
        assert [rid for _, rid in client.delivery_log] \
            == [r.rid for r in reqs]
        st = client.stats()
        assert st["requests"] == 48
        assert st["bytes_on_wire_per_req"] > 0
        client.close()
    finally:
        host.close()


def _digest_with_faults(tmp_path, name, plan, **client_kw):
    jdir = tmp_path / name
    jdir.mkdir()
    injector = FaultInjector(plan) if plan else None
    cfg = ServerConfig(max_batch=4, max_delay_s=0.0,
                       hot_classes=(("bits", "float32"),
                                    ("uniform", "float32")))
    host = transport.ShardHost(0, config=cfg, injector=injector)
    host.add_shard(0, str(jdir / "shard0.jsonl"))
    try:
        reqs = make_requests(burst=48, tenants=12, seed=7)
        client = FleetClient(
            {0: host.address}, {0: str(jdir / "shard0.jsonl")},
            config=FleetConfig(num_shards=1, journal_dir=str(jdir)),
            **client_kw)
        out = client.run_shard(0, reqs)
        order = [rid for _, rid in client.delivery_log]
        client.close()
    finally:
        host.close()
    assert order == [r.rid for r in reqs]      # in-order delivery held
    return audit.response_digest(out)


@pytest.mark.parametrize("faults", ["drop@13", "slow@11~0.3",
                                    "drop@5,drop@29"])
def test_pipelined_faults_preserve_order_and_bytes(tmp_path, faults):
    """drop/slow against the PIPELINED client: the burst completes,
    delivery stays in per-tenant order, and every byte matches the
    fault-free run — with coalescing and pools enabled."""
    base = _digest_with_faults(tmp_path, "base", None)
    hurt = _digest_with_faults(tmp_path, "hurt", FaultPlan.parse(faults))
    assert hurt == base


def test_duplicate_inflight_rid_attaches(tmp_path):
    """A resubmitted rid that is still pending/in-flight must attach to
    the existing gate entry (one serve, two replies) — the dedup the
    post-failover resubmission path relies on."""
    host = _host(tmp_path, max_batch=2)
    try:
        req = {"op": "request", "shard": 0, "rid": "dup/0",
               "tenant": "alice", "shape": [16], "sampler": "uniform",
               "dtype": "float32"}
        with socket.create_connection(host.address, timeout=10.0) as s1, \
                socket.create_connection(host.address, timeout=10.0) as s2:
            s1.settimeout(30.0); s2.settimeout(30.0)
            # parked (max_batch=2, only 1 pending) ...
            transport.send_wire(s1, req)
            # ... duplicate attaches as a waiter, then flush seals
            transport.send_wire(s2, dict(req))
            transport.send_wire(s2, {"op": "flush", "shard": 0})
            r1 = transport.recv_wire(s1)[0]
            r2 = transport.recv_wire(s2)[0]
            while r2.get("rid") is None:       # skip the flush ack
                r2 = transport.recv_wire(s2)[0]
        assert r1["ok"] and r2["ok"]
        a1 = transport.reply_array(r1)
        a2 = transport.reply_array(r2)
        assert a1.tobytes() == a2.tobytes()
        assert a1.shape == (16,)
        # served exactly once: journal holds ONE record for the rid
        jr = audit.Journal(str(tmp_path / "shard0.jsonl"), readonly=True)
        assert len([r for r in jr.requests() if r["rid"] == "dup/0"]) == 1
    finally:
        host.close()


def test_batch_journal_torn_tail_drops_whole_batch(tmp_path):
    """Group-committed batch records are atomic: truncating the file
    mid-line loses the WHOLE last microbatch, never part of one."""
    path = str(tmp_path / "j.jsonl")
    srv = RandServer(3, config=ServerConfig(max_batch=4,
                                            max_delay_s=0.25),
                     journal=audit.Journal(path), start=False)
    reqs = [RandRequest(tenant_id=f"t{i % 3}", shape=(8,),
                        sampler="uniform", out_dtype="float32",
                        rid=f"r/{i:03d}") for i in range(12)]
    futs = [srv.submit(r) for r in reqs]
    srv.start()          # whole burst enqueued: count-based batches of 4
    for f in futs:
        f.result(timeout=60)
    srv.shutdown()
    whole = audit.Journal(path, readonly=True)
    n_whole = len(whole.requests())
    assert n_whole == 12
    batch_lines = [ln for ln in open(path, "rb").read().splitlines()
                   if b'"batch"' in ln]
    assert len(batch_lines) == 3          # 12 requests / max_batch 4
    # tear the tail: chop into the last line
    with open(path, "rb") as f:
        raw = f.read()
    with open(path, "wb") as f:
        f.write(raw[:len(raw) - 7])
    torn = audit.Journal(path, readonly=True)
    n_torn = len(torn.requests())
    assert n_torn == 8                    # whole last batch gone
    # and what remains replays bit-identically
    replayed = audit.replay(torn, seed=3)
    assert set(replayed) == {f"r/{i:03d}" for i in range(8)}
