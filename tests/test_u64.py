"""Property tests for the u32-limb 64-bit arithmetic (vs python ints)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import u64

M64 = (1 << 64) - 1

u64_ints = st.integers(min_value=0, max_value=M64)
shift_amounts = st.integers(min_value=0, max_value=63)


def as_pair(v):
    return u64.const64(v)


def as_int(pair):
    return u64.join64(np.asarray(pair[0]), np.asarray(pair[1]))


@settings(max_examples=200, deadline=None)
@given(u64_ints, u64_ints)
def test_add64(a, b):
    assert as_int(u64.add64(as_pair(a), as_pair(b))) == (a + b) & M64


@settings(max_examples=200, deadline=None)
@given(u64_ints, u64_ints)
def test_sub64(a, b):
    assert as_int(u64.sub64(as_pair(a), as_pair(b))) == (a - b) & M64


@settings(max_examples=200, deadline=None)
@given(u64_ints, u64_ints)
def test_mul64(a, b):
    assert as_int(u64.mul64(as_pair(a), as_pair(b))) == (a * b) & M64


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=0, max_value=(1 << 32) - 1),
       st.integers(min_value=0, max_value=(1 << 32) - 1))
def test_mul32_wide_exact(a, b):
    hi, lo = u64.mul32_wide(u64.to_u32(a), u64.to_u32(b))
    assert (int(hi) << 32) | int(lo) == a * b


@settings(max_examples=100, deadline=None)
@given(u64_ints, u64_ints)
def test_xor64(a, b):
    assert as_int(u64.xor64(as_pair(a), as_pair(b))) == a ^ b


@settings(max_examples=200, deadline=None)
@given(u64_ints, shift_amounts)
def test_shr64(a, n):
    assert as_int(u64.shr64(as_pair(a), n)) == (a >> n)


@settings(max_examples=200, deadline=None)
@given(u64_ints, shift_amounts)
def test_shl64(a, n):
    assert as_int(u64.shl64(as_pair(a), n)) == (a << n) & M64


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=0, max_value=(1 << 32) - 1),
       st.integers(min_value=0, max_value=31))
def test_ror32(x, r):
    got = int(u64.ror32(u64.to_u32(x), u64.to_u32(r)))
    exp = ((x >> r) | (x << (32 - r))) & 0xFFFFFFFF
    assert got == exp


def test_vectorized_mul_matches_scalar(rng):
    a = rng.integers(0, 1 << 64, 512, dtype=np.uint64)
    b = rng.integers(0, 1 << 64, 512, dtype=np.uint64)
    pair_a = (jnp.asarray((a >> 32).astype(np.uint32)), jnp.asarray(a.astype(np.uint32)))
    pair_b = (jnp.asarray((b >> 32).astype(np.uint32)), jnp.asarray(b.astype(np.uint32)))
    hi, lo = u64.mul64(pair_a, pair_b)
    got = (np.asarray(hi).astype(np.uint64) << np.uint64(32)) | np.asarray(lo).astype(np.uint64)
    assert np.array_equal(got, a * b)


def test_eq64():
    assert bool(u64.eq64(as_pair(5), as_pair(5)))
    assert not bool(u64.eq64(as_pair(5), as_pair(6)))
    assert not bool(u64.eq64(as_pair(5), as_pair(5 + (1 << 32))))
