"""Statistical battery behaves as the paper's Tables 2-4 demand:
raw increment-parameterized LCG streams are strongly correlated; the
decorrelator (either mode) drives every measure to ~0."""
import numpy as np
import pytest

from repro.core import baselines, golden, statistics, stream

N_STEPS = 4096
N_STREAMS = 6


@pytest.fixture(scope="module")
def thundering_streams():
    s = stream.new_stream(777, 0)
    kids = stream.split(s, N_STREAMS)
    return np.stack([np.asarray(stream.random_bits(k, (N_STEPS,))) for k in kids])


@pytest.fixture(scope="module")
def raw_lcg_streams():
    return np.asarray(baselines.raw_lcg_bits(777, N_STREAMS, N_STEPS))


def test_raw_lcg_is_strongly_correlated(raw_lcg_streams):
    """Paper Table 3 'LCG Baseline': Pearson ~0.998."""
    rep = statistics.inter_stream_report(raw_lcg_streams[:4])
    assert rep["max_pearson"] > 0.9


def test_thundering_pairwise_near_zero(thundering_streams):
    """Paper Table 3 'ThundeRiNG' column: ~3e-5 at their sample size; we
    use smaller N so the null-hypothesis scale is ~1/sqrt(N)."""
    rep = statistics.inter_stream_report(thundering_streams[:4])
    bound = 4.0 / np.sqrt(N_STEPS)
    assert rep["max_pearson"] < bound
    assert rep["max_spearman"] < bound
    assert abs(rep["max_kendall"]) < 0.1


def test_thundering_intra_stream_battery(thundering_streams):
    for row in thundering_streams:
        rep = statistics.intra_stream_report(row)
        assert abs(rep["monobit"] - 0.5) < 0.01
        assert rep["byte_chi2_p"] > 1e-4
        assert abs(rep["runs_z"]) < 4.0
        assert abs(rep["lag1_autocorr"]) < 0.05
        assert abs(rep["hwd"]) < 0.05


def test_decorrelation_reduces_hwd():
    """Paper Table 4: LCG/LCG+permutation fail HWD; +decorrelation passes."""
    lcg_only = np.asarray(baselines.raw_lcg_bits(777, 4, N_STEPS, permute=True))
    inter_lcg = statistics.interleave(lcg_only)
    s = stream.new_stream(777, 0)
    kids = stream.split(s, 4)
    thunder = np.stack([np.asarray(stream.random_bits(k, (N_STEPS,))) for k in kids])
    inter_thunder = statistics.interleave(thunder)
    # interleaved streams sharing a root without decorrelation have strong
    # adjacent-output HWD; with decorrelation it's statistical noise
    assert abs(statistics.hamming_weight_dependency(inter_thunder)) < 0.05
    assert abs(statistics.hamming_weight_dependency(inter_lcg)) > \
        abs(statistics.hamming_weight_dependency(inter_thunder))


def test_faithful_mode_quality():
    """The paper-faithful xorshift decorrelator path also passes."""
    h = np.array([2 * i for i in range(4)], dtype=object)
    blk = golden.thundering_block(0x9E3779B97F4A7C15, h, N_STEPS, mode="faithful")
    rep = statistics.inter_stream_report(blk)
    assert rep["max_pearson"] < 4.0 / np.sqrt(N_STEPS)
    for row in blk:
        intra = statistics.intra_stream_report(row)
        assert abs(intra["monobit"] - 0.5) < 0.01


def test_ablation_ordering_matches_paper_table3():
    """Correlation ordering: LCG baseline >> LCG+perm > full pipeline.

    Paper Table 3: baseline 0.998, +permutation 0.00019, full 0.00003."""
    n = 2048
    lcg_raw = np.asarray(baselines.raw_lcg_bits(42, 3, n))
    lcg_perm = np.asarray(baselines.raw_lcg_bits(42, 3, n, permute=True,
                                                 h_mode="spread"))
    s = stream.new_stream(42, 0)
    kids = stream.split(s, 3)
    full = np.stack([np.asarray(stream.random_bits(k, (n,))) for k in kids])
    p_raw = statistics.inter_stream_report(lcg_raw)["max_pearson"]
    p_perm = statistics.inter_stream_report(lcg_perm)["max_pearson"]
    p_full = statistics.inter_stream_report(full)["max_pearson"]
    assert p_raw > 0.9
    assert p_perm < 0.1
    assert p_full < 0.1


def test_permutation_alone_keeps_hwd():
    """Paper Table 4: permutation does NOT fix Hamming-weight dependency of
    adjacent-offset streams; the decorrelator does."""
    n = 2048
    perm_only = np.asarray(baselines.raw_lcg_bits(42, 4, n, permute=True))
    inter = statistics.interleave(perm_only)
    assert abs(statistics.hamming_weight_dependency(inter)) > 0.2


def test_baseline_philox_quality():
    bits = np.asarray(baselines.philox_bits(123, 4, N_STEPS))
    rep = statistics.inter_stream_report(bits)
    assert rep["max_pearson"] < 4.0 / np.sqrt(N_STEPS)


def test_baseline_xoroshiro_quality():
    bits = np.asarray(baselines.xoroshiro_bits(123, 4, 2048))
    rep = statistics.inter_stream_report(bits)
    assert rep["max_pearson"] < 4.0 / np.sqrt(2048)


def test_baseline_pcg_xsh_rs_runs():
    bits = np.asarray(baselines.pcg_xsh_rs_bits(123, 4, 1024))
    assert bits.shape == (4, 1024)
    rep = statistics.intra_stream_report(bits[0])
    assert abs(rep["monobit"] - 0.5) < 0.02


# ---------------------------------------------------------------------------
# edge cases: degenerate inputs must return defined values, not NaN/raise
# ---------------------------------------------------------------------------

def test_pearson_constant_input_is_zero():
    const = np.full(64, 0xDEADBEEF, dtype=np.uint32)
    varying = np.arange(64, dtype=np.uint32) << 24
    assert statistics.pearson(const, varying) == 0.0
    assert statistics.pearson(const, const) == 0.0
    assert np.isfinite(statistics.pearson(const, const))


def test_spearman_constant_and_short_input():
    const = np.full(64, 7, dtype=np.uint32)
    varying = np.arange(64, dtype=np.uint32)
    # constant VALUES still rank 0..n-1 under stable argsort-of-argsort
    # ranking, so only the n < 2 guard applies; it must not raise or NaN
    assert np.isfinite(statistics.spearman(const, varying))
    assert statistics.spearman(np.array([1], np.uint32),
                               np.array([2], np.uint32)) == 0.0
    assert statistics.spearman(np.array([], np.uint32),
                               np.array([], np.uint32)) == 0.0


def test_kendall_below_two_elements_is_zero():
    one = np.array([5], dtype=np.uint32)
    assert statistics.kendall(one, one) == 0.0
    empty = np.array([], dtype=np.uint32)
    assert statistics.kendall(empty, empty) == 0.0


def test_byte_chi2_short_inputs():
    assert statistics.byte_chi2_pvalue(np.array([], np.uint32)) == 1.0
    p = statistics.byte_chi2_pvalue(np.array([1, 2, 3], np.uint32))
    assert 0.0 < p <= 1.0


# ---------------------------------------------------------------------------
# p-value primitives (promoted for the Crush-lite battery)
# ---------------------------------------------------------------------------

def test_chi2_sf_known_values():
    # scipy.stats.chi2.sf reference points
    assert abs(statistics.chi2_sf(3.841458820694124, 1) - 0.05) < 1e-9
    assert abs(statistics.chi2_sf(11.0705, 5) - 0.05) < 1e-5
    assert abs(statistics.chi2_sf(255.0, 255) - 0.4882) < 1e-3
    assert statistics.chi2_sf(0.0, 10) == 1.0
    assert statistics.chi2_sf(1e4, 10) < 1e-300 or \
        statistics.chi2_sf(1e4, 10) >= 0.0


def test_normal_sf_known_values():
    assert abs(statistics.normal_sf(0.0) - 0.5) < 1e-12
    assert abs(statistics.normal_sf(1.959963985) - 0.025) < 1e-9


def test_poisson_tails():
    # P(X <= 8 | lam=8) ~ 0.5925 (wolfram)
    assert abs(statistics.poisson_cdf(8, 8.0) - 0.59255) < 1e-4
    assert statistics.poisson_cdf(-1, 8.0) == 0.0
    assert statistics.poisson_two_sided(8, 8.0) == 1.0
    # far tails reject
    assert statistics.poisson_two_sided(100, 8.0) < 1e-9
    assert statistics.poisson_two_sided(0, 50.0) < 1e-9


def test_ks_uniform_pvalue_calibration():
    grid = (np.arange(200) + 0.5) / 200.0  # perfectly uniform
    assert statistics.ks_uniform_pvalue(grid) > 0.99
    assert statistics.ks_uniform_pvalue(grid ** 4) < 1e-6
    assert statistics.ks_uniform_pvalue(np.array([])) == 1.0


def test_interleave_roundtrip():
    x = np.arange(12, dtype=np.uint32).reshape(3, 4)
    inter = statistics.interleave(x)
    assert inter.tolist() == [0, 4, 8, 1, 5, 9, 2, 6, 10, 3, 7, 11]


# ---------------------------------------------------------------------------
# production stream counts: S = 2**16 over the SHARDED path (ROADMAP
# quality item) — paper Tables 3/4 at scale for the ctr decorrelator in
# both hash variants (splitmix64 and the cheaper fmix32)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("deco", ["splitmix64", "fmix32"])
def test_sharded_battery_at_production_stream_count(deco):
    """Generate S = 2**16 streams through generate_sharded and run the
    inter-stream pairwise + Hamming-weight tables on a spread probe set
    (first/last/adjacent/mid columns — exhaustive S^2 pairing is not the
    paper's method either; Table 3 reports max over sampled pairs)."""
    from repro.core import engine

    S, T = 2 ** 16, 1024
    plan = engine.make_plan(seed=20260726, num_streams=S, num_steps=T,
                            mode="ctr", deco=deco)
    blk = np.asarray(engine.generate_sharded(plan))
    assert blk.shape == (T, S)
    # sharded == single-device on the same plan (spot columns)
    direct = np.asarray(engine.generate(plan, backend="xla"))
    assert np.array_equal(blk[:, :: S // 8], direct[:, :: S // 8])

    # probe streams: adjacent pairs at both ends + spread interior
    probe_ids = [0, 1, S // 3, S // 2, S - 2, S - 1]
    probes = blk[:, probe_ids].T.copy()          # (6, T)
    rep = statistics.inter_stream_report(probes)
    bound = 4.0 / np.sqrt(T)
    assert rep["max_pearson"] < bound, rep
    assert rep["max_spearman"] < bound, rep
    assert abs(rep["max_kendall"]) < 0.1, rep
    assert abs(rep["interleaved_hwd"]) < 0.05, rep
    assert abs(rep["interleaved_monobit"] - 0.5) < 0.01, rep
    assert rep["interleaved_chi2_p"] > 1e-4, rep

    # Hamming-weight table over a WIDE interleave: 512 consecutive
    # streams round-robin (the Li-et-al inter-stream method at width)
    wide = statistics.interleave(blk[:, 4096:4608].T.copy())
    assert abs(statistics.hamming_weight_dependency(wide)) < 0.05

    # intra-stream battery on the probes
    for row in probes:
        intra = statistics.intra_stream_report(row)
        assert abs(intra["monobit"] - 0.5) < 0.02, intra
        assert intra["byte_chi2_p"] > 1e-4, intra
        assert abs(intra["runs_z"]) < 4.5, intra
        assert abs(intra["lag1_autocorr"]) < 0.1, intra
        assert abs(intra["hwd"]) < 0.1, intra
