"""Paper Figs. 8-9 / Table 7 analogue: the two Monte-Carlo case studies.

pi estimation and Black-Scholes option pricing, each in two builds:
  * thundering — ThundeRiNG ctr pipeline fused into the integrand
    (the kernels' ref path: generation never leaves registers/VMEM)
  * vendor    — the same integrand drawing from jax.random (threefry),
    the 'cuRAND equivalent' on this substrate.

Reported: wall time, throughput, and |error| vs the analytic value —
matching the paper's accuracy-at-throughput story.
"""
from __future__ import annotations

import functools
from math import erf, exp, log, pi, sqrt

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.kernels import ops

LANES = 2048
DRAWS = 2048  # per lane -> 4.2M draws total


@functools.partial(jax.jit, static_argnames=("n",))
def _pi_vendor(n: int):
    key = jax.random.PRNGKey(0)
    xy = jax.random.uniform(key, (2, n))
    inside = jnp.sum((xy[0] ** 2 + xy[1] ** 2) < 1.0)
    return 4.0 * inside / n


@functools.partial(jax.jit, static_argnames=("n",))
def _opt_vendor(n: int, s0=100.0, k=100.0, r=0.05, sigma=0.2, t=1.0):
    key = jax.random.PRNGKey(1)
    z = jax.random.normal(key, (n,))
    st = s0 * jnp.exp((r - sigma ** 2 / 2) * t + sigma * jnp.sqrt(t) * z)
    return jnp.mean(jnp.maximum(st - k, 0.0)) * jnp.exp(-r * t)


def _bs_closed(s0=100.0, k=100.0, r=0.05, sigma=0.2, t=1.0):
    d1 = (log(s0 / k) + (r + sigma ** 2 / 2) * t) / (sigma * sqrt(t))
    d2 = d1 - sigma * sqrt(t)
    N = lambda x: 0.5 * (1 + erf(x / sqrt(2)))
    return s0 * N(d1) - k * exp(-r * t) * N(d2)


def run(out):
    n = LANES * DRAWS
    # pi
    f_t = functools.partial(ops.estimate_pi, seed=5, num_lanes=LANES,
                            draws_per_lane=DRAWS, use_kernel=False)
    sec = time_fn(lambda: f_t(), iters=3)
    est = float(f_t())
    out(row("apps/pi/thundering", sec * 1e6,
            f"{n / sec / 1e6:.1f} Mdraw/s err={abs(est - pi):.2e}"))
    sec = time_fn(_pi_vendor, n, iters=3)
    est = float(_pi_vendor(n))
    out(row("apps/pi/vendor_threefry", sec * 1e6,
            f"{n / sec / 1e6:.1f} Mdraw/s err={abs(est - pi):.2e}"))
    # option pricing
    bs = _bs_closed()
    f_o = functools.partial(ops.price_option, seed=5, num_lanes=LANES,
                            draws_per_lane=DRAWS, use_kernel=False)
    sec = time_fn(lambda: f_o(), iters=3)
    est = float(f_o())
    out(row("apps/option/thundering", sec * 1e6,
            f"{n / sec / 1e6:.1f} Mdraw/s err={abs(est - bs) / bs:.2e}"))
    sec = time_fn(_opt_vendor, n, iters=3)
    est = float(_opt_vendor(n))
    out(row("apps/option/vendor_threefry", sec * 1e6,
            f"{n / sec / 1e6:.1f} Mdraw/s err={abs(est - bs) / bs:.2e}"))
