# One function per paper table. Prints ``name,us_per_call,derived`` CSV
# and dumps the machine-readable perf trajectory to BENCH_throughput.json
# (GSample/s per backend/sampler/dtype/variant).
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import apps, comparison, quality, roofline, throughput

    rows = []
    records = []

    def out(line: str):
        rows.append(line)
        print(line, flush=True)

    print("name,us_per_call,derived")
    suites = [
        ("quality", quality.run),          # Tables 2/3/4
        ("throughput",                     # Figs 5/6 + fused samplers
         lambda o: throughput.run(o, records=records)),
        ("pipelined",                      # block delivery: FIFO analogue
         lambda o: throughput.pipelined_smoke(o, records=records)),
        ("service",                        # randomness-as-a-service burst
         lambda o: throughput.service_smoke(o, records=records)),
        ("comparison", comparison.run),    # Tables 5/6
        ("apps", apps.run),                # Figs 8/9 + Table 7
        ("roofline",                       # GSample/s vs bandwidth bound
         lambda o: roofline.run(o, records=records)),
    ]
    t0 = time.time()
    failures = 0
    for name, fn in suites:
        try:
            fn(out)
        except Exception as e:  # pragma: no cover
            failures += 1
            out(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
    if records:
        throughput.write_bench_json(records)
        print(f"# wrote {throughput.BENCH_JSON} ({len(records)} rows)",
              flush=True)
    print(f"# {len(rows)} rows in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
