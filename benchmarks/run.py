# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import apps, comparison, quality, roofline, throughput

    rows = []

    def out(line: str):
        rows.append(line)
        print(line, flush=True)

    print("name,us_per_call,derived")
    suites = [
        ("quality", quality.run),          # Tables 2/3/4
        ("throughput", throughput.run),    # Figs 5/6
        ("comparison", comparison.run),    # Tables 5/6
        ("apps", apps.run),                # Figs 8/9 + Table 7
        ("roofline", roofline.run),        # deliverable (g)
    ]
    t0 = time.time()
    failures = 0
    for name, fn in suites:
        try:
            fn(out)
        except Exception as e:  # pragma: no cover
            failures += 1
            out(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
    print(f"# {len(rows)} rows in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
