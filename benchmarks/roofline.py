"""Deliverable (g): roofline table from the dry-run artifacts.

Reads experiments/dryrun/*.json (produced by ``repro.launch.dryrun``) and
emits one row per (arch x shape x mesh) with the three roofline terms,
the dominant bottleneck and the useful-flops ratio.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def run(out):
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        out(row("roofline/none", 0.0,
                "no dry-run artifacts; run python -m repro.launch.dryrun"))
        return
    for f in files:
        with open(f) as fh:
            rep = json.load(fh)
        tag = os.path.basename(f)[:-5]
        if rep.get("skipped"):
            out(row(f"roofline/{tag}", 0.0, "SKIP " + rep["skipped"][:60]))
            continue
        if rep.get("error"):
            out(row(f"roofline/{tag}", 0.0, "FAIL " + rep["error"][:80]))
            continue
        r = rep["roofline"]
        mem = rep["memory"].get("total_bytes_per_device", 0) / 2 ** 30
        out(row(
            f"roofline/{tag}", 0.0,
            f"compute={r['compute_s'] * 1e3:.1f}ms"
            f" memory={r['memory_s'] * 1e3:.1f}ms"
            f" collective={r['collective_s'] * 1e3:.1f}ms"
            f" bottleneck={r['bottleneck'].replace('_s', '')}"
            f" useful_ratio={r['useful_flops_ratio']:.2f}"
            f" mem/dev={mem:.2f}GiB"))
