"""Roofline harness: achieved GSample/s against the memory-bandwidth bound.

The paper's 655 GSample/s is a *bandwidth* statement: generation state is
on-chip, so the only mandatory memory traffic is WRITING the samples, and
the attainable rate is ``device_bandwidth / bytes_per_sample`` (205
GSample/s for u32/f32 on one 819 GB/s v5e chip, 410 for bf16, 3.3 T for
bernoulli bool).  This harness measures what the repo actually delivers
and reports it as a fraction of that bound, per variant:

  * ``single``       — one jitted ``engine.generate`` per window (the
    seed baseline every other variant must beat),
  * ``fused_w{W}``   — one jitted ``engine.generate_windows`` emitting W
    windows per dispatch (amortized launch path),
  * ``producer_d1``  — the standing ``BlockProducer`` at depth=1 (the
    delivery layer's own baseline: thread + lease + queue overhead),
  * ``donated_d{D}`` — depth-D producer cycling a fixed donated buffer
    ring (allocation-free steady state).

Bandwidth comes from a table of known TPU/GPU parts keyed on
``device_kind``; on anything unrecognized (CPU CI) a measured jitted
stream (read + write of a ~64 MiB buffer) stands in, tagged
``measured:`` so rows are honest about the bound's provenance.  Every
row lands in BENCH_throughput.json with ``roofline_pct`` and the paper's
655 GSample/s reference.

``check()`` is the CI gate: fused-W must hold >= ``CHECK_RATIO`` of the
single-window rate and donated-depth >= the same ratio of producer_d1 —
i.e. the optimized paths never regress below their OWN baseline tier
(donated rings race the producer machinery, not raw jit dispatch, which
a 1-CPU container could never honor).

``dryrun_rows`` keeps the previous deliverable: re-printing the
experiments/dryrun model-roofline artifacts when present.
"""
from __future__ import annotations

import functools
import glob
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import (bytes_per_sample, row, time_fn_stats,
                               write_bench_json)
from repro.core import engine
from repro.runtime.blocks import BlockService, donation_supported

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")

PAPER_GSAMPLES = 655.0   # U250 @ 2560 streams, paper Fig. 6
CHECK_RATIO = 0.75       # CI gate: optimized >= 75% of its baseline tier

# device_kind substring (lowercased) -> HBM/memory bandwidth, bytes/s.
# First match wins; keep more specific parts before their prefixes.
KNOWN_BW = (
    ("v6e", 1640e9), ("v6 lite", 1640e9), ("trillium", 1640e9),
    ("v5p", 2765e9), ("v5e", 819e9), ("v5 lite", 819e9),
    ("v4", 1228e9), ("v3", 900e9), ("v2", 700e9),
    ("h100", 3350e9), ("a100", 2039e9), ("v100", 900e9),
)

# (sampler, out_dtype) classes swept by the full run; smoke takes [:2].
CASES = (
    ("bits", "float32"),        # 4 B/sample  (uint32)
    ("uniform", "bfloat16"),    # 2 B/sample
    ("normal", "float32"),      # 4 B/sample
    ("bernoulli(0.5)", "float32"),  # 1 B/sample (bool)
)


def _measured_bandwidth(nbytes: int = 1 << 26) -> float:
    """Streaming bytes/s of a jitted elementwise pass (read + write)."""
    x = jnp.zeros((nbytes // 4,), jnp.uint32)
    f = jax.jit(lambda a: a + jnp.uint32(1))
    st = time_fn_stats(f, x, iters=5, warmup=2)
    return 2.0 * x.nbytes / st["median_s"]


def detect_bandwidth() -> tuple:
    """(bytes_per_s, source) for device 0 — part table, else measured."""
    kind = jax.devices()[0].device_kind
    low = kind.lower()
    for sub, bw in KNOWN_BW:
        if sub in low:
            return bw, f"table:{kind}"
    return _measured_bandwidth(), f"measured:{kind}"


def _producer_pass(svc: BlockService, name: str, t: int, n_blocks: int,
                   **prod_kw):
    """One full producer drain (n_blocks fresh windows), for timing."""
    def one_pass():
        last = None
        with svc.producer(name, t, count=n_blocks, **prod_kw) as prod:
            for _, blk in prod:
                last = blk
        return jax.block_until_ready(last)
    return one_pass


def run(out, records=None, *, s: int = 2048, t: int = 2048,
        n_blocks: int = 8, fuse_widths=(4, 8), depths=(2, 4),
        cases=CASES, iters: int = 3) -> None:
    """The engine roofline sweep + the legacy dryrun reprint."""
    bw, bw_src = detect_bandwidth()
    out(row("roofline/bandwidth", 0.0,
            f"{bw / 1e9:.0f} GB/s ({bw_src}); paper ref "
            f"{PAPER_GSAMPLES:.0f} GSample/s"))
    donate_ok = donation_supported()
    if not donate_ok:
        out(row("roofline/donation", 0.0,
                f"donation is a no-op on {jax.default_backend()}; "
                f"donated_d* rows skipped"))

    for sampler, out_dtype in cases:
        bps = bytes_per_sample(sampler, out_dtype)
        bound = bw / bps / 1e9          # GSample/s the memory system allows
        plan = engine.make_plan(seed=31, num_streams=s, num_steps=t,
                                sampler=sampler, out_dtype=out_dtype)
        backend = engine.select_backend(plan)
        tag = f"{sampler}/{out_dtype}"

        def rec(variant, st, samples, **extra):
            # achieved = best of the steady-state passes: a roofline
            # asks what the path CAN sustain, and min-time is far more
            # robust to scheduler jitter (1-CPU CI shares the core
            # between producer and consumer threads) than a median of
            # few passes.  us_per_call stays the median.
            gs = samples / st["best_s"] / 1e9
            pct = gs / bound
            out(row(f"roofline/{tag}/{variant}", st["us_per_call"],
                    f"{gs:.3f} GSample/s = {pct:.1%} of "
                    f"{bound:.0f} bound ({bps:.0f} B/sample)"))
            if records is not None:
                records.append(dict(
                    name=f"roofline/{tag}/S={s}", backend=backend,
                    sampler=sampler, dtype=out_dtype, variant=variant,
                    num_streams=s, num_steps=t,
                    us_per_call=st["us_per_call"],
                    compile_us=st["compile_us"], gsamples_per_s=gs,
                    bytes_per_sample=bps, gbytes_per_s=gs * bps,
                    bound_gsamples_per_s=bound, roofline_pct=pct,
                    bandwidth_gbytes_per_s=bw / 1e9,
                    bandwidth_source=bw_src,
                    paper_gsamples_per_s=PAPER_GSAMPLES, **extra))
            return gs

        # single jitted window: the dispatch-path baseline
        fn1 = jax.jit(functools.partial(engine.generate, plan,
                                        backend=backend))
        rec("single", time_fn_stats(fn1, iters=iters), s * t)

        # fused multi-window dispatches
        for w in fuse_widths:
            fnw = jax.jit(functools.partial(engine.generate_windows, plan,
                                            w, backend=backend))
            rec(f"fused_w{w}", time_fn_stats(fnw, iters=iters), w * s * t,
                fuse=w)

        # delivery layer: producers at each depth with donation off then
        # on — donated_dD races producer_dD, its equal-depth twin, so
        # the gate isolates the donation cost from queue-depth effects.
        # One standing service — successive timed passes consume fresh
        # windows through one cached window executable; producer passes
        # get extra iters because best-of must out-vote thread jitter.
        svc = BlockService(seed=31)
        svc.open("roofline", num_streams=s, sampler=sampler,
                 out_dtype=out_dtype)
        p_iters = iters + 2
        for d in sorted(set((1,) + tuple(depths))):
            one = _producer_pass(svc, "roofline", t, n_blocks, depth=d)
            rec(f"producer_d{d}",
                time_fn_stats(one, iters=p_iters, warmup=1),
                n_blocks * s * t, depth=d)
            if donate_ok and d in depths:
                one = _producer_pass(svc, "roofline", t, n_blocks,
                                     depth=d, donate=True)
                rec(f"donated_d{d}",
                    time_fn_stats(one, iters=p_iters, warmup=1),
                    n_blocks * s * t, depth=d, donate=True)

    dryrun_rows(out)


def smoke(out=print, records=None) -> None:
    """CI-sized roofline: two classes, small blocks, one fused width and
    one donated depth — enough to populate roofline_pct rows and drive
    ``check()`` without multi-minute CPU sweeps."""
    run(out, records, s=256, t=512, n_blocks=8, fuse_widths=(4,),
        depths=(2,), cases=CASES[:2], iters=3)


def check(records) -> list:
    """The regression gate: each optimized variant vs its baseline tier.

    Returns a list of human-readable failures (empty = pass): fused-W
    below ``CHECK_RATIO`` x single, or donated-depth below
    ``CHECK_RATIO`` x producer_d1, per (sampler, dtype) row group.
    """
    groups = {}
    for r in records:
        if not str(r.get("name", "")).startswith("roofline/"):
            continue
        key = (r["sampler"], r["dtype"])
        groups.setdefault(key, {})[r["variant"]] = r["gsamples_per_s"]
    failures = []
    for key, g in sorted(groups.items()):
        for variant, gs in sorted(g.items()):
            if variant.startswith("fused_"):
                base_name = "single"
            elif variant.startswith("donated_d"):
                # equal-depth producer twin, else the depth-1 baseline
                d = variant[len("donated_d"):]
                base_name = (f"producer_d{d}"
                             if f"producer_d{d}" in g else "producer_d1")
            else:
                continue
            base = g.get(base_name)
            if base and gs < CHECK_RATIO * base:
                failures.append(
                    f"{key[0]}/{key[1]}: {variant} {gs:.3f} GSample/s "
                    f"< {CHECK_RATIO:.0%} of {base_name} {base:.3f}")
    return failures


def dryrun_rows(out) -> None:
    """Legacy deliverable (g): model-roofline rows from the dry-run
    artifacts in experiments/dryrun/*.json, when present."""
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        out(row("roofline/dryrun/none", 0.0,
                "no dry-run artifacts; run python -m repro.launch.dryrun"))
        return
    for f in files:
        with open(f) as fh:
            rep = json.load(fh)
        tag = os.path.basename(f)[:-5]
        if rep.get("skipped"):
            out(row(f"roofline/{tag}", 0.0, "SKIP " + rep["skipped"][:60]))
            continue
        if rep.get("error"):
            out(row(f"roofline/{tag}", 0.0, "FAIL " + rep["error"][:80]))
            continue
        r = rep["roofline"]
        mem = rep["memory"].get("total_bytes_per_device", 0) / 2 ** 30
        out(row(
            f"roofline/{tag}", 0.0,
            f"compute={r['compute_s'] * 1e3:.1f}ms"
            f" memory={r['memory_s'] * 1e3:.1f}ms"
            f" collective={r['collective_s'] * 1e3:.1f}ms"
            f" bottleneck={r['bottleneck'].replace('_s', '')}"
            f" useful_ratio={r['useful_flops_ratio']:.2f}"
            f" mem/dev={mem:.2f}GiB"))


if __name__ == "__main__":
    import sys
    argv = sys.argv[1:]
    do_check = "--check" in argv
    full = "--full" in argv
    unknown = set(argv) - {"--check", "--full"}
    if unknown:
        raise SystemExit(f"unknown flag(s) {sorted(unknown)}; "
                         f"have --check, --full")
    records: list = []
    if full:
        run(print, records)
    else:
        smoke(print, records)
    write_bench_json(records, merge=True)
    print(f"# merged {len(records)} roofline rows into "
          f"BENCH_throughput.json")
    if do_check:
        failures = check(records)
        for f in failures:
            print(f"CHECK FAIL: {f}")
        if failures:
            sys.exit(1)
        print(f"# check OK: fused/donated within {CHECK_RATIO:.0%} of "
              f"their baselines")
