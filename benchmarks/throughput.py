"""Paper Figs. 5-6 analogue: bulk MISRN throughput vs number of stream
instances.

The paper scales SOU instances on a U250 (up to 655 Gnum/s).  Here the
jnp reference path (the same arithmetic the Pallas kernel runs per tile)
executes on the host CPU; the figure of merit is throughput scaling with
S (the state-sharing claim: cost per stream is one add + output stage —
adding streams must scale ~linearly until bandwidth saturates) plus the
projected TPU bound (bulk generation writes 4 B/sample; one v5e chip at
819 GB/s is HBM-bound at ~205 Gsample/s; the fused-consumer kernels in
benchmarks/apps.py beat that by never writing the samples).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import engine
from repro.kernels import ops

T_STEPS = 4096
HBM_BW = 819e9


@functools.partial(jax.jit, static_argnames=("s", "t", "mode", "deco",
                                             "backend"))
def _bulk(s: int, t: int, mode: str, deco: str = "splitmix64",
          backend: str = "ref"):
    return ops.thundering_bulk(seed=7, num_streams=s, num_steps=t,
                               mode=mode, deco=deco, backend=backend)


def run(out):
    prev = None
    for s in (128, 512, 2048, 8192):
        sec = time_fn(_bulk, s, T_STEPS, "ctr", iters=3)
        samples = s * T_STEPS
        gs = samples / sec / 1e9
        scale = f" x{gs / prev:.2f}" if prev else ""
        prev = gs
        out(row(f"throughput/ctr/S={s}", sec * 1e6,
                f"{gs:.3f} GSample/s host{scale}"))
    # faithful mode (serial xorshift decorrelator) at one size
    sec = time_fn(_bulk, 512, T_STEPS, "faithful", iters=3)
    gs = 512 * T_STEPS / sec / 1e9
    out(row("throughput/faithful/S=512", sec * 1e6,
            f"{gs:.3f} GSample/s host"))
    # fmix32 decorrelator (beyond-paper; 96 -> 30 uint ops/sample)
    sec64 = time_fn(_bulk, 2048, T_STEPS, "ctr", iters=3)
    sec32 = time_fn(_bulk, 2048, T_STEPS, "ctr", "fmix32", iters=3)
    gs = 2048 * T_STEPS / sec32 / 1e9
    out(row("throughput/ctr_fmix32/S=2048", sec32 * 1e6,
            f"{gs:.3f} GSample/s host x{sec64 / sec32:.2f} vs splitmix64"))
    # engine dispatch overhead: same plan through ref vs xla backends
    sec_ref = time_fn(_bulk, 2048, T_STEPS, "ctr", "splitmix64", "ref",
                      iters=3)
    sec_xla = time_fn(_bulk, 2048, T_STEPS, "ctr", "splitmix64", "xla",
                      iters=3)
    out(row("throughput/engine_xla/S=2048", sec_xla * 1e6,
            f"{2048 * T_STEPS / sec_xla / 1e9:.3f} GSample/s host "
            f"x{sec_ref / sec_xla:.2f} vs ref backend"))
    out(row("throughput/tpu_projection", 0.0,
            f"bulk HBM-bound {HBM_BW / 4 / 1e9:.0f} GSample/s/chip;"
            f" paper FPGA 655 Gnum/s"))


def smoke(out=print) -> None:
    """CI-sized sanity run: one small block per backend, bit-equal check."""
    import numpy as np

    plan = engine.make_plan(seed=7, num_streams=256, num_steps=64)
    base = np.asarray(engine.generate(plan, backend="ref"))
    for backend in ("xla", "pallas"):
        sec = time_fn(functools.partial(engine.generate, plan,
                                        backend=backend), iters=1)
        same = np.array_equal(base, np.asarray(engine.generate(
            plan, backend=backend)))
        assert same, f"{backend} disagrees with ref"
        out(row(f"smoke/{backend}", sec * 1e6, "bit-equal to ref"))
    sec = time_fn(functools.partial(engine.generate_sharded, plan), iters=1)
    assert np.array_equal(base, np.asarray(engine.generate_sharded(plan)))
    out(row("smoke/sharded", sec * 1e6,
            f"bit-equal over {len(jax.devices())} device(s)"))


if __name__ == "__main__":
    smoke()
