"""Paper Figs. 5-6 analogue: bulk MISRN throughput vs number of stream
instances, plus the fused sampler pipeline.

The paper scales SOU instances on a U250 (up to 655 Gnum/s).  Here the
jnp reference path (the same arithmetic the Pallas kernel runs per tile)
executes on the host CPU; the figure of merit is throughput scaling with
S (the state-sharing claim: cost per stream is one add + output stage —
adding streams must scale ~linearly until bandwidth saturates) plus the
projected TPU bound (bulk generation writes 4 B/sample; one v5e chip at
819 GB/s is HBM-bound at ~205 Gsample/s; bf16 fused sampling halves the
written bytes -> ~410 GSample/s ceiling; the fused-consumer kernels in
benchmarks/apps.py beat both by never writing the samples).

``run``/``smoke``/``sampler_smoke``/``dist_smoke``/``pipelined_smoke``/
``service_smoke`` also append machine-readable row dicts (GSample/s per
backend/sampler/dtype/variant; jitted rows carry ``compile_us`` so
``us_per_call`` is always steady state) that ``run.py`` and ``__main__``
dump to ``BENCH_throughput.json`` — the perf trajectory file.  The
sampler section times the fused one-pass path
(transform applied where the bits are generated) against the historical
two-pass path (uint32 block materialized by one jitted call, transformed
by a second), which is the HBM round-trip the sampler stage deletes.
``pipelined_smoke`` times the block-delivery layer: double-buffered
producer vs synchronous lease+generate, and the 1-D vs 2-D mesh rows.
``service_smoke`` times the randomness-as-a-service layer: a mixed
multi-tenant burst through the coalescing frontend + standing pool
(requests/s, p50/p99 latency, coalescing factor).
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (BENCH_JSON, bytes_per_sample, row, time_fn,
                               time_fn_stats, write_bench_json)
from repro.core import engine, sampler as sampler_mod
from repro.kernels import ops
from repro.runtime import BlockService

T_STEPS = 4096
HBM_BW = 819e9

SAMPLER_CASES = (
    ("uniform", "float32"),
    ("uniform", "bfloat16"),
    ("normal", "float32"),
    ("normal", "bfloat16"),
    ("bernoulli(0.5)", "float32"),
)

@functools.partial(jax.jit, static_argnames=("s", "t", "mode", "deco",
                                             "backend"))
def _bulk(s: int, t: int, mode: str, deco: str = "splitmix64",
          backend: str = "ref"):
    return ops.thundering_bulk(seed=7, num_streams=s, num_steps=t,
                               mode=mode, deco=deco, backend=backend)


@functools.partial(jax.jit, static_argnames=("s", "t", "sampler", "dtype",
                                             "backend"))
def _fused(s: int, t: int, sampler: str, dtype: str, backend: str):
    plan = engine.make_plan(seed=7, num_streams=s, num_steps=t,
                            sampler=sampler, out_dtype=dtype)
    return engine.generate(plan, backend=backend)


@functools.partial(jax.jit, static_argnames=("sampler", "dtype"))
def _transform(bits, sampler: str, dtype: str):
    return sampler_mod.apply(bits, sampler_mod.parse(sampler), dtype)


def _two_pass(s: int, t: int, sampler: str, dtype: str, backend: str):
    """bits-then-transform: two jitted calls, the uint32 block crosses the
    jit boundary (i.e. HBM on a real chip) in between."""
    bits = _fused(s, t, "bits", "float32", backend)
    return _transform(bits, sampler, dtype)


def _record(records, **kw):
    """Append one perf-trajectory row, deriving the bandwidth fields.

    Every row with a parseable sampler gains ``bytes_per_sample`` (the
    output element width — the roofline's traffic model) and
    ``gbytes_per_s`` (= GSample/s x bytes/sample), so bandwidth-bound
    comparisons never re-derive dtype widths from row names.  Rows may
    pre-set both (the service row's effective mixed-burst value).
    """
    if records is None:
        return
    g = kw.get("gsamples_per_s")
    if g is not None and "bytes_per_sample" not in kw:
        bps = bytes_per_sample(kw.get("sampler", ""),
                               kw.get("dtype") or "float32")
        if bps is not None:
            kw["bytes_per_sample"] = bps
            kw["gbytes_per_s"] = g * bps
    records.append(kw)


def _sampler_section(out, records, s: int, t: int, iters: int) -> None:
    backend = engine.select_backend(
        engine.make_plan(seed=7, num_streams=s, num_steps=t))
    n = s * t
    for sampler, dtype in SAMPLER_CASES:
        st_f = time_fn_stats(_fused, s, t, sampler, dtype, backend,
                             iters=iters)
        st_2 = time_fn_stats(_two_pass, s, t, sampler, dtype, backend,
                             iters=iters)
        sec_f, sec_2 = st_f["median_s"], st_2["median_s"]
        gs_f, gs_2 = n / sec_f / 1e9, n / sec_2 / 1e9
        speed = sec_2 / sec_f
        tag = f"{sampler}/{dtype}"
        out(row(f"throughput/sampler/{tag}/S={s}", sec_f * 1e6,
                f"{gs_f:.3f} GSample/s {backend} fused "
                f"x{speed:.2f} vs two-pass"))
        _record(records, name=f"sampler/{tag}/S={s}", backend=backend,
                sampler=sampler, dtype=dtype, variant="fused",
                num_streams=s, num_steps=t, us_per_call=st_f["us_per_call"],
                compile_us=st_f["compile_us"],
                gsamples_per_s=gs_f, speedup_vs_two_pass=speed)
        _record(records, name=f"sampler/{tag}/S={s}", backend=backend,
                sampler=sampler, dtype=dtype, variant="two_pass",
                num_streams=s, num_steps=t, us_per_call=st_2["us_per_call"],
                compile_us=st_2["compile_us"], gsamples_per_s=gs_2)


def run(out, records=None):
    prev = None
    for s in (128, 512, 2048, 8192):
        st = time_fn_stats(_bulk, s, T_STEPS, "ctr", iters=3)
        sec = st["median_s"]
        samples = s * T_STEPS
        gs = samples / sec / 1e9
        scale = f" x{gs / prev:.2f}" if prev else ""
        prev = gs
        out(row(f"throughput/ctr/S={s}", sec * 1e6,
                f"{gs:.3f} GSample/s host{scale}"))
        _record(records, name=f"bulk/ctr/S={s}", backend="ref",
                sampler="bits", dtype="uint32", variant="fused",
                num_streams=s, num_steps=T_STEPS,
                us_per_call=st["us_per_call"],
                compile_us=st["compile_us"], gsamples_per_s=gs)
    # faithful mode (serial xorshift decorrelator) at one size
    st = time_fn_stats(_bulk, 512, T_STEPS, "faithful", iters=3)
    sec = st["median_s"]
    gs = 512 * T_STEPS / sec / 1e9
    out(row("throughput/faithful/S=512", sec * 1e6,
            f"{gs:.3f} GSample/s host"))
    _record(records, name="bulk/faithful/S=512", backend="ref",
            sampler="bits", dtype="uint32", variant="fused",
            num_streams=512, num_steps=T_STEPS,
            us_per_call=st["us_per_call"], compile_us=st["compile_us"],
            gsamples_per_s=gs)
    # fmix32 decorrelator (beyond-paper; 96 -> 30 uint ops/sample)
    sec64 = time_fn(_bulk, 2048, T_STEPS, "ctr", iters=3)
    sec32 = time_fn(_bulk, 2048, T_STEPS, "ctr", "fmix32", iters=3)
    gs = 2048 * T_STEPS / sec32 / 1e9
    out(row("throughput/ctr_fmix32/S=2048", sec32 * 1e6,
            f"{gs:.3f} GSample/s host x{sec64 / sec32:.2f} vs splitmix64"))
    # engine dispatch overhead: same plan through ref vs xla backends
    sec_ref = time_fn(_bulk, 2048, T_STEPS, "ctr", "splitmix64", "ref",
                      iters=3)
    sec_xla = time_fn(_bulk, 2048, T_STEPS, "ctr", "splitmix64", "xla",
                      iters=3)
    out(row("throughput/engine_xla/S=2048", sec_xla * 1e6,
            f"{2048 * T_STEPS / sec_xla / 1e9:.3f} GSample/s host "
            f"x{sec_ref / sec_xla:.2f} vs ref backend"))
    # fused sampler pipeline vs the bits-then-transform two-pass path
    _sampler_section(out, records, s=2048, t=T_STEPS, iters=3)
    out(row("throughput/tpu_projection", 0.0,
            f"bulk HBM-bound {HBM_BW / 4 / 1e9:.0f} GSample/s/chip "
            f"(f32/u32), {HBM_BW / 2 / 1e9:.0f} bf16 fused;"
            f" paper FPGA 655 Gnum/s"))


def smoke(out=print, records=None) -> None:
    """CI-sized sanity run: one small block per backend, bit-equal check.

    Each path is timed as a JITTED function with the warm-up factored
    out (``time_fn_stats``): ``us_per_call`` is steady-state dispatch +
    execution, and trace+compile cost lands in its own ``compile_us``
    field — an eager first call used to dominate these rows and made
    them incomparable with the jitted sampler rows.
    """
    plan = engine.make_plan(seed=7, num_streams=256, num_steps=64)
    base = np.asarray(engine.generate(plan, backend="ref"))
    for backend in ("xla", "pallas"):
        fn = jax.jit(functools.partial(engine.generate, plan,
                                       backend=backend))
        st = time_fn_stats(fn, iters=3)
        assert np.array_equal(base, np.asarray(fn())), \
            f"{backend} disagrees with ref"
        out(row(f"smoke/{backend}", st["us_per_call"],
                f"bit-equal to ref, compile {st['compile_us'] / 1e3:.0f}ms"))
        _record(records, name=f"smoke/{backend}", backend=backend,
                sampler="bits", dtype="uint32", variant="fused",
                num_streams=256, num_steps=64,
                us_per_call=st["us_per_call"], compile_us=st["compile_us"],
                gsamples_per_s=256 * 64 / st["median_s"] / 1e9)
    fn = jax.jit(functools.partial(engine.generate_sharded, plan))
    st = time_fn_stats(fn, iters=3)
    assert np.array_equal(base, np.asarray(fn()))
    out(row("smoke/sharded", st["us_per_call"],
            f"bit-equal over {len(jax.devices())} device(s), "
            f"compile {st['compile_us'] / 1e3:.0f}ms"))
    _record(records, name="smoke/sharded", backend="sharded",
            sampler="bits", dtype="uint32", variant="fused",
            num_streams=256, num_steps=64, us_per_call=st["us_per_call"],
            compile_us=st["compile_us"],
            gsamples_per_s=256 * 64 / st["median_s"] / 1e9)


def sampler_smoke(out=print, records=None) -> None:
    """CI-sized fused-sampler run: parity per backend + fused/two-pass
    timing at one small size."""
    for sampler, dtype in SAMPLER_CASES:
        plan = engine.make_plan(seed=11, num_streams=256, num_steps=64,
                                sampler=sampler, out_dtype=dtype)
        base = np.asarray(engine.generate(plan, backend="ref"))

        def raw(a):
            return a.view(np.uint16) if a.dtype == jnp.bfloat16 else a

        for backend in ("xla", "pallas"):
            got = np.asarray(engine.generate(plan, backend=backend))
            if sampler == "normal":  # libm ULP slack, see test_sampler
                assert np.allclose(got.astype(np.float32),
                                   base.astype(np.float32), rtol=1e-5), \
                    (sampler, backend)
            else:
                assert np.array_equal(raw(got), raw(base)), \
                    (sampler, backend)
        out(row(f"smoke/sampler/{sampler}/{dtype}", 0.0,
                "matches ref on xla+pallas"))
    _sampler_section(out, records, s=2048, t=2048, iters=2)


DIST_CASES = (
    ("exponential(1.5)", "float32"),
    ("exponential(1.5)", "bfloat16"),
    ("poisson(3.5)", "float32"),
    ("gamma(2.5)", "float32"),
    ("categorical[0.5,0.25,0.125,0.125]", "float32"),
)


def dist_smoke(out=print, records=None, *, s: int = 2048,
               t: int = 2048) -> None:
    """Distribution-stage rows: backend parity at small size, then
    fused-vs-two-pass GSample/s per (distribution, dtype) at S=2048.

    The fused path applies the distribution transform where the bits are
    generated (one executable, no uint32 intermediate); the two-pass
    path materializes the bit block first — the HBM round-trip the
    in-kernel stages delete.  Gamma is the expensive row (6 unrolled
    Marsaglia-Tsang retry rows, each with a Box-Muller candidate);
    poisson costs one compare per threshold-ladder rung; categorical one
    compare per outcome."""
    for spec, dtype in DIST_CASES:
        plan = engine.make_plan(seed=11, num_streams=256, num_steps=64,
                                sampler=spec, out_dtype=dtype)
        base = np.asarray(engine.generate(plan, backend="ref"))

        def raw(a):
            return a.view(np.uint16) if a.dtype == jnp.bfloat16 else a

        for backend in ("xla", "pallas"):
            got = np.asarray(engine.generate(plan, backend=backend))
            if backend == "pallas" and spec.startswith(("exponential",
                                                        "gamma")):
                # log-based stages: few-ULP libm lane slack on padded
                # tiles (see tests/test_distributions.py)
                assert np.allclose(got.astype(np.float32),
                                   base.astype(np.float32), rtol=1e-5), \
                    (spec, backend)
            else:
                assert np.array_equal(raw(got), raw(base)), (spec, backend)
        out(row(f"smoke/dist/{spec}/{dtype}", 0.0,
                "matches ref on xla+pallas"))
    n = s * t
    backend = engine.select_backend(
        engine.make_plan(seed=7, num_streams=s, num_steps=t))
    for spec, dtype in DIST_CASES:
        st_f = time_fn_stats(_fused, s, t, spec, dtype, backend, iters=2)
        st_2 = time_fn_stats(_two_pass, s, t, spec, dtype, backend, iters=2)
        sec_f, sec_2 = st_f["median_s"], st_2["median_s"]
        gs_f, gs_2 = n / sec_f / 1e9, n / sec_2 / 1e9
        speed = sec_2 / sec_f
        tag = f"{spec}/{dtype}"
        out(row(f"throughput/dist/{tag}/S={s}", sec_f * 1e6,
                f"{gs_f:.3f} GSample/s {backend} fused "
                f"x{speed:.2f} vs two-pass"))
        _record(records, name=f"dist/{tag}/S={s}", backend=backend,
                sampler=spec, dtype=dtype, variant="fused",
                num_streams=s, num_steps=t, us_per_call=st_f["us_per_call"],
                compile_us=st_f["compile_us"],
                gsamples_per_s=gs_f, speedup_vs_two_pass=speed)
        _record(records, name=f"dist/{tag}/S={s}", backend=backend,
                sampler=spec, dtype=dtype, variant="two_pass",
                num_streams=s, num_steps=t, us_per_call=st_2["us_per_call"],
                compile_us=st_2["compile_us"], gsamples_per_s=gs_2)


def _consume(block):
    """Stand-in application kernel: one jitted reduction per block (so the
    double-buffered producer has real consumer work to overlap with)."""
    return jnp.sum(jnp.asarray(block, jnp.float32) if block.dtype ==
                   jnp.uint32 else block)


def pipelined_smoke(out=print, records=None, *, s: int = 512, t: int = 2048,
                    n_blocks: int = 8) -> None:
    """Block-delivery smoke: double-buffered producer vs synchronous
    lease+generate, and the 1-D vs 2-D mesh fan-out, all bit-checked.

    On this 1-CPU container the producer thread shares the XLA device
    with the consumer, so the double-buffer win is host-dispatch overlap
    only; the HBM-level story is the TPU projection (see EXPERIMENTS.md).
    """
    n = s * t * n_blocks

    # one standing service per variant: successive timed calls consume
    # FRESH windows (the ledger forbids reuse) through one cached window
    # executable — the steady-state delivery cost, not trace time.
    svc_s = BlockService(seed=23)
    svc_s.open("bench", num_streams=s)
    svc_p = BlockService(seed=23)
    svc_p.open("bench", num_streams=s)

    def run_sync():
        acc = []
        for _ in range(n_blocks):
            acc.append(_consume(svc_s.take("bench", t)))
        return jax.block_until_ready(jnp.stack(acc))

    def run_pipelined():
        with svc_p.producer("bench", t, count=n_blocks) as prod:
            acc = [_consume(block) for _, block in prod]
        return jax.block_until_ready(jnp.stack(acc))

    # same seed + same windows => bit-identical first pass
    base = np.asarray(run_sync())
    assert np.array_equal(base, np.asarray(run_pipelined())), \
        "double-buffered blocks disagree with synchronous"
    st_s = time_fn_stats(run_sync, iters=3, warmup=1)
    st_p = time_fn_stats(run_pipelined, iters=3, warmup=1)
    sec_s, sec_p = st_s["median_s"], st_p["median_s"]
    gs_s, gs_p = n / sec_s / 1e9, n / sec_p / 1e9
    out(row(f"pipelined/sync/S={s}", sec_s * 1e6,
            f"{gs_s:.3f} GSample/s lease+generate per block"))
    out(row(f"pipelined/double_buffered/S={s}", sec_p * 1e6,
            f"{gs_p:.3f} GSample/s x{sec_s / sec_p:.2f} vs sync"))
    _record(records, name=f"pipelined/S={s}", backend="service",
            sampler="bits", dtype="uint32", variant="sync",
            num_streams=s, num_steps=t * n_blocks,
            us_per_call=st_s["us_per_call"], compile_us=st_s["compile_us"],
            gsamples_per_s=gs_s)
    _record(records, name=f"pipelined/S={s}", backend="service",
            sampler="bits", dtype="uint32", variant="double_buffered",
            num_streams=s, num_steps=t * n_blocks,
            us_per_call=st_p["us_per_call"], compile_us=st_p["compile_us"],
            gsamples_per_s=gs_p, speedup_vs_two_pass=sec_s / sec_p)

    # 1-D vs 2-D mesh fan-out (degenerate single-device grids here; the
    # row exists so the TPU run records the real (hosts, streams) split)
    plan = engine.make_plan(seed=23, num_streams=s, num_steps=t)
    base = np.asarray(engine.generate(plan, backend="xla"))
    devs = np.array(jax.devices())
    meshes = {
        "mesh1d": (jax.sharding.Mesh(devs, ("streams",)), ("streams",)),
        "mesh2d": (jax.sharding.Mesh(devs.reshape(1, -1),
                                     ("hosts", "streams")),
                   ("hosts", "streams")),
    }
    for name, (mesh, axes) in meshes.items():
        fn = jax.jit(functools.partial(engine.generate_sharded, plan,
                                       mesh=mesh, axis_names=axes))
        assert np.array_equal(base, np.asarray(fn())), name
        st = time_fn_stats(fn, iters=2)
        gs = s * t / st["median_s"] / 1e9
        out(row(f"pipelined/{name}/S={s}", st["us_per_call"],
                f"{gs:.3f} GSample/s over {mesh.devices.size} device(s) "
                f"axes={'x'.join(axes)}"))
        _record(records, name=f"pipelined/{name}/S={s}", backend="sharded",
                sampler="bits", dtype="uint32", variant=name,
                num_streams=s, num_steps=t, us_per_call=st["us_per_call"],
                compile_us=st["compile_us"], gsamples_per_s=gs)


def service_smoke(out=print, records=None, *, burst: int = 192,
                  tenants: int = 64) -> None:
    """RandService serving rows: requests/s, p50/p99 latency, coalescing.

    A first (untimed) burst traces/compiles the fused window functions
    and fills the standing pool; the timed burst re-runs the same shape
    mix against fresh counter windows, so the row is steady-state
    serving cost (the warm-up wall time is reported as ``compile_us``).
    """
    import time as _time

    from repro.service import RandServer, ServerConfig
    from repro.service.audit import verify_ledger_disjoint
    from repro.service.burst import make_requests, run_burst

    srv = RandServer(seed=29, config=ServerConfig(
        max_batch=64, max_delay_s=0.05,
        hot_classes=(("uniform", "float32"),)))
    reqs = make_requests(burst=burst, tenants=tenants, seed=1)
    t0 = _time.perf_counter()
    run_burst(srv, reqs)                       # warm-up: trace + compile
    warm_s = _time.perf_counter() - t0
    srv.reset_metrics()
    t0 = _time.perf_counter()
    got = run_burst(srv, reqs)                 # fresh windows, cached fns
    wall = _time.perf_counter() - t0
    assert len(got) == burst
    stats = srv.stats()
    verify_ledger_disjoint(srv.block_service)
    srv.shutdown()
    rps = burst / wall
    # mixed burst: effective bytes/sample from the actual responses
    total_samples = sum(int(np.asarray(a).size) for a in got)
    total_bytes = sum(int(np.asarray(a).nbytes) for a in got)
    eff_bps = total_bytes / max(1, total_samples)
    gs = total_samples / wall / 1e9
    out(row(f"service/burst={burst}", wall / burst * 1e6,
            f"{rps:.0f} req/s p50={stats['latency_p50_ms']:.1f}ms "
            f"p99={stats['latency_p99_ms']:.1f}ms "
            f"{stats['calls_per_request']:.3f} calls/req "
            f"(x{stats['coalescing_factor']:.0f} coalescing)"))
    _record(records, name=f"service/burst={burst}", backend="service",
            sampler="mixed", dtype="mixed", variant="coalesced+pool",
            num_streams=tenants, num_steps=burst,
            us_per_call=wall / burst * 1e6, compile_us=warm_s * 1e6,
            gsamples_per_s=gs, bytes_per_sample=eff_bps,
            gbytes_per_s=gs * eff_bps,
            requests_per_s=rps,
            latency_p50_ms=stats["latency_p50_ms"],
            latency_p99_ms=stats["latency_p99_ms"],
            calls_per_request=stats["calls_per_request"],
            coalescing_factor=stats["coalescing_factor"],
            fill_ratio=stats["fill_ratio"])


def fleet_smoke(out=print, records=None, *, burst: int = 96,
                tenants: int = 32, shards: int = 2) -> None:
    """Wire-level fleet rows: the adversarial traffic suite over
    subprocess shards + socket transport, with pipelined clients,
    microbatch coalescing and standing pools in the shards.

    Accounting: warm variants run an untimed warm-up burst first
    (rids prefixed ``warm/`` so they never collide with the timed
    burst in the journal), then reset both client- and shard-side
    metrics — so the row is steady-state serving cost with the
    first-connect/handshake/jit split out (reported as
    ``compile_us``).  The ``kill`` pair runs COLD: warm-up rids parse
    through ``rid_index`` and would fire the scripted injector early.

    Variants: ``binary`` vs ``json`` (same array-heavy traffic, wire
    v2 vs v1 — the transport speedup pair CI gates on), ``hammer``
    (every request from ONE tenant — no routing spread), ``unique``
    (every request a distinct shape — zero class coalescing), and
    ``kill`` (mixed traffic, scripted kill at the burst midpoint:
    ``recovery_ms`` is the failover cost and the response digest is
    asserted equal to the cold no-fault run — the failover correctness
    check as a benchmark side effect, now with pools + coalescing +
    pipelining all on).
    """
    import tempfile
    import time as _time

    from repro.runtime.fault import FaultPlan
    from repro.service import transport
    from repro.service.audit import response_digest
    from repro.service.burst import make_requests
    from repro.service.fleet import Fleet, FleetConfig, run_fleet_burst

    def reset_fleet(client) -> None:
        for logical, proc in sorted(client._owner.items()):
            transport.rpc(client.addresses[proc],
                          {"op": "reset", "shard": logical}, timeout=10.0)
        client.reset_metrics()

    def shard_counters(client) -> dict:
        engine = leases = served = pooled = 0
        for logical, proc in sorted(client._owner.items()):
            try:
                reply = transport.rpc(client.addresses[proc],
                                      {"op": "stats", "shard": logical},
                                      timeout=10.0)
            except (OSError, transport.TransportError):
                continue            # fenced/dead owner
            if reply.get("ok"):
                s = reply["stats"]
                engine += s.get("engine_calls", 0)
                leases += s.get("lease_calls", 0)
                served += s.get("requests_served", 0)
                pooled += s.get("pool_requests", 0)
        return {"coalesce_calls_per_req": ((engine + leases) / served
                                           if served else 0.0),
                "pool_hit_rate": pooled / served if served else 0.0}

    def one(variant: str, pattern: str, plan: FaultPlan, *,
            binary: bool = True, warm: bool = True, max_side: int = 64):
        with tempfile.TemporaryDirectory() as jdir:
            cfg = FleetConfig(num_shards=shards, seed=31,
                              journal_dir=jdir)
            reqs = make_requests(burst=burst, tenants=tenants, seed=2,
                                 pattern=pattern, max_side=max_side)
            with Fleet(cfg, plan) as fleet:
                client = fleet.client(binary=binary)
                warm_s = 0.0
                if warm:
                    t0 = _time.perf_counter()
                    run_fleet_burst(client, make_requests(
                        burst=burst, tenants=tenants, seed=2,
                        pattern=pattern, max_side=max_side,
                        rid_prefix="warm"))
                    warm_s = _time.perf_counter() - t0
                    reset_fleet(client)
                t0 = _time.perf_counter()
                got = run_fleet_burst(client, reqs)
                wall = _time.perf_counter() - t0
                stats = client.stats()
                stats.update(shard_counters(client))
                client.close()
        assert len(got) == burst
        digest = response_digest(got)
        rps = burst / wall
        rec_ms = stats["recovery_ms"]
        out(row(f"fleet/{variant}/burst={burst}", wall / burst * 1e6,
                f"{rps:.0f} req/s p50={stats['latency_p50_ms']:.1f}ms "
                f"p99={stats['latency_p99_ms']:.1f}ms "
                f"{stats['bytes_on_wire_per_req']:.0f} B/req "
                f"{stats['coalesce_calls_per_req']:.2f} calls/req "
                f"pool={stats['pool_hit_rate']:.2f}"
                + (f" recovery={rec_ms:.0f}ms" if rec_ms is not None
                   else "")))
        _record(records, name=f"fleet/{variant}/burst={burst}",
                backend="fleet", sampler="mixed", dtype="mixed",
                variant=variant, num_streams=tenants, num_steps=burst,
                us_per_call=wall / burst * 1e6,
                compile_us=warm_s * 1e6,
                requests_per_s=rps,
                latency_p50_ms=stats["latency_p50_ms"],
                latency_p99_ms=stats["latency_p99_ms"],
                retries=stats["retries"], failovers=stats["failovers"],
                recovery_ms=rec_ms,
                bytes_on_wire_per_req=stats["bytes_on_wire_per_req"],
                coalesce_calls_per_req=stats["coalesce_calls_per_req"],
                pool_hit_rate=stats["pool_hit_rate"])
        return digest, rps

    def wire_pair():
        """Transport-isolated array-heavy pair: framed round-trips of
        1 MiB-array replies over a socketpair, v2 vs v1.  This is the
        layer the binary format accelerates (no serving cost mixed
        in) — the CI ``fleet-perf`` gate asserts v2 >= 2x v1 here."""
        import socket as _socket
        import threading as _threading

        arr = (np.arange(512 * 512, dtype=np.uint32)
               .astype(np.float32).reshape(512, 512))
        frames = 32
        for variant, ver in (("wire-binary", transport.WIRE_V2),
                             ("wire-json", transport.WIRE_V1)):
            a, b = _socket.socketpair()
            a.settimeout(60.0); b.settimeout(60.0)
            got = []

            def pump():
                for _ in range(frames):
                    msg, _v = transport.recv_wire(b)
                    got.append(transport.reply_array(msg))

            t = _threading.Thread(target=pump, daemon=True)
            t.start()
            t0 = _time.perf_counter()
            sent = 0
            for i in range(frames):
                sent += transport.send_wire(
                    a, {"ok": True, "rid": f"w/{i}", "array": arr},
                    version=ver)
            t.join(timeout=120)
            wall = _time.perf_counter() - t0
            a.close(); b.close()
            assert len(got) == frames
            assert got[0].tobytes() == arr.tobytes()
            rps = frames / wall
            out(row(f"fleet/{variant}/frames={frames}",
                    wall / frames * 1e6,
                    f"{rps:.0f} frames/s "
                    f"{sent / frames / 1e6:.2f} MB/frame "
                    f"{sent / wall / 1e9:.2f} GB/s"))
            _record(records, name=f"fleet/{variant}/frames={frames}",
                    backend="fleet", sampler="bits", dtype="float32",
                    variant=variant, num_streams=1, num_steps=frames,
                    us_per_call=wall / frames * 1e6,
                    requests_per_s=rps,
                    bytes_on_wire_per_req=sent / frames,
                    gbytes_per_s=sent / wall / 1e9)

    wire_pair()
    # end-to-end pair: identical array-heavy traffic, wire v2 vs v1 —
    # asserts payload transparency (serving cost dominates this scale,
    # so the e2e ratio is informational; the gate reads the wire pair)
    bin_digest, bin_rps = one("binary", "mixed", FaultPlan(),
                              binary=True, max_side=128)
    json_digest, json_rps = one("json", "mixed", FaultPlan(),
                                binary=False, max_side=128)
    assert bin_digest == json_digest, (
        "binary v2 responses diverged from JSON v1 — wire framing is "
        "NOT payload-transparent")
    out(f"# fleet: binary/json steady-state speedup "
        f"{bin_rps / json_rps:.2f}x e2e (digests equal)")
    one("hammer", "hammer", FaultPlan())
    one("unique", "unique", FaultPlan())
    # kill pair runs cold (no warm-up: warm rids would fire the injector)
    baseline, _ = one("nofault", "mixed", FaultPlan(), warm=False)
    killed, _ = one("kill", "mixed",
                    FaultPlan.parse(f"kill@{burst // 2}"), warm=False)
    assert killed == baseline, (
        "kill-mid-burst digest diverged from the no-fault run — "
        "failover is NOT bit-identical")
    out("# fleet: kill-mid-burst digest == no-fault digest "
        "(bit-identical, pools+coalescing+pipelining on)")


def inference_smoke(out=print, records=None, *, batch: int = 64,
                    vocab: int = 512, sequences: int = 96,
                    rate: float = 8.0, max_steps: int = 400) -> None:
    """Continuous-batching serving rows: tokens/s, slot occupancy,
    p50/p99 per-token latency, calls/step — plus a fused-vs-two-pass
    step-kernel microbenchmark (the HBM-noise-block round trip the
    fused gumbel-max kernel deletes).

    The offline run executes with ``--parity`` semantics (the fused
    run's transcript digest is asserted against an xla two-pass re-run)
    so every benchmark invocation is also a correctness check.
    """
    import time as _time

    from repro.core import u64
    from repro.inference import (GumbelMaxSampler, SamplingSpec,
                                 ScheduleConfig, run_offline)

    cfg = ScheduleConfig(capacity=batch, vocab=vocab, sequences=sequences,
                         rate=rate, seed=29, max_steps=max_steps)
    report = run_offline(cfg, parity=True)      # raises on digest mismatch
    j = report.to_json()
    out(row(f"inference/offline/b={batch}", j["p50_ms"] * 1e3,
            f"{j['tokens_per_s']:.0f} tok/s occ={j['occupancy']:.2f} "
            f"p99={j['p99_ms']:.1f}ms "
            f"{j['calls_per_step']:.2f} calls/step (parity ok)"))
    _record(records, name=f"inference/offline/b={batch}",
            backend="inference", sampler="gumbel", dtype="float32",
            variant="continuous-batching", num_streams=batch,
            num_steps=j["decode_steps"], us_per_call=j["p50_ms"] * 1e3,
            gsamples_per_s=j["tokens_per_s"] / 1e9,
            tokens_per_s=j["tokens_per_s"], occupancy=j["occupancy"],
            latency_p50_ms=j["p50_ms"], latency_p99_ms=j["p99_ms"],
            calls_per_step=j["calls_per_step"],
            parity_checked=j["parity_digest"] is not None)

    # step-kernel micro, three variants of the same step (tokens equal):
    #   twopass — noise block materialized by one jitted call, reduced by
    #             a second (crosses the jit boundary = HBM round trip);
    #   onepass — the xla path, noise + reduce in ONE executable;
    #   fused   — the Pallas kernel, bits -> token ids in-kernel (runs
    #             interpreted off-TPU, so its CPU timing is informational).
    s = GumbelMaxSampler.standalone(seed=29, vocab=vocab, capacity=batch,
                                    spec=SamplingSpec(temperature=0.8))
    from repro.inference.kernels import twopass_argmax
    purpose = s.service.channel(s.channel).purpose
    x0, h_fam = engine.family_from_seed(s.service.seed, purpose)
    inv_temp = s.spec.inv_temp

    @jax.jit
    def _noise(tag_hi, tag_lo, c_hi, c_lo):
        h = engine.derive_leaf(
            (jnp.broadcast_to(jnp.asarray(h_fam[0]), tag_hi.shape),
             jnp.broadcast_to(jnp.asarray(h_fam[1]), tag_lo.shape)),
            (tag_hi, tag_lo))
        plan = engine.GenPlan(x0=x0, h=h, num_steps=vocab,
                              ctr=(c_hi, c_lo), offset=None, mode="ctr",
                              deco=s.deco, sampler="gumbel",
                              out_dtype="float32")
        return engine.generate(plan, backend="xla",
                               block_t=s.service.block_t,
                               block_s=s.service.block_s)

    @jax.jit
    def _reduce(lg, noise):
        lt = lg.astype(jnp.float32).T
        thresh = jnp.full((batch,), -jnp.inf, jnp.float32)
        return twopass_argmax(lt, noise, thresh, inv_temp=inv_temp)

    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(batch, vocab)).astype(np.float32))
    tags = jnp.arange(batch, dtype=jnp.uint32)
    c = tuple(map(jnp.asarray, u64.const64(0)))
    args = (logits, jnp.zeros_like(tags), tags, c[0], c[1])

    def two_pass():
        return _reduce(logits, _noise(*args[1:]))

    got = {"fused": np.asarray(s.jitted("fused")(*args)),
           "onepass": np.asarray(s.jitted("xla")(*args)),
           "twopass": np.asarray(two_pass())}
    assert np.array_equal(got["fused"], got["onepass"]) and \
        np.array_equal(got["onepass"], got["twopass"]), \
        "step-micro token mismatch across fused/onepass/twopass"
    t_fused = time_fn_stats(lambda: s.jitted("fused")(*args), iters=30)
    t_one = time_fn_stats(lambda: s.jitted("xla")(*args), iters=30)
    t_two = time_fn_stats(two_pass, iters=30)
    sp = {"fused": t_two["us_per_call"] / t_fused["us_per_call"],
          "onepass": t_two["us_per_call"] / t_one["us_per_call"],
          "twopass": 1.0}
    best = max(sp["fused"], sp["onepass"])
    tok = batch / (t_one["us_per_call"] * 1e-6)
    out(row(f"inference/step/b={batch}", t_one["us_per_call"],
            f"onepass {tok / 1e6:.2f} Mtok/s, {sp['onepass']:.2f}x vs "
            f"two-pass (pallas {sp['fused']:.2f}x"
            f"{', interpreted' if engine.use_interpret() else ''}; "
            f"parity-asserted)"))
    for variant, t in (("fused", t_fused), ("onepass", t_one),
                       ("twopass", t_two)):
        _record(records, name=f"inference/step/b={batch}",
                backend="inference", sampler="gumbel", dtype="float32",
                variant=variant, num_streams=batch, num_steps=vocab,
                us_per_call=t["us_per_call"], compile_us=t["compile_us"],
                gsamples_per_s=batch / (t["us_per_call"] * 1e-6) / 1e9,
                fused_speedup=sp[variant], best_fused_speedup=best,
                interpreted=bool(engine.use_interpret())
                            and variant == "fused")


SMOKES = {
    "smoke": smoke,
    "sampler": sampler_smoke,
    "dist": dist_smoke,
    "pipelined": pipelined_smoke,
    "service": service_smoke,
    "fleet": fleet_smoke,
    "inference": inference_smoke,
}


if __name__ == "__main__":
    import sys
    only = sys.argv[1:]
    unknown = set(only) - set(SMOKES)
    if unknown:
        raise SystemExit(f"unknown smoke(s) {sorted(unknown)}; "
                         f"have {sorted(SMOKES)}")
    records = []
    for name, fn in SMOKES.items():
        if only and name not in only:
            continue
        fn(records=records)
    write_bench_json(records, merge=bool(only))
    print(f"# wrote {BENCH_JSON} ({len(records)} rows"
          f"{' merged' if only else ''})")
