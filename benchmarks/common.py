"""Timing helpers for the benchmark harnesses."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, iters: int = 5, warmup: int = 2, **kw):
    """Median wall seconds per call of a jitted function."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
