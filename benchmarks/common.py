"""Timing helpers for the benchmark harnesses."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, iters: int = 5, warmup: int = 2, **kw):
    """Median wall seconds per call of a jitted function."""
    return time_fn_stats(fn, *args, iters=iters, warmup=warmup,
                         **kw)["median_s"]


def time_fn_stats(fn, *args, iters: int = 5, warmup: int = 2, **kw):
    """Timing with the jit warm-up made explicit.

    The FIRST call — trace + compile for a jitted ``fn`` — is timed on
    its own, the remaining ``warmup - 1`` calls are discarded, and the
    median of ``iters`` steady-state calls is reported separately, so a
    smoke row can never mix compile time into ``us_per_call``.  Returns
    ``{"median_s", "us_per_call", "first_call_us", "compile_us"}``
    where ``compile_us`` is the first-call excess over steady state
    (clamped at 0 for non-jitted functions).
    """
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args, **kw))
    first = time.perf_counter() - t0
    for _ in range(max(0, warmup - 1)):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    med = times[len(times) // 2]
    return {"median_s": med, "us_per_call": med * 1e6,
            "first_call_us": first * 1e6,
            "compile_us": max(0.0, (first - med) * 1e6)}


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
