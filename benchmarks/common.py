"""Timing + row-schema helpers for the benchmark harnesses."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Optional

import jax

BENCH_JSON = pathlib.Path("BENCH_throughput.json")


def bytes_per_sample(sampler: str, out_dtype: str) -> Optional[float]:
    """Bytes WRITTEN per delivered sample for a (sampler, out_dtype) row.

    Bulk generation's only memory traffic is the output block (state and
    hash tables are cache/VMEM-resident), so bytes/sample is just the
    result element width: 4 for bits/uint32 and f32, 2 for bf16, 1 for
    bernoulli bool.  Returns None for unparseable pseudo-classes (e.g.
    the service row's "mixed") — callers drop the bandwidth fields.
    """
    from repro.core import sampler as sampler_mod
    try:
        spec = sampler_mod.parse(sampler)
        dt = sampler_mod.result_dtype(spec, out_dtype)
    except ValueError:
        return None
    import jax.numpy as jnp
    return float(jnp.dtype(dt).itemsize)


def write_bench_json(records, path: pathlib.Path = BENCH_JSON, *,
                     merge: bool = False) -> None:
    """Dump the perf-trajectory rows; ``merge=True`` (filtered smoke
    runs) replaces only the matching (name, variant) rows in an
    existing file instead of discarding the other sections' rows."""
    if merge and path.exists():
        try:
            old = json.loads(path.read_text()).get("rows", [])
        except (json.JSONDecodeError, OSError):
            old = []
        fresh = {(r.get("name"), r.get("variant")) for r in records}
        records = [r for r in old
                   if (r.get("name"), r.get("variant")) not in fresh] \
                  + list(records)
    path.write_text(json.dumps({
        "schema": "bench_throughput/v1",
        "platform": jax.default_backend(),
        "rows": records,
    }, indent=1))


def time_fn(fn, *args, iters: int = 5, warmup: int = 2, **kw):
    """Median wall seconds per call of a jitted function."""
    return time_fn_stats(fn, *args, iters=iters, warmup=warmup,
                         **kw)["median_s"]


def time_fn_stats(fn, *args, iters: int = 5, warmup: int = 2, **kw):
    """Timing with the jit warm-up made explicit.

    The FIRST call — trace + compile for a jitted ``fn`` — is timed on
    its own, the remaining ``warmup - 1`` calls are discarded, and the
    median of ``iters`` steady-state calls is reported separately, so a
    smoke row can never mix compile time into ``us_per_call``.  Returns
    ``{"median_s", "us_per_call", "first_call_us", "compile_us"}``
    where ``compile_us`` is the first-call excess over steady state
    (clamped at 0 for non-jitted functions).
    """
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args, **kw))
    first = time.perf_counter() - t0
    for _ in range(max(0, warmup - 1)):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    med = times[len(times) // 2]
    return {"median_s": med, "us_per_call": med * 1e6,
            "best_s": times[0],
            "first_call_us": first * 1e6,
            "compile_us": max(0.0, (first - med) * 1e6)}


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
