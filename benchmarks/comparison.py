"""Paper Tables 5-6 analogue: ThundeRiNG vs the baseline PRNGs, all
implemented in this repo's JAX substrate and run on the same host.

The paper's table compares FPGA/GPU devices; the portable comparison here
is algorithmic cost per sample on identical hardware: ThundeRiNG's
counter mode is a pure map (like philox) with a *shared* root recurrence,
vs philox's 10-round per-sample block cipher and the serial scan
generators (xoroshiro / pcg), whose time dimension cannot parallelize.
We also compare against jax.random (threefry — the 'vendor library').
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import baselines
from repro.kernels import ops

S, T = 1024, 4096


@functools.partial(jax.jit, static_argnames=("kind",))
def _gen(kind: str):
    if kind == "thundering":
        return ops.thundering_bulk(seed=1, num_streams=S, num_steps=T,
                                   mode="ctr", use_kernel=False)
    if kind == "philox":
        return baselines.philox_bits(1, S, T)
    if kind == "xoroshiro":
        return baselines.xoroshiro_bits(1, S, T)
    if kind == "pcg_xsh_rs":
        return baselines.pcg_xsh_rs_bits(1, S, T)
    if kind == "jax_threefry":
        return jax.random.bits(jax.random.PRNGKey(0), (S, T), jnp.uint32)
    raise ValueError(kind)


def run(out):
    base = None
    for kind in ("thundering", "philox", "xoroshiro", "pcg_xsh_rs",
                 "jax_threefry"):
        sec = time_fn(_gen, kind, iters=3)
        gs = S * T / sec / 1e9
        if base is None:
            base = sec
        out(row(f"comparison/{kind}", sec * 1e6,
                f"{gs:.3f} GSample/s speedup_vs_thundering="
                f"{sec / base:.2f}x_slower" if kind != "thundering"
                else f"{gs:.3f} GSample/s (reference)"))
