"""Paper Tables 2/3/4 analogue: statistical battery.

Table 2 — intra-stream battery per generator (monobit/chi2/runs/autocorr).
Table 3 — pairwise Pearson/Spearman/Kendall with technique ablation.
Table 4 — Hamming-weight dependency with technique ablation.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import baselines, statistics, stream

N = 8192
S = 4


def _thunder(n_streams, n):
    s = stream.new_stream(20240513, 0)
    kids = stream.split(s, n_streams)
    return np.stack([np.asarray(stream.random_bits(k, (n,))) for k in kids])


def run(out):
    gens = {
        "thundering": _thunder(S, N),
        "philox4x32": np.asarray(baselines.philox_bits(1, S, N)),
        "xoroshiro128ss": np.asarray(baselines.xoroshiro_bits(1, S, N)),
        "pcg_xsh_rs": np.asarray(baselines.pcg_xsh_rs_bits(1, S, N)),
    }
    # Table 2 analogue
    for name, bits in gens.items():
        rep = statistics.intra_stream_report(bits[0])
        ok = (abs(rep["monobit"] - 0.5) < 0.01 and rep["byte_chi2_p"] > 1e-4
              and abs(rep["runs_z"]) < 4)
        out(row(f"quality/intra/{name}", 0.0,
                f"monobit={rep['monobit']:.4f} chi2_p={rep['byte_chi2_p']:.3f}"
                f" runs_z={rep['runs_z']:.2f} lag1={rep['lag1_autocorr']:.4f}"
                f" pass={ok}"))
    # Table 3 analogue: ablation of pairwise correlation
    ablations = {
        "lcg_baseline": np.asarray(baselines.raw_lcg_bits(42, S, N)),
        "lcg_permutation": np.asarray(
            baselines.raw_lcg_bits(42, S, N, permute=True, h_mode="spread")),
        "thundering": gens["thundering"],
    }
    for name, bits in ablations.items():
        rep = statistics.inter_stream_report(bits)
        out(row(f"quality/pairwise/{name}", 0.0,
                f"pearson={rep['max_pearson']:.5f}"
                f" spearman={rep['max_spearman']:.5f}"
                f" kendall={rep['max_kendall']:.5f}"))
    # Table 4 analogue: HWD of interleaved streams
    hwd_cases = {
        "lcg_baseline": np.asarray(baselines.raw_lcg_bits(42, S, N)),
        "lcg_permutation": np.asarray(
            baselines.raw_lcg_bits(42, S, N, permute=True)),
        "thundering": gens["thundering"],
    }
    for name, bits in hwd_cases.items():
        hwd = statistics.hamming_weight_dependency(statistics.interleave(bits))
        out(row(f"quality/hwd/{name}", 0.0, f"hwd={hwd:.5f}"))
