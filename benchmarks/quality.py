"""Paper Tables 2/3/4 analogue, rebuilt on the ``repro.quality`` subsystem.

Runs the Crush-lite battery at the ``tiny`` profile (seconds on CPU;
the committed evidence is the ``fast`` profile in QUALITY_report.json)
and emits one row per generator plus the headline cross-battery
numbers — the same Table 3/4 ordering the full battery documents:

  Table 2 — intra-stream battery verdict per generator.
  Table 3 — pairwise correlation sweep with technique ablation.
  Table 4 — interleaved Hamming-weight dependency with ablation.
"""
from __future__ import annotations

from benchmarks.common import row


def run(out):
    from repro.quality import run_battery

    report = run_battery("tiny")
    # Table 2 analogue: per-generator intra-stream battery verdicts
    for g in report["generators"]:
        if g["intra"] is None:
            continue
        tests = g["intra"]["tests"]
        worst = min(t.get("p_ks", t.get("p", 1.0)) for t in tests.values())
        out(row(f"quality/intra/{g['name']}", 0.0,
                f"ok={g['intra']['ok']} worst_p={worst:.4g} "
                f"tests={len(tests)}"))
    # Table 3/4 analogue: the cross-battery ablation ordering
    for g in report["generators"]:
        if g["cross"] is None:
            continue
        sweep = g["cross"]["tests"]["pairwise_sweep"]
        hwd = g["cross"]["tests"]["interleaved/hwd"]
        out(row(f"quality/pairwise/{g['name']}", 0.0,
                f"max_abs_r={sweep['max_abs_r']:.5f} p={sweep['p']:.3g} "
                f"ok={sweep['ok']}"))
        out(row(f"quality/hwd/{g['name']}", 0.0,
                f"p_ks={hwd['p_ks']:.3g} p_min={hwd['p_min']:.3g} "
                f"ok={hwd['ok']}"))
    out(row("quality/battery", 0.0,
            f"profile=tiny ok={report['ok']} "
            f"as_expected={sum(g['as_expected'] for g in report['generators'])}"
            f"/{len(report['generators'])}"))
