"""Pure-jnp oracles for every Pallas kernel in this package.

These are the reference semantics the kernels must reproduce bit-exactly
(integer outputs) or to float tolerance (fused float kernels).  They are
themselves validated against the numpy golden (`core/golden.py`) in tests,
so the chain is: numpy golden <-> jnp ref <-> Pallas kernel.

Block layout convention: bulk generation is **time-major** `(T, S)` —
time steps on sublanes, streams on lanes.  This is the FPGA dataflow
rotated for a 8x128 VPU: the paper emits one root state per cycle shared
by S SOUs; we emit one root *row* per time index shared by S lanes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import lcg, sampler, splitmix, u64, xorshift
from repro.core.u64 import U32, U64Pair


def leaf_outputs(root: U64Pair, h: U64Pair) -> jnp.ndarray:
    """XSH_RR(root[t] + h[s]) for all (t, s): (T,)-roots x (S,)-offsets -> (T, S)."""
    rt = (root[0][:, None], root[1][:, None])
    hs = (h[0][None, :], h[1][None, :])
    leaf = u64.add64(rt, hs)
    return lcg.xsh_rr(leaf)


def thundering_block_ctr(x0: U64Pair, h: U64Pair, num_steps: int,
                         ctr: U64Pair, deco: str = "splitmix64"
                         ) -> jnp.ndarray:
    """(T, S) uint32 block, ctr-mode decorrelator.

    Element (t, s) = XSH_RR(A_{ctr+t+1} x0 + C_{ctr+t+1} + h_s)
                     XOR deco(h_s, ctr + t).

    ``deco``: "splitmix64" (default) or "fmix32" (the 3.2x-cheaper
    beyond-paper variant; EXPERIMENTS.md §Perf/H3)."""
    roots = lcg.root_states_vector(x0, ctr, num_steps)
    permuted = leaf_outputs(roots, h)
    t_idx = jnp.arange(num_steps, dtype=U32)
    ctr_t = u64.add64((jnp.broadcast_to(ctr[0], t_idx.shape),
                       jnp.broadcast_to(ctr[1], t_idx.shape)),
                      (jnp.zeros_like(t_idx), t_idx))
    S = h[0].shape[0]
    deco_fn = splitmix.ctr_decorrelator if deco == "splitmix64" \
        else splitmix.ctr_decorrelator32
    dec = deco_fn(
        (jnp.broadcast_to(h[0][None, :], (num_steps, S)),
         jnp.broadcast_to(h[1][None, :], (num_steps, S))),
        (jnp.broadcast_to(ctr_t[0][:, None], (num_steps, S)),
         jnp.broadcast_to(ctr_t[1][:, None], (num_steps, S))))
    return permuted ^ dec


def thundering_block_faithful(x0: U64Pair, h: U64Pair, num_steps: int,
                              xs_state: jnp.ndarray,
                              ctr: U64Pair) -> jnp.ndarray:
    """(T, S) uint32 block, paper-faithful serial xorshift128 decorrelator.

    ``xs_state``: (S, 4) uint32 — per-stream xorshift128 state already
    advanced to the block start (substream s jumped by ctr).
    """
    roots = lcg.root_states_vector(x0, ctr, num_steps)
    permuted = leaf_outputs(roots, h)  # (T, S)

    def body(state, perm_row):
        x, y, z, w = (state[..., i] for i in range(4))
        x, y, z, w = xorshift.step_xyzw(x, y, z, w)
        return jnp.stack([x, y, z, w], -1), perm_row ^ w

    _, out = jax.lax.scan(body, xs_state, permuted)
    return out


def dropout_mask_bits(h: U64Pair, x0: U64Pair, ctr0: U64Pair,
                      n: int) -> jnp.ndarray:
    """The uint32 stream consumed by fused dropout: full ThundeRiNG ctr
    pipeline for elements ctr0 .. ctr0+n-1 of leaf h (flat)."""
    roots = lcg.root_states_vector(x0, ctr0, n)
    leaf = u64.add64(roots, (jnp.broadcast_to(h[0], (n,)),
                             jnp.broadcast_to(h[1], (n,))))
    permuted = lcg.xsh_rr(leaf)
    idx = jnp.arange(n, dtype=U32)
    ctr = u64.add64((jnp.broadcast_to(ctr0[0], idx.shape),
                     jnp.broadcast_to(ctr0[1], idx.shape)),
                    (jnp.zeros_like(idx), idx))
    deco = splitmix.ctr_decorrelator(
        (jnp.broadcast_to(h[0], (n,)), jnp.broadcast_to(h[1], (n,))), ctr)
    return permuted ^ deco


def fused_dropout(x: jnp.ndarray, h: U64Pair, x0: U64Pair, ctr0: U64Pair,
                  rate: float) -> jnp.ndarray:
    """Reference fused dropout: mask from ThundeRiNG bits, scaled by 1/keep."""
    from repro.kernels.fused_dropout import keep_threshold
    bits = dropout_mask_bits(h, x0, ctr0, x.size).reshape(x.shape)
    keep = bits < U32(keep_threshold(rate)) if rate > 0 \
        else jnp.ones_like(bits, bool)
    scale = jnp.asarray(1.0 / (1.0 - rate), x.dtype)
    return jnp.where(keep, x * scale, jnp.zeros_like(x))


def uniform_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """U[0,1) float32 from the top 24 bits (the shared sampler stage)."""
    return sampler.uniform_from_bits(bits)


def mc_pi_from_uniforms(ux: jnp.ndarray, uy: jnp.ndarray) -> jnp.ndarray:
    """(S,) int32 in-circle counts from (T, S) coordinate uniforms."""
    return jnp.sum((ux * ux + uy * uy) < 1.0, axis=0, dtype=jnp.int32)


def mc_pi_partial(x0: U64Pair, hx: U64Pair, hy: U64Pair, num_draws: int,
                  ctr: U64Pair) -> jnp.ndarray:
    """Reference for the fused pi kernel.  Each of the S lanes owns two
    ThundeRiNG streams (leaf hx[s] for x coords, hy[s] for y); returns the
    int32 count of in-circle draws per lane, shape (S,)."""
    ux = uniform_from_bits(thundering_block_ctr(x0, hx, num_draws, ctr))
    uy = uniform_from_bits(thundering_block_ctr(x0, hy, num_draws, ctr))
    return mc_pi_from_uniforms(ux, uy)


def box_muller(u1: jnp.ndarray, u2: jnp.ndarray) -> jnp.ndarray:
    """Standard normal from two U[0,1) arrays (the shared sampler stage)."""
    return sampler.box_muller(u1, u2)


def mc_option_from_uniforms(u1: jnp.ndarray, u2: jnp.ndarray, s0: float,
                            k: float, r: float, sigma: float,
                            t: float) -> jnp.ndarray:
    """(S,) f32 per-stream discounted-payoff sums from (T, S) uniforms."""
    z = box_muller(u1, u2)
    drift = (r - 0.5 * sigma * sigma) * t
    st = s0 * jnp.exp(drift + sigma * jnp.sqrt(jnp.float32(t)) * z)
    payoff = jnp.maximum(st - k, 0.0) * jnp.exp(-r * t)
    return jnp.sum(payoff, axis=0, dtype=jnp.float32)


def mc_option_partial(x0: U64Pair, hx: U64Pair, hy: U64Pair, num_draws: int,
                      ctr: U64Pair, s0: float, k: float, r: float,
                      sigma: float, t: float) -> jnp.ndarray:
    """Reference for the fused Black-Scholes MC kernel: per-stream sum of
    discounted call payoffs over num_draws GBM terminal prices. (S,) f32."""
    u1 = uniform_from_bits(thundering_block_ctr(x0, hx, num_draws, ctr))
    u2 = uniform_from_bits(thundering_block_ctr(x0, hy, num_draws, ctr))
    return mc_option_from_uniforms(u1, u2, s0, k, r, sigma, t)
