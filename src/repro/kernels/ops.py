"""Public jit'd wrappers around the unified RNG engine and Pallas kernels.

All bulk generation is expressed as an ``engine.GenPlan`` and dispatched
through ``repro.core.engine`` — the same plan runs on the "ref" (jnp
oracle), "xla" (fused elementwise) and "pallas" (tiled kernel) backends
bit-identically.  On CPU (this container) the Pallas backend runs under
``interpret=True``; on TPU the same code lowers through Mosaic.

Entry points:
  * ``thundering_bulk``   — (T, S) bulk MISRN block, mode "ctr"/"faithful"
  * ``fused_dropout``     — dropout with inline mask generation
  * ``estimate_pi``       — fused Monte-Carlo pi (paper Sec. 6 app 1)
  * ``price_option``      — fused Black-Scholes MC (paper Sec. 6 app 2)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine, stream as stream_mod
from repro.kernels import fused_dropout as _fd
from repro.kernels import mc as _mc


_use_interpret = engine.use_interpret


def h_table(seed: int, num_streams: int, purpose: int = 0
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(S,) even leaf offsets h_s, derived the same way ThunderStream.derive
    does (one shared helper: engine.derive_leaf), so bulk blocks and the
    stream API live in the same MISRN family."""
    _, h_fam = engine.family_from_seed(seed, purpose)
    return engine.leaf_table(h_fam, num_streams)


@functools.partial(jax.jit, static_argnames=(
    "num_streams", "num_steps", "mode", "offset", "seed", "block_t",
    "block_s", "use_kernel", "deco", "backend", "sampler", "out_dtype"))
def thundering_bulk(*, seed: int, num_streams: int, num_steps: int,
                    mode: str = "ctr", offset: int = 0,
                    block_t: int = engine.DEFAULT_BLOCK_T,
                    block_s: int = engine.DEFAULT_BLOCK_S,
                    use_kernel: bool = True,
                    deco: str = "splitmix64",
                    backend: Optional[str] = None,
                    sampler: str = "bits",
                    out_dtype: str = "float32") -> jnp.ndarray:
    """(num_steps, num_streams) MISRN block (time-major).

    ``sampler``/``out_dtype`` select the fused output stage (uint32 bits
    by default; see ``repro.core.sampler``).  ``backend`` names an engine
    backend explicitly; otherwise ``use_kernel`` keeps its historical
    meaning (True -> "pallas", False -> "ref").
    """
    plan = engine.make_plan(seed=seed, num_streams=num_streams,
                            num_steps=num_steps, offset=offset, mode=mode,
                            deco=deco, sampler=sampler, out_dtype=out_dtype)
    be = backend or ("pallas" if use_kernel else "ref")
    return engine.generate(plan, backend=be, block_t=block_t,
                           block_s=block_s)


def fused_dropout(x: jnp.ndarray, stream, rate: float, *, block_m: int = 8,
                  use_kernel: bool = True) -> jnp.ndarray:
    """Dropout over arbitrary-shape x, mask addressed by (stream, flat idx).

    The same (stream, counter) always produces the same mask regardless of
    tiling/sharding — deterministic under resharding and elastic restarts.
    The mask bits are the stream's engine plan; the kernel path fuses their
    generation into the read-x/write-y stream (mask never hits HBM).

    ``stream`` may also be a ``BlockService`` lease (``runtime.blocks``):
    the mask is then addressed by the lease's channel stream at its
    window start, and the window must cover ``fused_dropout.mask_elems(
    x.shape)`` elements — leased masks make re-using dropout randomness
    across layers/steps a structural error instead of a bug hunt.
    """
    if not isinstance(stream, stream_mod.ThunderStream):
        lease = stream
        if lease.length < _fd.mask_elems(x.shape):
            raise ValueError(
                f"lease window [{lease.lo}, {lease.hi}) is smaller than the "
                f"{_fd.mask_elems(x.shape)}-element mask for shape {x.shape}")
        stream = lease.stream()
    if rate <= 0.0:
        return x
    shape = x.shape
    n = x.size
    last = shape[-1] if len(shape) >= 1 else 1
    x2 = x.reshape(n // last, last)
    if not use_kernel:
        # keep mask = engine bernoulli sampler at p = 1 - rate: the same
        # exact host-int threshold as the kernel's keep_threshold.
        plan = engine.plan_for_stream(stream, n,
                                      sampler=f"bernoulli({1.0 - rate!r})")
        keep = engine.generate_flat(plan).reshape(x2.shape)
        scale = jnp.asarray(1.0 / (1.0 - rate), x.dtype)
        out = jnp.where(keep, x2 * scale, jnp.zeros_like(x2))
        return out.reshape(shape)
    h = (stream.h_hi, stream.h_lo)
    x0 = (stream.x0_hi, stream.x0_lo)
    ctr0 = (stream.ctr_hi, stream.ctr_lo)
    out = _fd.fused_dropout_2d(x2, h, x0, ctr0, rate, block_m=block_m,
                               interpret=_use_interpret())
    return out.reshape(shape)


def _mc_plans(seed: int, num_lanes: int, draws_per_lane: int,
              purpose_x: int, purpose_y: int, offset: int = 0):
    """Two engine plans (x/y coordinate stream families, shared root).

    ``offset`` is the draw-window start: counter rows ``[offset,
    offset + draws_per_lane)`` — the window a ``BlockService`` lease
    hands out, so repeated app calls never re-spend randomness.
    """
    px = engine.make_plan(seed=seed, num_streams=num_lanes,
                          num_steps=draws_per_lane, purpose=purpose_x,
                          offset=offset)
    py = engine.make_plan(seed=seed, num_streams=num_lanes,
                          num_steps=draws_per_lane, purpose=purpose_y,
                          offset=offset)
    return px, py


@functools.partial(jax.jit, static_argnames=(
    "seed", "num_lanes", "draws_per_lane", "block_t", "block_s",
    "use_kernel", "offset"))
def estimate_pi(*, seed: int, num_lanes: int, draws_per_lane: int,
                offset: int = 0,
                block_t: int = _mc.DEFAULT_BLOCK_T,
                block_s: int = _mc.DEFAULT_BLOCK_S,
                use_kernel: bool = True) -> jnp.ndarray:
    """Monte-Carlo pi over num_lanes independent stream pairs (paper Fig. 8)."""
    px, py = _mc_plans(seed, num_lanes, draws_per_lane, 1, 2, offset)
    if use_kernel:
        partials = _mc.pi_partials_from_plans(px, py, block_t=block_t,
                                              block_s=block_s,
                                              interpret=_use_interpret())
        inside = jnp.sum(partials.astype(jnp.float32))
    else:
        from repro.kernels import ref
        ux = engine.sample(px, sampler="uniform", backend="ref")
        uy = engine.sample(py, sampler="uniform", backend="ref")
        inside = jnp.sum(ref.mc_pi_from_uniforms(ux, uy).astype(jnp.float32))
    total = num_lanes * draws_per_lane
    return 4.0 * inside / total


@functools.partial(jax.jit, static_argnames=(
    "seed", "num_lanes", "draws_per_lane", "s0", "strike", "r", "sigma",
    "t", "block_t", "block_s", "use_kernel", "offset"))
def price_option(*, seed: int, num_lanes: int, draws_per_lane: int,
                 offset: int = 0,
                 s0: float = 100.0, strike: float = 100.0, r: float = 0.05,
                 sigma: float = 0.2, t: float = 1.0,
                 block_t: int = _mc.DEFAULT_BLOCK_T,
                 block_s: int = _mc.DEFAULT_BLOCK_S,
                 use_kernel: bool = True) -> jnp.ndarray:
    """European call price via GBM Monte-Carlo (paper Fig. 9 / Table 7)."""
    px, py = _mc_plans(seed, num_lanes, draws_per_lane, 3, 4, offset)
    if use_kernel:
        partials = _mc.option_partials_from_plans(
            px, py, s0=s0, strike=strike, r=r, sigma=sigma, t=t,
            block_t=block_t, block_s=block_s, interpret=_use_interpret())
        payoff_sum = jnp.sum(partials)
    else:
        from repro.kernels import ref
        u1 = engine.sample(px, sampler="uniform", backend="ref")
        u2 = engine.sample(py, sampler="uniform", backend="ref")
        payoff_sum = jnp.sum(ref.mc_option_from_uniforms(
            u1, u2, s0, strike, r, sigma, t))
    total = num_lanes * draws_per_lane
    return payoff_sum / total
