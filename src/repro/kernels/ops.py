"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) kernels run under ``interpret=True`` — the kernel
body executes in Python/XLA exactly as written, which is how correctness
is validated offline; on TPU the same code lowers through Mosaic.

Entry points:
  * ``thundering_bulk``   — (T, S) bulk MISRN block, mode "ctr"/"faithful"
  * ``fused_dropout``     — dropout with inline mask generation
  * ``estimate_pi``       — fused Monte-Carlo pi (paper Sec. 6 app 1)
  * ``price_option``      — fused Black-Scholes MC (paper Sec. 6 app 2)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lcg, splitmix, stream as stream_mod, u64, xorshift
from repro.core.u64 import U32
from repro.kernels import fused_dropout as _fd
from repro.kernels import mc as _mc
from repro.kernels import thundering_block as _tb


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def h_table(seed: int, num_streams: int, purpose: int = 0
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(S,) even leaf offsets h_s, derived the same way ThunderStream.derive
    does (splitmix of (family h, index)), so bulk blocks and the stream API
    live in the same MISRN family."""
    fam = stream_mod.new_stream(seed, purpose)
    sid = jnp.arange(num_streams, dtype=U32)
    mixed = splitmix.splitmix64(
        (jnp.broadcast_to(fam.h_hi, sid.shape),
         jnp.broadcast_to(fam.h_lo, sid.shape)),
        (jnp.zeros_like(sid), sid))
    return u64.shl64(mixed, 1)


def _roots_and_ctr(x0, offset: int, num_steps: int):
    ctr = u64.const64(offset)
    roots = lcg.root_states_vector(x0, ctr, num_steps)
    t_idx = jnp.arange(num_steps, dtype=U32)
    ctr_rows = u64.add64((jnp.broadcast_to(ctr[0], t_idx.shape),
                          jnp.broadcast_to(ctr[1], t_idx.shape)),
                         (jnp.zeros_like(t_idx), t_idx))
    return roots, ctr_rows


@functools.partial(jax.jit, static_argnames=(
    "num_streams", "num_steps", "mode", "offset", "seed", "block_t",
    "block_s", "use_kernel", "deco"))
def thundering_bulk(*, seed: int, num_streams: int, num_steps: int,
                    mode: str = "ctr", offset: int = 0,
                    block_t: int = _tb.DEFAULT_BLOCK_T,
                    block_s: int = _tb.DEFAULT_BLOCK_S,
                    use_kernel: bool = True,
                    deco: str = "splitmix64") -> jnp.ndarray:
    """(num_steps, num_streams) uint32 MISRN block (time-major)."""
    fam = stream_mod.new_stream(seed, 0)
    x0 = (fam.x0_hi, fam.x0_lo)
    h = h_table(seed, num_streams)
    roots, ctr_rows = _roots_and_ctr(x0, offset, num_steps)
    if mode == "ctr":
        if not use_kernel:
            from repro.kernels import ref
            return ref.thundering_block_ctr(x0, h, num_steps,
                                            u64.const64(offset), deco=deco)
        return _tb.block_ctr(roots, ctr_rows, h, block_t=block_t,
                             block_s=block_s, interpret=_use_interpret(),
                             deco=deco)
    elif mode == "faithful":
        bt = min(block_t, -(-num_steps // 8) * 8)
        n_tiles = -(-num_steps // bt)
        # per-(tile, stream) xorshift state: substream s jumped by
        # offset + i*bt (host-side exact GF(2) jumps; trace-time constants)
        tbl = xorshift.lane_table(num_streams)
        states = np.empty((n_tiles, 4, num_streams), np.uint32)
        for s in range(num_streams):
            st = tuple(int(w) for w in tbl[s])
            if offset:
                st = xorshift.jump(st, offset)
            for i in range(n_tiles):
                states[i, :, s] = st
                st = xorshift.jump(st, bt)
        if not use_kernel:
            from repro.kernels import ref
            return ref.thundering_block_faithful(
                x0, h, num_steps, jnp.asarray(states[0]).T,
                u64.const64(offset))
        return _tb.block_faithful(roots, h, jnp.asarray(states),
                                  block_t=bt, block_s=block_s,
                                  interpret=_use_interpret())
    raise ValueError(mode)


def fused_dropout(x: jnp.ndarray, stream: stream_mod.ThunderStream,
                  rate: float, *, block_m: int = 8,
                  use_kernel: bool = True) -> jnp.ndarray:
    """Dropout over arbitrary-shape x, mask addressed by (stream, flat idx).

    The same (stream, counter) always produces the same mask regardless of
    tiling/sharding — deterministic under resharding and elastic restarts.
    """
    if rate <= 0.0:
        return x
    shape = x.shape
    n = x.size
    last = shape[-1] if len(shape) >= 1 else 1
    x2 = x.reshape(n // last, last)
    h = (stream.h_hi, stream.h_lo)
    x0 = (stream.x0_hi, stream.x0_lo)
    ctr0 = (stream.ctr_hi, stream.ctr_lo)
    if not use_kernel:
        from repro.kernels import ref
        return ref.fused_dropout(x2, h, x0, ctr0, rate).reshape(shape)
    out = _fd.fused_dropout_2d(x2, h, x0, ctr0, rate, block_m=block_m,
                               interpret=_use_interpret())
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=(
    "seed", "num_lanes", "draws_per_lane", "block_t", "block_s",
    "use_kernel"))
def estimate_pi(*, seed: int, num_lanes: int, draws_per_lane: int,
                block_t: int = _mc.DEFAULT_BLOCK_T,
                block_s: int = _mc.DEFAULT_BLOCK_S,
                use_kernel: bool = True) -> jnp.ndarray:
    """Monte-Carlo pi over num_lanes independent stream pairs (paper Fig. 8)."""
    fam = stream_mod.new_stream(seed, 0)
    x0 = (fam.x0_hi, fam.x0_lo)
    hx = h_table(seed, num_lanes, purpose=1)
    hy = h_table(seed, num_lanes, purpose=2)
    roots, ctr_rows = _roots_and_ctr(x0, 0, draws_per_lane)
    if use_kernel:
        partials = _mc.pi_partials(roots, ctr_rows, hx, hy, block_t=block_t,
                                   block_s=block_s,
                                   interpret=_use_interpret())
        inside = jnp.sum(partials.astype(jnp.float32))
    else:
        from repro.kernels import ref
        inside = jnp.sum(ref.mc_pi_partial(x0, hx, hy, draws_per_lane,
                                           u64.const64(0)).astype(jnp.float32))
    total = num_lanes * draws_per_lane
    return 4.0 * inside / total


@functools.partial(jax.jit, static_argnames=(
    "seed", "num_lanes", "draws_per_lane", "s0", "strike", "r", "sigma",
    "t", "block_t", "block_s", "use_kernel"))
def price_option(*, seed: int, num_lanes: int, draws_per_lane: int,
                 s0: float = 100.0, strike: float = 100.0, r: float = 0.05,
                 sigma: float = 0.2, t: float = 1.0,
                 block_t: int = _mc.DEFAULT_BLOCK_T,
                 block_s: int = _mc.DEFAULT_BLOCK_S,
                 use_kernel: bool = True) -> jnp.ndarray:
    """European call price via GBM Monte-Carlo (paper Fig. 9 / Table 7)."""
    fam = stream_mod.new_stream(seed, 0)
    x0 = (fam.x0_hi, fam.x0_lo)
    hx = h_table(seed, num_lanes, purpose=3)
    hy = h_table(seed, num_lanes, purpose=4)
    roots, ctr_rows = _roots_and_ctr(x0, 0, draws_per_lane)
    if use_kernel:
        partials = _mc.option_partials(
            roots, ctr_rows, hx, hy, s0=s0, strike=strike, r=r, sigma=sigma,
            t=t, block_t=block_t, block_s=block_s,
            interpret=_use_interpret())
        payoff_sum = jnp.sum(partials)
    else:
        from repro.kernels import ref
        payoff_sum = jnp.sum(ref.mc_option_partial(
            x0, hx, hy, draws_per_lane, u64.const64(0), s0, strike, r,
            sigma, t))
    total = num_lanes * draws_per_lane
    return payoff_sum / total
