"""Pallas TPU kernels for ThundeRiNG's compute hot-spots.

  thundering_block.py — bulk (T, S) MISRN generation (ctr + faithful modes)
  fused_dropout.py    — dropout with inline mask generation
  mc.py               — fused Monte-Carlo pi / option-pricing kernels
  ops.py              — jit'd public wrappers (interpret=True off-TPU)
  ref.py              — pure-jnp oracles for all of the above
"""
