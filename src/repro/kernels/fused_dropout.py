"""Pallas TPU kernel: dropout with the mask generated inline (never hits HBM).

Plain dropout reads x, reads (or writes) a mask array, writes y: >= 3
HBM round-trips of x's footprint.  ThundeRiNG's counter-addressable form
lets the kernel *regenerate* the mask bits for any element from (leaf h,
element index) alone, so the kernel is a pure read-x/write-y stream with
the full RNG pipeline (shared-root affine + XSH-RR + ctr decorrelator)
evaluated in VREGs.  This is the paper's state-sharing idea as a memory-
bandwidth optimization: one pre-advanced root state per tile (the single
multiply) plus trace-time in-tile affine tables.

Tile layout: (BM, N) row-blocks over a (M, N) 2-D view of x, so flat
element indices are contiguous per tile: p = tile_base + k, k row-major.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import lcg, sampler, splitmix, u64
from repro.core.u64 import U32


def keep_threshold(rate: float) -> int:
    """uint32 keep threshold for a drop rate: round((1-rate) * 2**32).

    The engine's bernoulli sampler threshold at p = 1 - rate: exact
    host-int arithmetic, clamped to 2**32 - 1 so a tiny positive rate
    cannot round up to 2**32 and wrap to an all-drop threshold.
    """
    return sampler.bernoulli_threshold(1.0 - rate)


def mask_elems(shape) -> int:
    """Counter elements a dropout mask over ``shape`` consumes.

    This is the lease-sizing rule for the block-delivery layer: a
    ``BlockService`` window feeding ``ops.fused_dropout`` must span at
    least this many elements of the mask stream (flat row-major
    addressing, one u32 per element — exactly the counters the kernel
    regenerates in VREGs).
    """
    n = 1
    for d in shape:
        n *= int(d)
    return n


def _kernel(x_ref, rb_hi_ref, rb_lo_ref, cb_hi_ref, cb_lo_ref,
            h_hi_ref, h_lo_ref, a_hi_ref, a_lo_ref, c_hi_ref, c_lo_ref,
            o_ref, *, thresh: int, scale: float, n_cols: int):
    x = x_ref[...]                                   # (BM, N)
    bm = x.shape[0]
    # per-tile base root state (already advanced to ctr0 + tile offset)
    rb = (rb_hi_ref[...], rb_lo_ref[...])            # (1, 1)
    # in-tile affine expansion: root(k) = A_{k+1} * rb + C_{k+1}
    A = (a_hi_ref[...], a_lo_ref[...])               # (BM, N)
    C = (c_hi_ref[...], c_lo_ref[...])
    roots = u64.add64(u64.mul64(A, rb), C)
    h = (h_hi_ref[...], h_lo_ref[...])               # (1, 1)
    leaf = u64.add64(roots, h)
    perm = lcg.xsh_rr(leaf)
    # element counter = ctr_base + k (k row-major in-tile)
    k = (jax.lax.broadcasted_iota(U32, (bm, n_cols), 0) * U32(n_cols)
         + jax.lax.broadcasted_iota(U32, (bm, n_cols), 1))
    ctr = u64.add64((cb_hi_ref[...], cb_lo_ref[...]), (jnp.zeros_like(k), k))
    deco = splitmix.ctr_decorrelator(h, ctr)
    bits = perm ^ deco
    keep = bits < U32(thresh)
    o_ref[...] = jnp.where(keep, x * x.dtype.type(scale), jnp.zeros_like(x))


def fused_dropout_2d(x: jnp.ndarray, h, x0, ctr0, rate: float,
                     *, block_m: int = 8, interpret=False) -> jnp.ndarray:
    """Dropout on a (M, N) array; h/x0/ctr0 are u64 (hi, lo) scalar pairs.

    Element (m, n) keeps iff ThundeRiNG bits for flat counter
    ctr0 + m*N + n are below (1-rate)*2^32; kept values scale by 1/(1-rate).
    Bit-exact with ref.fused_dropout for any tiling.
    """
    if rate <= 0.0:
        return x
    M, N = x.shape
    bm = min(block_m, M)
    while M % bm:
        bm -= 1  # fall back to a divisor (shapes here are multiples of 8)
    n_tiles = M // bm
    tile_elems = bm * N

    # Per-tile pre-advanced base roots: A(ctr0 + i*tile) x0 + C(...)
    i_idx = jnp.arange(n_tiles, dtype=U32)
    # offset = i * tile_elems as exact u64 via 32x32->64 product
    off_hi, off_lo = u64.mul32_wide(i_idx, U32(tile_elems))
    base = u64.add64((jnp.broadcast_to(ctr0[0], (n_tiles,)),
                      jnp.broadcast_to(ctr0[1], (n_tiles,))),
                     (off_hi, off_lo))
    A, C = lcg.lcg_skip_traced(base)
    rb = u64.add64(u64.mul64(A, (jnp.broadcast_to(x0[0], (n_tiles,)),
                                 jnp.broadcast_to(x0[1], (n_tiles,)))), C)

    # In-tile affine tables (trace-time constants, shared by all tiles).
    A_hi, A_lo, C_hi, C_lo = lcg.block_affine_constants(tile_elems + 1)
    At = (jnp.asarray(A_hi[1:]).reshape(bm, N), jnp.asarray(A_lo[1:]).reshape(bm, N))
    Ct = (jnp.asarray(C_hi[1:]).reshape(bm, N), jnp.asarray(C_lo[1:]).reshape(bm, N))

    thresh = keep_threshold(rate)
    scale = 1.0 / (1.0 - rate)

    col = lambda v: v.reshape(n_tiles, 1)
    one = lambda v: jnp.broadcast_to(v, (1, 1))

    out = pl.pallas_call(
        functools.partial(_kernel, thresh=thresh, scale=scale, n_cols=N),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((bm, N), lambda i: (i, 0)),      # x
            pl.BlockSpec((1, 1), lambda i: (i, 0)),       # rb hi
            pl.BlockSpec((1, 1), lambda i: (i, 0)),       # rb lo
            pl.BlockSpec((1, 1), lambda i: (i, 0)),       # ctr base hi
            pl.BlockSpec((1, 1), lambda i: (i, 0)),       # ctr base lo
            pl.BlockSpec((1, 1), lambda i: (0, 0)),       # h hi
            pl.BlockSpec((1, 1), lambda i: (0, 0)),       # h lo
            pl.BlockSpec((bm, N), lambda i: (0, 0)),      # A hi
            pl.BlockSpec((bm, N), lambda i: (0, 0)),      # A lo
            pl.BlockSpec((bm, N), lambda i: (0, 0)),      # C hi
            pl.BlockSpec((bm, N), lambda i: (0, 0)),      # C lo
        ],
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, col(rb[0]), col(rb[1]),
      col(base[0]), col(base[1]),
      one(h[0]), one(h[1]),
      At[0], At[1], Ct[0], Ct[1])
    return out
