"""Pallas TPU kernel: bulk ThundeRiNG block generation, (T, S) time-major.

This is the executor behind the engine's "pallas" backend
(``repro.core.engine``); build a ``GenPlan`` and call ``engine.generate``
rather than invoking ``block_ctr``/``block_faithful`` directly.

The FPGA architecture (Fig. 3) maps onto the TPU grid as:

  RSGU (root state generation)  ->  done OUTSIDE the kernel with the
      two-level jump-ahead (`lcg.root_states_vector`): exactly one 64-bit
      multiply per time step *total*, shared by all S streams — the paper's
      "one multiplier for any number of instances".  The (T,) root-state
      vector is streamed into the kernel as a (BT, 1) block per tile.
  SOU daisy chain               ->  S lanes.  Leaf transition is a
      broadcast add (BT,1)+(1,BS); the XSH-RR permutation is elementwise.
  Decorrelator                  ->  two modes:
      * "ctr"       fully parallel splitmix counter decorrelator (TPU-native,
                    beyond-paper; see DESIGN.md).
      * "faithful"  serial xorshift128 per stream, vectorized across lanes
                    and stepped BT times per tile — the FPGA dataflow with
                    time rotated onto the sublane axis.  Per-tile start
                    states are pre-jumped with the GF(2) matrix (outside).
  FIFO into consumer            ->  the fused *sampler* output stage
      (``repro.core.sampler``): uniform / normal / bernoulli transforms
      run on the uint32 tile while it is still in VMEM, so raw bits never
      reach HBM and a bfloat16 output halves the written bytes — the
      paper's never-spill-raw-numbers dataflow (Table 7).

VMEM per tile (defaults BT=256, BS=512): out 512 KB + ~6 u32 temporaries
of the same shape ~ 3.5 MB, comfortably inside 16 MB.  Lane dim BS is a
multiple of 128, sublane dim BT a multiple of 8 (16 for bfloat16 output,
32 for bool — see ``tile_t``).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import lcg, sampler as sampler_mod, u64, xorshift

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_S = 512

BITS: Tuple[str, None] = ("bits", None)


def _ctr_kernel(root_hi_ref, root_lo_ref, ctr_hi_ref, ctr_lo_ref,
                h_hi_ref, h_lo_ref, o_ref, *, deco: str = "splitmix64",
                sampler=BITS, out_dtype: str = "float32"):
    rh, rl = root_hi_ref[...], root_lo_ref[...]      # (BT, 1)
    hh, hl = h_hi_ref[...], h_lo_ref[...]            # (1, BS)
    ch, cl = ctr_hi_ref[...], ctr_lo_ref[...]        # (BT, 1)
    bits = sampler_mod.ctr_bits((rh, rl), (ch, cl), (hh, hl), deco=deco)
    # Sampler output stage fused in-VMEM: the uint32 block never leaves
    # the kernel, only the (possibly half-width) samples hit HBM.
    o_ref[...] = sampler_mod.apply(bits, sampler, out_dtype,
                                   roll=pltpu.roll)


def _faithful_kernel(root_hi_ref, root_lo_ref, h_hi_ref, h_lo_ref,
                     xs_ref, o_ref, *refs, block_t: int, sampler=BITS,
                     out_dtype: str = "float32"):
    # With a non-bits sampler the uint32 block accumulates in a VMEM
    # scratch buffer (o_ref holds the transformed dtype); with "bits" the
    # output ref itself is the accumulator, as before.
    bits_ref = refs[0] if refs else o_ref
    rh, rl = root_hi_ref[...], root_lo_ref[...]      # (BT, 1)
    hh, hl = h_hi_ref[...], h_lo_ref[...]            # (1, BS)
    leaf = u64.add64((rh, rl), (hh, hl))
    bits_ref[...] = lcg.xsh_rr(leaf)                 # permuted, pre-XOR

    # Serial decorrelator: advance xorshift128 once per sublane row — the
    # FPGA's one-output-per-cycle LFSR, vectorized across BS lanes.
    x = xs_ref[0, 0, :]
    y = xs_ref[0, 1, :]
    z = xs_ref[0, 2, :]
    w = xs_ref[0, 3, :]

    def body(t, carry):
        x, y, z, w = carry
        x, y, z, w = xorshift.step_xyzw(x, y, z, w)
        row = pl.load(bits_ref, (pl.dslice(t, 1), slice(None)))
        pl.store(bits_ref, (pl.dslice(t, 1), slice(None)), row ^ w[None, :])
        return x, y, z, w

    jax.lax.fori_loop(0, block_t, body, (x, y, z, w))
    if refs:
        o_ref[...] = sampler_mod.apply(bits_ref[...], sampler, out_dtype,
                                       roll=pltpu.roll)


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def tile_t(block_t: int, T: int, dtype) -> int:
    """Row-tile size: a multiple of the out dtype's min sublane tile (8
    for f32/u32, 16 for bf16, 32 for bool) — in particular always even,
    so Box-Muller row pairs never straddle a tile boundary.  A requested
    ``block_t`` that is not a multiple is rounded DOWN (never below one
    sublane tile): an odd tile height would flip the pairing parity of
    every subsequent tile."""
    sub = sampler_mod.sublane_multiple(dtype)
    bt = min(block_t, _pad_to(T, sub))
    return max(sub, bt - bt % sub)


def block_ctr(roots, ctr_rows, h, *, block_t=DEFAULT_BLOCK_T,
              block_s=DEFAULT_BLOCK_S, interpret=False,
              deco: str = "splitmix64", sampler=BITS,
              out_dtype: str = "float32") -> jnp.ndarray:
    """(T, S) block via the ctr-mode kernel; dtype set by ``sampler``.

    roots: ((T,), (T,)) u32 root states; ctr_rows: ((T,), (T,)) per-row
    counters; h: ((S,), (S,)) leaf offsets.  ``sampler`` is a parsed
    ``repro.core.sampler`` spec tuple; its output stage runs inside the
    kernel, so only the transformed samples are ever written to HBM.
    """
    T = roots[0].shape[0]
    S = h[0].shape[0]
    dtype = sampler_mod.result_dtype(sampler, out_dtype)
    bt = tile_t(block_t, T, dtype)
    bs = min(block_s, _pad_to(S, 128))
    Tp, Sp = _pad_to(T, bt), _pad_to(S, bs)

    def pad_col(v):  # (T,) -> (Tp, 1)
        return jnp.pad(v, (0, Tp - T)).reshape(Tp, 1)

    def pad_row(v):  # (S,) -> (1, Sp)
        return jnp.pad(v, (0, Sp - S)).reshape(1, Sp)

    grid = (Tp // bt, Sp // bs)
    out = pl.pallas_call(
        functools.partial(_ctr_kernel, deco=deco, sampler=sampler,
                          out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bs), lambda i, j: (0, j)),
            pl.BlockSpec((1, bs), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bt, bs), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, Sp), dtype),
        interpret=interpret,
    )(pad_col(roots[0]), pad_col(roots[1]),
      pad_col(ctr_rows[0]), pad_col(ctr_rows[1]),
      pad_row(h[0]), pad_row(h[1]))
    return out[:T, :S]


def block_ctr_windows(roots, ctr_rows, h, *, num_windows: int,
                      window_len: int, block_t=DEFAULT_BLOCK_T,
                      block_s=DEFAULT_BLOCK_S, interpret=False,
                      deco: str = "splitmix64", sampler=BITS,
                      out_dtype: str = "float32") -> jnp.ndarray:
    """(W, T, S) stack of W consecutive counter windows, ONE pallas_call.

    The fusion behind ``engine.generate_windows``: instead of W separate
    kernel dispatches (one per window — W trips through the launch path,
    W small output allocations), the grid grows a leading *window* axis
    ``(W, T_tiles, S_tiles)`` and the per-row root/counter streams are
    indexed by the window ``program_id`` through the BlockSpec index
    maps.  The kernel body is exactly ``_ctr_kernel`` — each (w, i, j)
    program sees the same (BT, 1) root/counter columns it would have
    seen as tile (i, j) of a standalone window-w call, so the output is
    bit-identical to W stacked ``block_ctr`` calls by construction.

    roots / ctr_rows: ((W*T,), (W*T,)) u32 — absolute per-row values for
    all W windows, window-major (row w*T + t is step t of window w).
    """
    W, T = num_windows, window_len
    S = h[0].shape[0]
    assert roots[0].shape[0] == W * T, (roots[0].shape, W, T)
    dtype = sampler_mod.result_dtype(sampler, out_dtype)
    bt = tile_t(block_t, T, dtype)
    bs = min(block_s, _pad_to(S, 128))
    Tp, Sp = _pad_to(T, bt), _pad_to(S, bs)
    n_t = Tp // bt

    def pad_col(v):  # (W*T,) -> (W*Tp, 1): per-window tail padding
        return jnp.pad(v.reshape(W, T), ((0, 0), (0, Tp - T))) \
                  .reshape(W * Tp, 1)

    def pad_row(v):  # (S,) -> (1, Sp)
        return jnp.pad(v, (0, Sp - S)).reshape(1, Sp)

    col = pl.BlockSpec((bt, 1), lambda w, i, j: (w * n_t + i, 0))
    lane = pl.BlockSpec((1, bs), lambda w, i, j: (0, j))
    out = pl.pallas_call(
        functools.partial(_ctr_kernel, deco=deco, sampler=sampler,
                          out_dtype=out_dtype),
        grid=(W, n_t, Sp // bs),
        in_specs=[col, col, col, col, lane, lane],
        out_specs=pl.BlockSpec((bt, bs), lambda w, i, j: (w * n_t + i, j)),
        out_shape=jax.ShapeDtypeStruct((W * Tp, Sp), dtype),
        interpret=interpret,
    )(pad_col(roots[0]), pad_col(roots[1]),
      pad_col(ctr_rows[0]), pad_col(ctr_rows[1]),
      pad_row(h[0]), pad_row(h[1]))
    return out.reshape(W, Tp, Sp)[:, :T, :S]


def block_faithful_windows(roots, h, xs_tile_states, *, num_windows: int,
                           window_len: int, block_t=DEFAULT_BLOCK_T,
                           block_s=DEFAULT_BLOCK_S, interpret=False,
                           sampler=BITS, out_dtype: str = "float32"
                           ) -> jnp.ndarray:
    """(W, T, S) faithful-mode analogue of ``block_ctr_windows``.

    xs_tile_states: (W * T_tiles, 4, S) uint32 — the xorshift128 state of
    every stream at the first row of tile (w, i), pre-jumped to the
    absolute offset ``w * T + i * bt`` (window-major flat order).  One
    pallas_call over the (W, T_tiles, S_tiles) grid; the serial
    decorrelator chain restarts per tile from its pre-jumped state
    exactly as in ``block_faithful``.
    """
    W, T = num_windows, window_len
    S = h[0].shape[0]
    assert roots[0].shape[0] == W * T, (roots[0].shape, W, T)
    dtype = sampler_mod.result_dtype(sampler, out_dtype)
    bt = tile_t(block_t, T, dtype)
    bs = min(block_s, _pad_to(S, 128))
    Tp, Sp = _pad_to(T, bt), _pad_to(S, bs)
    n_t = Tp // bt
    assert xs_tile_states.shape == (W * n_t, 4, S), xs_tile_states.shape
    xs = jnp.pad(xs_tile_states, ((0, 0), (0, 0), (0, Sp - S)))

    def pad_col(v):
        return jnp.pad(v.reshape(W, T), ((0, 0), (0, Tp - T))) \
                  .reshape(W * Tp, 1)

    def pad_row(v):
        return jnp.pad(v, (0, Sp - S)).reshape(1, Sp)

    col = pl.BlockSpec((bt, 1), lambda w, i, j: (w * n_t + i, 0))
    lane = pl.BlockSpec((1, bs), lambda w, i, j: (0, j))
    scratch = [] if sampler == BITS else [pltpu.VMEM((bt, bs), jnp.uint32)]
    out = pl.pallas_call(
        functools.partial(_faithful_kernel, block_t=bt, sampler=sampler,
                          out_dtype=out_dtype),
        grid=(W, n_t, Sp // bs),
        in_specs=[col, col, lane, lane,
                  pl.BlockSpec((1, 4, bs), lambda w, i, j: (w * n_t + i,
                                                            0, j))],
        out_specs=pl.BlockSpec((bt, bs), lambda w, i, j: (w * n_t + i, j)),
        out_shape=jax.ShapeDtypeStruct((W * Tp, Sp), dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(pad_col(roots[0]), pad_col(roots[1]), pad_row(h[0]), pad_row(h[1]),
      xs)
    return out.reshape(W, Tp, Sp)[:, :T, :S]


def block_faithful(roots, h, xs_tile_states, *, block_t=DEFAULT_BLOCK_T,
                   block_s=DEFAULT_BLOCK_S, interpret=False, sampler=BITS,
                   out_dtype: str = "float32") -> jnp.ndarray:
    """(T, S) block via the faithful serial-xorshift kernel.

    xs_tile_states: (T//bt, 4, S) uint32 — per (row-tile, stream) xorshift
    state at the tile's first step (pre-jumped via the GF(2) matrix).
    The caller's bt must match ``tile_t(block_t, T, dtype)``.
    """
    T = roots[0].shape[0]
    S = h[0].shape[0]
    dtype = sampler_mod.result_dtype(sampler, out_dtype)
    bt = tile_t(block_t, T, dtype)
    bs = min(block_s, _pad_to(S, 128))
    Tp, Sp = _pad_to(T, bt), _pad_to(S, bs)
    n_t = Tp // bt
    assert xs_tile_states.shape == (n_t, 4, S), xs_tile_states.shape
    xs = jnp.pad(xs_tile_states, ((0, 0), (0, 0), (0, Sp - S)))

    def pad_col(v):
        return jnp.pad(v, (0, Tp - T)).reshape(Tp, 1)

    def pad_row(v):
        return jnp.pad(v, (0, Sp - S)).reshape(1, Sp)

    grid = (n_t, Sp // bs)
    scratch = [] if sampler == BITS else [pltpu.VMEM((bt, bs), jnp.uint32)]
    out = pl.pallas_call(
        functools.partial(_faithful_kernel, block_t=bt, sampler=sampler,
                          out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bs), lambda i, j: (0, j)),
            pl.BlockSpec((1, bs), lambda i, j: (0, j)),
            pl.BlockSpec((1, 4, bs), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((bt, bs), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, Sp), dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(pad_col(roots[0]), pad_col(roots[1]), pad_row(h[0]), pad_row(h[1]), xs)
    return out[:T, :S]
