"""Pallas TPU kernels for the paper's two case studies (Sec. 6):
pi estimation and Black-Scholes Monte-Carlo option pricing.

Generation is FUSED into the integrand: bits are produced in VREGs,
converted to uniforms, consumed, and only a per-(tile, lane) partial
reduction leaves the kernel.  Arithmetic intensity goes from ~1 op/byte
(bulk generation: every output hits HBM) to ~(pipeline ops x draws)/4B —
the TPU counterpart of the paper's on-chip FIFO into the application
kernels (their Table 7 apps never spill random numbers to DDR either).

Grid (T_tiles, S_tiles); each instance draws BT samples for BS lanes and
emits one (1, BS) partial (count or payoff-sum); the host sums partials.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import lcg, splitmix, u64
from repro.core.u64 import U32

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_S = 512


def _bits(root, ctr_rows, h):
    """(BT, BS) ThundeRiNG ctr-mode bits from (BT,1) roots + (1,BS) h."""
    leaf = u64.add64(root, h)
    perm = lcg.xsh_rr(leaf)
    deco = splitmix.ctr_decorrelator(h, ctr_rows)
    return perm ^ deco


def _uniform(bits):
    return (bits >> U32(8)).astype(jnp.float32) * np.float32(2.0 ** -24)


def _pi_kernel(root_hi_ref, root_lo_ref, ctr_hi_ref, ctr_lo_ref,
               hx_hi_ref, hx_lo_ref, hy_hi_ref, hy_lo_ref, o_ref):
    root = (root_hi_ref[...], root_lo_ref[...])
    ctr = (ctr_hi_ref[...], ctr_lo_ref[...])
    ux = _uniform(_bits(root, ctr, (hx_hi_ref[...], hx_lo_ref[...])))
    uy = _uniform(_bits(root, ctr, (hy_hi_ref[...], hy_lo_ref[...])))
    inside = (ux * ux + uy * uy) < 1.0
    o_ref[...] = jnp.sum(inside.astype(jnp.int32), axis=0, keepdims=True)


def _option_kernel(root_hi_ref, root_lo_ref, ctr_hi_ref, ctr_lo_ref,
                   hx_hi_ref, hx_lo_ref, hy_hi_ref, hy_lo_ref, o_ref,
                   *, s0: float, strike: float, r: float, sigma: float,
                   t: float):
    root = (root_hi_ref[...], root_lo_ref[...])
    ctr = (ctr_hi_ref[...], ctr_lo_ref[...])
    u1 = _uniform(_bits(root, ctr, (hx_hi_ref[...], hx_lo_ref[...])))
    u2 = _uniform(_bits(root, ctr, (hy_hi_ref[...], hy_lo_ref[...])))
    tiny = np.float32(1.1754944e-38)
    rad = jnp.sqrt(-2.0 * jnp.log(jnp.maximum(u1, tiny)))
    z = rad * jnp.cos(2.0 * np.float32(jnp.pi) * u2)
    drift = np.float32((r - 0.5 * sigma * sigma) * t)
    vol = np.float32(sigma) * jnp.sqrt(np.float32(t))
    st = np.float32(s0) * jnp.exp(drift + vol * z)
    payoff = jnp.maximum(st - np.float32(strike), 0.0) * \
        jnp.exp(np.float32(-r * t))
    o_ref[...] = jnp.sum(payoff, axis=0, keepdims=True)


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _launch(kernel, roots, ctr_rows, hx, hy, out_dtype, *, block_t, block_s,
            interpret):
    T = roots[0].shape[0]
    S = hx[0].shape[0]
    bt = min(block_t, _pad_to(T, 8))
    bs = min(block_s, _pad_to(S, 128))
    Tp, Sp = _pad_to(T, bt), _pad_to(S, bs)
    assert Tp == T, "num draws must be a multiple of the T block"

    def pad_col(v):
        return jnp.pad(v, (0, Tp - T)).reshape(Tp, 1)

    def pad_row(v):
        return jnp.pad(v, (0, Sp - S)).reshape(1, Sp)

    grid = (Tp // bt, Sp // bs)
    col_spec = pl.BlockSpec((bt, 1), lambda i, j: (i, 0))
    row_spec = pl.BlockSpec((1, bs), lambda i, j: (0, j))
    partials = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[col_spec, col_spec, col_spec, col_spec,
                  row_spec, row_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((1, bs), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((grid[0], Sp), out_dtype),
        interpret=interpret,
    )(pad_col(roots[0]), pad_col(roots[1]),
      pad_col(ctr_rows[0]), pad_col(ctr_rows[1]),
      pad_row(hx[0]), pad_row(hx[1]), pad_row(hy[0]), pad_row(hy[1]))
    return partials[:, :S]


def pi_partials(roots, ctr_rows, hx, hy, *, block_t=DEFAULT_BLOCK_T,
                block_s=DEFAULT_BLOCK_S, interpret=False) -> jnp.ndarray:
    """(T_tiles, S) int32 in-circle partial counts."""
    return _launch(_pi_kernel, roots, ctr_rows, hx, hy, jnp.int32,
                   block_t=block_t, block_s=block_s, interpret=interpret)


def option_partials(roots, ctr_rows, hx, hy, *, s0, strike, r, sigma, t,
                    block_t=DEFAULT_BLOCK_T, block_s=DEFAULT_BLOCK_S,
                    interpret=False) -> jnp.ndarray:
    """(T_tiles, S) f32 partial discounted-payoff sums."""
    kern = functools.partial(_option_kernel, s0=s0, strike=strike, r=r,
                             sigma=sigma, t=t)
    return _launch(kern, roots, ctr_rows, hx, hy, jnp.float32,
                   block_t=block_t, block_s=block_s, interpret=interpret)
