"""Pallas TPU kernels for the paper's two case studies (Sec. 6):
pi estimation and Black-Scholes Monte-Carlo option pricing.

Generation is FUSED into the integrand: bits are produced in VREGs,
converted to uniforms, consumed, and only a per-(tile, lane) partial
reduction leaves the kernel.  Arithmetic intensity goes from ~1 op/byte
(bulk generation: every output hits HBM) to ~(pipeline ops x draws)/4B —
the TPU counterpart of the paper's on-chip FIFO into the application
kernels (their Table 7 apps never spill random numbers to DDR either).

The generation and distribution stages are the shared sampler stages
(``repro.core.sampler``): these kernels are compositions of
``sampler.ctr_bits`` -> ``sampler.uniform_from_bits`` -> integrand, the
same stages the engine's fused sampler pipeline runs, so they stay
bit-identical with the engine-backed reference paths by construction.

Grid (T_tiles, S_tiles); each instance draws BT samples for BS lanes and
emits one (1, BS) partial (count or payoff-sum); the host sums partials.
T need not be a tile multiple: padded rows are masked out of the partial
reductions inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import sampler as sampler_mod
from repro.core.u64 import U32

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_S = 512


def _uniform_draw(root, ctr_rows, h):
    """One fused sampler stage: ctr-mode bits -> U[0,1) f32, in VREGs."""
    return sampler_mod.uniform_from_bits(
        sampler_mod.ctr_bits(root, ctr_rows, h))


def _row_mask(tile_rows: int, n_cols: int, block_t: int, num_steps: int):
    """(BT, BS) bool: True for rows whose global time index is < T."""
    t0 = pl.program_id(0) * block_t
    row = t0 + jax.lax.broadcasted_iota(jnp.int32, (tile_rows, n_cols), 0)
    return row < num_steps


def _pi_kernel(root_hi_ref, root_lo_ref, ctr_hi_ref, ctr_lo_ref,
               hx_hi_ref, hx_lo_ref, hy_hi_ref, hy_lo_ref, o_ref,
               *, block_t: int, num_steps: int):
    root = (root_hi_ref[...], root_lo_ref[...])
    ctr = (ctr_hi_ref[...], ctr_lo_ref[...])
    ux = _uniform_draw(root, ctr, (hx_hi_ref[...], hx_lo_ref[...]))
    uy = _uniform_draw(root, ctr, (hy_hi_ref[...], hy_lo_ref[...]))
    inside = (ux * ux + uy * uy) < 1.0
    valid = _row_mask(ux.shape[0], ux.shape[1], block_t, num_steps)
    o_ref[...] = jnp.sum((inside & valid).astype(jnp.int32), axis=0,
                         keepdims=True)


def _option_kernel(root_hi_ref, root_lo_ref, ctr_hi_ref, ctr_lo_ref,
                   hx_hi_ref, hx_lo_ref, hy_hi_ref, hy_lo_ref, o_ref,
                   *, block_t: int, num_steps: int, s0: float, strike: float,
                   r: float, sigma: float, t: float):
    root = (root_hi_ref[...], root_lo_ref[...])
    ctr = (ctr_hi_ref[...], ctr_lo_ref[...])
    u1 = _uniform_draw(root, ctr, (hx_hi_ref[...], hx_lo_ref[...]))
    u2 = _uniform_draw(root, ctr, (hy_hi_ref[...], hy_lo_ref[...]))
    z = sampler_mod.box_muller(u1, u2)
    drift = np.float32((r - 0.5 * sigma * sigma) * t)
    vol = np.float32(sigma) * jnp.sqrt(np.float32(t))
    st = np.float32(s0) * jnp.exp(drift + vol * z)
    payoff = jnp.maximum(st - np.float32(strike), 0.0) * \
        jnp.exp(np.float32(-r * t))
    valid = _row_mask(u1.shape[0], u1.shape[1], block_t, num_steps)
    payoff = jnp.where(valid, payoff, jnp.zeros_like(payoff))
    o_ref[...] = jnp.sum(payoff, axis=0, keepdims=True)


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _launch(kernel, roots, ctr_rows, hx, hy, out_dtype, *, block_t, block_s,
            interpret):
    T = roots[0].shape[0]
    S = hx[0].shape[0]
    bt = min(block_t, _pad_to(T, 8))
    bs = min(block_s, _pad_to(S, 128))
    Tp, Sp = _pad_to(T, bt), _pad_to(S, bs)

    def pad_col(v):
        return jnp.pad(v, (0, Tp - T)).reshape(Tp, 1)

    def pad_row(v):
        return jnp.pad(v, (0, Sp - S)).reshape(1, Sp)

    grid = (Tp // bt, Sp // bs)
    col_spec = pl.BlockSpec((bt, 1), lambda i, j: (i, 0))
    row_spec = pl.BlockSpec((1, bs), lambda i, j: (0, j))
    partials = pl.pallas_call(
        functools.partial(kernel, block_t=bt, num_steps=T),
        grid=grid,
        in_specs=[col_spec, col_spec, col_spec, col_spec,
                  row_spec, row_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((1, bs), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((grid[0], Sp), out_dtype),
        interpret=interpret,
    )(pad_col(roots[0]), pad_col(roots[1]),
      pad_col(ctr_rows[0]), pad_col(ctr_rows[1]),
      pad_row(hx[0]), pad_row(hx[1]), pad_row(hy[0]), pad_row(hy[1]))
    return partials[:, :S]


def _plan_rows(px):
    """Shared-root (roots, ctr_rows) for a coordinate plan's draw window.

    The plan's counter start IS the leased window's ``ctr_lo``
    (``engine.make_plan(offset=...)``), so a ``BlockService`` lease of
    ``draws_per_lane`` steps maps 1:1 onto the kernel grid rows — MC
    consumers draw from disjoint counter windows with no per-call state.
    """
    from repro.core import engine
    return engine.root_and_ctr_rows(px.x0, px.ctr, px.num_steps)


def pi_partials_from_plans(px, py, *, block_t=DEFAULT_BLOCK_T,
                           block_s=DEFAULT_BLOCK_S,
                           interpret=False) -> jnp.ndarray:
    """``pi_partials`` addressed by two engine plans (x/y coordinate
    families of one shared root, any counter window)."""
    roots, ctr_rows = _plan_rows(px)
    return pi_partials(roots, ctr_rows, px.h, py.h, block_t=block_t,
                       block_s=block_s, interpret=interpret)


def option_partials_from_plans(px, py, *, s0, strike, r, sigma, t,
                               block_t=DEFAULT_BLOCK_T,
                               block_s=DEFAULT_BLOCK_S,
                               interpret=False) -> jnp.ndarray:
    """``option_partials`` addressed by two engine plans."""
    roots, ctr_rows = _plan_rows(px)
    return option_partials(roots, ctr_rows, px.h, py.h, s0=s0, strike=strike,
                           r=r, sigma=sigma, t=t, block_t=block_t,
                           block_s=block_s, interpret=interpret)


def pi_partials(roots, ctr_rows, hx, hy, *, block_t=DEFAULT_BLOCK_T,
                block_s=DEFAULT_BLOCK_S, interpret=False) -> jnp.ndarray:
    """(T_tiles, S) int32 in-circle partial counts."""
    return _launch(_pi_kernel, roots, ctr_rows, hx, hy, jnp.int32,
                   block_t=block_t, block_s=block_s, interpret=interpret)


def option_partials(roots, ctr_rows, hx, hy, *, s0, strike, r, sigma, t,
                    block_t=DEFAULT_BLOCK_T, block_s=DEFAULT_BLOCK_S,
                    interpret=False) -> jnp.ndarray:
    """(T_tiles, S) f32 partial discounted-payoff sums."""
    kern = functools.partial(_option_kernel, s0=s0, strike=strike, r=r,
                             sigma=sigma, t=t)
    return _launch(kern, roots, ctr_rows, hx, hy, jnp.float32,
                   block_t=block_t, block_s=block_s, interpret=interpret)
