"""mamba2-2.7b [ssm]: 64L d2560 (attn-free) vocab50280, ssm_state=128.
SSD (state-space duality); expand=2 -> d_inner 5120, head_dim 64 -> 80
heads, 1 group, conv4.  [arXiv:2405.21060]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280, tie_embeddings=True,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64)
