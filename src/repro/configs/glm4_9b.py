"""glm4-9b [dense]: 40L d4096 32H (GQA kv=2) ff13696 vocab151552.
RoPE + SwiGLU.  [hf:THUDM/glm-4-9b; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab=151552, act="silu",
    rope_theta=10000.0)
