"""granite-moe-3b-a800m [moe]: 32L d1536 24H (GQA kv=8) expert_ff=512
vocab49155, MoE 40 experts top-8.  [hf:ibm-granite granite-3.0 family; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155, act="silu",
    n_experts=40, top_k=8, rope_theta=10000.0,
    # E=40 doesn't divide the 16-way model axis, so experts run f-sharded;
    # group 512 keeps the (gs, E, C) dispatch tensors within 16 GB/chip
    moe_group=512)
