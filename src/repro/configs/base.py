"""Shape grid, config registry and ShapeDtypeStruct input specs.

Shapes (assigned):
  train_4k     seq 4096   global_batch 256   (training)
  prefill_32k  seq 32768  global_batch 32    (inference prefill)
  decode_32k   ctx 32768  global_batch 128   (one-token decode step)
  long_500k    ctx 524288 global_batch 1     (long-context decode;
               sub-quadratic archs only — full-attention archs skip)
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.common import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_IDS = [
    "gemma_7b", "glm4_9b", "qwen15_32b", "granite_34b", "qwen2_vl_72b",
    "granite_moe_3b", "olmoe_1b_7b", "mamba2_2p7b", "zamba2_7b",
    "whisper_small",
]

# accept dashed public ids too
_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update({
    "gemma-7b": "gemma_7b", "glm4-9b": "glm4_9b",
    "qwen1.5-32b": "qwen15_32b", "granite-34b": "granite_34b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "olmoe-1b-7b": "olmoe_1b_7b", "mamba2-2.7b": "mamba2_2p7b",
    "zamba2-7b": "zamba2_7b", "whisper-small": "whisper_small",
})


def get_config(arch: str) -> ArchConfig:
    key = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def shape_skipped(cfg: ArchConfig, shape: str) -> Optional[str]:
    """Reason this (arch, shape) cell is skipped, or None if runnable."""
    if shape == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return ("pure full-attention arch: 500k-token decode needs "
                "sub-quadratic attention (DESIGN.md §Arch-applicability)")
    return None


def runnable_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape_skipped(cfg, shape) is None:
                yield arch, shape


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ArchConfig, shape_name: str,
                model=None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one step of the given kind.

    train  -> {"tokens", "labels"} (+ "patches"/"frames")
    prefill-> {"tokens"} (+ extras)
    decode -> {"token", "cache", "pos"} — cache specs from
              Model.init_cache evaluated abstractly (no allocation).
    """
    spec = SHAPES[shape_name]
    B, S = spec.global_batch, spec.seq_len
    extras: Dict[str, Any] = {}
    if cfg.family == "vlm":
        extras["patches"] = _sds((B, cfg.vision_prefix, cfg.d_model),
                                 L.COMPUTE_DTYPE)
    if cfg.family == "encdec":
        extras["frames"] = _sds((B, cfg.enc_ctx, cfg.d_model),
                                L.COMPUTE_DTYPE)
    if spec.kind == "train":
        return {"tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32), **extras}
    if spec.kind == "prefill":
        return {"tokens": _sds((B, S), jnp.int32), **extras}
    if spec.kind == "decode":
        from repro.models import registry
        m = model or registry.build(cfg)
        cache = jax.eval_shape(lambda: m.init_cache(B, S))
        return {"token": _sds((B, 1), jnp.int32),
                "cache": cache,
                "pos": _sds((), jnp.int32)}
    raise ValueError(spec.kind)
