"""qwen1.5-32b [dense]: 64L d5120 40H (kv=40) ff27392 vocab152064.
QKV bias.  [hf:Qwen/Qwen1.5 family; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=40, d_ff=27392, vocab=152064, act="silu",
    qkv_bias=True, rope_theta=1000000.0,
    # 40-head full-MHA KV at 32k x 128 is 5.5 TB in bf16 (21.5 GiB/chip
    # even context+batch sharded) — store the cache in float8_e4m3
    kv_dtype="f8")
