"""granite-34b [dense]: 88L d6144 48H (MQA kv=1) ff24576 vocab49152.
Code model; GPTBigCode-style plain-GELU MLP (2 matrices — matches the 34B
parameter count).  [arXiv:2405.04324; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense", n_layers=88, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab=49152, act="gelu",
    rope_theta=10000.0)
