"""whisper-small [audio/enc-dec]: 12+12L d768 12H ff3072 vocab51865.
Conv frontend is a STUB — input_specs() supplies precomputed frame
embeddings (B, 1500, 768).  Sinusoidal positions on both sides (the
reference uses learned decoder positions; documented deviation).
[arXiv:2212.04356]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec", n_layers=12, enc_layers=12,
    d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865,
    act="gelu", tie_embeddings=True, rope_theta=0.0, enc_ctx=1500,
    norm_eps=1e-5)
