"""olmoe-1b-7b [moe]: 16L d2048 16H (kv=16) expert_ff=1024 vocab50304,
MoE 64 experts top-8.  [arXiv:2409.02060; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304, act="silu",
    n_experts=64, top_k=8, rope_theta=10000.0)
