"""zamba2-7b [hybrid]: 81 mamba2 layers d3584 + ONE shared attention+MLP
block (32H kv=32, ff 14336) applied every 6 layers; ssm_state=64.
Per-application LoRA of the shared block omitted (DESIGN.md).
[arXiv:2411.15242]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14336, vocab=32000,
    act="silu", tie_embeddings=True, attn_every=6,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64)
