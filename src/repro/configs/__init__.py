"""Assigned architecture configs (exact published dims) + input shapes."""
from repro.configs.base import (ARCH_IDS, SHAPES, get_config, input_specs,
                                runnable_cells, shape_skipped)

__all__ = ["ARCH_IDS", "SHAPES", "get_config", "input_specs",
           "runnable_cells", "shape_skipped"]
