"""qwen2-vl-72b [vlm]: 80L d8192 64H (GQA kv=8) ff29568 vocab152064.
M-RoPE realized as RoPE over collapsed position ids; dynamic-resolution
vision frontend is a STUB — input_specs() supplies precomputed patch
embeddings for a 1024-token vision prefix.  [arXiv:2409.12191; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=29568, vocab=152064, act="silu",
    qkv_bias=True, rope_theta=1000000.0, vision_prefix=1024)
