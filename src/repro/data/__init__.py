from repro.data.pipeline import LeasedBatchFeeder, SyntheticLMPipeline

__all__ = ["LeasedBatchFeeder", "SyntheticLMPipeline"]
