from repro.data.pipeline import SyntheticLMPipeline

__all__ = ["SyntheticLMPipeline"]
