"""Deterministic, seekable synthetic LM data pipeline on ThundeRiNG.

Every batch is a pure function of (seed, step): batch b at step s draws
tokens from the MISRN stream ``derive(data_root, s)`` at counter 0.  This
is the fault-tolerance property the counter-addressable design buys:

  * exact resume after restart from the step number alone — no shard
    iterators to checkpoint, no log replay;
  * any worker can recompute any other worker's shard (straggler /
    failure recovery), because shards are counter ranges, not stateful
    cursors;
  * bitwise-identical batches under any device count or mesh shape.

Delivery goes through the block layer (``runtime.blocks``):
``LeasedBatchFeeder`` registers the pipeline as a ``BlockService``
channel whose window unit is ONE OPTIMIZER STEP — step ``s`` is the
window ``[s, s+1)``, i.e. the counter range of the derived leaf that
batch consumes.  A producer thread leases and dispatches batch ``s+1``
while step ``s`` computes (double-buffering), the lease ledger makes
feeding a step's randomness twice a structural error, and exact
mid-epoch resume falls out of restoring the ledger snapshot stored in
the checkpoint.

The token distribution is Zipfian over the vocab (a rough LM-like
marginal) with a deterministic shift mixing so batches differ per step.
For the paper-shaped use case (the RNG *is* the substrate under test)
this synthetic stream doubles as the data-side consumer of MISRN.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stream as tstream
from repro.core.u64 import U32


@dataclasses.dataclass
class SyntheticLMPipeline:
    seed: int
    vocab: int
    global_batch: int
    seq_len: int
    zipf_alpha: float = 1.1
    extras: Optional[Dict[str, tuple]] = None   # name -> shape suffix

    def __post_init__(self):
        self._root = tstream.new_stream(self.seed, 0xDA7A)
        # Zipf CDF over vocab (host-side, once)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        w = ranks ** (-self.zipf_alpha)
        self._cdf = jnp.asarray(np.cumsum(w) / w.sum(), jnp.float32)

    def batch_at(self, step: int | jnp.ndarray) -> Dict[str, jnp.ndarray]:
        """The batch for a given step (pure; jit-friendly)."""
        if isinstance(step, int):
            st = tstream.derive(self._root, step)
        else:
            st = tstream.derive(self._root, step.astype(U32))
        B, S = self.global_batch, self.seq_len
        u = tstream.uniform(st, (B, S + 1))
        toks = jnp.searchsorted(self._cdf, u).astype(jnp.int32)
        toks = jnp.clip(toks, 0, self.vocab - 1)
        batch = {"tokens": toks[:, :S], "labels": toks[:, 1:]}
        if self.extras:
            est = tstream.derive(st, 0xE57A)
            for name, suffix in self.extras.items():
                batch[name] = tstream.normal(
                    est, (B, *suffix), jnp.bfloat16)
        return batch

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class LeasedBatchFeeder:
    """Lease-accounted, double-buffered batch source for the train loop.

    One ``BlockService`` channel (``"data/batches"``, window unit = one
    optimizer step) delivers the SAME bits as calling ``batch_at(step)``
    directly — the batch function is unchanged and pure — but through
    the block layer: a producer thread dispatches batch ``s+1`` while
    the trainer runs step ``s`` (``block_until_ready``-free handoff),
    and the lease ledger records exactly which steps' randomness has
    been consumed.

    ``batch_for(step)`` expects sequential steps; a non-sequential step
    (restart-from-checkpoint) repositions the producer, which the ledger
    only permits after ``service.restore_ledger`` rewound it — the
    double-spend protection the per-step ``derive`` convention never
    had.
    """

    CHANNEL = "data/batches"

    def __init__(self, pipe: SyntheticLMPipeline, service, *,
                 depth: int = 1):
        self._pipe = pipe
        self._service = service
        self._depth = depth
        self._jit_batch = jax.jit(lambda s: pipe.batch_at(s))
        self._producer = None
        self._next: Optional[int] = None
        service.open(self.CHANNEL, window_fn=self._window)

    def _window(self, lo: int, hi: int):
        if hi != lo + 1:
            raise ValueError(f"data windows are single steps, got "
                             f"[{lo}, {hi})")
        return self._jit_batch(jnp.uint32(lo))

    def batch_for(self, step: int) -> Dict[str, jnp.ndarray]:
        """The (prefetched) batch for ``step``; commits its lease."""
        if self._producer is None or self._next != step:
            self.reset()
            self._producer = self._service.producer(
                self.CHANNEL, 1, depth=self._depth, start=step)
            self._next = step
        lease, batch = next(self._producer)
        assert lease.lo == step, (lease.lo, step)
        self._next = step + 1
        return batch

    def reset(self) -> None:
        """Close the producer and drop its unconsumed reservations (call
        after a ledger restore, before resuming from the restored step)."""
        if self._producer is not None:
            self._producer.close()
            self._producer = None
        self._next = None
