"""Deterministic, seekable synthetic LM data pipeline on ThundeRiNG.

Every batch is a pure function of (seed, step): batch b at step s draws
tokens from the MISRN stream ``derive(data_root, s)`` at counter 0.  This
is the fault-tolerance property the counter-addressable design buys:

  * exact resume after restart from the step number alone — no shard
    iterators to checkpoint, no log replay;
  * any worker can recompute any other worker's shard (straggler /
    failure recovery), because shards are counter ranges, not stateful
    cursors;
  * bitwise-identical batches under any device count or mesh shape.

The token distribution is Zipfian over the vocab (a rough LM-like
marginal) with a deterministic shift mixing so batches differ per step.
For the paper-shaped use case (the RNG *is* the substrate under test)
this synthetic stream doubles as the data-side consumer of MISRN.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stream as tstream
from repro.core.u64 import U32


@dataclasses.dataclass
class SyntheticLMPipeline:
    seed: int
    vocab: int
    global_batch: int
    seq_len: int
    zipf_alpha: float = 1.1
    extras: Optional[Dict[str, tuple]] = None   # name -> shape suffix

    def __post_init__(self):
        self._root = tstream.new_stream(self.seed, 0xDA7A)
        # Zipf CDF over vocab (host-side, once)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        w = ranks ** (-self.zipf_alpha)
        self._cdf = jnp.asarray(np.cumsum(w) / w.sum(), jnp.float32)

    def batch_at(self, step: int | jnp.ndarray) -> Dict[str, jnp.ndarray]:
        """The batch for a given step (pure; jit-friendly)."""
        if isinstance(step, int):
            st = tstream.derive(self._root, step)
        else:
            st = tstream.derive(self._root, step.astype(U32))
        B, S = self.global_batch, self.seq_len
        u = tstream.uniform(st, (B, S + 1))
        toks = jnp.searchsorted(self._cdf, u).astype(jnp.int32)
        toks = jnp.clip(toks, 0, self.vocab - 1)
        batch = {"tokens": toks[:, :S], "labels": toks[:, 1:]}
        if self.extras:
            est = tstream.derive(st, 0xE57A)
            for name, suffix in self.extras.items():
                batch[name] = tstream.normal(
                    est, (B, *suffix), jnp.bfloat16)
        return batch

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
