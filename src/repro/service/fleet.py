"""RandService fleet: sharded serving with journal-backed failover.

The paper's decorrelated counter addressing makes every response a pure
function of ``(seed, tenant tags, counter window)`` — so a serving
*fleet* needs no shared mutable state at all.  Each shard process runs
a full ``RandServer`` over the SAME global plan; the client-side hash
ring decides which tenants it serves; the only durable state is the
shard's append-only journal.  Failover is therefore *stateless*: a
surviving peer takes the dead shard's journal lock (the OS releases a
flock only when the owner is truly gone — fencing for free), restores
the journaled windows into a fresh ledger, raises the lease floor to
the journaled high-water mark, and resumes the dead shard's tenant
regions.

Shards serve COALESCED (``max_batch > 1``) with standing producer
pools, yet failover stays digest-identical, because batch composition
is deterministic end to end: the client sends each shard's request
subsequence in order on ONE pipelined connection (arrival order =
send order), the shard's transport gate seals batches purely by count
or an explicit ``flush`` op (never wall-clock, never connection EOF),
and every sealed batch is journaled as ONE atomic record before its
responses release.  A crashed shard's journal is therefore always
batch-aligned; the adopter re-forms the identical batches from the
client's in-order resubmission — which is exactly what the
kill-mid-burst CI check asserts by digest equality.

Pieces:

  * :class:`HashRing` — consistent tenant -> logical-shard routing
    (blake2s vnodes, pure function of the shard count),
  * :class:`Fleet` — controller that spawns N ``ShardHost``
    subprocesses, hands out addresses, and can *fence* (SIGKILL + wait)
    a shard that is alive-but-hung so its journal lock drops,
  * :class:`FleetClient` — PIPELINED router: per shard, a bounded
    in-flight window of rid-tagged frames over the negotiated wire
    version (binary v2 by default), out-of-order completion, in-order
    per-tenant delivery, per-request deadlines, bounded exponential
    backoff, and fence-gated hedged resubmission: when the owner of a
    shard stops answering, the client asks the failover peer to adopt
    the shard's journal; the peer's flock attempt either succeeds
    (owner dead -> hedge serves there) or reports ``locked`` (owner
    alive -> back off, optionally fence, retry); after failover every
    unanswered request resubmits in original order (journaled rids
    answer by replay, parked rids dedup server-side),
  * :func:`run_fleet_burst` — per-shard in-order burst driver (the
    deterministic traffic shape the digest checks rely on).

Subprocess entry: ``python -m repro.service.fleet --serve --shard i``
(spawned by :class:`Fleet`; drains gracefully on SIGTERM/SIGINT).
"""
from __future__ import annotations

import argparse
import bisect
import dataclasses
import hashlib
import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.fault import FaultInjector, FaultPlan
from repro.service import transport
from repro.service.frontend import RandRequest
from repro.service.server import ServerConfig, drain_signal_event


# ---------------------------------------------------------------------------
# Consistent-hash routing
# ---------------------------------------------------------------------------

def _h64(text: str) -> int:
    return int.from_bytes(
        hashlib.blake2s(text.encode("utf-8"), digest_size=8).digest(),
        "little")


class HashRing:
    """Consistent tenant -> shard map: ``replicas`` blake2s vnodes per
    shard on a u64 ring.  Pure function of ``(num_shards, replicas)`` —
    every client and every test derives the identical routing table
    with zero coordination.

    Example:
        >>> from repro.service.fleet import HashRing
        >>> ring = HashRing(2)
        >>> ring.owner("tenant/00042") == ring.owner("tenant/00042")
        True
        >>> sorted({ring.owner(f"t{i}") for i in range(64)})
        [0, 1]
    """

    def __init__(self, num_shards: int, *, replicas: int = 64):
        if num_shards < 1:
            raise ValueError(f"need >= 1 shard, got {num_shards}")
        self.num_shards = num_shards
        self.replicas = replicas
        pts = []
        for s in range(num_shards):
            for r in range(replicas):
                pts.append((_h64(f"shard:{s}:vnode:{r}"), s))
        pts.sort()
        self._points = [p for p, _ in pts]
        self._owners = [s for _, s in pts]

    def owner(self, tenant_id: str) -> int:
        """Logical shard owning ``tenant_id``'s region."""
        h = _h64(f"tenant:{tenant_id}")
        i = bisect.bisect_right(self._points, h) % len(self._points)
        return self._owners[i]

    def peers(self, shard: int) -> List[int]:
        """Failover preference order for ``shard``: the other shards,
        nearest successor first (deterministic — every client picks the
        same adoption target)."""
        return [(shard + k) % self.num_shards
                for k in range(1, self.num_shards)]


# ---------------------------------------------------------------------------
# Fleet controller (parent process)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Topology + client policy of one fleet run.

    ``max_batch > 1`` is safe because batch composition is itself
    deterministic: the client's per-shard pipeline sends in order, the
    shard's gate seals purely by count (or the client's trailing
    ``flush``), and each sealed microbatch journals as one atomic
    record — so crash-replay and adoption re-form identical batches
    and the kill-mid-burst digest-equality check still holds.

    ``pipeline_depth`` bounds the client's in-flight window per shard
    connection; it is clamped up to the server's negotiated
    ``max_batch`` so a full batch can always be in flight (a smaller
    window would deadlock: the gate waits for arrivals the client is
    withholding).  ``binary=True`` negotiates wire v2 (raw
    little-endian array payloads, zero-copy decode); v1 JSON remains
    for compatibility.  ``hot_classes`` lists ``(sampler, dtype)``
    pairs each shard keeps standing producer pools for.
    """
    num_shards: int = 2
    seed: int = 0
    journal_dir: str = "."
    host: str = "127.0.0.1"
    max_batch: int = 32
    pipeline_depth: int = 32
    binary: bool = True
    hot_classes: Tuple[Tuple[str, str], ...] = (
        ("bits", "float32"), ("uniform", "float32"))
    queue_depth: int = 4096
    deadline_s: float = 120.0        # generous: first contacts pay jit
    connect_timeout_s: float = 10.0
    max_retries: int = 6
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    replicas: int = 64
    spawn_timeout_s: float = 120.0


class FleetError(RuntimeError):
    """A request could not be served within the retry/deadline budget."""


class Fleet:
    """Spawn and supervise ``num_shards`` ShardHost subprocesses.

    Each child binds an ephemeral port and writes it to
    ``<journal_dir>/shard<i>.port``; stdout/stderr stream to
    ``shard<i>.log``.  ``fence(i)`` is the STONITH step: SIGKILL + wait,
    guaranteeing the child's journal flock is released before a peer
    adopts it.
    """

    def __init__(self, config: FleetConfig,
                 fault_plan: Optional[FaultPlan] = None):
        self.config = config
        self.fault_plan = fault_plan or FaultPlan()
        os.makedirs(config.journal_dir, exist_ok=True)
        self._procs: List[subprocess.Popen] = []
        self._addrs: Dict[int, Tuple[str, int]] = {}
        src_root = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        env = dict(os.environ)
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env.setdefault("JAX_PLATFORMS", "cpu")
        for i in range(config.num_shards):
            cmd = [sys.executable, "-m", "repro.service.fleet", "--serve",
                   "--shard", str(i), "--seed", str(config.seed),
                   "--host", config.host,
                   "--journal", self.journal_path(i),
                   "--port-file", self._port_file(i),
                   "--max-batch", str(config.max_batch),
                   "--queue-depth", str(config.queue_depth),
                   "--hot-classes", ",".join(
                       f"{s}:{d}" for s, d in config.hot_classes)]
            if self.fault_plan:
                cmd += ["--fault-plan", self.fault_plan.to_json()]
            log = open(os.path.join(config.journal_dir,
                                    f"shard{i}.log"), "ab")
            try:
                self._procs.append(subprocess.Popen(
                    cmd, env=env, stdout=log, stderr=subprocess.STDOUT))
            finally:
                log.close()
        self._await_ports()

    def _port_file(self, i: int) -> str:
        return os.path.join(self.config.journal_dir, f"shard{i}.port")

    def journal_path(self, i: int) -> str:
        return os.path.join(self.config.journal_dir, f"shard{i}.jsonl")

    def _await_ports(self) -> None:
        deadline = time.monotonic() + self.config.spawn_timeout_s
        for i, proc in enumerate(self._procs):
            pf = self._port_file(i)
            while True:
                if os.path.exists(pf):
                    try:
                        port = int(open(pf).read().strip())
                        break
                    except ValueError:
                        pass        # partially written; poll again
                if proc.poll() is not None:
                    raise FleetError(
                        f"shard {i} exited rc={proc.returncode} before "
                        f"listening (see shard{i}.log)")
                if time.monotonic() > deadline:
                    raise FleetError(f"shard {i} never published a port")
                time.sleep(0.02)
            self._addrs[i] = (self.config.host, port)

    def address(self, i: int) -> Tuple[str, int]:
        return self._addrs[i]

    def addresses(self) -> Dict[int, Tuple[str, int]]:
        return dict(self._addrs)

    def journals(self) -> Dict[int, str]:
        return {i: self.journal_path(i)
                for i in range(self.config.num_shards)}

    def alive(self, i: int) -> bool:
        return self._procs[i].poll() is None

    def fence(self, i: int) -> None:
        """Guarantee shard process ``i`` is dead (SIGKILL + reap) so its
        journal lock is released — the STONITH step before adoption."""
        proc = self._procs[i]
        if proc.poll() is None:
            proc.kill()
        proc.wait(timeout=30)

    def client(self, **overrides) -> "FleetClient":
        return FleetClient(self.addresses(), self.journals(),
                           config=self.config, fencer=self.fence,
                           **overrides)

    def stop(self, timeout: float = 60.0) -> None:
        """Graceful fleet shutdown: SIGTERM (drain) then SIGKILL."""
        for proc in self._procs:
            if proc.poll() is None:
                proc.terminate()
        deadline = time.monotonic() + timeout
        for proc in self._procs:
            try:
                proc.wait(timeout=max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=30)

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


# ---------------------------------------------------------------------------
# Client-side router
# ---------------------------------------------------------------------------

class _MeterSock:
    """Byte-metering socket wrapper: counts exactly what crosses the
    wire so ``bytes_on_wire_per_req`` in the bench rows is measured,
    not estimated."""

    __slots__ = ("sock", "tx", "rx")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.tx = 0
        self.rx = 0

    def sendall(self, data) -> None:
        self.sock.sendall(data)
        self.tx += len(data)

    def recv(self, n: int) -> bytes:
        data = self.sock.recv(n)
        self.rx += len(data)
        return data

    def settimeout(self, t: Optional[float]) -> None:
        self.sock.settimeout(t)

    def close(self) -> None:
        self.sock.close()


class _PipeConn:
    """One persistent PIPELINED connection to whichever process owns a
    logical shard.  Single-owner (the per-shard burst thread).

    ``ensure()`` connects lazily and runs the hello negotiation once
    per connection: the client offers its wire versions, the server
    answers with the highest common one plus its ``max_batch`` (which
    the caller folds into its in-flight window).  Byte counters
    survive reconnects: ``disconnect()`` folds the dead socket's
    totals into the conn before dropping it.
    """

    def __init__(self, addr: Tuple[str, int], *, connect_timeout: float,
                 versions: Tuple[int, ...]):
        self.addr = addr
        self.connect_timeout = connect_timeout
        self.versions = versions
        self.sock: Optional[_MeterSock] = None
        self.version = transport.WIRE_V1
        self.server_max_batch = 1
        self.tx = 0                  # folded totals from dead sockets
        self.rx = 0

    def ensure(self) -> None:
        if self.sock is not None:
            return
        raw = socket.create_connection(self.addr,
                                       timeout=self.connect_timeout)
        self.sock = _MeterSock(raw)
        transport.send_wire(
            self.sock, {"op": "hello",
                        "versions": sorted(self.versions)},
            version=transport.WIRE_V1)
        got = transport.recv_wire(self.sock)
        if got is None:
            raise transport.TornFrame(f"no hello reply from {self.addr}")
        reply, _ = got
        if not reply.get("ok"):
            raise transport.WireError(
                reply.get("kind", "error"),
                str(reply.get("error", "hello refused")))
        self.version = int(reply.get("version", transport.WIRE_V1))
        self.server_max_batch = int(reply.get("max_batch", 1))

    def send(self, obj: Dict[str, Any]) -> None:
        self.ensure()
        transport.send_wire(self.sock, obj, version=self.version)

    def recv(self, timeout: float) -> Dict[str, Any]:
        self.sock.settimeout(timeout)
        got = transport.recv_wire(self.sock)
        if got is None:
            raise transport.TornFrame(f"EOF from {self.addr}")
        return got[0]

    def bytes_total(self) -> Tuple[int, int]:
        live_tx = self.sock.tx if self.sock is not None else 0
        live_rx = self.sock.rx if self.sock is not None else 0
        return self.tx + live_tx, self.rx + live_rx

    def disconnect(self) -> None:
        if self.sock is not None:
            self.tx += self.sock.tx
            self.rx += self.sock.rx
            try:
                self.sock.close()
            except OSError:
                pass
            self.sock = None

    def reset(self, addr: Tuple[str, int]) -> None:
        self.disconnect()
        self.addr = addr


class FleetClient:
    """Route requests to shard owners; pipeline, retry, hedge, fail
    over.

    Each logical shard gets ONE pipelined connection: a bounded
    in-flight window of rid-tagged request frames, completions
    accepted out of order, responses released strictly in the shard's
    original request order (which is per-tenant order, since a tenant
    maps to exactly one shard).  After the window's last request a
    ``flush`` op seals any partial microbatch server-side.

    The failure path for a shard whose owner stopped answering:

    1. bounded exponential backoff retries against the current owner
       (covers transient slowness and scripted ``slow`` faults —
       idempotent because a journaled rid is answered by replay),
    2. in parallel with each retry, a *fence-gated hedge*: ask the
       ring's failover peer to ``adopt`` the shard's journal.  The
       peer's exclusive flock attempt is the safety interlock — it
       succeeds only if the owner is actually dead,
    3. if adoption keeps reporting ``locked`` (owner alive but hung)
       and a ``fencer`` is available, fence the owner (SIGKILL + wait)
       and adopt — never two writers, never a lost response,
    4. after reconnecting, every still-unanswered request resubmits in
       its original order: journaled rids answer by replay, parked
       rids attach to the in-flight future, the rest re-enter the gate
       — so batch composition (and hence every byte) matches a
       fault-free run.
    """

    def __init__(self, addresses: Dict[int, Tuple[str, int]],
                 journals: Dict[int, str], *,
                 config: Optional[FleetConfig] = None,
                 fencer: Optional[Callable[[int], None]] = None,
                 ring: Optional[HashRing] = None,
                 deadline_s: Optional[float] = None,
                 fence_after: int = 2,
                 binary: Optional[bool] = None):
        self.config = config or FleetConfig(num_shards=len(addresses))
        self.addresses = dict(addresses)
        self.journals = dict(journals)
        self.fencer = fencer
        self.fence_after = fence_after
        self.deadline_s = (self.config.deadline_s
                           if deadline_s is None else deadline_s)
        self.binary = self.config.binary if binary is None else binary
        self._versions: Tuple[int, ...] = (
            (transport.WIRE_V1, transport.WIRE_V2) if self.binary
            else (transport.WIRE_V1,))
        self.ring = ring or HashRing(len(addresses),
                                     replicas=self.config.replicas)
        # logical shard -> process index currently hosting it
        self._owner: Dict[int, int] = {i: i for i in addresses}
        self._conns: Dict[int, _PipeConn] = {}
        self._lock = threading.Lock()
        self.latencies: List[float] = []
        self.retries = 0
        self.failovers = 0
        self.errors = 0
        self.recovery_s: Optional[float] = None
        # (tenant_id, rid) in delivery order — the per-tenant ordering
        # oracle the pipelining tests assert over
        self.delivery_log: List[Tuple[str, str]] = []
        self._bytes_base = (0, 0)    # byte totals at last reset_metrics

    # -- connection/ownership ---------------------------------------------

    def _conn(self, logical: int) -> _PipeConn:
        with self._lock:
            proc = self._owner[logical]
            conn = self._conns.get(logical)
            addr = self.addresses[proc]
            if conn is None:
                conn = _PipeConn(
                    addr, connect_timeout=self.config.connect_timeout_s,
                    versions=self._versions)
                self._conns[logical] = conn
            elif conn.addr != addr:
                conn.reset(addr)
            return conn

    def _try_adopt(self, logical: int) -> bool:
        """Hedge to the failover peer: adopted -> reroute and return
        True; ``locked`` (owner still alive) -> False."""
        dead_proc = self._owner[logical]
        for peer_logical in self.ring.peers(logical):
            with self._lock:
                peer_proc = self._owner[peer_logical]
            if peer_proc == dead_proc:
                continue
            try:
                reply = transport.rpc(
                    self.addresses[peer_proc],
                    {"op": "adopt", "shard": logical,
                     "journal": self.journals[logical]},
                    timeout=self.config.connect_timeout_s)
            except (OSError, transport.TransportError):
                continue            # peer also unreachable; next one
            if reply.get("ok"):
                with self._lock:
                    self._owner[logical] = peer_proc
                    conn = self._conns.get(logical)
                if conn is not None:
                    conn.reset(self.addresses[peer_proc])
                self.failovers += 1
                return True
            if reply.get("kind") != "locked":
                continue
        return False

    # -- request path ------------------------------------------------------

    def run_shard(self, logical: int, reqs: List[RandRequest],
                  responses: Optional[Dict[str, np.ndarray]] = None
                  ) -> Dict[str, np.ndarray]:
        """Serve ``reqs`` (one shard's in-order subsequence) through a
        bounded pipelined window, riding out owner death.

        Completions arrive rid-tagged and possibly out of order;
        delivery into ``responses`` (and ``delivery_log``) is strictly
        in ``reqs`` order.  On a wire failure every unanswered request
        resubmits in original order after the adopt/fence dance — the
        server dedups by rid, so composition is preserved.
        """
        if responses is None:
            responses = {}
        for r in reqs:
            if r.rid is None:
                raise ValueError("fleet requests need caller-stamped rids")
        resolved: Dict[str, np.ndarray] = {}
        t_first: Dict[str, float] = {}
        delivered = 0

        def release() -> None:
            nonlocal delivered
            while (delivered < len(reqs)
                   and reqs[delivered].rid in resolved):
                req = reqs[delivered]
                responses[req.rid] = resolved[req.rid]
                with self._lock:
                    self.latencies.append(
                        time.perf_counter() - t_first[req.rid])
                    self.delivery_log.append((req.tenant_id, req.rid))
                delivered += 1

        attempt = 0
        failed_at: Optional[float] = None
        last_exc: Optional[BaseException] = None
        while delivered < len(reqs):
            conn = self._conn(logical)
            try:
                conn.ensure()
                window = max(self.config.pipeline_depth,
                             conn.server_max_batch)
                todo = [r for r in reqs if r.rid not in resolved]
                inflight: set = set()
                sent = 0
                flushed = False
                while inflight or sent < len(todo) or not flushed:
                    while sent < len(todo) and len(inflight) < window:
                        r = todo[sent]
                        t_first.setdefault(r.rid, time.perf_counter())
                        conn.send(transport.request_to_wire(r, logical))
                        inflight.add(r.rid)
                        sent += 1
                    if sent >= len(todo) and not flushed:
                        # seal any partial microbatch server-side
                        conn.send({"op": "flush", "shard": logical})
                        flushed = True
                    if not inflight and flushed:
                        break
                    reply = conn.recv(self.deadline_s)
                    rid = reply.get("rid")
                    if rid is None:
                        continue            # op ack (flush)
                    if reply.get("ok"):
                        if (failed_at is not None
                                and self.recovery_s is None):
                            self.recovery_s = (time.perf_counter()
                                               - failed_at)
                        inflight.discard(rid)
                        resolved[rid] = transport.reply_array(reply)
                        release()
                        continue
                    if reply.get("kind") == "not_owner":
                        # ownership moved (another thread's failover
                        # won): rediscover, then resubmit unanswered
                        raise transport.WireError(
                            "not_owner", str(reply.get("error", "")))
                    self.errors += 1
                    raise FleetError(
                        f"shard {logical} refused {rid}: "
                        f"{reply.get('kind')}: {reply.get('error')}")
                continue                    # loop guard re-checks
            except (OSError, transport.TransportError,
                    transport.WireError) as e:
                last_exc = e
                if failed_at is None:
                    failed_at = time.perf_counter()
                self.retries += 1
                conn.disconnect()
                adopted = self._try_adopt(logical)
                if not adopted:
                    if (self.fencer is not None
                            and attempt + 1 >= self.fence_after):
                        # hung owner: its journal lock is still held —
                        # fence (SIGKILL + wait) so adoption can proceed
                        self.fencer(self._owner[logical])
                        adopted = self._try_adopt(logical)
                if not adopted:
                    time.sleep(min(self.config.backoff_cap_s,
                                   self.config.backoff_base_s
                                   * (2 ** attempt)))
                attempt += 1
                if attempt > self.config.max_retries:
                    self.errors += 1
                    raise FleetError(
                        f"shard {logical} burst exhausted "
                        f"{self.config.max_retries} retries "
                        f"({len(reqs) - delivered} undelivered)"
                        ) from last_exc
        return responses

    def request(self, req: RandRequest) -> np.ndarray:
        """Serve one request (a single-element pipelined window; the
        trailing ``flush`` seals the server's partial batch)."""
        if req.rid is None:
            raise ValueError("fleet requests need caller-stamped rids")
        out = self.run_shard(self.ring.owner(req.tenant_id), [req])
        return out[req.rid]

    def reset_metrics(self) -> None:
        """Zero latency/retry/byte accounting (connections stay up) so
        a benchmark can split warm-up from a steady-state window."""
        with self._lock:
            self.latencies = []
            self.delivery_log = []
            self.retries = self.failovers = self.errors = 0
            self.recovery_s = None
            self._bytes_base = (
                sum(c.bytes_total()[0] for c in self._conns.values()),
                sum(c.bytes_total()[1] for c in self._conns.values()))

    def stats(self) -> Dict[str, Any]:
        lat = np.asarray(self.latencies, np.float64)
        with self._lock:
            tx = (sum(c.bytes_total()[0] for c in self._conns.values())
                  - self._bytes_base[0])
            rx = (sum(c.bytes_total()[1] for c in self._conns.values())
                  - self._bytes_base[1])
        n = int(lat.size)
        return {
            "requests": n,
            "retries": self.retries,
            "failovers": self.failovers,
            "errors": self.errors,
            "recovery_ms": (None if self.recovery_s is None
                            else self.recovery_s * 1e3),
            "latency_p50_ms": (float(np.percentile(lat, 50)) * 1e3
                               if lat.size else 0.0),
            "latency_p99_ms": (float(np.percentile(lat, 99)) * 1e3
                               if lat.size else 0.0),
            "bytes_tx": tx,
            "bytes_rx": rx,
            "bytes_on_wire_per_req": ((tx + rx) / n if n else 0.0),
        }

    def close(self) -> None:
        with self._lock:
            conns = list(self._conns.values())
        for conn in conns:
            conn.disconnect()


def run_fleet_burst(client: FleetClient,
                    requests: List[RandRequest]
                    ) -> Dict[str, np.ndarray]:
    """Drive a burst through the fleet: requests partition by owning
    shard (order preserved) and each partition runs through the
    pipelined per-shard engine on its own thread — every shard sees a
    deterministic in-order subsequence (bounded in-flight window,
    in-order delivery), so assignments are reproducible, fault or no
    fault.
    """
    by_shard: Dict[int, List[RandRequest]] = {}
    for req in requests:
        by_shard.setdefault(client.ring.owner(req.tenant_id),
                            []).append(req)
    responses: Dict[str, np.ndarray] = {}
    failures: List[BaseException] = []
    lock = threading.Lock()

    def worker(shard: int, reqs: List[RandRequest]) -> None:
        try:
            out = client.run_shard(shard, reqs)
        except BaseException as e:   # noqa: BLE001 — surfaced below
            with lock:
                failures.append(e)
            return
        with lock:
            responses.update(out)

    threads = [threading.Thread(target=worker, args=(shard, reqs),
                                daemon=True)
               for shard, reqs in by_shard.items()]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if failures:
        raise failures[0]
    return responses


# ---------------------------------------------------------------------------
# Shard subprocess entry
# ---------------------------------------------------------------------------

def serve_shard(args) -> int:
    injector = None
    if args.fault_plan:
        injector = FaultInjector(FaultPlan.parse(args.fault_plan))
    hot = tuple(tuple(p.split(":", 1))
                for p in args.hot_classes.split(",") if p)
    cfg = ServerConfig(max_batch=args.max_batch, max_delay_s=0.0,
                       queue_depth=args.queue_depth, hot_classes=hot)
    host = transport.ShardHost(args.seed, host=args.host, port=args.port,
                               config=cfg, injector=injector)
    host.add_shard(args.shard, args.journal)
    stop = drain_signal_event()
    # port file last: its existence means "accepting and shard is open"
    tmp = args.port_file + ".tmp"
    with open(tmp, "w") as f:
        f.write(str(host.address[1]))
    os.replace(tmp, args.port_file)
    stop.wait()
    host.close()
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="RandService fleet shard process")
    ap.add_argument("--serve", action="store_true")
    ap.add_argument("--shard", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--journal", required=True)
    ap.add_argument("--port-file", required=True)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--queue-depth", type=int, default=4096)
    ap.add_argument("--hot-classes", default="",
                    help="comma-joined sampler:dtype pool classes")
    ap.add_argument("--fault-plan", default="")
    args = ap.parse_args(argv)
    if not args.serve:
        ap.error("--serve is the only mode (spawned by fleet.Fleet)")
    return serve_shard(args)


if __name__ == "__main__":
    sys.exit(main())
