"""RandService fleet: sharded serving with journal-backed failover.

The paper's decorrelated counter addressing makes every response a pure
function of ``(seed, tenant tags, counter window)`` — so a serving
*fleet* needs no shared mutable state at all.  Each shard process runs
a full ``RandServer`` over the SAME global plan; the client-side hash
ring decides which tenants it serves; the only durable state is the
shard's append-only journal.  Failover is therefore *stateless*: a
surviving peer takes the dead shard's journal lock (the OS releases a
flock only when the owner is truly gone — fencing for free), restores
the journaled windows into a fresh ledger, raises the lease floor to
the journaled high-water mark, and resumes the dead shard's tenant
regions.  Because each shard serves its request subsequence in client
order with ``max_batch=1``, the assignment of every request — and hence
every byte — is identical to a run where the shard never died, which is
exactly what the kill-mid-burst CI check asserts by digest equality.

Pieces:

  * :class:`HashRing` — consistent tenant -> logical-shard routing
    (blake2s vnodes, pure function of the shard count),
  * :class:`Fleet` — controller that spawns N ``ShardHost``
    subprocesses, hands out addresses, and can *fence* (SIGKILL + wait)
    a shard that is alive-but-hung so its journal lock drops,
  * :class:`FleetClient` — router with per-request deadlines, bounded
    exponential backoff, and fence-gated hedged resubmission: when the
    owner of a shard stops answering, the client asks the failover peer
    to adopt the shard's journal; the peer's flock attempt either
    succeeds (owner dead -> hedge serves there) or reports ``locked``
    (owner alive -> back off, optionally fence, retry),
  * :func:`run_fleet_burst` — per-shard in-order burst driver (the
    deterministic traffic shape the digest checks rely on).

Subprocess entry: ``python -m repro.service.fleet --serve --shard i``
(spawned by :class:`Fleet`; drains gracefully on SIGTERM/SIGINT).
"""
from __future__ import annotations

import argparse
import bisect
import dataclasses
import hashlib
import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.fault import FaultInjector, FaultPlan
from repro.service import transport
from repro.service.frontend import RandRequest
from repro.service.server import ServerConfig, drain_signal_event


# ---------------------------------------------------------------------------
# Consistent-hash routing
# ---------------------------------------------------------------------------

def _h64(text: str) -> int:
    return int.from_bytes(
        hashlib.blake2s(text.encode("utf-8"), digest_size=8).digest(),
        "little")


class HashRing:
    """Consistent tenant -> shard map: ``replicas`` blake2s vnodes per
    shard on a u64 ring.  Pure function of ``(num_shards, replicas)`` —
    every client and every test derives the identical routing table
    with zero coordination.

    Example:
        >>> from repro.service.fleet import HashRing
        >>> ring = HashRing(2)
        >>> ring.owner("tenant/00042") == ring.owner("tenant/00042")
        True
        >>> sorted({ring.owner(f"t{i}") for i in range(64)})
        [0, 1]
    """

    def __init__(self, num_shards: int, *, replicas: int = 64):
        if num_shards < 1:
            raise ValueError(f"need >= 1 shard, got {num_shards}")
        self.num_shards = num_shards
        self.replicas = replicas
        pts = []
        for s in range(num_shards):
            for r in range(replicas):
                pts.append((_h64(f"shard:{s}:vnode:{r}"), s))
        pts.sort()
        self._points = [p for p, _ in pts]
        self._owners = [s for _, s in pts]

    def owner(self, tenant_id: str) -> int:
        """Logical shard owning ``tenant_id``'s region."""
        h = _h64(f"tenant:{tenant_id}")
        i = bisect.bisect_right(self._points, h) % len(self._points)
        return self._owners[i]

    def peers(self, shard: int) -> List[int]:
        """Failover preference order for ``shard``: the other shards,
        nearest successor first (deterministic — every client picks the
        same adoption target)."""
        return [(shard + k) % self.num_shards
                for k in range(1, self.num_shards)]


# ---------------------------------------------------------------------------
# Fleet controller (parent process)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Topology + client policy of one fleet run.

    ``max_batch=1`` is deliberate: each shard serves its request
    subsequence one at a time in arrival order, making every assignment
    a pure function of (per-shard request order, ledger high-water) —
    the property the kill-mid-burst digest-equality check depends on.
    """
    num_shards: int = 2
    seed: int = 0
    journal_dir: str = "."
    host: str = "127.0.0.1"
    max_batch: int = 1
    queue_depth: int = 4096
    deadline_s: float = 120.0        # generous: first contacts pay jit
    connect_timeout_s: float = 10.0
    max_retries: int = 6
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    replicas: int = 64
    spawn_timeout_s: float = 120.0


class FleetError(RuntimeError):
    """A request could not be served within the retry/deadline budget."""


class Fleet:
    """Spawn and supervise ``num_shards`` ShardHost subprocesses.

    Each child binds an ephemeral port and writes it to
    ``<journal_dir>/shard<i>.port``; stdout/stderr stream to
    ``shard<i>.log``.  ``fence(i)`` is the STONITH step: SIGKILL + wait,
    guaranteeing the child's journal flock is released before a peer
    adopts it.
    """

    def __init__(self, config: FleetConfig,
                 fault_plan: Optional[FaultPlan] = None):
        self.config = config
        self.fault_plan = fault_plan or FaultPlan()
        os.makedirs(config.journal_dir, exist_ok=True)
        self._procs: List[subprocess.Popen] = []
        self._addrs: Dict[int, Tuple[str, int]] = {}
        src_root = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        env = dict(os.environ)
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env.setdefault("JAX_PLATFORMS", "cpu")
        for i in range(config.num_shards):
            cmd = [sys.executable, "-m", "repro.service.fleet", "--serve",
                   "--shard", str(i), "--seed", str(config.seed),
                   "--host", config.host,
                   "--journal", self.journal_path(i),
                   "--port-file", self._port_file(i),
                   "--max-batch", str(config.max_batch),
                   "--queue-depth", str(config.queue_depth)]
            if self.fault_plan:
                cmd += ["--fault-plan", self.fault_plan.to_json()]
            log = open(os.path.join(config.journal_dir,
                                    f"shard{i}.log"), "ab")
            try:
                self._procs.append(subprocess.Popen(
                    cmd, env=env, stdout=log, stderr=subprocess.STDOUT))
            finally:
                log.close()
        self._await_ports()

    def _port_file(self, i: int) -> str:
        return os.path.join(self.config.journal_dir, f"shard{i}.port")

    def journal_path(self, i: int) -> str:
        return os.path.join(self.config.journal_dir, f"shard{i}.jsonl")

    def _await_ports(self) -> None:
        deadline = time.monotonic() + self.config.spawn_timeout_s
        for i, proc in enumerate(self._procs):
            pf = self._port_file(i)
            while True:
                if os.path.exists(pf):
                    try:
                        port = int(open(pf).read().strip())
                        break
                    except ValueError:
                        pass        # partially written; poll again
                if proc.poll() is not None:
                    raise FleetError(
                        f"shard {i} exited rc={proc.returncode} before "
                        f"listening (see shard{i}.log)")
                if time.monotonic() > deadline:
                    raise FleetError(f"shard {i} never published a port")
                time.sleep(0.02)
            self._addrs[i] = (self.config.host, port)

    def address(self, i: int) -> Tuple[str, int]:
        return self._addrs[i]

    def addresses(self) -> Dict[int, Tuple[str, int]]:
        return dict(self._addrs)

    def journals(self) -> Dict[int, str]:
        return {i: self.journal_path(i)
                for i in range(self.config.num_shards)}

    def alive(self, i: int) -> bool:
        return self._procs[i].poll() is None

    def fence(self, i: int) -> None:
        """Guarantee shard process ``i`` is dead (SIGKILL + reap) so its
        journal lock is released — the STONITH step before adoption."""
        proc = self._procs[i]
        if proc.poll() is None:
            proc.kill()
        proc.wait(timeout=30)

    def client(self, **overrides) -> "FleetClient":
        return FleetClient(self.addresses(), self.journals(),
                           config=self.config, fencer=self.fence,
                           **overrides)

    def stop(self, timeout: float = 60.0) -> None:
        """Graceful fleet shutdown: SIGTERM (drain) then SIGKILL."""
        for proc in self._procs:
            if proc.poll() is None:
                proc.terminate()
        deadline = time.monotonic() + timeout
        for proc in self._procs:
            try:
                proc.wait(timeout=max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=30)

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


# ---------------------------------------------------------------------------
# Client-side router
# ---------------------------------------------------------------------------

class _ShardConn:
    """One persistent connection to whichever process owns a logical
    shard.  Single-owner (the per-shard burst thread); reconnects on
    demand."""

    def __init__(self, host: str, port: int, *, connect_timeout: float):
        self.addr = (host, port)
        self.connect_timeout = connect_timeout
        self._sock: Optional[socket.socket] = None

    def call(self, msg: Dict[str, Any], *,
             deadline_s: float) -> Dict[str, Any]:
        if self._sock is None:
            self._sock = socket.create_connection(
                self.addr, timeout=self.connect_timeout)
        self._sock.settimeout(deadline_s)
        try:
            transport.send_frame(self._sock, msg)
            reply = transport.recv_frame(self._sock)
        except (OSError, transport.TransportError):
            self.close()
            raise
        if reply is None:
            self.close()
            raise transport.TornFrame(f"EOF from {self.addr}")
        return reply

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None


class FleetClient:
    """Route requests to shard owners; retry, hedge, and fail over.

    The failure path for a request whose owner stopped answering:

    1. bounded exponential backoff retries against the current owner
       (covers transient slowness and scripted ``slow`` faults —
       idempotent because a journaled rid is answered by replay),
    2. in parallel with each retry, a *fence-gated hedge*: ask the
       ring's failover peer to ``adopt`` the shard's journal.  The
       peer's exclusive flock attempt is the safety interlock — it
       succeeds only if the owner is actually dead,
    3. if adoption keeps reporting ``locked`` (owner alive but hung)
       and a ``fencer`` is available, fence the owner (SIGKILL + wait)
       and adopt — never two writers, never a lost response.
    """

    def __init__(self, addresses: Dict[int, Tuple[str, int]],
                 journals: Dict[int, str], *,
                 config: Optional[FleetConfig] = None,
                 fencer: Optional[Callable[[int], None]] = None,
                 ring: Optional[HashRing] = None,
                 deadline_s: Optional[float] = None,
                 fence_after: int = 2):
        self.config = config or FleetConfig(num_shards=len(addresses))
        self.addresses = dict(addresses)
        self.journals = dict(journals)
        self.fencer = fencer
        self.fence_after = fence_after
        self.deadline_s = (self.config.deadline_s
                           if deadline_s is None else deadline_s)
        self.ring = ring or HashRing(len(addresses),
                                     replicas=self.config.replicas)
        # logical shard -> process index currently hosting it
        self._owner: Dict[int, int] = {i: i for i in addresses}
        self._conns: Dict[int, _ShardConn] = {}
        self._lock = threading.Lock()
        self.latencies: List[float] = []
        self.retries = 0
        self.failovers = 0
        self.errors = 0
        self.recovery_s: Optional[float] = None

    # -- connection/ownership ---------------------------------------------

    def _conn(self, logical: int) -> _ShardConn:
        with self._lock:
            proc = self._owner[logical]
            conn = self._conns.get(logical)
            host, port = self.addresses[proc]
            if conn is None or conn.addr != (host, port):
                if conn is not None:
                    conn.close()
                conn = _ShardConn(
                    host, port,
                    connect_timeout=self.config.connect_timeout_s)
                self._conns[logical] = conn
            return conn

    def _try_adopt(self, logical: int) -> bool:
        """Hedge to the failover peer: adopted -> reroute and return
        True; ``locked`` (owner still alive) -> False."""
        dead_proc = self._owner[logical]
        for peer_logical in self.ring.peers(logical):
            with self._lock:
                peer_proc = self._owner[peer_logical]
            if peer_proc == dead_proc:
                continue
            try:
                reply = transport.rpc(
                    self.addresses[peer_proc],
                    {"op": "adopt", "shard": logical,
                     "journal": self.journals[logical]},
                    timeout=self.config.connect_timeout_s)
            except (OSError, transport.TransportError):
                continue            # peer also unreachable; next one
            if reply.get("ok"):
                with self._lock:
                    self._owner[logical] = peer_proc
                    conn = self._conns.pop(logical, None)
                if conn is not None:
                    conn.close()
                self.failovers += 1
                return True
            if reply.get("kind") != "locked":
                continue
        return False

    # -- request path ------------------------------------------------------

    def request(self, req: RandRequest) -> np.ndarray:
        """Serve one request, riding out owner death: deadline, bounded
        backoff, fence-gated hedged resubmission."""
        if req.rid is None:
            raise ValueError("fleet requests need caller-stamped rids")
        logical = self.ring.owner(req.tenant_id)
        msg = transport.request_to_wire(req, logical)
        t0 = time.perf_counter()
        failed_at: Optional[float] = None
        last_exc: Optional[BaseException] = None
        for attempt in range(self.config.max_retries + 1):
            try:
                reply = self._conn(logical).call(
                    msg, deadline_s=self.deadline_s)
            except (OSError, transport.TransportError) as e:
                last_exc = e
                if failed_at is None:
                    failed_at = time.perf_counter()
                self.retries += 1
                adopted = self._try_adopt(logical)
                if not adopted:
                    if (self.fencer is not None
                            and attempt + 1 >= self.fence_after):
                        # hung owner: its journal lock is still held —
                        # fence (SIGKILL + wait) so adoption can proceed
                        self.fencer(self._owner[logical])
                        adopted = self._try_adopt(logical)
                if not adopted:
                    time.sleep(min(self.config.backoff_cap_s,
                                   self.config.backoff_base_s
                                   * (2 ** attempt)))
                continue
            if reply.get("ok"):
                if failed_at is not None and self.recovery_s is None:
                    self.recovery_s = time.perf_counter() - failed_at
                self.latencies.append(time.perf_counter() - t0)
                return transport.decode_array(reply["array"])
            if reply.get("kind") == "not_owner":
                # ownership moved (e.g. another thread's failover won):
                # re-adopt / rediscover, then retry
                last_exc = transport.WireError("not_owner",
                                               reply.get("error", ""))
                self.retries += 1
                self._try_adopt(logical)
                continue
            self.errors += 1
            raise FleetError(
                f"shard {logical} refused {req.rid}: "
                f"{reply.get('kind')}: {reply.get('error')}")
        self.errors += 1
        raise FleetError(
            f"request {req.rid} exhausted {self.config.max_retries} "
            f"retries against shard {logical}") from last_exc

    def stats(self) -> Dict[str, Any]:
        lat = np.asarray(self.latencies, np.float64)
        return {
            "requests": int(lat.size),
            "retries": self.retries,
            "failovers": self.failovers,
            "errors": self.errors,
            "recovery_ms": (None if self.recovery_s is None
                            else self.recovery_s * 1e3),
            "latency_p50_ms": (float(np.percentile(lat, 50)) * 1e3
                               if lat.size else 0.0),
            "latency_p99_ms": (float(np.percentile(lat, 99)) * 1e3
                               if lat.size else 0.0),
        }

    def close(self) -> None:
        with self._lock:
            conns, self._conns = list(self._conns.values()), {}
        for conn in conns:
            conn.close()


def run_fleet_burst(client: FleetClient,
                    requests: List[RandRequest]
                    ) -> Dict[str, np.ndarray]:
    """Drive a burst through the fleet: requests partition by owning
    shard (order preserved) and each partition is served strictly
    in-order on its own thread — so every shard sees a deterministic
    subsequence and assignments are reproducible, fault or no fault.
    """
    by_shard: Dict[int, List[RandRequest]] = {}
    for req in requests:
        by_shard.setdefault(client.ring.owner(req.tenant_id),
                            []).append(req)
    responses: Dict[str, np.ndarray] = {}
    failures: List[BaseException] = []
    lock = threading.Lock()

    def worker(reqs: List[RandRequest]) -> None:
        for req in reqs:
            try:
                a = client.request(req)
            except BaseException as e:   # noqa: BLE001 — surfaced below
                with lock:
                    failures.append(e)
                return
            with lock:
                responses[req.rid] = a

    threads = [threading.Thread(target=worker, args=(reqs,), daemon=True)
               for reqs in by_shard.values()]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if failures:
        raise failures[0]
    return responses


# ---------------------------------------------------------------------------
# Shard subprocess entry
# ---------------------------------------------------------------------------

def serve_shard(args) -> int:
    injector = None
    if args.fault_plan:
        injector = FaultInjector(FaultPlan.parse(args.fault_plan))
    cfg = ServerConfig(max_batch=args.max_batch, max_delay_s=0.0,
                       queue_depth=args.queue_depth)
    host = transport.ShardHost(args.seed, host=args.host, port=args.port,
                               config=cfg, injector=injector)
    host.add_shard(args.shard, args.journal)
    stop = drain_signal_event()
    # port file last: its existence means "accepting and shard is open"
    tmp = args.port_file + ".tmp"
    with open(tmp, "w") as f:
        f.write(str(host.address[1]))
    os.replace(tmp, args.port_file)
    stop.wait()
    host.close()
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="RandService fleet shard process")
    ap.add_argument("--serve", action="store_true")
    ap.add_argument("--shard", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--journal", required=True)
    ap.add_argument("--port-file", required=True)
    ap.add_argument("--max-batch", type=int, default=1)
    ap.add_argument("--queue-depth", type=int, default=4096)
    ap.add_argument("--fault-plan", default="")
    args = ap.parse_args(argv)
    if not args.serve:
        ap.error("--serve is the only mode (spawned by fleet.Fleet)")
    return serve_shard(args)


if __name__ == "__main__":
    sys.exit(main())
