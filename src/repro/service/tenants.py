"""Deterministic tenant registry: ids -> disjoint stream-tag regions.

The paper's core economics — one shared root state, per-stream cost of
one add plus an output stage — is exactly what a multi-tenant service
needs: handing a new client its own independent sequences must not cost
per-client generator state.  This module maps arbitrary tenant ids onto
the engine's 64-bit leaf-tag space (the ``tag`` argument of
``engine.derive_leaf``) so that

  * every tenant owns a private, contiguous *region* of
    ``2**REGION_BITS`` stream slots, derived purely from ``blake2s`` of
    the id (stable across processes and restarts — the journal must
    mean the same streams after a crash),
  * regions of distinct tenants are disjoint by construction whenever
    their region bases differ, and the registry *verifies* rather than
    assumes this: a base collision between distinct ids raises
    ``TenantCollisionError`` deterministically (probability ~n^2/2^49
    for n tenants; ~2e-7 at n = 10^4),
  * per-tenant consumption is metered: ``charge`` accumulates samples
    served against an optional quota.

All tenants of one request class share a single ``GenPlan`` family
(one ``x0``, one family offset — see ``frontend.class_channel``); a
tenant's streams are the family leaves at its region's tags.  Millions
of logical clients therefore cost the service nothing but table rows
here — the software restatement of "adding SOU instances needs no
extra root hardware".
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from typing import Dict, Optional

#: log2 of the number of stream slots in one tenant region.  16 leaves
#: 2**48 distinct regions: ample slots for any single microbatch, and a
#: ~2e-7 collision probability across 10^4 tenants (collisions are
#: detected, not silently tolerated).
REGION_BITS = 16


class TenantCollisionError(ValueError):
    """Two distinct tenant ids hashed to the same stream-tag region."""


class QuotaExceeded(RuntimeError):
    """A request would push a tenant past its sample quota."""


def tenant_region(tenant_id: str, region_bits: int = REGION_BITS) -> int:
    """Region base tag for ``tenant_id``: blake2s-64 with the low
    ``region_bits`` cleared.

    The region is ``[base, base + 2**region_bits)`` in the u64 leaf-tag
    space; bases are multiples of the region size, so *distinct bases
    imply disjoint regions* — injectivity of this function over the
    registered ids is the whole non-overlap argument (and is property-
    tested over >= 10^4 ids in ``tests/test_service.py``).

    Example:
        >>> from repro.service.tenants import tenant_region
        >>> a, b = tenant_region("alice"), tenant_region("bob")
        >>> a != b and a % (1 << 16) == 0
        True
    """
    digest = hashlib.blake2s(tenant_id.encode("utf-8"),
                             digest_size=8).digest()
    h = int.from_bytes(digest, "little")
    return (h >> region_bits) << region_bits


@dataclasses.dataclass
class Tenant:
    """One registered tenant: its region and its consumption meters."""
    tenant_id: str
    region_lo: int           # first stream tag owned by this tenant
    region_hi: int           # one past the last owned tag
    quota: Optional[int]     # max samples ever served (None = unmetered)
    served: int = 0          # samples handed out so far
    requests: int = 0        # requests admitted so far

    @property
    def region_slots(self) -> int:
        return self.region_hi - self.region_lo

    def tag(self, slot: int) -> int:
        """Absolute leaf tag of ``slot`` within this tenant's region."""
        if not 0 <= slot < self.region_slots:
            raise ValueError(
                f"slot {slot} outside tenant {self.tenant_id!r} region of "
                f"{self.region_slots} slots")
        return self.region_lo + slot


class TenantRegistry:
    """Thread-safe id -> ``Tenant`` table with collision detection.

    Registration is idempotent and deterministic: the same id always
    maps to the same region, in any process, with no coordination —
    which is what lets a restarted service resume serving the same
    tenants from the journal alone.

    Example:
        >>> from repro.service.tenants import TenantRegistry
        >>> reg = TenantRegistry(default_quota=100)
        >>> t = reg.register("alice")
        >>> t.region_slots
        65536
        >>> reg.charge("alice", 64).served
        64
    """

    def __init__(self, *, region_bits: int = REGION_BITS,
                 default_quota: Optional[int] = None):
        self.region_bits = region_bits
        self.default_quota = default_quota
        self._tenants: Dict[str, Tenant] = {}
        self._by_region: Dict[int, str] = {}
        self._lock = threading.Lock()

    def register(self, tenant_id: str,
                 quota: Optional[int] = None) -> Tenant:
        """Return (creating if needed) the ``Tenant`` for ``tenant_id``."""
        with self._lock:
            t = self._tenants.get(tenant_id)
            if t is not None:
                return t
            base = tenant_region(tenant_id, self.region_bits)
            other = self._by_region.get(base)
            if other is not None:
                raise TenantCollisionError(
                    f"tenant {tenant_id!r} collides with {other!r} on "
                    f"region base {base:#x} (region_bits="
                    f"{self.region_bits})")
            t = Tenant(tenant_id=tenant_id, region_lo=base,
                       region_hi=base + (1 << self.region_bits),
                       quota=self.default_quota if quota is None else quota)
            self._tenants[tenant_id] = t
            self._by_region[base] = tenant_id
            return t

    def get(self, tenant_id: str) -> Tenant:
        return self._tenants[tenant_id]

    def __len__(self) -> int:
        return len(self._tenants)

    def __contains__(self, tenant_id: str) -> bool:
        return tenant_id in self._tenants

    def charge(self, tenant_id: str, num_samples: int) -> Tenant:
        """Meter ``num_samples`` against the tenant's quota (registering
        the tenant on first contact); raises ``QuotaExceeded`` without
        consuming anything when the quota would be passed."""
        t = self.register(tenant_id)
        with self._lock:
            if t.quota is not None and t.served + num_samples > t.quota:
                raise QuotaExceeded(
                    f"tenant {tenant_id!r}: {t.served} served + "
                    f"{num_samples} requested > quota {t.quota}")
            t.served += num_samples
            t.requests += 1
        return t

    def refund(self, tenant_id: str, num_samples: int) -> Tenant:
        """Return samples charged for a request that later failed (e.g.
        the fused engine call errored after admission) so a tenant is
        only ever billed for bytes actually served."""
        t = self.get(tenant_id)
        with self._lock:
            t.served = max(0, t.served - num_samples)
            t.requests = max(0, t.requests - 1)
        return t

    def retire(self, tenant_id: str) -> Optional[Tenant]:
        """Drop a tenant from the table, freeing its row (idempotent).

        This is the sequence-churn primitive for the inference tier:
        every live decode sequence is a tenant, and at millions of
        finished sequences the registry must not grow without bound.
        Retiring only removes the TABLE ROW — the id -> region map is a
        pure hash, so re-registering the same id later lands on the
        same region with fresh meters (counter-window disjointness
        across the reuse is the lease ledger's job, not the registry's:
        see ``BlockService.release(name)``).  Returns the retired
        ``Tenant`` snapshot, or ``None`` if it was never registered.
        """
        with self._lock:
            t = self._tenants.pop(tenant_id, None)
            if t is not None:
                self._by_region.pop(t.region_lo, None)
            return t

    def usage(self) -> Dict[str, Dict[str, int]]:
        """Per-tenant accounting snapshot (JSON-able)."""
        with self._lock:
            return {tid: {"served": t.served, "requests": t.requests,
                          "region_lo": t.region_lo,
                          "region_hi": t.region_hi}
                    for tid, t in sorted(self._tenants.items())}
