"""``python -m repro.service`` — serve a deterministic mixed burst.

Starts a RandServer, fires ``--burst`` mixed (shape, sampler, dtype)
requests from ``--tenants`` distinct tenants, prints serving stats
(requests/s, p50/p99 latency, coalescing factor) and an
order-independent response digest, then drains gracefully.

  PYTHONPATH=src python -m repro.service --burst 512 --tenants 1024 \\
      --journal /tmp/rand.jsonl --verify-replay

``--verify-replay`` re-reads the journal in a FRESH server context and
asserts byte-identical regeneration; ``--linger`` keeps the server up
after the burst until SIGINT/SIGTERM, either of which triggers the
graceful drain (the Makefile's ``make service`` and the signal tests
drive this path).

``--fleet N`` runs the same burst against an N-shard subprocess fleet
(``repro.service.fleet``) over the socket transport instead of an
in-process server; ``--fault-plan`` scripts the adversary:

  PYTHONPATH=src python -m repro.service --fleet 2 --burst 1024 \\
      --tenants 256 --journal-dir /tmp/fleet --fault-plan kill@512

Shards coalesce (``--fleet-max-batch``), keep standing producer pools
(``--fleet-hot``), and speak binary v2 wire frames to a pipelined
client (``--pipeline-depth``) — yet the printed digest is identical
with and without the fault plan, because each shard's microbatch
composition is journaled atomically before responses release and the
client resubmits unanswered requests in original order.  That equality
is the failover correctness check CI runs three times in a row.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.service import audit
from repro.service.burst import make_requests, run_burst
from repro.service.server import (RandServer, ServerConfig,
                                  drain_signal_event)


def _shard_stats(client) -> dict:
    """Aggregate serving-side counters (engine calls, pool hits) over
    every live shard owner — the CI coalescing gate reads these."""
    from repro.service import transport

    engine = leases = served = pooled = 0
    for logical, proc in sorted(client._owner.items()):
        try:
            reply = transport.rpc(client.addresses[proc],
                                  {"op": "stats", "shard": logical},
                                  timeout=10.0)
        except (OSError, transport.TransportError):
            continue                # fenced/dead owner: skip
        if not reply.get("ok"):
            continue
        s = reply["stats"]
        engine += s.get("engine_calls", 0)
        leases += s.get("lease_calls", 0)
        served += s.get("requests_served", 0)
        pooled += s.get("pool_requests", 0)
    return {
        "engine_calls": engine,
        "lease_calls": leases,
        "requests_served": served,
        "coalesce_calls_per_req": ((engine + leases) / served
                                   if served else 0.0),
        "pool_hit_rate": (pooled / served if served else 0.0),
    }


def _run_fleet(args) -> int:
    """The ``--fleet N`` path: subprocess shards, socket transport,
    scripted faults, digest + optional union replay over the shard
    journals."""
    from repro.runtime.fault import FaultPlan
    from repro.service.fleet import Fleet, FleetConfig, run_fleet_burst

    plan = FaultPlan.parse(args.fault_plan)
    hot = tuple(tuple(p.split(":", 1))
                for p in args.fleet_hot.split(",") if p)
    fcfg = FleetConfig(num_shards=args.fleet, seed=args.seed,
                       journal_dir=args.journal_dir,
                       max_batch=args.fleet_max_batch,
                       pipeline_depth=args.pipeline_depth,
                       binary=not args.no_binary,
                       hot_classes=hot,
                       queue_depth=max(4096, args.burst))
    reqs = make_requests(burst=args.burst, tenants=args.tenants,
                         seed=args.seed, pattern=args.pattern)
    with Fleet(fcfg, plan) as fleet:
        client = fleet.client()
        t0 = time.perf_counter()
        responses = run_fleet_burst(client, reqs)
        wall_s = time.perf_counter() - t0
        cstats = client.stats()
        cstats.update(_shard_stats(client))
        client.close()
        journals = fleet.journals()
        fleet.stop()

    digest = audit.response_digest(responses)
    print(f"fleet[{args.fleet}] served {len(responses)}/{args.burst} "
          f"requests from {args.tenants} tenants in {wall_s:.3f}s "
          f"({len(responses) / wall_s:.0f} req/s wall)"
          + (f"  [faults: {args.fault_plan}]" if plan else ""))
    print(f"latency p50={cstats['latency_p50_ms']:.2f}ms "
          f"p99={cstats['latency_p99_ms']:.2f}ms  "
          f"retries={cstats['retries']} failovers={cstats['failovers']}"
          + (f" recovery={cstats['recovery_ms']:.0f}ms"
             if cstats["recovery_ms"] is not None else ""))
    print(f"coalescing: {cstats['engine_calls']} engine calls + "
          f"{cstats['lease_calls']} leases for "
          f"{cstats['requests_served']} requests "
          f"({cstats['coalesce_calls_per_req']:.3f} calls/request, "
          f"pool hit rate {cstats['pool_hit_rate']:.3f})")
    print(f"wire: {cstats['bytes_on_wire_per_req']:.0f} bytes/req "
          f"({'binary v2' if not args.no_binary else 'json v1'})")
    print(f"digest {digest}")

    rc = 0
    if args.verify_replay:
        # union replay: each shard journal regenerates its slice of the
        # burst in a fresh context; together they must reproduce every
        # response byte-for-byte
        replayed = {}
        for i, path in sorted(journals.items()):
            part = audit.replay(path, seed=args.seed)
            audit.verify_ledger_disjoint(audit.Journal(path,
                                                       readonly=True))
            replayed.update(part)
        same = (set(replayed) == set(responses)
                and audit.response_digest(replayed) == digest)
        print(f"replay: {'OK — bit-identical' if same else 'MISMATCH'} "
              f"({len(replayed)} journaled requests across "
              f"{len(journals)} shards)")
        if not same:
            rc = 1

    if args.digest_out:
        with open(args.digest_out, "w") as f:
            f.write(digest + "\n")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"burst": args.burst, "tenants": args.tenants,
                       "seed": args.seed, "fleet": args.fleet,
                       "fault_plan": args.fault_plan, "wall_s": wall_s,
                       "digest": digest, "stats": cstats}, f, indent=2)
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--burst", type=int, default=512)
    ap.add_argument("--tenants", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pattern", default="mixed",
                    choices=("mixed", "hammer", "unique"),
                    help="traffic shape: mixed classes, single-tenant "
                         "hammer, or all-unique shapes")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve over N subprocess shards via the socket "
                         "transport instead of in-process")
    ap.add_argument("--fault-plan", default="",
                    help="scripted faults for fleet mode, e.g. "
                         "'kill@512' or 'hang@40#1~30' (see "
                         "repro.runtime.fault.FaultPlan.parse)")
    ap.add_argument("--journal-dir", default="/tmp/repro-fleet",
                    help="fleet mode: per-shard journal/log directory")
    ap.add_argument("--fleet-max-batch", type=int, default=32,
                    help="fleet mode: per-shard microbatch size "
                         "(composition is journaled, so >1 is safe)")
    ap.add_argument("--pipeline-depth", type=int, default=32,
                    help="fleet mode: client in-flight window per shard")
    ap.add_argument("--no-binary", action="store_true",
                    help="fleet mode: force JSON v1 wire frames")
    ap.add_argument("--fleet-hot", default="bits:float32,uniform:float32",
                    help="fleet mode: comma-joined sampler:dtype pool "
                         "classes ('' disables standing pools)")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--max-delay", type=float, default=0.25,
                    help="microbatch deadline seconds (generous default "
                         "keeps single-threaded bursts deterministic)")
    ap.add_argument("--submit-threads", type=int, default=0,
                    help="0 = in-order submission (deterministic); >0 = "
                         "concurrent submitter threads")
    ap.add_argument("--hot", action="store_true",
                    help="standing producer pool for uniform/float32")
    ap.add_argument("--journal", default=None,
                    help="journal JSONL path (default: in-memory)")
    ap.add_argument("--verify-replay", action="store_true")
    ap.add_argument("--digest-out", default=None,
                    help="write the response digest to this file")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write stats+digest JSON to this file")
    ap.add_argument("--linger", type=float, default=0.0,
                    help="stay up this many seconds after the burst "
                         "(SIGINT drains gracefully and exits 0)")
    args = ap.parse_args(argv)

    if args.fleet:
        return _run_fleet(args)

    deterministic = args.submit_threads == 0
    cfg = ServerConfig(
        max_batch=args.max_batch, max_delay_s=args.max_delay,
        queue_depth=max(4096, args.burst),
        hot_classes=((("uniform", "float32"),) if args.hot else ()))
    journal = audit.Journal(args.journal)
    # deterministic mode: enqueue the WHOLE burst before the dispatch
    # loop starts, so microbatch composition is count-based (chunks of
    # max_batch in submission order), never wall-clock-based — the
    # cross-run digest comparison must not depend on scheduler timing
    server = RandServer(args.seed, config=cfg, journal=journal,
                        start=not deterministic)

    # SIGINT (interactive ^C) and SIGTERM (supervisors) both trigger
    # the same graceful drain
    interrupted = drain_signal_event()

    reqs = make_requests(burst=args.burst, tenants=args.tenants,
                         seed=args.seed, pattern=args.pattern)
    t0 = time.perf_counter()
    if deterministic:
        futs = [server.submit(r) for r in reqs]
        server.start()
        responses = {r.rid: f.result(timeout=600)
                     for r, f in zip(reqs, futs)}
    else:
        responses = run_burst(server, reqs,
                              submit_threads=args.submit_threads)
    wall_s = time.perf_counter() - t0
    digest = audit.response_digest(responses)
    stats = server.stats()
    audit.verify_ledger_disjoint(server.block_service)
    if journal.windows():
        audit.verify_ledger_disjoint(journal)

    print(f"served {len(responses)}/{args.burst} requests from "
          f"{stats['tenants']} tenants in {wall_s:.3f}s "
          f"({len(responses) / wall_s:.0f} req/s wall)")
    print(f"latency p50={stats['latency_p50_ms']:.2f}ms "
          f"p99={stats['latency_p99_ms']:.2f}ms")
    print(f"coalescing: {stats['engine_calls']} engine calls + "
          f"{stats['lease_calls']} leases for {stats['requests_served']} "
          f"requests ({stats['calls_per_request']:.3f} calls/request, "
          f"fill {stats['fill_ratio']:.3f})")
    print(f"digest {digest}")

    rc = 0
    if args.verify_replay:
        replayed = audit.replay(journal, seed=args.seed)
        same = (set(replayed) == set(responses)
                and audit.response_digest(replayed) == digest)
        print(f"replay: {'OK — bit-identical' if same else 'MISMATCH'} "
              f"({len(replayed)} journaled requests)")
        if not same:
            rc = 1

    if args.digest_out:
        with open(args.digest_out, "w") as f:
            f.write(digest + "\n")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"burst": args.burst, "tenants": args.tenants,
                       "seed": args.seed, "wall_s": wall_s,
                       "digest": digest, "stats": stats}, f, indent=2)

    if args.linger > 0 and rc == 0:
        print("ready (SIGINT/SIGTERM to drain)", flush=True)
        deadline = time.monotonic() + args.linger
        while not interrupted.is_set() and time.monotonic() < deadline:
            interrupted.wait(0.1)
    server.shutdown()
    print("drained", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
