"""Deterministic mixed-traffic burst driver for RandService.

One shared implementation of "fire a burst of mixed (shape, sampler,
dtype) requests from many tenants and account for every byte", used by
``python -m repro.service``, the ``--service`` dry-run scenario, the
``service_smoke`` benchmark rows, and the acceptance tests.

The request list is a pure function of ``(seed, burst, tenants)`` —
reproducing a burst in another process (the CI determinism check runs
the module twice and compares response digests) needs no coordination
beyond the same three integers.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.service.frontend import RandRequest

#: the mixed request classes a burst cycles through
BURST_CLASSES: Tuple[Tuple[str, str], ...] = (
    ("bits", "float32"),
    ("uniform", "float32"),
    ("uniform", "bfloat16"),
    ("normal", "float32"),
    ("bernoulli(0.25)", "float32"),
)


def make_requests(*, burst: int, tenants: int, seed: int,
                  max_side: int = 64,
                  rid_prefix: str = "burst") -> List[RandRequest]:
    """``burst`` rid-stamped requests over ``tenants`` distinct tenant
    ids with mixed shapes (1-D and 2-D), samplers and dtypes.

    ``rid_prefix`` keeps rids unique across bursts sharing one journal
    (journaled rids may never repeat)."""
    rng = random.Random(seed ^ 0x5EED5)
    reqs: List[RandRequest] = []
    for i in range(burst):
        sampler, dtype = BURST_CLASSES[i % len(BURST_CLASSES)]
        if rng.random() < 0.5:
            shape: Tuple[int, ...] = (rng.randint(1, max_side * max_side),)
        else:
            shape = (rng.randint(1, max_side), rng.randint(1, max_side))
        reqs.append(RandRequest(
            tenant_id=f"tenant/{i % tenants:05d}", shape=shape,
            sampler=sampler, out_dtype=dtype, rid=f"{rid_prefix}/{i:06d}"))
    return reqs


def run_burst(server, requests: List[RandRequest], *,
              submit_threads: int = 0,
              timeout: Optional[float] = 120.0
              ) -> Dict[str, np.ndarray]:
    """Submit ``requests`` and gather every response.

    ``submit_threads=0`` submits in order from the calling thread
    (deterministic batching — what the CI determinism check uses);
    ``submit_threads>0`` fans submission over a thread pool (the
    concurrent-burst acceptance test).
    """
    if submit_threads > 0:
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=submit_threads) as ex:
            futs = list(ex.map(server.submit, requests))
    else:
        futs = [server.submit(r) for r in requests]
    return {r.rid: f.result(timeout=timeout)
            for r, f in zip(requests, futs)}
