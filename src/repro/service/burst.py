"""Deterministic mixed-traffic burst driver for RandService.

One shared implementation of "fire a burst of mixed (shape, sampler,
dtype) requests from many tenants and account for every byte", used by
``python -m repro.service``, the ``--service`` dry-run scenario, the
``service_smoke`` benchmark rows, and the acceptance tests.

The request list is a pure function of ``(seed, burst, tenants)`` —
reproducing a burst in another process (the CI determinism check runs
the module twice and compares response digests) needs no coordination
beyond the same three integers.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.service.frontend import RandRequest

#: the mixed request classes a burst cycles through — spans the full
#: sampler grammar including the distribution stages, so every burst
#: (CI service job, fleet failover rounds, acceptance tests) exercises
#: shaped-request journal replay for free
BURST_CLASSES: Tuple[Tuple[str, str], ...] = (
    ("bits", "float32"),
    ("uniform", "float32"),
    ("uniform", "bfloat16"),
    ("normal", "float32"),
    ("bernoulli(0.25)", "float32"),
    ("exponential(1.5)", "float32"),
    ("poisson(3.5)", "bfloat16"),
    ("gamma(2.5)", "float32"),
    ("categorical[0.5,0.25,0.125,0.125]", "float32"),
)


#: adversarial traffic shapes (see ``make_requests(pattern=...)``)
BURST_PATTERNS: Tuple[str, ...] = ("mixed", "hammer", "unique")


def make_requests(*, burst: int, tenants: int, seed: int,
                  max_side: int = 64,
                  rid_prefix: str = "burst",
                  pattern: str = "mixed") -> List[RandRequest]:
    """``burst`` rid-stamped requests over ``tenants`` distinct tenant
    ids with mixed shapes (1-D and 2-D), samplers and dtypes.

    ``rid_prefix`` keeps rids unique across bursts sharing one journal
    (journaled rids may never repeat).

    ``pattern`` selects the traffic shape — the adversarial suite the
    fleet benchmark sweeps:
      * ``"mixed"`` — the default spread over tenants/classes/shapes,
      * ``"hammer"`` — every request from ONE tenant (no routing
        spread: one shard absorbs the whole burst; worst case for the
        hash ring and for a kill on that shard),
      * ``"unique"`` — every request a distinct (shape, class): zero
        coalescing opportunity, every request its own quantised window.
    """
    if pattern not in BURST_PATTERNS:
        raise ValueError(f"unknown pattern {pattern!r}; "
                         f"have {BURST_PATTERNS}")
    rng = random.Random(seed ^ 0x5EED5)
    reqs: List[RandRequest] = []
    for i in range(burst):
        sampler, dtype = BURST_CLASSES[i % len(BURST_CLASSES)]
        if pattern == "unique":
            # distinct sizes -> distinct quantised rows per request
            shape: Tuple[int, ...] = (max(1, i) * 7 + rng.randint(0, 6),)
        elif rng.random() < 0.5:
            shape = (rng.randint(1, max_side * max_side),)
        else:
            shape = (rng.randint(1, max_side), rng.randint(1, max_side))
        tenant = ("tenant/00000" if pattern == "hammer"
                  else f"tenant/{i % tenants:05d}")
        reqs.append(RandRequest(
            tenant_id=tenant, shape=shape,
            sampler=sampler, out_dtype=dtype, rid=f"{rid_prefix}/{i:06d}"))
    return reqs


def run_burst(server, requests: List[RandRequest], *,
              submit_threads: int = 0,
              timeout: Optional[float] = 120.0
              ) -> Dict[str, np.ndarray]:
    """Submit ``requests`` and gather every response.

    ``submit_threads=0`` submits in order from the calling thread
    (deterministic batching — what the CI determinism check uses);
    ``submit_threads>0`` fans submission over a thread pool (the
    concurrent-burst acceptance test).
    """
    if submit_threads > 0:
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=submit_threads) as ex:
            futs = list(ex.map(server.submit, requests))
    else:
        futs = [server.submit(r) for r in requests]
    return {r.rid: f.result(timeout=timeout)
            for r, f in zip(requests, futs)}
