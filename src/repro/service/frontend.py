"""Request coalescing: many small heterogeneous requests, few fused calls.

A randomness request is tiny — "tenant X wants an (8, 17) float32
uniform block" — and a service that issued one engine call per request
would spend its life in dispatch overhead.  The ThundeRiNG substrate
offers a better shape: every sample is counter-addressed, a pure
function of ``(x0, h_tag, ctr + t)``, and *columns are the cheap axis*
(the paper's SOU-instance scaling).  So the coalescer packs a
microbatch of requests into one fused ``engine.generate`` per request
class:

  * requests are grouped by **class** ``(sampler, out_dtype)``; each
    class owns one ``BlockService`` channel (one ``GenPlan`` family of
    the service seed, all tenants shared),
  * the batch leases ONE counter window ``[lo, lo + T)`` on the class
    channel's ledger (PR 3 accounting: overlap is structurally
    impossible), with ``T`` the largest quantized row count any request
    in the batch needs,
  * each request is assigned ``ceil(n / T)`` *columns* — leaf tags
    drawn from its tenant's private region (``repro.service.tenants``),
    packed per tenant in arrival order — and the whole batch becomes a
    single gathered-tag ``(T, S)`` plan,
  * responses are column-major slices: request ``i`` reads its columns
    top-to-bottom and keeps the first ``n`` samples.

Because every element is a pure function of its (tag, counter)
address, a request's bytes do not depend on which batch it rode in
*given its assignment* — the journal (``repro.service.audit``) records
the assignment, and replaying it through plain ``engine.generate``
reproduces every response bit-identically.

The per-shape jitted window functions keep the counter and the tag
table TRACED, so steady traffic reuses a small set of executables
(shapes are quantized: rows to powers of two up to ``max_rows``,
columns padded to the next power of two).

Request classes span the full sampler grammar — "tenant A wants
Poisson(3.5) bfloat16" is just ``RandRequest(sampler="poisson(3.5)",
out_dtype="bfloat16")`` — and every distribution parameter is part of
the class key, so ``exponential(1.5)`` and ``exponential(2.0)`` get
disjoint GenPlan families.  Because adversarial (or merely diverse)
tenants can mint unboundedly many classes, the jitted window-fn cache
is LRU-BOUNDED at ``WINDOW_FN_CACHE_SIZE`` entries: a hot set of
classes stays compiled while a million-class churn can only ever pin
``WINDOW_FN_CACHE_SIZE`` executables (evicted classes re-jit on next
use — correctness is unaffected, the cache is purely a retrace saver).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, sampler as sampler_mod, u64
from repro.runtime import blocks
from repro.service import tenants as tenants_mod

#: row-count ceiling for one coalesced window (counter steps per lease)
DEFAULT_MAX_ROWS = 2048
_MIN_ROWS = 8

#: LRU bound on the coalescer's jitted window-fn cache: one entry per
#: (purpose, rows, cols, sampler, out_dtype) shape class.  Tenants
#: choose sampler specs, so the class space is unbounded; the cache
#: must not be.
WINDOW_FN_CACHE_SIZE = 64


def class_channel(sampler: str, out_dtype: str) -> str:
    """Ledger/family channel name for one (sampler, dtype) request class.

    Distinct classes get distinct channels, hence distinct ``GenPlan``
    families (disjoint h-spaces of the same root seed) and independent
    counter ledgers — a uniform/float32 window can never alias a
    bits/uint32 window.
    """
    return f"service/class/{sampler}/{out_dtype}"


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def request_rows(n: int, max_rows: int = DEFAULT_MAX_ROWS) -> int:
    """Quantized row count for an ``n``-sample request: the next power
    of two, clamped to ``[8, max_rows]`` (powers of two keep the jit
    cache small and satisfy the normal sampler's even-T constraint)."""
    if n <= 0:
        raise ValueError(f"request size must be positive, got {n}")
    return max(_MIN_ROWS, min(_next_pow2(n), max_rows))


@dataclasses.dataclass(frozen=True)
class RandRequest:
    """One tenant's ask: ``shape`` samples of ``sampler``/``out_dtype``.

    ``rid`` names the request in responses and in the journal; the
    server assigns one when the caller does not.

    Example:
        >>> from repro.service.frontend import RandRequest
        >>> r = RandRequest(tenant_id="alice", shape=(4, 3),
        ...                 sampler="uniform", rid="r0")
        >>> r.num_samples
        12
    """
    tenant_id: str
    shape: Tuple[int, ...]
    sampler: str = "bits"
    out_dtype: str = "float32"
    rid: Optional[str] = None

    @property
    def num_samples(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n

    @property
    def klass(self) -> Tuple[str, str]:
        return (self.sampler, self.out_dtype)

    def validate(self) -> None:
        spec = sampler_mod.parse(self.sampler)        # raises on bad spec
        sampler_mod.result_dtype(spec, self.out_dtype)
        if self.num_samples <= 0:
            raise ValueError(f"empty request shape {self.shape!r}")


@dataclasses.dataclass(frozen=True)
class Assignment:
    """Where one request's samples live: the journal-able provenance.

    ``replay`` (``repro.service.audit``) needs nothing else: the plan
    is ``(seed, channel->purpose, tags, [lo, lo+rows), sampler,
    out_dtype)`` and the response is the column-major flatten of the
    generated ``(rows, len(tags))`` block truncated to ``n``.
    """
    rid: str
    tenant_id: str
    sampler: str
    out_dtype: str
    shape: Tuple[int, ...]
    channel: str
    lo: int                 # counter-window start (lease.lo)
    rows: int               # counter-window length (the batch's T)
    tags: Tuple[int, ...]   # absolute leaf tags of the assigned columns
    deco: str = "splitmix64"

    @property
    def num_samples(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n


def slice_response(block: np.ndarray, col0: int, ncols: int,
                   assignment_n: int, shape: Tuple[int, ...]) -> np.ndarray:
    """Column-major slice: columns ``[col0, col0+ncols)`` read
    top-to-bottom, first ``n`` samples, reshaped."""
    flat = np.ascontiguousarray(block[:, col0:col0 + ncols].T).reshape(-1)
    return flat[:assignment_n].reshape(shape)


class Coalescer:
    """Batches requests into one leased fused engine call per class.

    ``flush(requests)`` is deterministic in the ORDER of ``requests``:
    the same ordered list against the same service/ledger state always
    produces the same assignments and the same bytes (the async server
    on top only adds arrival ordering; the quality battery calls this
    directly for a fully deterministic delivery surface).
    """

    def __init__(self, service: blocks.BlockService,
                 registry: tenants_mod.TenantRegistry, *,
                 journal=None, backend: Optional[str] = None,
                 deco: str = "splitmix64",
                 max_rows: int = DEFAULT_MAX_ROWS,
                 window_fn_cache_size: int = WINDOW_FN_CACHE_SIZE):
        self.service = service
        self.registry = registry
        self.journal = journal
        self.backend = backend
        self.deco = deco
        self.max_rows = max_rows
        self.window_fn_cache_size = int(window_fn_cache_size)
        if self.window_fn_cache_size < 1:
            raise ValueError(f"window_fn_cache_size must be >= 1, got "
                             f"{window_fn_cache_size!r}")
        self._window_fns: "collections.OrderedDict[Tuple, Callable]" = \
            collections.OrderedDict()
        self._fn_lock = threading.Lock()
        # cumulative coalescing stats (read by RandServer.stats)
        self.requests_served = 0
        self.engine_calls = 0
        self.lease_calls = 0
        self.samples_served = 0
        self.samples_generated = 0

    # -- fused window functions -------------------------------------------

    def _window_fn(self, purpose: int, rows: int, cols: int, sampler: str,
                   out_dtype: str) -> Callable:
        """One jitted gathered-tag window fn per quantized shape class.

        Tags and counter are TRACED; only (purpose, rows, padded cols,
        sampler, dtype) key the cache, so steady mixed traffic runs on
        a handful of executables.  The cache is LRU-bounded at
        ``window_fn_cache_size`` entries (class churn evicts, never
        grows without bound); an evicted class simply re-jits.
        """
        key = (purpose, rows, cols, sampler, out_dtype)
        with self._fn_lock:
            fn = self._window_fns.get(key)
            if fn is not None:
                self._window_fns.move_to_end(key)
        if fn is not None:
            return fn
        x0, h_fam = engine.family_from_seed(self.service.seed, purpose)
        deco, backend = self.deco, self.backend
        block_t, block_s = self.service.block_t, self.service.block_s

        @jax.jit
        def window(tag_hi, tag_lo, ctr_hi, ctr_lo):
            h = engine.derive_leaf(
                (jnp.broadcast_to(jnp.asarray(h_fam[0]), tag_hi.shape),
                 jnp.broadcast_to(jnp.asarray(h_fam[1]), tag_lo.shape)),
                (tag_hi, tag_lo))
            plan = engine.GenPlan(
                x0=x0, h=h, num_steps=rows, ctr=(ctr_hi, ctr_lo),
                offset=None, mode="ctr", deco=deco, sampler=sampler,
                out_dtype=out_dtype)
            return engine.generate(plan, backend=backend, block_t=block_t,
                                   block_s=block_s)

        with self._fn_lock:
            fn = self._window_fns.setdefault(key, window)
            self._window_fns.move_to_end(key)
            while len(self._window_fns) > self.window_fn_cache_size:
                self._window_fns.popitem(last=False)
        return fn

    # -- batching ----------------------------------------------------------

    def flush(self, requests: List[RandRequest]
              ) -> Tuple[Dict[str, np.ndarray], List[Assignment],
                         Dict[str, BaseException]]:
        """Serve an ordered microbatch; returns (responses by rid,
        assignments in request order, per-rid errors).

        Quota rejections and invalid requests fail individually; the
        rest of the batch is unaffected.
        """
        by_class: Dict[Tuple[str, str], List[RandRequest]] = {}
        errors: Dict[str, BaseException] = {}
        rids = [req.rid for req in requests]
        if None in rids:
            raise ValueError("flush needs rid-stamped requests")
        if len(set(rids)) != len(rids):
            raise ValueError("flush needs unique rids within a batch")
        for req in requests:
            try:
                req.validate()
            except Exception as e:
                errors[req.rid] = e
                continue
            by_class.setdefault(req.klass, []).append(req)

        responses: Dict[str, np.ndarray] = {}
        assignments: List[Assignment] = []
        for klass in sorted(by_class):
            try:
                got, asg, errs = self._flush_class(klass, by_class[klass])
            except Exception as e:
                # one class's failure (lease/engine) fails ITS requests
                # only; _flush_class already refunded and released
                for req in by_class[klass]:
                    errors.setdefault(req.rid, e)
                continue
            responses.update(got)
            assignments.extend(asg)
            errors.update(errs)
        # keep journal/assignment order = request order, not class order
        order = {req.rid: i for i, req in enumerate(requests)}
        assignments.sort(key=lambda a: order[a.rid])
        if self.journal is not None:
            for a in assignments:
                self.journal.append_request(a)
            self.journal.flush()
        return responses, assignments, errors

    def _flush_class(self, klass: Tuple[str, str],
                     reqs: List[RandRequest]):
        sampler, out_dtype = klass
        channel = class_channel(sampler, out_dtype)
        rows = max(request_rows(r.num_samples, self.max_rows) for r in reqs)

        # pack columns: per-tenant slot cursors restart every batch (the
        # fresh counter window is what makes the draws fresh)
        cursors: Dict[str, int] = {}
        packed = []          # (req, col0, ncols, tags)
        tags: List[int] = []
        errors: Dict[str, BaseException] = {}
        for req in reqs:
            n = req.num_samples
            ncols = -(-n // rows)
            try:
                # every fallible admission check runs BEFORE charge():
                # a rejected request must not consume quota
                tenant = self.registry.register(req.tenant_id)
                slot0 = cursors.get(req.tenant_id, 0)
                if slot0 + ncols > tenant.region_slots:
                    raise tenants_mod.QuotaExceeded(
                        f"tenant {req.tenant_id!r} needs {slot0 + ncols} "
                        f"slots in one microbatch; region has "
                        f"{tenant.region_slots}")
                self.registry.charge(req.tenant_id, n)
            except Exception as e:
                errors[req.rid] = e
                continue
            cursors[req.tenant_id] = slot0 + ncols
            rtags = [tenant.tag(slot0 + j) for j in range(ncols)]
            packed.append((req, len(tags), ncols, rtags))
            tags.extend(rtags)
        if not packed:
            return {}, [], errors

        cols = max(_MIN_ROWS, _next_pow2(len(tags)))
        padded = tags + [tags[-1]] * (cols - len(tags))  # dup cols: sliced off
        tag_hi = np.asarray([t >> 32 for t in padded], np.uint32)
        tag_lo = np.asarray([t & 0xFFFFFFFF for t in padded], np.uint32)

        self.service.open(channel, num_streams=1)
        lease = self.service.lease(channel, rows)
        self.lease_calls += 1
        purpose = blocks.channel_purpose(channel)
        fn = self._window_fn(purpose, rows, cols, sampler, out_dtype)
        c_hi, c_lo = (u64.to_u32(v) for v in u64.const64(lease.lo))
        try:
            block = np.asarray(fn(jnp.asarray(tag_hi), jnp.asarray(tag_lo),
                                  jnp.asarray(c_hi), jnp.asarray(c_lo)))
        except Exception:
            self.service.release(lease)
            for req, _, _, _ in packed:   # nothing served: refund quota
                self.registry.refund(req.tenant_id, req.num_samples)
            raise
        self.engine_calls += 1
        if self.journal is not None:
            self.journal.append_window(channel, lease.lo, lease.hi)
        lease.commit()
        self.samples_generated += rows * cols

        responses: Dict[str, np.ndarray] = {}
        assignments: List[Assignment] = []
        for req, col0, ncols, rtags in packed:
            n = req.num_samples
            responses[req.rid] = slice_response(block, col0, ncols, n,
                                                req.shape)
            assignments.append(Assignment(
                rid=req.rid, tenant_id=req.tenant_id, sampler=sampler,
                out_dtype=out_dtype, shape=tuple(req.shape),
                channel=channel, lo=lease.lo, rows=rows, tags=tuple(rtags),
                deco=self.deco))
            self.requests_served += 1
            self.samples_served += n
        return responses, assignments, errors

    def stats(self) -> Dict[str, Any]:
        served = max(1, self.requests_served)
        return {
            "requests_served": self.requests_served,
            "engine_calls": self.engine_calls,
            "lease_calls": self.lease_calls,
            "calls_per_request": (self.engine_calls + self.lease_calls)
                                 / served,
            "samples_served": self.samples_served,
            "samples_generated": self.samples_generated,
            "fill_ratio": self.samples_served
                          / max(1, self.samples_generated),
            "window_fn_cache": len(self._window_fns),
            "window_fn_cache_max": self.window_fn_cache_size,
        }
