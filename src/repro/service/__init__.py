"""RandService: multi-tenant randomness-as-a-service.

The serving layer over ``core.engine`` + ``runtime.blocks``: a
deterministic tenant registry (``tenants``), a request-coalescing
frontend (``frontend``), a bounded-queue dispatch server with standing
producer pools (``server``), and an append-only replayable request
journal (``audit``).  See ``docs/service.md``.
"""
from repro.service.audit import Journal, replay, verify_ledger_disjoint
from repro.service.frontend import (Coalescer, RandRequest, class_channel,
                                    request_rows)
from repro.service.server import RandServer, ServerConfig, ServiceClosed
from repro.service.tenants import (QuotaExceeded, Tenant,
                                   TenantCollisionError, TenantRegistry,
                                   tenant_region)

__all__ = [
    "Coalescer", "Journal", "QuotaExceeded", "RandRequest", "RandServer",
    "ServerConfig", "ServiceClosed", "Tenant", "TenantCollisionError",
    "TenantRegistry", "class_channel", "replay", "request_rows",
    "tenant_region", "verify_ledger_disjoint",
]
