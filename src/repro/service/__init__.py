"""RandService: multi-tenant randomness-as-a-service.

The serving layer over ``core.engine`` + ``runtime.blocks``: a
deterministic tenant registry (``tenants``), a request-coalescing
frontend (``frontend``), a bounded-queue dispatch server with standing
producer pools (``server``), an append-only replayable request journal
(``audit``), and — over the wire — a length-prefixed socket transport
(``transport``) plus a sharded fleet with journal-backed failover
(``fleet``).  See ``docs/service.md``.

``fleet``/``transport`` symbols are imported lazily (PEP 562): the
in-process service must stay importable without touching the socket
layer.
"""
from repro.service.audit import (Journal, JournalLockedError, replay,
                                 replay_entry, verify_ledger_disjoint)
from repro.service.frontend import (Coalescer, RandRequest, class_channel,
                                    request_rows)
from repro.service.server import (RandServer, ServerConfig, ServiceClosed,
                                  drain_signal_event)
from repro.service.tenants import (QuotaExceeded, Tenant,
                                   TenantCollisionError, TenantRegistry,
                                   tenant_region)

_WIRE = {
    "Fleet": "repro.service.fleet",
    "FleetClient": "repro.service.fleet",
    "FleetConfig": "repro.service.fleet",
    "FleetError": "repro.service.fleet",
    "HashRing": "repro.service.fleet",
    "run_fleet_burst": "repro.service.fleet",
    "ShardHost": "repro.service.transport",
    "TransportError": "repro.service.transport",
    "FrameTooLarge": "repro.service.transport",
    "TornFrame": "repro.service.transport",
    "WireError": "repro.service.transport",
}


def __getattr__(name):
    mod = _WIRE.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)


__all__ = [
    "Coalescer", "Fleet", "FleetClient", "FleetConfig", "FleetError",
    "FrameTooLarge", "HashRing", "Journal", "JournalLockedError",
    "QuotaExceeded", "RandRequest", "RandServer", "ServerConfig",
    "ServiceClosed", "ShardHost", "Tenant", "TenantCollisionError",
    "TenantRegistry", "TornFrame", "TransportError", "WireError",
    "class_channel", "drain_signal_event", "replay", "replay_entry",
    "request_rows", "run_fleet_burst", "tenant_region",
    "verify_ledger_disjoint",
]
