"""RandServer: the dispatch loop of randomness-as-a-service.

One daemon thread owns a bounded request queue (a full queue blocks
``submit`` — backpressure, not unbounded buffering) and turns arrivals
into microbatches under a two-sided watermark: a batch closes when it
reaches ``max_batch`` requests OR when the oldest request has waited
``max_delay_s``.  Each batch is served by

  * **standing producer pools** for the configured hot
    ``(sampler, dtype)`` classes: a ``runtime.blocks.BlockProducer``
    keeps pre-generated ``(pool_rows, pool_cols)`` blocks ready
    (double-buffered, leased + dispatched ahead of demand — the
    paper's FIFO-into-application), and small requests are served by
    slicing whole columns off the current block, or
  * the **coalescing frontend** (``repro.service.frontend``) for
    everything else: one leased counter window + one fused gathered-tag
    ``engine.generate`` per request class.

Every response's assignment is journaled and fsynced *before* the
caller's future resolves, so a crash after a response was released is
always replayable (``repro.service.audit``).  Journaling is
**group-committed**: each microbatch becomes ONE atomic ``batch``
record (its composition in batch order + every counter window it
consumed) and ONE fsync, instead of a write+fsync per request.  On
construction with a non-empty journal the server fences every
journaled window off its ledgers — a restarted service can never
re-serve consumed randomness — and standing pools resume mid-block at
the exact column cursor the journal implies, so a failover peer's
pool serves the same columns the dead owner would have.

Shutdown is a graceful drain: ``shutdown()`` stops new admissions,
serves everything already queued, closes the pools (releasing their
unconsumed reservations), and only then returns.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import signal as signal_mod
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime import blocks
from repro.service import tenants as tenants_mod
from repro.service.audit import Journal
from repro.service.frontend import (DEFAULT_MAX_ROWS, Assignment, Coalescer,
                                    RandRequest, slice_response)

_STOP = object()


class _SealedBatch:
    """A pre-composed microbatch enqueued as ONE queue item.

    ``submit_batch`` wraps its requests in this so the dispatch loop
    serves them exactly as composed — never merged with neighbouring
    arrivals, never re-chunked — which is what lets a wire shard seal
    batch composition at the transport gate and journal it atomically.
    """
    __slots__ = ("items",)

    def __init__(self, items: List) -> None:
        self.items = items


class ServiceClosed(RuntimeError):
    """submit() after shutdown began (or the queue was torn down)."""


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Knobs of the dispatch loop and the standing pools.

    ``max_batch``/``max_delay_s`` are the microbatch watermark (size OR
    deadline); ``queue_depth`` bounds admission (backpressure);
    ``hot_classes`` lists the (sampler, out_dtype) pairs that get a
    standing double-buffered producer pool.
    """
    max_batch: int = 256
    max_delay_s: float = 0.005
    queue_depth: int = 4096
    max_rows: int = DEFAULT_MAX_ROWS
    hot_classes: Tuple[Tuple[str, str], ...] = ()
    pool_rows: int = 1024
    pool_cols: int = 64
    pool_depth: int = 2
    pool_donate: bool = True
    pool_fuse: int = 1
    default_quota: Optional[int] = None


def pool_channel(sampler: str, out_dtype: str) -> str:
    """Channel of one hot class's standing pool (distinct from the
    coalescer's class channel: pooled columns are the channel's own
    leaf table 0..pool_cols-1, not tenant-region tags)."""
    return f"service/pool/{sampler}/{out_dtype}"


class _Pool:
    """Standing producer for one hot class + a column cursor over the
    current pre-generated block.  Dispatcher-thread only (no locks)."""

    def __init__(self, service: blocks.BlockService, sampler: str,
                 out_dtype: str, *, rows: int, cols: int, depth: int,
                 donate: bool = False, fuse: int = 1):
        self.sampler, self.out_dtype = sampler, out_dtype
        self.channel = pool_channel(sampler, out_dtype)
        self.rows, self.cols = rows, cols
        self._service = service
        # donation is an optimisation, never a requirement: fall back to
        # plain allocation where the runtime can't alias
        self.donate = donate and blocks.donation_supported()
        service.open(self.channel, num_streams=cols, sampler=sampler,
                     out_dtype=out_dtype)
        self._producer = service.producer(self.channel, rows, depth=depth,
                                          donate=self.donate, fuse=fuse)
        self._lease: Optional[blocks.Lease] = None
        self._block: Optional[np.ndarray] = None
        self._col = 0
        self.blocks_consumed = 0
        self.requests_served = 0

    def can_serve(self, n: int) -> bool:
        return -(-n // self.rows) <= self.cols

    def serve(self, req: RandRequest
              ) -> Tuple[np.ndarray, Assignment, bool]:
        """Slice one request off the current block; the third result is
        True when this serve pulled (and so must journal) a new window."""
        n = req.num_samples
        ncols = -(-n // self.rows)
        fresh = False
        if self._block is None or self._col + ncols > self.cols:
            # leftover columns are discarded, never served twice: the
            # lease stays committed (fenced) either way
            self._lease, blk = next(self._producer)
            # donated blocks are valid only until the next producer pull,
            # and np.asarray of a CPU jax array may be a zero-copy view of
            # ring memory the next window will overwrite — force a copy.
            self._block = np.array(blk) if self.donate else np.asarray(blk)
            self._col = 0
            self.blocks_consumed += 1
            fresh = True
        col0, self._col = self._col, self._col + ncols
        resp = slice_response(self._block, col0, ncols, n, req.shape)
        asg = Assignment(
            rid=req.rid, tenant_id=req.tenant_id, sampler=self.sampler,
            out_dtype=self.out_dtype, shape=tuple(req.shape),
            channel=self.channel, lo=self._lease.lo, rows=self.rows,
            tags=tuple(range(col0, col0 + ncols)))
        self.requests_served += 1
        return resp, asg, fresh

    def resume(self, lo: int, consumed: int) -> None:
        """Re-enter the middle of the journaled block ``[lo, lo+rows)``
        with ``consumed`` columns already served.

        The window is already durable (journaled + fenced), so the
        block is REGENERATED — bit-identical by counter addressing —
        without leasing; the column cursor continues exactly where the
        previous owner's journal left off.  A restarted/adopting server
        therefore serves the same columns for the same arrivals the
        dead owner would have — the pool half of deterministic
        failover.
        """
        blk = self._service.regenerate(self.channel, lo, self.rows)
        self._block = np.asarray(blk)
        self._lease = blocks.Lease(channel=self.channel, lo=int(lo),
                                   hi=int(lo) + self.rows,
                                   service=self._service)
        self._col = int(consumed)

    def close(self) -> None:
        self._producer.close()


class RandServer:
    """Multi-tenant randomness service over one seed's stream space.

    Example:
        >>> from repro.service import RandServer, ServerConfig
        >>> srv = RandServer(seed=3, config=ServerConfig(max_batch=1))
        >>> u = srv.request("docs/tenant", (4,), sampler="uniform")
        >>> (u.shape, str(u.dtype))
        ((4,), 'float32')
        >>> srv.shutdown()     # True: drained (and journal closed)
        True
    """

    def __init__(self, seed: int = 0, *,
                 config: Optional[ServerConfig] = None,
                 registry: Optional[tenants_mod.TenantRegistry] = None,
                 journal: Optional[Journal] = None,
                 backend: Optional[str] = None, deco: str = "splitmix64",
                 start: bool = True):
        self.seed = seed
        self.config = config or ServerConfig()
        self.journal = journal
        self.block_service = blocks.BlockService(seed, backend=backend)
        if journal is not None and journal.entries:
            # restart/adopt: restore committed windows AND raise each
            # channel's lease floor to its journaled high-water mark —
            # this MUST happen before the pools below spin up their
            # producers (restore_ledger wipes reservations, so a later
            # restore would strand every producer's leased-ahead block)
            journal.restore_into(self.block_service, fence=True)
        # explicit None-check: a freshly constructed registry is empty,
        # hence falsy (__len__) — `registry or ...` would discard it
        self.registry = (registry if registry is not None else
                         tenants_mod.TenantRegistry(
                             default_quota=self.config.default_quota))
        # the coalescer runs journal-less under the server: the server
        # group-commits ONE atomic `batch` record per microbatch (see
        # _serve_batch) instead of per-request/per-window records, so
        # windows are derived from the returned assignments.  Direct
        # Coalescer users (quality battery) keep per-record journaling.
        self.coalescer = Coalescer(
            self.block_service, self.registry, journal=None,
            backend=backend, deco=deco, max_rows=self.config.max_rows)
        self._pools: Dict[Tuple[str, str], _Pool] = {}
        for sampler, out_dtype in self.config.hot_classes:
            self._pools[(sampler, out_dtype)] = _Pool(
                self.block_service, sampler, out_dtype,
                rows=self.config.pool_rows, cols=self.config.pool_cols,
                depth=self.config.pool_depth,
                donate=self.config.pool_donate,
                fuse=self.config.pool_fuse)
        if journal is not None and journal.entries:
            self._resume_pools(journal)
        self._queue: "queue.Queue" = queue.Queue(
            maxsize=self.config.queue_depth)
        self._closed = threading.Event()
        self._drained = threading.Event()
        self._rid_lock = threading.Lock()
        self._rid = 0
        self._session_rids = set()
        if journal is not None:
            self._session_rids = {e["rid"] for e in journal.requests()}
            # continue auto-rids past anything already journaled: a
            # restarted server must never reuse a pre-crash rid (replay
            # keys responses by rid)
            for e in journal.requests():
                rid = e.get("rid", "")
                if rid.startswith("r") and rid[1:].isdigit():
                    self._rid = max(self._rid, int(rid[1:]))
        self._latencies = collections.deque(maxlen=100_000)
        self._served = 0
        self._failed = 0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._close_lock = threading.Lock()
        self._thread = threading.Thread(target=self._loop,
                                        name="randservice", daemon=True)
        self.started = False
        if start:
            self.start()

    def _resume_pools(self, journal: Journal) -> None:
        """Continue each standing pool mid-block from the journal.

        The journal's last request against a pool channel names the
        block window (``lo``) and, via its highest tag, the column
        cursor; regenerating that window (no lease — it is already
        committed and fenced) and setting the cursor makes the resumed
        pool's future serves identical to the dead owner's.
        """
        entries = journal.requests()
        for pool in self._pools.values():
            last_lo: Optional[int] = None
            last_rows = 0
            consumed = 0
            for e in entries:
                if e["channel"] != pool.channel:
                    continue
                if e["lo"] != last_lo:
                    last_lo, consumed = e["lo"], 0
                    last_rows = int(e["rows"])
                if e["tags"]:
                    consumed = max(consumed, max(e["tags"]) + 1)
            # a changed pool geometry (rows) or an exhausted block means
            # there is nothing to re-enter; fresh leases start past the
            # fence either way
            if (last_lo is not None and last_rows == pool.rows
                    and consumed < pool.cols):
                pool.resume(last_lo, consumed)

    def start(self) -> None:
        """Start the dispatch loop (idempotent).  ``start=False`` at
        construction lets a caller enqueue a whole burst FIRST, making
        microbatch composition count-based — pure chunks of
        ``max_batch`` in submission order — instead of wall-clock-based
        (what the cross-run determinism check relies on)."""
        if not self.started:
            self.started = True
            self._thread.start()

    # -- client API --------------------------------------------------------

    def _next_rid(self) -> str:
        with self._rid_lock:
            self._rid += 1
            return f"r{self._rid:08d}"

    def submit(self, request: RandRequest,
               timeout: Optional[float] = None):
        """Enqueue a request; returns a ``concurrent.futures.Future``.

        A full queue BLOCKS the caller (bounded admission); after
        ``shutdown`` began, raises ``ServiceClosed``.
        """
        import concurrent.futures
        request.validate()
        if request.rid is None:
            request = dataclasses.replace(request, rid=self._next_rid())
        if self.journal is not None:
            # the journal is keyed by rid: a reused rid would make the
            # earlier response unauditable, so refuse it at admission
            with self._rid_lock:
                if request.rid in self._session_rids:
                    raise ValueError(
                        f"rid {request.rid!r} was already used in this "
                        f"journal; rids must be unique")
                self._session_rids.add(request.rid)
        fut: "concurrent.futures.Future" = concurrent.futures.Future()
        # the closed-check and the put are one atomic step against
        # drain()'s closed-set + _STOP put: anything enqueued here sits
        # BEFORE the sentinel and is served by the drain, never orphaned.
        # The put under the lock is non-blocking — a full queue releases
        # the lock and retries (backpressure without deadlocking drain).
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._close_lock:
                if self._closed.is_set():
                    raise ServiceClosed("RandServer is shut down")
                try:
                    self._queue.put_nowait(
                        (request, fut, time.perf_counter()))
                    return fut
                except queue.Full:
                    pass
            if deadline is not None and time.monotonic() >= deadline:
                raise queue.Full("RandServer queue stayed full "
                                 f"for {timeout}s")
            time.sleep(0.002)

    def submit_batch(self, requests: List[RandRequest],
                     timeout: Optional[float] = None) -> List:
        """Enqueue a SEALED microbatch; returns one Future per request.

        The batch is served exactly as composed — one queue item, one
        ``_serve_batch`` call, one atomic journal record — never merged
        with other arrivals or re-chunked by the watermark.  This is the
        wire shard's path: the transport gate seals composition (by
        count or explicit flush), and determinism of the journal record
        then makes failover reconstruct identical batches.
        """
        import concurrent.futures
        reqs: List[RandRequest] = []
        for request in requests:
            request.validate()
            if request.rid is None:
                request = dataclasses.replace(request, rid=self._next_rid())
            reqs.append(request)
        if self.journal is not None:
            # all-or-nothing admission against the session rid set: a
            # rejected batch must not leak partial registrations
            with self._rid_lock:
                for r in reqs:
                    if r.rid in self._session_rids:
                        raise ValueError(
                            f"rid {r.rid!r} was already used in this "
                            f"journal; rids must be unique")
                for r in reqs:
                    self._session_rids.add(r.rid)
        t0 = time.perf_counter()
        futs = [concurrent.futures.Future() for _ in reqs]
        sealed = _SealedBatch(
            [(r, f, t0) for r, f in zip(reqs, futs)])
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._close_lock:
                if self._closed.is_set():
                    raise ServiceClosed("RandServer is shut down")
                try:
                    self._queue.put_nowait(sealed)
                    return futs
                except queue.Full:
                    pass
            if deadline is not None and time.monotonic() >= deadline:
                raise queue.Full("RandServer queue stayed full "
                                 f"for {timeout}s")
            time.sleep(0.002)

    def request(self, tenant_id: str, shape, sampler: str = "bits",
                out_dtype: str = "float32",
                timeout: Optional[float] = None) -> np.ndarray:
        """Synchronous convenience: submit one request, wait, return."""
        return self.submit(RandRequest(
            tenant_id=tenant_id, shape=tuple(shape), sampler=sampler,
            out_dtype=out_dtype)).result(timeout)

    # -- dispatch loop -----------------------------------------------------

    def _loop(self) -> None:
        cfg = self.config
        stop = False
        while not stop:
            try:
                item = self._queue.get(timeout=0.05)
            except queue.Empty:
                continue
            if item is _STOP:
                break
            if isinstance(item, _SealedBatch):
                # sealed composition: serve verbatim, never merge
                self._serve_batch(item.items)
                continue
            batch = [item]
            pending: Optional[_SealedBatch] = None
            deadline = time.perf_counter() + cfg.max_delay_s
            while len(batch) < cfg.max_batch:
                left = deadline - time.perf_counter()
                if left <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=left)
                except queue.Empty:
                    break
                if nxt is _STOP:
                    stop = True
                    break
                if isinstance(nxt, _SealedBatch):
                    pending = nxt
                    break
                batch.append(nxt)
            self._serve_batch(batch)
            if pending is not None:
                self._serve_batch(pending.items)
        # stragglers racing the shutdown sentinel: fail, don't hang
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is _STOP:
                continue
            items = item.items if isinstance(item, _SealedBatch) else [item]
            for it in items:
                it[1].set_exception(
                    ServiceClosed("RandServer is shut down"))
        for pool in self._pools.values():
            pool.close()
        if self.journal is not None:
            self.journal.flush()
        self._drained.set()

    def _serve_batch(self, batch: List) -> None:
        t_batch = time.perf_counter()
        if self._t_first is None:
            self._t_first = t_batch
        ready = []     # (fut, result-or-exc, is_error, t_submit)
        coalesce: List[RandRequest] = []
        futs: Dict[str, Tuple] = {}
        seen_rids = set()
        served: List = []          # Assignments, batch order
        windows: List[Tuple[str, int, int]] = []
        for req, fut, t0 in batch:
            if req.rid in seen_rids:
                ready.append((fut, ValueError(
                    f"duplicate rid {req.rid!r} in one batch"), True, t0))
                continue
            seen_rids.add(req.rid)
            pool = self._pools.get(req.klass)
            if pool is not None and pool.can_serve(req.num_samples):
                try:
                    self.registry.charge(req.tenant_id, req.num_samples)
                except Exception as e:
                    ready.append((fut, e, True, t0))
                    continue
                try:
                    resp, asg, fresh = pool.serve(req)
                    if fresh:
                        windows.append(
                            (asg.channel, asg.lo, asg.lo + asg.rows))
                    served.append(asg)
                    ready.append((fut, resp, False, t0))
                except Exception as e:
                    # admission was charged but nothing served: refund
                    self.registry.refund(req.tenant_id, req.num_samples)
                    ready.append((fut, e, True, t0))
            else:
                coalesce.append(req)
                futs[req.rid] = (fut, t0)
        if coalesce:
            try:
                responses, asgs, errors = self.coalescer.flush(coalesce)
            except Exception as e:      # whole-batch failure
                responses, asgs, errors = {}, [], \
                    {r.rid: e for r in coalesce}
            # the journal-less coalescer no longer records its windows;
            # they are fully determined by the assignments (each class
            # batch shares one [lo, lo+rows) lease)
            seen_w = set()
            for a in asgs:
                if (a.channel, a.lo) not in seen_w:
                    seen_w.add((a.channel, a.lo))
                    windows.append((a.channel, a.lo, a.lo + a.rows))
            served.extend(asgs)
            for rid, (fut, t0) in futs.items():
                if rid in responses:
                    ready.append((fut, responses[rid], False, t0))
                else:
                    err = errors.get(
                        rid, RuntimeError(f"request {rid} not served"))
                    ready.append((fut, err, True, t0))
        # group commit, then durability before release: ONE atomic
        # batch record (composition + windows), ONE fsync, THEN resolve
        if self.journal is not None:
            if served:
                self.journal.append_batch(served, windows)
            self.journal.flush()
        t_done = time.perf_counter()
        self._t_last = t_done
        for fut, result, is_error, t0 in ready:
            self._latencies.append(t_done - t0)
            if is_error:
                self._failed += 1
                fut.set_exception(result)
            else:
                self._served += 1
                fut.set_result(result)

    # -- lifecycle / introspection ----------------------------------------

    def drain(self, timeout: Optional[float] = 60.0) -> bool:
        """Stop admissions, serve everything queued, close the pools.

        ``timeout=None`` waits forever (drain IS bounded by the queued
        work, so "forever" means "until every admitted request is
        answered").  Returns True once the loop has fully drained,
        False if the timeout elapsed first — callers that must not close
        the journal under the loop's feet (``shutdown``) check this.
        """
        with self._close_lock:
            first = not self._closed.is_set()
            self._closed.set()     # submits now refuse; queue can only
                                   # shrink, so the put below completes
        self.start()               # a never-started server still drains
        if first:
            self._queue.put(_STOP)
        drained = self._drained.wait(timeout)
        if drained:
            self._thread.join(timeout)
        return drained

    def shutdown(self, timeout: Optional[float] = 60.0) -> bool:
        """Graceful drain; closes the journal (releasing its lock) only
        once the drain completed — a timed-out drain leaves the journal
        open so the still-running loop cannot write through a closed
        fh.  Returns the drain result."""
        drained = self.drain(timeout)
        if drained and self.journal is not None:
            self.journal.close()
        return drained

    def __enter__(self) -> "RandServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def ledger_state(self) -> Dict[str, Any]:
        return self.block_service.ledger_state()

    def reset_metrics(self) -> None:
        """Zero the serving metrics (NOT the ledgers/quotas) so a
        benchmark can measure a steady-state window after warm-up."""
        self._latencies.clear()
        self._served = self._failed = 0
        self._t_first = self._t_last = None
        co = self.coalescer
        co.requests_served = co.engine_calls = co.lease_calls = 0
        co.samples_served = co.samples_generated = 0
        for p in self._pools.values():
            p.blocks_consumed = p.requests_served = 0

    def stats(self) -> Dict[str, Any]:
        """Serving metrics: requests/s, p50/p99 latency, coalescing."""
        lat = np.asarray(self._latencies, np.float64)
        span = ((self._t_last - self._t_first)
                if self._t_first is not None and self._t_last else 0.0)
        pool_calls = sum(p.blocks_consumed for p in self._pools.values())
        pool_served = sum(p.requests_served for p in self._pools.values())
        co = self.coalescer.stats()
        total = max(1, self._served)
        calls = co["engine_calls"] + co["lease_calls"] + 2 * pool_calls
        return {
            "requests_served": self._served,
            "requests_failed": self._failed,
            "pool_requests": pool_served,
            "pool_hit_rate": pool_served / total,
            "requests_per_s": (self._served / span) if span > 0 else 0.0,
            "latency_p50_ms": (float(np.percentile(lat, 50)) * 1e3
                               if lat.size else 0.0),
            "latency_p99_ms": (float(np.percentile(lat, 99)) * 1e3
                               if lat.size else 0.0),
            "engine_calls": co["engine_calls"] + pool_calls,
            "lease_calls": co["lease_calls"] + pool_calls,
            "calls_per_request": calls / total,
            "coalescing_factor": total / max(1, calls),
            "fill_ratio": co["fill_ratio"],
            "tenants": len(self.registry),
        }


def drain_signal_event(
        signals: Tuple[int, ...] = (signal_mod.SIGINT, signal_mod.SIGTERM)
) -> threading.Event:
    """Install handlers that set (and return) a ``threading.Event`` on
    the first delivery of any of ``signals`` — the trigger for a
    graceful drain.  SIGTERM is what process supervisors (and
    ``fleet.Fleet.stop``) send; SIGINT covers interactive ^C.  Main
    thread only (CPython restricts ``signal.signal``)."""
    stop = threading.Event()

    def _handler(signum, frame):
        stop.set()

    for s in signals:
        signal_mod.signal(s, _handler)
    return stop
