"""Wire transport for the RandService fleet: framed JSON over TCP.

A frame is a 4-byte big-endian length ``N`` followed by ``N`` bytes of
UTF-8 JSON.  Arrays travel as ``{"dtype", "shape", "data": base64}`` —
dtype by name (including ``bfloat16`` via ml_dtypes), bytes verbatim, so
``response_digest`` over wire-decoded responses equals the digest over
the server's own arrays.  Robustness rules of the framing layer:

  * a frame whose declared length exceeds ``max_frame`` is refused with
    an error frame and the connection is closed (the stream cannot be
    resynchronized after an untrusted length),
  * a peer that disconnects mid-frame raises :class:`TornFrame` on the
    reader's side; the server closes that connection and keeps
    accepting — one client's torn write can never wedge the accept
    loop,
  * the reply to a request whose rid is already journaled is computed
    by ``audit.replay_entry`` (flagged ``"replayed": true``), never by
    serving a second counter window — retries are idempotent by
    construction.

:class:`ShardHost` is one fleet process: a TCP accept loop over a set
of *logical shards*, each an independent ``RandServer`` + journal.  A
host usually starts owning exactly one shard; after a peer dies it
*adopts* the dead shard — takes the journal's exclusive flock (the
fencing step: the OS grants it only once the owner is truly gone),
fences the journaled windows off a fresh ledger, and resumes that
shard's tenant regions bit-identically.  The scripted fault layer
(``runtime.fault.FaultInjector``) hooks the request path so kill /
hang / drop / slow adversaries run deterministically in CI.
"""
from __future__ import annotations

import base64
import json
import os
import socket
import struct
import sys
import threading
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.runtime.fault import FaultInjector, rid_index
from repro.service import audit
from repro.service.frontend import RandRequest
from repro.service.server import RandServer, ServerConfig

_HEADER = struct.Struct("!I")

#: default cap on one frame's JSON payload (requests and responses are
#: far smaller; the cap exists so a hostile length prefix cannot make
#: the server allocate unbounded memory)
MAX_FRAME = 16 << 20


class TransportError(RuntimeError):
    """Base of the wire-level failure modes."""


class FrameTooLarge(TransportError):
    """Declared frame length exceeds the negotiated cap."""


class TornFrame(TransportError):
    """Peer vanished mid-frame (partial header or body)."""


class WireError(RuntimeError):
    """A structured error frame from the server (``kind`` + message)."""

    def __init__(self, kind: str, message: str):
        super().__init__(message)
        self.kind = kind


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------

def send_frame(sock: socket.socket, obj: Dict[str, Any], *,
               max_frame: int = MAX_FRAME) -> None:
    """Serialize ``obj`` and write one length-prefixed frame."""
    data = json.dumps(obj, sort_keys=True).encode("utf-8")
    if len(data) > max_frame:
        raise FrameTooLarge(
            f"frame of {len(data)} bytes exceeds cap {max_frame}")
    sock.sendall(_HEADER.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """``n`` bytes or None on EOF at offset 0; TornFrame on EOF mid-read."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise TornFrame(
                f"peer closed after {len(buf)} of {n} bytes")
        buf.extend(chunk)
    return bytes(buf)


def recv_frame(sock: socket.socket, *,
               max_frame: int = MAX_FRAME) -> Optional[Dict[str, Any]]:
    """Read one frame; ``None`` on clean EOF at a frame boundary.

    Raises :class:`TornFrame` on a mid-frame disconnect and
    :class:`FrameTooLarge` when the declared length exceeds the cap
    (after which the stream is unrecoverable — close the socket).
    """
    head = _recv_exact(sock, _HEADER.size)
    if head is None:
        return None
    (length,) = _HEADER.unpack(head)
    if length > max_frame:
        raise FrameTooLarge(
            f"declared frame length {length} exceeds cap {max_frame}")
    body = _recv_exact(sock, length)
    if body is None:        # EOF right after the header: torn, not clean
        raise TornFrame(f"peer closed before {length}-byte body")
    return json.loads(body.decode("utf-8"))


# ---------------------------------------------------------------------------
# Array + request encoding
# ---------------------------------------------------------------------------

def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes        # jax dependency: bfloat16 and friends
        return np.dtype(getattr(ml_dtypes, name))


def encode_array(a: np.ndarray) -> Dict[str, Any]:
    """JSON-able form of an array: dtype name, shape, base64 bytes."""
    a = np.asarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii")}


def decode_array(d: Dict[str, Any]) -> np.ndarray:
    """Inverse of :func:`encode_array`, byte-exact."""
    raw = base64.b64decode(d["data"])
    return np.frombuffer(raw, dtype=_resolve_dtype(d["dtype"])) \
             .reshape(tuple(d["shape"]))


def request_to_wire(req: RandRequest, shard: int) -> Dict[str, Any]:
    return {"op": "request", "shard": int(shard), "rid": req.rid,
            "tenant": req.tenant_id, "shape": list(req.shape),
            "sampler": req.sampler, "dtype": req.out_dtype}


def request_from_wire(msg: Dict[str, Any]) -> RandRequest:
    return RandRequest(tenant_id=msg["tenant"],
                       shape=tuple(int(d) for d in msg["shape"]),
                       sampler=msg["sampler"], out_dtype=msg["dtype"],
                       rid=msg["rid"])


# ---------------------------------------------------------------------------
# ShardHost: one fleet process
# ---------------------------------------------------------------------------

class _DropReply(Exception):
    """Scripted drop-frame fault: close the connection instead of
    replying (the request WAS served and journaled)."""


class ShardHost:
    """TCP host for one or more logical RandService shards.

    Every logical shard is a full ``RandServer`` over the *same* global
    plan (same seed): which tenants a shard serves is decided entirely
    by the client-side hash ring, so any host can adopt any shard —
    state is (seed, journal), nothing else.
    """

    def __init__(self, seed: int, *, host: str = "127.0.0.1",
                 port: int = 0, config: Optional[ServerConfig] = None,
                 injector: Optional[FaultInjector] = None,
                 backend: Optional[str] = None,
                 max_frame: int = MAX_FRAME):
        self.seed = seed
        self.config = config or ServerConfig(max_batch=1,
                                             max_delay_s=0.0)
        self.injector = injector
        self.backend = backend
        self.max_frame = max_frame
        self._servers: Dict[int, RandServer] = {}
        self._journals: Dict[int, audit.Journal] = {}
        self._adopted: set = set()
        self._hung = threading.Event()
        self._lock = threading.Lock()
        self._closing = threading.Event()
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        # poll the listener: closing a socket does NOT wake a thread
        # blocked in accept() on Linux, so a timeout is the only way
        # close() can reliably retire the accept thread (accepted conns
        # come out blocking: stdlib accept() resets inherited timeouts)
        self._sock.settimeout(0.2)
        self.address: Tuple[str, int] = self._sock.getsockname()
        self._conns: set = set()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="shardhost-accept", daemon=True)
        self._accept_thread.start()

    # -- shard lifecycle ---------------------------------------------------

    def add_shard(self, shard: int,
                  journal_path: Optional[str] = None) -> RandServer:
        """Open logical shard ``shard`` on this host (initial ownership)."""
        journal = (audit.Journal(journal_path)
                   if journal_path is not None else None)
        srv = RandServer(self.seed, config=self.config, journal=journal,
                         backend=self.backend)
        with self._lock:
            self._servers[shard] = srv
            if journal is not None:
                self._journals[shard] = journal
        return srv

    def adopt(self, shard: int, journal_path: str) -> RandServer:
        """Take over a dead peer's shard: lock its journal (fencing —
        raises ``JournalLockedError`` while the owner still lives),
        fence the journaled windows, resume its tenant regions.
        """
        journal = audit.Journal(journal_path)     # flock = the fence
        try:
            srv = RandServer(self.seed, config=self.config,
                             journal=journal, backend=self.backend)
            # belt over braces: raise the lease floor to the journaled
            # high-water mark so even explicit at= leases cannot land
            # below what the dead shard may have served
            journal.restore_into(srv.block_service, fence=True)
        except Exception:
            journal.close()
            raise
        with self._lock:
            self._servers[shard] = srv
            self._journals[shard] = journal
            # the scripted adversary targets a shard's ORIGINAL owner;
            # without this, every process's injector would re-fire the
            # same spec when the retried request reaches the adopter —
            # a scripted single kill would cascade through the fleet
            self._adopted.add(shard)
        return srv

    def shards(self) -> Tuple[int, ...]:
        with self._lock:
            return tuple(sorted(self._servers))

    # -- accept/serve loops ------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue            # poll tick: re-check _closing
            except OSError:
                break               # listener closed by close()
            with self._lock:
                self._conns.add(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name="shardhost-conn", daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._closing.is_set():
                try:
                    msg = recv_frame(conn, max_frame=self.max_frame)
                except FrameTooLarge as e:
                    # the stream cannot be resynced after a bad length:
                    # best-effort error frame, then close
                    try:
                        send_frame(conn, {"ok": False,
                                          "kind": "frame_too_large",
                                          "error": str(e)})
                    except OSError:
                        pass
                    return
                except (TornFrame, OSError):
                    return          # torn client write: drop the conn only
                if msg is None:
                    return          # clean EOF
                try:
                    reply = self._dispatch(msg)
                except _DropReply:
                    return          # scripted fault: vanish without reply
                except Exception as e:   # noqa: BLE001 — reply, don't die
                    reply = {"ok": False, "kind": "server_error",
                             "error": f"{type(e).__name__}: {e}"}
                try:
                    send_frame(conn, reply, max_frame=self.max_frame)
                except OSError:
                    return
        finally:
            with self._lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    # -- op handlers -------------------------------------------------------

    def _dispatch(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        op = msg.get("op")
        if op == "request":
            return self._handle_request(msg)
        if op == "adopt":
            return self._handle_adopt(msg)
        if op == "stats":
            return self._handle_stats(msg)
        if op == "ping":
            return {"ok": True, "op": "ping", "shards": list(self.shards())}
        return {"ok": False, "kind": "bad_request",
                "error": f"unknown op {op!r}"}

    def _shard_server(self, msg) -> Tuple[int, RandServer]:
        shard = int(msg.get("shard", -1))
        with self._lock:
            srv = self._servers.get(shard)
        if srv is None:
            raise WireError("not_owner",
                            f"shard {shard} is not hosted here "
                            f"(have {list(self.shards())})")
        return shard, srv

    def _handle_request(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        try:
            shard, srv = self._shard_server(msg)
        except WireError as e:
            return {"ok": False, "kind": e.kind, "error": str(e)}
        req = request_from_wire(msg)
        if self._hung.is_set():
            # a hung host is wedged for good: every request (including
            # reconnect retries) stalls, holding the journal flock —
            # only fencing (SIGKILL) + peer adoption recovers the shard
            time.sleep(3600.0)
        drop_after = False
        if self.injector is not None and shard not in self._adopted:
            spec = self.injector.fire(shard, rid_index(req.rid))
            if spec is not None:
                if spec.kind == "kill":
                    # SIGKILL semantics: no unwind, no journal write for
                    # this request, flock released by the kernel
                    os._exit(137)
                elif spec.kind == "hang":
                    self._hung.set()
                    time.sleep(3600.0)
                elif spec.kind == "slow":
                    time.sleep(spec.seconds)
                elif spec.kind == "drop":
                    drop_after = True
        journal = self._journals.get(shard)
        if journal is not None and req.rid is not None:
            entry = journal.find_request(req.rid)
            if entry is not None:
                # idempotent retry: the assignment is durable — replay
                # it instead of serving a second window
                a = audit.replay_entry(entry, seed=self.seed,
                                       backend=self.backend or "xla")
                return {"ok": True, "rid": req.rid, "replayed": True,
                        "array": encode_array(a)}
        result = srv.submit(req).result(timeout=600)
        if drop_after:
            raise _DropReply()
        return {"ok": True, "rid": req.rid, "replayed": False,
                "array": encode_array(result)}

    def _handle_adopt(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        shard = int(msg["shard"])
        with self._lock:
            if shard in self._servers:
                return {"ok": True, "shard": shard, "already": True}
        try:
            self.adopt(shard, msg["journal"])
        except audit.JournalLockedError as e:
            return {"ok": False, "kind": "locked", "error": str(e)}
        return {"ok": True, "shard": shard, "already": False}

    def _handle_stats(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        try:
            shard, srv = self._shard_server(msg)
        except WireError as e:
            return {"ok": False, "kind": e.kind, "error": str(e)}
        return {"ok": True, "shard": shard, "stats": srv.stats()}

    # -- lifecycle ---------------------------------------------------------

    def close(self, timeout: Optional[float] = 60.0) -> None:
        """Stop accepting, drain every hosted shard, close journals,
        and retire every transport thread — an in-process host must not
        leak accept/conn threads into its embedder."""
        self._closing.set()
        self._accept_thread.join(timeout=5.0)
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            servers = list(self._servers.values())
        for srv in servers:
            srv.shutdown(timeout)
        # idle persistent connections sit blocked in recv; close() alone
        # does not wake them, shutdown() delivers EOF and does
        with self._lock:
            conns, self._conns = list(self._conns), set()
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass

    def __enter__(self) -> "ShardHost":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Client-side RPC helper
# ---------------------------------------------------------------------------

def rpc(address: Tuple[str, int], msg: Dict[str, Any], *,
        timeout: Optional[float] = 60.0,
        max_frame: int = MAX_FRAME) -> Dict[str, Any]:
    """One-shot request/response against a ShardHost (fresh connection)."""
    with socket.create_connection(address, timeout=timeout) as sock:
        send_frame(sock, msg, max_frame=max_frame)
        reply = recv_frame(sock, max_frame=max_frame)
    if reply is None:
        raise TornFrame(f"no reply from {address}")
    return reply
