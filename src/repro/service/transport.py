"""Wire transport for the RandService fleet: two frame formats, one TCP.

Two wire versions coexist on every connection, disambiguated by the
first byte of each frame:

  * **v1 (JSON)** — a 4-byte big-endian length ``N`` followed by ``N``
    bytes of UTF-8 JSON.  Arrays travel as ``{"dtype", "shape",
    "data": base64}`` — dtype by name (including ``bfloat16`` via
    ml_dtypes), bytes verbatim.  Because the length prefix is capped at
    16 MiB, a v1 frame's first byte is always ``0x00`` or ``0x01``.
  * **v2 (binary)** — ``0xB7`` magic + version byte + two u32
    little-endian lengths (header, payload) + a compact JSON header +
    the raw array payload.  Array values leave the message dict and
    travel as raw little-endian bytes after the header, described by a
    ``"_bin"`` table of ``{dtype, shape, off, nbytes}`` — no base64
    inflation, no ``json.dumps`` over megabyte payloads — and decode
    ZERO-COPY: ``np.frombuffer`` views over the received buffer (the
    views are read-only; copy before mutating).

Either way ``response_digest`` over wire-decoded responses equals the
digest over the server's own arrays.  Versions are negotiated per
connection with a ``hello`` op (the server replies to every frame in
the version the frame arrived in, so v1-only peers keep working
unannounced).  Robustness rules of the framing layer:

  * a frame whose declared length exceeds ``max_frame`` is refused with
    an error frame and the connection is closed (the stream cannot be
    resynchronized after an untrusted length) — v2 header/payload
    lengths included,
  * a peer that disconnects mid-frame raises :class:`TornFrame` on the
    reader's side; the server closes that connection and keeps
    accepting — one client's torn write can never wedge the accept
    loop,
  * the reply to a request whose rid is already journaled is computed
    by ``audit.replay_entry`` (flagged ``"replayed": true``), never by
    serving a second counter window — retries are idempotent by
    construction.

:class:`ShardHost` is one fleet process: a TCP accept loop over a set
of *logical shards*, each an independent ``RandServer`` + journal.
Requests are served PIPELINED: the connection reader admits each
request to its shard's :class:`_Gate` (an arrival-order microbatch
gate) and keeps reading; replies are rid-tagged and sent as their
futures resolve, possibly out of order.  A gate seals a batch only by
COUNT (``max_batch``) or an explicit client ``flush`` op — never by
wall-clock and never on connection EOF — which makes batch composition
a pure function of per-shard arrival order and is what keeps failover
digest-identical with coalescing enabled (see ``_Gate``).

A host usually starts owning exactly one shard; after a peer dies it
*adopts* the dead shard — takes the journal's exclusive flock (the
fencing step: the OS grants it only once the owner is truly gone),
fences the journaled windows off a fresh ledger, and resumes that
shard's tenant regions bit-identically.  The scripted fault layer
(``runtime.fault.FaultInjector``) hooks the request path so kill /
hang / drop / slow adversaries run deterministically in CI.
"""
from __future__ import annotations

import base64
import json
import os
import socket
import struct
import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.fault import FaultInjector, rid_index
from repro.service import audit
from repro.service.frontend import RandRequest
from repro.service.server import RandServer, ServerConfig

_HEADER = struct.Struct("!I")

#: v2 binary framing: magic + version byte, then LE (header, payload)
#: lengths.  The magic can never open a v1 frame — a v1 length prefix
#: under the 16 MiB cap starts 0x00/0x01, never 0xB7.
WIRE_MAGIC = 0xB7
WIRE_V1 = 1
WIRE_V2 = 2
SUPPORTED_VERSIONS = (WIRE_V1, WIRE_V2)
_V2_HEAD = struct.Struct("<II")

#: default cap on one frame's JSON payload (requests and responses are
#: far smaller; the cap exists so a hostile length prefix cannot make
#: the server allocate unbounded memory)
MAX_FRAME = 16 << 20


class TransportError(RuntimeError):
    """Base of the wire-level failure modes."""


class FrameTooLarge(TransportError):
    """Declared frame length exceeds the negotiated cap."""


class TornFrame(TransportError):
    """Peer vanished mid-frame (partial header or body)."""


class WireError(RuntimeError):
    """A structured error frame from the server (``kind`` + message)."""

    def __init__(self, kind: str, message: str):
        super().__init__(message)
        self.kind = kind


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------

def send_frame(sock: socket.socket, obj: Dict[str, Any], *,
               max_frame: int = MAX_FRAME) -> None:
    """Serialize ``obj`` and write one length-prefixed frame."""
    data = json.dumps(obj, sort_keys=True).encode("utf-8")
    if len(data) > max_frame:
        raise FrameTooLarge(
            f"frame of {len(data)} bytes exceeds cap {max_frame}")
    sock.sendall(_HEADER.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """``n`` bytes or None on EOF at offset 0; TornFrame on EOF mid-read."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise TornFrame(
                f"peer closed after {len(buf)} of {n} bytes")
        buf.extend(chunk)
    return bytes(buf)


def recv_frame(sock: socket.socket, *,
               max_frame: int = MAX_FRAME) -> Optional[Dict[str, Any]]:
    """Read one frame; ``None`` on clean EOF at a frame boundary.

    Raises :class:`TornFrame` on a mid-frame disconnect and
    :class:`FrameTooLarge` when the declared length exceeds the cap
    (after which the stream is unrecoverable — close the socket).
    """
    head = _recv_exact(sock, _HEADER.size)
    if head is None:
        return None
    (length,) = _HEADER.unpack(head)
    if length > max_frame:
        raise FrameTooLarge(
            f"declared frame length {length} exceeds cap {max_frame}")
    body = _recv_exact(sock, length)
    if body is None:        # EOF right after the header: torn, not clean
        raise TornFrame(f"peer closed before {length}-byte body")
    return json.loads(body.decode("utf-8"))


def send_wire(sock: socket.socket, obj: Dict[str, Any], *,
              version: int = WIRE_V1,
              max_frame: int = MAX_FRAME) -> int:
    """Send one frame in ``version``; returns bytes put on the wire.

    ``obj`` may carry live ``np.ndarray`` values at the top level: v1
    encodes them via :func:`encode_array` (base64 JSON); v2 ships them
    as raw little-endian bytes after the compact header, described by
    the ``"_bin"`` table.
    """
    if version == WIRE_V1:
        enc = {k: (encode_array(v) if isinstance(v, np.ndarray) else v)
               for k, v in obj.items()}
        data = json.dumps(enc, sort_keys=True).encode("utf-8")
        if len(data) > max_frame:
            raise FrameTooLarge(
                f"frame of {len(data)} bytes exceeds cap {max_frame}")
        sock.sendall(_HEADER.pack(len(data)) + data)
        return _HEADER.size + len(data)
    if version != WIRE_V2:
        raise TransportError(f"unknown wire version {version}")
    head: Dict[str, Any] = {}
    bins: Dict[str, Dict[str, Any]] = {}
    chunks: List[bytes] = []
    off = 0
    for k, v in obj.items():
        if isinstance(v, np.ndarray):
            raw = np.ascontiguousarray(v).tobytes()
            bins[k] = {"dtype": str(v.dtype), "shape": list(v.shape),
                       "off": off, "nbytes": len(raw)}
            chunks.append(raw)
            off += len(raw)
        else:
            head[k] = v
    if bins:
        head["_bin"] = bins
    hdata = json.dumps(head, sort_keys=True).encode("utf-8")
    total = 2 + _V2_HEAD.size + len(hdata) + off
    if total > max_frame:
        raise FrameTooLarge(
            f"frame of {total} bytes exceeds cap {max_frame}")
    sock.sendall(bytes((WIRE_MAGIC, WIRE_V2))
                 + _V2_HEAD.pack(len(hdata), off) + hdata)
    for raw in chunks:
        sock.sendall(raw)
    return total


def recv_wire(sock: socket.socket, *, max_frame: int = MAX_FRAME
              ) -> Optional[Tuple[Dict[str, Any], int]]:
    """Read one frame of EITHER version; ``(msg, version)``, or ``None``
    on clean EOF at a frame boundary.

    The version is sniffed from the first byte (``WIRE_MAGIC`` opens a
    v2 frame; anything else is a v1 length prefix).  v2 array payloads
    decode zero-copy — each ``"_bin"`` entry becomes a read-only
    ``np.frombuffer`` view over the received payload buffer, placed
    back into the message under its key.  Torn/oversize containment
    matches :func:`recv_frame` exactly.
    """
    first = _recv_exact(sock, 1)
    if first is None:
        return None
    if first[0] != WIRE_MAGIC:
        rest = _recv_exact(sock, _HEADER.size - 1)
        if rest is None:
            raise TornFrame("peer closed inside a v1 frame header")
        (length,) = _HEADER.unpack(first + rest)
        if length > max_frame:
            raise FrameTooLarge(
                f"declared frame length {length} exceeds cap {max_frame}")
        body = _recv_exact(sock, length)
        if body is None:
            raise TornFrame(f"peer closed before {length}-byte body")
        return json.loads(body.decode("utf-8")), WIRE_V1
    rest = _recv_exact(sock, 1 + _V2_HEAD.size)
    if rest is None:
        raise TornFrame("peer closed inside a v2 frame header")
    version = rest[0]
    if version != WIRE_V2:
        raise TransportError(f"unsupported wire version {version}")
    hlen, plen = _V2_HEAD.unpack(rest[1:])
    if 2 + _V2_HEAD.size + hlen + plen > max_frame:
        raise FrameTooLarge(
            f"declared v2 frame of {hlen}+{plen} bytes exceeds cap "
            f"{max_frame}")
    hdata = _recv_exact(sock, hlen)
    if hdata is None:
        raise TornFrame("peer closed before the v2 header")
    msg = json.loads(hdata.decode("utf-8"))
    payload = b""
    if plen:
        payload = _recv_exact(sock, plen)
        if payload is None:
            raise TornFrame(f"peer closed before {plen}-byte payload")
    bins = msg.pop("_bin", None)
    if bins:
        for k, d in bins.items():
            dt = _resolve_dtype(d["dtype"])
            msg[k] = np.frombuffer(
                payload, dtype=dt, count=d["nbytes"] // dt.itemsize,
                offset=d["off"]).reshape(tuple(d["shape"]))
    return msg, version


def reply_array(reply: Dict[str, Any]) -> np.ndarray:
    """The array of a reply read by :func:`recv_wire`, either version:
    a v2 reply already holds the zero-copy ndarray; a v1 reply holds
    the base64 encoding."""
    a = reply["array"]
    if isinstance(a, np.ndarray):
        return a
    return decode_array(a)


# ---------------------------------------------------------------------------
# Array + request encoding
# ---------------------------------------------------------------------------

def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes        # jax dependency: bfloat16 and friends
        return np.dtype(getattr(ml_dtypes, name))


def encode_array(a: np.ndarray) -> Dict[str, Any]:
    """JSON-able form of an array: dtype name, shape, base64 bytes."""
    a = np.asarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii")}


def decode_array(d: Dict[str, Any]) -> np.ndarray:
    """Inverse of :func:`encode_array`, byte-exact."""
    raw = base64.b64decode(d["data"])
    return np.frombuffer(raw, dtype=_resolve_dtype(d["dtype"])) \
             .reshape(tuple(d["shape"]))


def request_to_wire(req: RandRequest, shard: int) -> Dict[str, Any]:
    return {"op": "request", "shard": int(shard), "rid": req.rid,
            "tenant": req.tenant_id, "shape": list(req.shape),
            "sampler": req.sampler, "dtype": req.out_dtype}


def request_from_wire(msg: Dict[str, Any]) -> RandRequest:
    return RandRequest(tenant_id=msg["tenant"],
                       shape=tuple(int(d) for d in msg["shape"]),
                       sampler=msg["sampler"], out_dtype=msg["dtype"],
                       rid=msg["rid"])


# ---------------------------------------------------------------------------
# ShardHost: one fleet process
# ---------------------------------------------------------------------------

class _DropReply(Exception):
    """Scripted drop-frame fault: close the connection instead of
    replying (the request WAS served and journaled)."""


class _Gate:
    """Per-shard arrival-order microbatch gate + in-flight rid registry.

    The determinism contract of pooled/coalesced fleet serving: a batch
    seals when it reaches ``max_batch`` requests or when an explicit
    client ``flush`` op arrives — NEVER on wall-clock and NEVER on
    connection EOF.  A dying client connection therefore cannot change
    batch composition: its parked requests stay parked; the client
    reconnects and resubmits unanswered rids in their original order;
    and the registry attaches those duplicate arrivals to the
    already-parked entry (or the in-flight future) instead of
    re-admitting them.  The gate's arrival sequence — and hence every
    journaled ``batch`` record — is identical to the no-fault run's,
    which is what the kill-mid-burst digest equality rests on.
    """

    def __init__(self, srv: RandServer, max_batch: int):
        self.srv = srv
        self.max_batch = max(1, int(max_batch))
        self._lock = threading.Lock()
        # arrival order; each entry carries every waiter for its rid
        self._pending: List[Tuple[RandRequest, List[Callable]]] = []
        self._pending_rids: Dict[str, List[Callable]] = {}
        self._inflight: Dict[str, Any] = {}       # rid -> Future

    def admit(self, req: RandRequest, deliver: Callable) -> None:
        """Park ``req``; ``deliver(future)`` fires when it resolves.

        A rid already in flight (or parked) gains a second waiter
        instead of a second slot — resubmissions after a connection
        death cannot perturb composition.
        """
        with self._lock:
            fut = self._inflight.get(req.rid)
            if fut is not None:
                fut.add_done_callback(deliver)
                return
            waiters = self._pending_rids.get(req.rid)
            if waiters is not None:
                waiters.append(deliver)
                return
            waiters = [deliver]
            self._pending.append((req, waiters))
            self._pending_rids[req.rid] = waiters
            if len(self._pending) >= self.max_batch:
                self._seal_locked()

    def flush(self) -> None:
        """Seal the current partial batch (client end-of-burst op)."""
        with self._lock:
            if self._pending:
                self._seal_locked()

    def _seal_locked(self) -> None:
        import concurrent.futures
        batch, self._pending = self._pending, []
        self._pending_rids = {}
        try:
            futs = self.srv.submit_batch([r for r, _ in batch])
        except Exception as e:          # refused batch: fail each waiter
            failed: "concurrent.futures.Future" = concurrent.futures.Future()
            failed.set_exception(e)
            for _, waiters in batch:
                for deliver in waiters:
                    deliver(failed)
            return
        for (req, waiters), fut in zip(batch, futs):
            self._inflight[req.rid] = fut
            fut.add_done_callback(self._retire(req.rid))
            for deliver in waiters:
                fut.add_done_callback(deliver)

    def _retire(self, rid: str) -> Callable:
        def cb(fut) -> None:
            # by resolution time the batch record is durable (the
            # server fsyncs before resolving), so late duplicates fall
            # through to the journal replay path
            with self._lock:
                self._inflight.pop(rid, None)
        return cb


class ShardHost:
    """TCP host for one or more logical RandService shards.

    Every logical shard is a full ``RandServer`` over the *same* global
    plan (same seed): which tenants a shard serves is decided entirely
    by the client-side hash ring, so any host can adopt any shard —
    state is (seed, journal), nothing else.
    """

    def __init__(self, seed: int, *, host: str = "127.0.0.1",
                 port: int = 0, config: Optional[ServerConfig] = None,
                 injector: Optional[FaultInjector] = None,
                 backend: Optional[str] = None,
                 max_frame: int = MAX_FRAME):
        self.seed = seed
        self.config = config or ServerConfig(max_batch=1,
                                             max_delay_s=0.0)
        self.injector = injector
        self.backend = backend
        self.max_frame = max_frame
        self._servers: Dict[int, RandServer] = {}
        self._journals: Dict[int, audit.Journal] = {}
        self._gates: Dict[int, _Gate] = {}
        self._adopted: set = set()
        self._hung = threading.Event()
        self._lock = threading.Lock()
        self._closing = threading.Event()
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        # poll the listener: closing a socket does NOT wake a thread
        # blocked in accept() on Linux, so a timeout is the only way
        # close() can reliably retire the accept thread (accepted conns
        # come out blocking: stdlib accept() resets inherited timeouts)
        self._sock.settimeout(0.2)
        self.address: Tuple[str, int] = self._sock.getsockname()
        self._conns: set = set()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="shardhost-accept", daemon=True)
        self._accept_thread.start()

    # -- shard lifecycle ---------------------------------------------------

    def add_shard(self, shard: int,
                  journal_path: Optional[str] = None) -> RandServer:
        """Open logical shard ``shard`` on this host (initial ownership)."""
        journal = (audit.Journal(journal_path)
                   if journal_path is not None else None)
        srv = RandServer(self.seed, config=self.config, journal=journal,
                         backend=self.backend)
        with self._lock:
            self._servers[shard] = srv
            self._gates[shard] = _Gate(srv, self.config.max_batch)
            if journal is not None:
                self._journals[shard] = journal
        return srv

    def adopt(self, shard: int, journal_path: str) -> RandServer:
        """Take over a dead peer's shard: lock its journal (fencing —
        raises ``JournalLockedError`` while the owner still lives),
        fence the journaled windows, resume its tenant regions.
        """
        journal = audit.Journal(journal_path)     # flock = the fence
        try:
            # the constructor restores + FENCES the journaled ledger
            # before its pool producers lease ahead (a second restore
            # here would wipe those producers' reservations)
            srv = RandServer(self.seed, config=self.config,
                             journal=journal, backend=self.backend)
        except Exception:
            journal.close()
            raise
        with self._lock:
            self._servers[shard] = srv
            self._gates[shard] = _Gate(srv, self.config.max_batch)
            self._journals[shard] = journal
            # the scripted adversary targets a shard's ORIGINAL owner;
            # without this, every process's injector would re-fire the
            # same spec when the retried request reaches the adopter —
            # a scripted single kill would cascade through the fleet
            self._adopted.add(shard)
        return srv

    def shards(self) -> Tuple[int, ...]:
        with self._lock:
            return tuple(sorted(self._servers))

    # -- accept/serve loops ------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue            # poll tick: re-check _closing
            except OSError:
                break               # listener closed by close()
            with self._lock:
                self._conns.add(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name="shardhost-conn", daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        # one write lock per connection: rid-tagged replies are sent by
        # whichever thread resolves the future (pipelined, possibly out
        # of order), and must never interleave mid-frame
        wlock = threading.Lock()
        try:
            while not self._closing.is_set():
                try:
                    got = recv_wire(conn, max_frame=self.max_frame)
                except FrameTooLarge as e:
                    # the stream cannot be resynced after a bad length:
                    # best-effort error frame, then close
                    self._send(conn, wlock, WIRE_V1,
                               {"ok": False, "kind": "frame_too_large",
                                "error": str(e)})
                    return
                except (TornFrame, TransportError, OSError):
                    return          # torn client write: drop the conn only
                if got is None:
                    return          # clean EOF
                msg, version = got
                if msg.get("op") == "request":
                    try:
                        self._handle_request(msg, conn, wlock, version)
                    except _DropReply:
                        return      # scripted fault: vanish without reply
                    except Exception as e:   # noqa: BLE001 — reply, don't die
                        self._send(conn, wlock, version,
                                   {"ok": False, "kind": "server_error",
                                    "rid": msg.get("rid"),
                                    "error": f"{type(e).__name__}: {e}"})
                    continue
                try:
                    reply = self._dispatch(msg)
                except Exception as e:   # noqa: BLE001 — reply, don't die
                    reply = {"ok": False, "kind": "server_error",
                             "error": f"{type(e).__name__}: {e}"}
                if not self._send(conn, wlock, version, reply):
                    return
        finally:
            with self._lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _send(self, conn: socket.socket, wlock: threading.Lock,
              version: int, obj: Dict[str, Any]) -> bool:
        with wlock:
            try:
                send_wire(conn, obj, version=version,
                          max_frame=self.max_frame)
                return True
            except (OSError, TransportError):
                # receiver gone (or reply unsendable): the client's
                # retry path owns recovery — journaled work replays
                return False

    # -- op handlers -------------------------------------------------------

    def _dispatch(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        op = msg.get("op")
        if op == "adopt":
            return self._handle_adopt(msg)
        if op == "stats":
            return self._handle_stats(msg)
        if op == "hello":
            return self._handle_hello(msg)
        if op == "flush":
            return self._handle_flush(msg)
        if op == "reset":
            return self._handle_reset(msg)
        if op == "ping":
            return {"ok": True, "op": "ping", "shards": list(self.shards())}
        return {"ok": False, "kind": "bad_request",
                "error": f"unknown op {op!r}"}

    def _handle_hello(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        """Version negotiation: highest wire version both sides speak.

        The reply itself goes out in the version the hello ARRIVED in
        (like every reply), so a v1-only peer never sees v2 bytes.
        """
        offered = set(msg.get("versions", [WIRE_V1]))
        common = [v for v in SUPPORTED_VERSIONS if v in offered]
        if not common:
            return {"ok": False, "kind": "bad_request",
                    "error": f"no common wire version in {sorted(offered)}; "
                             f"supported {list(SUPPORTED_VERSIONS)}"}
        return {"ok": True, "op": "hello", "version": max(common),
                "max_batch": self.config.max_batch}

    def _handle_flush(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        """Seal the addressed shard's partial batch (end-of-burst)."""
        try:
            shard, _ = self._shard_server(msg)
        except WireError as e:
            return {"ok": False, "kind": e.kind, "error": str(e)}
        with self._lock:
            gate = self._gates.get(shard)
        if gate is not None:
            gate.flush()
        return {"ok": True, "op": "flush", "shard": shard}

    def _handle_reset(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        """Zero the shard's serving metrics (benchmark warm-up split)."""
        try:
            shard, srv = self._shard_server(msg)
        except WireError as e:
            return {"ok": False, "kind": e.kind, "error": str(e)}
        srv.reset_metrics()
        return {"ok": True, "op": "reset", "shard": shard}

    def _shard_server(self, msg) -> Tuple[int, RandServer]:
        shard = int(msg.get("shard", -1))
        with self._lock:
            srv = self._servers.get(shard)
        if srv is None:
            raise WireError("not_owner",
                            f"shard {shard} is not hosted here "
                            f"(have {list(self.shards())})")
        return shard, srv

    def _handle_request(self, msg: Dict[str, Any], conn: socket.socket,
                        wlock: threading.Lock, version: int) -> None:
        """Admit one request (reader thread); the reply is sent by the
        future's done-callback — rid-tagged, possibly out of order with
        later requests on the same connection (pipelining)."""
        try:
            shard, srv = self._shard_server(msg)
        except WireError as e:
            self._send(conn, wlock, version,
                       {"ok": False, "kind": e.kind,
                        "rid": msg.get("rid"), "error": str(e)})
            return
        req = request_from_wire(msg)
        if self._hung.is_set():
            # a hung host is wedged for good: every request (including
            # reconnect retries) stalls, holding the journal flock —
            # only fencing (SIGKILL) + peer adoption recovers the shard
            time.sleep(3600.0)
        drop_after = False
        if self.injector is not None and shard not in self._adopted:
            spec = self.injector.fire(shard, rid_index(req.rid))
            if spec is not None:
                if spec.kind == "kill":
                    # SIGKILL semantics: no unwind, no journal write for
                    # this request, flock released by the kernel
                    os._exit(137)
                elif spec.kind == "hang":
                    self._hung.set()
                    time.sleep(3600.0)
                elif spec.kind == "slow":
                    # head-of-line on THIS connection only: the reader
                    # stalls, so later arrivals on the conn queue behind
                    time.sleep(spec.seconds)
                elif spec.kind == "drop":
                    drop_after = True
        journal = self._journals.get(shard)
        if journal is not None and req.rid is not None:
            entry = journal.find_request(req.rid)
            if entry is not None:
                # idempotent retry: the assignment is durable — replay
                # it instead of serving a second window.  Never admitted
                # to the gate, so resubmissions of journaled rids cannot
                # perturb batch composition.
                a = audit.replay_entry(entry, seed=self.seed,
                                       backend=self.backend or "xla")
                if drop_after:
                    raise _DropReply()
                self._send(conn, wlock, version,
                           {"ok": True, "rid": req.rid, "replayed": True,
                            "array": np.asarray(a)})
                return
        with self._lock:
            gate = self._gates[shard]

        def deliver(fut) -> None:
            try:
                result = fut.result()
            except Exception as e:      # noqa: BLE001 — reply, don't die
                obj = {"ok": False, "kind": "server_error",
                       "rid": req.rid, "error": f"{type(e).__name__}: {e}"}
            else:
                obj = {"ok": True, "rid": req.rid, "replayed": False,
                       "array": np.asarray(result)}
            if drop_after:
                # scripted fault: the request WAS served and journaled;
                # vanish (close the conn) instead of replying
                self._drop_conn(conn)
                return
            self._send(conn, wlock, version, obj)

        gate.admit(req, deliver)

    def _drop_conn(self, conn: socket.socket) -> None:
        try:
            conn.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            conn.close()
        except OSError:
            pass

    def _handle_adopt(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        shard = int(msg["shard"])
        with self._lock:
            if shard in self._servers:
                return {"ok": True, "shard": shard, "already": True}
        try:
            self.adopt(shard, msg["journal"])
        except audit.JournalLockedError as e:
            return {"ok": False, "kind": "locked", "error": str(e)}
        return {"ok": True, "shard": shard, "already": False}

    def _handle_stats(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        try:
            shard, srv = self._shard_server(msg)
        except WireError as e:
            return {"ok": False, "kind": e.kind, "error": str(e)}
        return {"ok": True, "shard": shard, "stats": srv.stats()}

    # -- lifecycle ---------------------------------------------------------

    def close(self, timeout: Optional[float] = 60.0) -> None:
        """Stop accepting, drain every hosted shard, close journals,
        and retire every transport thread — an in-process host must not
        leak accept/conn threads into its embedder."""
        self._closing.set()
        self._accept_thread.join(timeout=5.0)
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            servers = list(self._servers.values())
        for srv in servers:
            srv.shutdown(timeout)
        # idle persistent connections sit blocked in recv; close() alone
        # does not wake them, shutdown() delivers EOF and does
        with self._lock:
            conns, self._conns = list(self._conns), set()
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass

    def __enter__(self) -> "ShardHost":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Client-side RPC helper
# ---------------------------------------------------------------------------

def rpc(address: Tuple[str, int], msg: Dict[str, Any], *,
        timeout: Optional[float] = 60.0,
        max_frame: int = MAX_FRAME) -> Dict[str, Any]:
    """One-shot request/response against a ShardHost (fresh connection)."""
    with socket.create_connection(address, timeout=timeout) as sock:
        send_frame(sock, msg, max_frame=max_frame)
        reply = recv_frame(sock, max_frame=max_frame)
    if reply is None:
        raise TornFrame(f"no reply from {address}")
    return reply
