"""Append-only request journal: crash -> replay -> bit-identical bytes.

Counter addressing makes a randomness service auditable in a way a
stateful generator never is: a response is a pure function of its
*assignment* — ``(seed, channel, leaf tags, counter window, sampler,
dtype)`` — so an append-only log of assignments IS a complete backup
of every byte the service ever served.  The journal writes two kinds
of records:

  * ``window``  — one per committed class-channel lease (the PR 3
    ledger made durable: ``ledger_state()`` rebuilds the exact
    committed-window set, so a restarted service re-opens its ledgers
    with every consumed window still fenced off), and
  * ``request`` — one per served request (the
    ``frontend.Assignment``), flushed+fsynced before the response is
    released to the caller, and
  * ``batch``   — one per served *microbatch* (group commit): the
    batch's composition (its request assignments, in batch order) plus
    every window it consumed, as ONE JSON line.  A single line is
    atomic under the torn-tail repair — either the whole batch is
    durable or none of it is — so a crashed server's journal is always
    batch-aligned, which is what lets a failover peer re-form the
    identical microbatches (and hence identical assignments) for the
    un-journaled suffix.  One record = one write = one fsync per
    batch instead of one per request.

``replay`` regenerates every journaled response through plain
``engine.generate`` — deliberately NOT the coalescer's cached fused
functions — so the replay check is also an independence check on the
serving path: a gathered-column slice of a fused batch must equal the
stand-alone plan of just that request's tags.
"""
from __future__ import annotations

import functools
import hashlib
import json
import os
from typing import Any, Dict, Iterable, List, Optional, Tuple, Union

import numpy as np

from repro.core import engine, u64
from repro.runtime import blocks
from repro.service.frontend import Assignment, slice_response

try:                               # POSIX only; fencing degrades to a
    import fcntl                   # no-op where flock does not exist
except ImportError:                # pragma: no cover - non-POSIX
    fcntl = None


def _request_record(a: Assignment) -> Dict[str, Any]:
    """The JSON-able journal form of one assignment (shared by the
    per-request ``request`` records and the members of ``batch``
    records, so ``replay_entry`` handles both identically)."""
    return {"kind": "request", "rid": a.rid,
            "tenant": a.tenant_id, "sampler": a.sampler,
            "dtype": a.out_dtype, "shape": list(a.shape),
            "channel": a.channel, "lo": int(a.lo),
            "rows": int(a.rows), "tags": [int(t) for t in a.tags],
            "deco": a.deco}


def _iter_requests(entries: Iterable[Dict[str, Any]]
                   ) -> Iterable[Dict[str, Any]]:
    """Every request record in ``entries``, expanding batch records."""
    for e in entries:
        if e["kind"] == "request":
            yield e
        elif e["kind"] == "batch":
            yield from e["requests"]


class JournalLockedError(RuntimeError):
    """Another live process holds this journal's exclusive lock.

    Exactly one process may ever append to a journal: two writers would
    silently interleave windows and requests, corrupting the replay
    record.  The lock doubles as the fleet's *fencing* primitive — a
    failover peer adopts a dead shard by taking its journal lock, which
    the OS only releases when the owning process is actually gone.
    """


class Journal:
    """Append-only JSONL journal (or in-memory when ``path`` is None).

    Re-opening an existing path loads its records first and appends
    after them — the restart flow is ``Journal(path)`` followed by
    ``restore_into(service)`` and, when responses must be re-served,
    ``replay(journal, seed=...)``.

    Opening a path takes an exclusive ``flock`` held for the journal's
    lifetime (:class:`JournalLockedError` if another process has it);
    ``readonly=True`` skips the lock and the append handle — an
    auditor's view that can inspect a journal another process is
    actively writing.

    Example:
        >>> from repro.service.audit import Journal
        >>> j = Journal()                      # in-memory
        >>> j.append_window("service/class/bits/float32", 0, 8)
        >>> [e["kind"] for e in j.entries]
        ['window']
    """

    def __init__(self, path: Optional[str] = None, *,
                 readonly: bool = False):
        self.path = path
        self.readonly = readonly
        self._entries: List[Dict[str, Any]] = []
        self._fh = None
        self._rid_entries: Dict[str, Dict[str, Any]] = {}
        self._rid_cursor = 0
        if path is None:
            return
        if readonly:
            if os.path.exists(path):
                self._load(path, repair=False)
            return
        # lock BEFORE the torn-tail repair: a second writer must fail
        # here, not interleave its own repair/appends with ours
        self._fh = open(path, "a", encoding="utf-8")
        if fcntl is not None:
            try:
                fcntl.flock(self._fh.fileno(),
                            fcntl.LOCK_EX | fcntl.LOCK_NB)
            except OSError:
                fh, self._fh = self._fh, None
                fh.close()
                raise JournalLockedError(
                    f"journal {path!r} is locked by another live "
                    f"process; a journal has exactly one writer "
                    f"(fence the owner before adopting its journal)")
        self._load(path, repair=True)

    def _load(self, path: str, *, repair: bool) -> None:
        with open(path, "rb") as f:
            raw_lines = f.read().splitlines(keepends=True)
        good_bytes = 0
        for i, bline in enumerate(raw_lines):
            line = bline.strip()
            if not line:
                good_bytes += len(bline)
                continue
            try:
                self._entries.append(json.loads(line))
            except (json.JSONDecodeError, UnicodeDecodeError):
                if i == len(raw_lines) - 1:
                    break   # torn final line: crashed mid-write
                raise
            good_bytes += len(bline)
        if not repair:
            return
        if good_bytes < sum(len(b) for b in raw_lines):
            with open(path, "r+b") as f:
                f.truncate(good_bytes)  # drop the torn tail
        elif raw_lines and not raw_lines[-1].endswith(b"\n"):
            # crash AFTER the final brace but before the newline:
            # the record is complete — terminate its line so the
            # next append cannot concatenate onto it
            with open(path, "ab") as f:
                f.write(b"\n")

    @property
    def entries(self) -> List[Dict[str, Any]]:
        return list(self._entries)

    def _append(self, record: Dict[str, Any]) -> None:
        self._entries.append(record)
        if self._fh is not None:
            self._fh.write(json.dumps(record, sort_keys=True) + "\n")

    def append_window(self, channel: str, lo: int, hi: int) -> None:
        """Record one committed class-channel counter window."""
        self._append({"kind": "window", "channel": channel,
                      "lo": int(lo), "hi": int(hi)})

    def append_request(self, a: Assignment) -> None:
        """Record one served request's assignment."""
        self._append(_request_record(a))

    def append_batch(self, assignments: List[Assignment],
                     windows: Iterable[Tuple[str, int, int]]) -> None:
        """Record one served microbatch as ONE atomic line (group commit).

        ``assignments`` is the batch's composition in batch order;
        ``windows`` the (channel, lo, hi) counter windows the batch
        consumed (class-channel leases and freshly pulled pool blocks).
        The torn-tail repair drops a partial line wholly, so a journal
        can never hold half a batch — the invariant the fleet's
        deterministic-handoff protocol rests on.
        """
        self._append({
            "kind": "batch",
            "rids": sorted(a.rid for a in assignments),
            "windows": [{"channel": c, "lo": int(lo), "hi": int(hi)}
                        for c, lo, hi in windows],
            "requests": [_request_record(a) for a in assignments],
        })

    def flush(self) -> None:
        """Make everything appended so far durable (fsync) — called by
        the frontend BEFORE responses are handed to callers."""
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        """Close the append handle — which also releases the exclusive
        journal lock, letting the next writer (restart or failover
        peer) take ownership."""
        if self._fh is not None:
            self._fh.close()        # flock released with the descriptor
            self._fh = None

    def requests(self) -> List[Dict[str, Any]]:
        """Every request record, batch members expanded in batch order."""
        return list(_iter_requests(self._entries))

    def find_request(self, rid: str) -> Optional[Dict[str, Any]]:
        """The journaled request record for ``rid`` (``None`` if never
        journaled).  Incremental index over the live entry list, so the
        fleet's idempotent-retry path (a resubmitted rid is answered by
        replay, never served twice) stays O(1) amortized."""
        while self._rid_cursor < len(self._entries):
            e = self._entries[self._rid_cursor]
            self._rid_cursor += 1
            for r in _iter_requests([e]):
                self._rid_entries[r["rid"]] = r
        return self._rid_entries.get(rid)

    def windows(self) -> List[Dict[str, Any]]:
        """Every window record, batch-consumed windows expanded."""
        out: List[Dict[str, Any]] = []
        for e in self._entries:
            if e["kind"] == "window":
                out.append(e)
            elif e["kind"] == "batch":
                out.extend(e["windows"])
        return out

    def ledger_state(self) -> Dict[str, Any]:
        """The ``BlockService.restore_ledger`` state implied by the
        journal: every journaled window, merged per channel."""
        per: Dict[str, List] = {}
        for w in self.windows():
            per.setdefault(w["channel"], []).append((w["lo"], w["hi"]))
        channels = {}
        for name, wins in per.items():
            merged: List[List[int]] = []
            for lo, hi in sorted(wins):
                if merged and merged[-1][1] >= lo:
                    merged[-1][1] = max(merged[-1][1], hi)
                else:
                    merged.append([lo, hi])
            channels[name] = {"committed": merged, "floor": 0}
        return {"channels": channels}

    def restore_into(self, service: blocks.BlockService, *,
                     fence: bool = False) -> None:
        """Fence off every journaled window in a (fresh) BlockService so
        a restarted server leases strictly new counters.

        ``fence=True`` additionally raises each channel's lease *floor*
        to its journaled high-water mark (``BlockService.fence``): even
        an explicit ``lease(at=...)`` into a gap below it is refused —
        the guarantee a failover peer needs before resuming a dead
        shard's tenant regions.
        """
        state = self.ledger_state()
        service.restore_ledger(state)
        if fence:
            for name, led in state.get("channels", {}).items():
                wins = led.get("committed", [])
                if wins:
                    service.fence(name, max(int(hi) for _, hi in wins))


def _entries_of(journal: Union[Journal, str, Iterable[Dict[str, Any]]]
                ) -> List[Dict[str, Any]]:
    if isinstance(journal, Journal):
        return journal.entries
    if isinstance(journal, str):
        # an auditor's read, never a write: no lock, no tail repair —
        # replay over a path works even while the owner is still live
        return Journal(journal, readonly=True).entries
    return list(journal)


def replay(journal: Union[Journal, str, Iterable[Dict[str, Any]]], *,
           seed: int, backend: Optional[str] = "xla"
           ) -> Dict[str, np.ndarray]:
    """Regenerate every journaled response, bit-identically.

    Independent of the live serving path: each request becomes its own
    stand-alone ``GenPlan`` (its tags only, static offset) through
    ``engine.generate`` — counter addressing guarantees the bytes match
    what the fused batched call served.

    Example:
        >>> import numpy as np
        >>> from repro.runtime import BlockService
        >>> from repro.service import (Coalescer, Journal, RandRequest,
        ...                            TenantRegistry, replay)
        >>> j = Journal()
        >>> co = Coalescer(BlockService(5), TenantRegistry(), journal=j)
        >>> got, _, _ = co.flush([RandRequest("alice", (16,), rid="r0")])
        >>> again = replay(j, seed=5)
        >>> bool(np.array_equal(got["r0"], again["r0"]))
        True
    """
    out: Dict[str, np.ndarray] = {}
    for e in _iter_requests(_entries_of(journal)):
        out[e["rid"]] = replay_entry(e, seed=seed, backend=backend)
    return out


def replay_entry(e: Dict[str, Any], *, seed: int,
                 backend: Optional[str] = "xla") -> np.ndarray:
    """Regenerate ONE journaled request record, bit-identically.

    The fleet transport answers a resubmitted rid through this (the
    idempotent-retry path): a request whose assignment is already
    durable is replayed from the journal, never served a second window.
    """
    purpose = blocks.channel_purpose(e["channel"])
    x0, h_fam = engine.family_from_seed(seed, purpose)
    tags = e["tags"]
    tag_hi = np.asarray([t >> 32 for t in tags], np.uint32)
    tag_lo = np.asarray([t & 0xFFFFFFFF for t in tags], np.uint32)
    c_hi, c_lo = (u64.to_u32(v) for v in u64.const64(e["lo"]))
    fn = _replay_fn(int(e["rows"]), len(tags), e["sampler"], e["dtype"],
                    e.get("deco", "splitmix64"), backend)
    block = np.asarray(fn(x0[0], x0[1], h_fam[0], h_fam[1],
                          tag_hi, tag_lo, c_hi, c_lo))
    shape = tuple(e["shape"])
    n = 1
    for d in shape:
        n *= d
    return slice_response(block, 0, len(tags), n, shape)


@functools.lru_cache(maxsize=512)
def _replay_fn(rows: int, ncols: int, sampler: str, out_dtype: str,
               deco: str, backend: Optional[str]):
    """Jitted per-request regeneration, one executable per shape class.

    Deliberately NOT the coalescer's window functions: the plan here is
    the request's own ``ncols`` tags (no batch padding, no gathered
    neighbours), with the family limbs passed as traced operands —
    parity between this and the fused serving path is the replay
    guarantee being checked, not an artifact of sharing executables.
    """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def fn(x0_hi, x0_lo, hf_hi, hf_lo, tag_hi, tag_lo, c_hi, c_lo):
        h = engine.derive_leaf(
            (jnp.broadcast_to(hf_hi, tag_hi.shape),
             jnp.broadcast_to(hf_lo, tag_lo.shape)),
            (tag_hi, tag_lo))
        plan = engine.GenPlan(
            x0=(x0_hi, x0_lo), h=h, num_steps=rows, ctr=(c_hi, c_lo),
            offset=None, mode="ctr", deco=deco, sampler=sampler,
            out_dtype=out_dtype)
        return engine.generate(plan, backend=backend)

    return fn


def response_digest(responses: Dict[str, np.ndarray]) -> str:
    """Order-independent sha256 over (rid, dtype, shape, bytes) — the
    cross-run determinism check the CI service job compares."""
    h = hashlib.sha256()
    for rid in sorted(responses):
        a = np.asarray(responses[rid])
        h.update(rid.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def verify_ledger_disjoint(state_or_service) -> Dict[str, int]:
    """Assert every committed window in a ledger state (or a live
    ``BlockService``) is well-formed and pairwise disjoint; returns the
    per-channel window count.  This is the acceptance check "zero
    counter-window overlap, ledger-verified" as an executable."""
    if isinstance(state_or_service, Journal):
        # the journal's RAW (unmerged) windows: each lease as recorded
        per: Dict[str, List] = {}
        for w in state_or_service.windows():
            per.setdefault(w["channel"], []).append((w["lo"], w["hi"]))
        state = {"channels": {n: {"committed": ws}
                              for n, ws in per.items()}}
    else:
        state = (state_or_service.ledger_state()
                 if hasattr(state_or_service, "ledger_state")
                 else state_or_service)
    counts: Dict[str, int] = {}
    for name, led in state.get("channels", {}).items():
        wins = [(int(lo), int(hi)) for lo, hi in led.get("committed", [])]
        prev_hi = None
        for lo, hi in sorted(wins):
            if lo >= hi:
                raise blocks.LeaseError(
                    f"{name}: malformed window [{lo}, {hi})")
            if prev_hi is not None and lo < prev_hi:
                raise blocks.LeaseError(
                    f"{name}: window [{lo}, {hi}) overlaps previous "
                    f"ending at {prev_hi}")
            prev_hi = hi
        counts[name] = len(wins)
    return counts
