"""``python -m repro.quality`` — run the battery then render the docs.

Equivalent to ``python -m repro.quality.battery`` followed by
``python -m repro.quality.render`` on the report the battery just wrote
(kept as one entry point so the report and its rendered documentation
cannot go out of step; this is what the CI ``docs`` job runs before
diffing the tree).
"""
import argparse
import sys

from repro.quality import battery, render


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--profile", default="fast",
                    choices=sorted(battery.PROFILES))
    ap.add_argument("--seed", type=int, default=battery.DEFAULT_SEED)
    ap.add_argument("--out", default="QUALITY_report.json")
    args = ap.parse_args(argv)
    rc = battery.main(["--profile", args.profile, "--seed", str(args.seed),
                       "--out", args.out])
    # render from the report just written — never from a stale default
    render.main(["--report", args.out])
    return rc


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
