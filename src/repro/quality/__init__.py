"""repro.quality — the Crush-lite battery as executable documentation.

The paper's headline claim is not throughput alone: ThundeRiNG *passes
TestU01* while cheap decorrelation keeps unlimited streams independent
(paper Sec. 6, Tables 2-4).  This package is that claim as a subsystem:

  * ``crush``   — per-block SmallCrush-style tests (birthday spacings,
    gap, serial, collision, GF(2) matrix rank, spectral, longest-run)
    with TestU01-style two-level aggregation,
  * ``cross``   — the inter-stream battery (full pairwise-correlation
    sweep at S = 2**10 + interleaved-pair sub-battery),
  * ``battery`` — ``run_battery``: draws through ``engine.generate`` /
    ``generate_sharded`` / leased ``BlockService`` windows and emits the
    deterministic ``QUALITY_report.json``,
  * ``render``  — turns the report into ``docs/quality.md`` and the
    EXPERIMENTS.md quality section; CI regenerates both and fails on
    drift, so the documentation cannot detach from measured evidence.

Public surface: ``run_battery`` (and the profile registry ``PROFILES``).
"""
from repro.quality.battery import (DEFAULT_SEED, PROFILES, Profile,
                                   run_battery)

__all__ = ["DEFAULT_SEED", "PROFILES", "Profile", "run_battery"]
