"""Crush-lite battery driver: generators x tests -> QUALITY_report.json.

``run_battery(profile=...)`` draws blocks through the real delivery
surfaces — ``engine.generate`` (every backend, both decorrelator modes),
``engine.generate_sharded`` (the mesh fan-out), leased
``runtime.blocks.BlockService`` windows, and coalesced multi-tenant
``repro.service`` frontend requests — runs the Crush-lite tests
(``repro.quality.crush``) per stream column with TestU01-style two-level
aggregation, and the inter-stream cross-battery
(``repro.quality.cross``) at S = 2**10, then renders one deterministic,
machine-readable report.

The report is *executable documentation*: ``repro.quality.render`` turns
it into ``docs/quality.md`` and the EXPERIMENTS.md quality section, and
CI regenerates both from the fixed seed and fails on drift — the
published quality claims can never detach from measured evidence.

Verdict semantics reproduce the paper's Table 3/4 ordering at real
discriminating power:

  * every ``thundering/*`` generator must PASS (intra and cross),
  * every ``dist/*`` generator — the fused distribution stages
    (exponential, poisson, gamma, categorical) on all three backends,
    reduced to uniform words by the probability integral transform
    (``repro.quality.pit``) — must PASS,
  * the ``ablation/raw_lcg`` (no permutation, no decorrelator) and
    ``ablation/no_deco`` (permutation only) generators must FAIL the
    cross-battery, and ``ablation/raw_lcg_pit`` (raw LCG pushed through
    the exponential stage) must STILL fail through the PIT — the
    distribution transform does not launder a flawed source — the
    top-level ``ok`` flag is true only when every generator behaves as
    expected.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

from repro.core import statistics as st
from repro.quality import cross as cross_mod
from repro.quality import crush

#: battery-wide thresholds (TestU01's "suspect" band, scaled to our block
#: counts): a test fails when its second-level aggregate rejects at
#: ``alpha`` or any single first-level p-value falls below ``hard``.
ALPHA_KS = 1e-3
ALPHA_POISSON = 1e-3
ALPHA_CROSS = 1e-4
HARD_P = 1e-9

DEFAULT_SEED = 20260726


@dataclasses.dataclass(frozen=True)
class Profile:
    """One battery size: every test dimension is a pure function of it."""
    name: str
    intra_t: int        # words per stream column (first-level block)
    intra_s: int        # stream columns per generator (second-level N)
    cross_s: int        # streams in the cross-battery sweep
    cross_t: int        # words per stream in the cross-battery
    max_pairs: int      # interleaved pairs in the cross-battery


PROFILES: Dict[str, Profile] = {
    # CI / committed-report profile: discriminates the ablations hard
    # while regenerating in minutes on CPU (acceptance profile).
    "fast": Profile("fast", intra_t=4096, intra_s=32,
                    cross_s=1024, cross_t=2048, max_pairs=32),
    # benchmark/tier-1 smoke: seconds, still separates the ablations.
    "tiny": Profile("tiny", intra_t=1024, intra_s=8,
                    cross_s=128, cross_t=1024, max_pairs=16),
    # slow battery (the scheduled quality-full CI job and pytest -m
    # slow): SmallCrush-scale sample sizes; cross_s = 2**14 rides the
    # blocked Gram sweep (cross.SWEEP_BLOCK tiles).
    "full": Profile("full", intra_t=16384, intra_s=64,
                    cross_s=16384, cross_t=4096, max_pairs=64),
}


# ---------------------------------------------------------------------------
# block sources
# ---------------------------------------------------------------------------

def _engine_block(seed: int, t: int, s: int, mode: str, deco: str,
                  backend: str) -> np.ndarray:
    """(T, S) uint32 through ``engine.generate`` on one backend."""
    from repro.core import engine
    plan = engine.make_plan(seed=seed, num_streams=s, num_steps=t,
                            mode=mode, deco=deco)
    return np.asarray(engine.generate(plan, backend=backend))


def _leased_block(seed: int, t: int, s: int, mode: str, deco: str,
                  n_windows: int = 4) -> np.ndarray:
    """(T, S) uint32 drawn as ``n_windows`` consecutive BlockService
    leases — the battery exercising the delivery layer: disjoint
    counter-window accounting must hand back the same bits as one bulk
    ``engine.generate`` call (asserted here, not assumed)."""
    from repro.core import engine
    from repro.runtime import blocks
    service = blocks.BlockService(seed, backend="xla")
    service.open("quality/intra", num_streams=s, mode=mode, deco=deco)
    step = t // n_windows
    lengths = [step] * (n_windows - 1) + [t - step * (n_windows - 1)]
    parts = [np.asarray(service.generate(service.lease("quality/intra", n)))
             for n in lengths]
    block = np.concatenate(parts, axis=0)
    plan = engine.make_plan(seed=seed, num_streams=s, num_steps=t,
                            mode=mode, deco=deco,
                            purpose=blocks.channel_purpose("quality/intra"))
    direct = np.asarray(engine.generate(plan, backend="xla"))
    if not np.array_equal(block, direct):
        raise AssertionError(
            "BlockService leased windows disagree with bulk generation")
    return block


def _sharded_block(seed: int, t: int, s: int, mode: str,
                   deco: str) -> np.ndarray:
    """(T, S) uint32 through the ``generate_sharded`` mesh fan-out."""
    from repro.core import engine
    plan = engine.make_plan(seed=seed, num_streams=s, num_steps=t,
                            mode=mode, deco=deco)
    return np.asarray(engine.generate_sharded(plan))


def _service_block(seed: int, t: int, s: int, deco: str) -> np.ndarray:
    """(T, S) uint32 drawn through the RandService coalescing frontend.

    One single-column request per stream from ``s`` DISTINCT tenants —
    the multi-tenant serving surface: every column is a different
    tenant's region of the class family, packed into one fused
    gathered-tag call.  Each response is parity-checked against its
    journal replay (a stand-alone per-request ``engine.generate``), so
    the battery asserts, not assumes, that coalesced slices equal bulk
    generation."""
    from repro.runtime import blocks
    from repro.service import audit as audit_mod
    from repro.service.frontend import Coalescer, RandRequest
    from repro.service.tenants import TenantRegistry
    journal = audit_mod.Journal()
    service = blocks.BlockService(seed, backend="xla")
    co = Coalescer(service, TenantRegistry(), journal=journal,
                   backend="xla", deco=deco, max_rows=t)
    reqs = [RandRequest(tenant_id=f"quality/{j:04d}", shape=(t,),
                        rid=f"q{j:04d}") for j in range(s)]
    responses, _, errors = co.flush(reqs)
    if errors:
        raise AssertionError(f"service flush errors: {errors}")
    replayed = audit_mod.replay(journal, seed=seed, backend="xla")
    block = np.stack([responses[f"q{j:04d}"] for j in range(s)], axis=1)
    direct = np.stack([replayed[f"q{j:04d}"] for j in range(s)], axis=1)
    if not np.array_equal(block, direct):
        raise AssertionError(
            "coalesced service responses disagree with journal replay")
    return block


def _ablation_block(seed: int, t: int, s: int, kind: str) -> np.ndarray:
    """(T, S) uint32 for the paper's Table 3/4 ablation baselines."""
    from repro.core import baselines
    if kind == "raw_lcg":
        streams = baselines.raw_lcg_bits(seed, s, t)
    elif kind == "no_deco":
        streams = baselines.raw_lcg_bits(seed, s, t, permute=True,
                                         h_mode="adjacent")
    else:
        raise ValueError(f"unknown ablation {kind!r}")
    return np.asarray(streams).T.copy()


def _dist_block(seed: int, t: int, s: int, spec: str, mode: str,
                backend: str) -> np.ndarray:
    """(T, S) uint32 PIT words for a distribution stage.

    Shaped samples come through the real delivery surface
    (``engine.generate`` with the sampler spec fused in-plan, on the
    requested backend); the randomization bits of the PIT come from an
    independent draw of the same family (engine purpose 1), matching
    ``repro.quality.pit``'s independence requirement.  A correct stage
    yields words statistically indistinguishable from the raw
    generator's, so the full Crush-lite/cross machinery tests the
    distribution kernels at the same discriminating power as the bits
    path.
    """
    from repro.core import engine
    from repro.quality import pit
    plan = engine.make_plan(seed=seed, num_streams=s, num_steps=t,
                            mode=mode, sampler=spec)
    x = np.asarray(engine.generate(plan, backend=backend))
    vplan = engine.make_plan(seed=seed, num_streams=s, num_steps=t,
                             mode=mode, purpose=1)
    v = np.asarray(engine.generate(vplan, backend=backend))
    return pit.pit_words(x, spec, v)


def _ablation_pit_block(seed: int, t: int, s: int) -> np.ndarray:
    """(T, S) uint32: raw-LCG bits pushed through the exponential stage
    and reduced by the PIT — the transform-laundering ablation.

    Must FAIL the cross-battery: the PIT maps each sample back through
    its own CDF, so the inter-stream correlation of the flawed upstream
    generator survives the distribution transform intact.  This is the
    ablation that proves the PIT reduction preserves discriminating
    power (a battery that only tested the uniform path could be fooled
    by a sampler fed from a bad source).
    """
    import jax.numpy as jnp

    from repro.core import baselines
    from repro.core import sampler as sampler_mod
    from repro.quality import pit
    bits = np.ascontiguousarray(
        np.asarray(baselines.raw_lcg_bits(seed, s, t)).T)
    spec = sampler_mod.parse("exponential(1.0)")
    x = np.asarray(sampler_mod.apply(jnp.asarray(bits), spec, "float32"))
    v = np.ascontiguousarray(
        np.asarray(baselines.raw_lcg_bits(seed ^ 0x9E3779B9, s, t)).T)
    return pit.pit_words(x, spec, v)


# ---------------------------------------------------------------------------
# two-level intra battery
# ---------------------------------------------------------------------------

def run_intra(block: np.ndarray) -> Dict:
    """Per-column Crush-lite tests over a (T, S) block, aggregated.

    Chi-square-family tests yield one p-value per stream column and a
    KS-uniformity second level; counting-family tests sum their Poisson
    counts over columns into a single two-sided Poisson tail.
    """
    t, s = block.shape
    tests: Dict[str, Dict] = {}
    for name in sorted(crush.CHI2_TESTS):
        fn = crush.CHI2_TESTS[name]
        ps = np.array([fn(np.ascontiguousarray(block[:, j]))
                       for j in range(s)])
        p_ks = st.ks_uniform_pvalue(ps)
        p_min = float(ps.min())
        tests[name] = {"agg": "ks", "n_blocks": s, "p_ks": p_ks,
                       "p_min": p_min,
                       "ok": p_ks >= ALPHA_KS and p_min >= HARD_P}
    for name in sorted(crush.POISSON_TESTS):
        fn = crush.POISSON_TESTS[name]
        counts, lam = 0, 0.0
        for j in range(s):
            c, l = fn(np.ascontiguousarray(block[:, j]))
            counts += c
            lam += l
        p = st.poisson_two_sided(counts, lam)
        tests[name] = {"agg": "poisson_sum", "n_blocks": s,
                       "count": counts, "mean": lam, "p": p,
                       "ok": p >= ALPHA_POISSON}
    return {"block_words": t, "num_blocks": s, "tests": tests,
            "ok": all(rep["ok"] for rep in tests.values())}


# ---------------------------------------------------------------------------
# generator configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GeneratorConfig:
    name: str
    expect: str                   # "pass" | "fail"
    delivery: str                 # provenance string for the report
    kind: str = "engine"          # "engine" | "leased" | "sharded" |
                                  # "service" | "dist" | ablation
    mode: str = "ctr"
    deco: str = "splitmix64"
    backend: str = "xla"
    sampler: str = "bits"         # distribution spec for kind="dist"
    run_intra: bool = True
    run_cross: bool = False


#: the distribution stages the battery PIT-verifies (one spec per kind,
#: matching the service burst classes so the battery and the serving
#: path exercise the same kernels)
DIST_SPECS: tuple = ("exponential(1.5)", "poisson(3.5)", "gamma(2.5)",
                     "categorical[0.5,0.25,0.125,0.125]")


def battery_configs() -> List[GeneratorConfig]:
    """The acceptance matrix: thundering across both decorrelator modes
    and all three backends (+ the fmix32 hash and the delivery layers),
    against the two ablations that must fail."""
    cfgs: List[GeneratorConfig] = []
    for mode in ("ctr", "faithful"):
        for backend in ("ref", "xla", "pallas"):
            if mode == "ctr" and backend == "xla":
                # the xla/ctr draw goes through BlockService leases so the
                # battery also validates the delivery layer's accounting
                cfgs.append(GeneratorConfig(
                    name="thundering/ctr/xla", expect="pass", kind="leased",
                    mode=mode, backend=backend,
                    delivery="runtime.blocks.BlockService (4 leased "
                             "windows, parity-checked vs bulk)"))
            else:
                cfgs.append(GeneratorConfig(
                    name=f"thundering/{mode}/{backend}", expect="pass",
                    kind="engine", mode=mode, backend=backend,
                    delivery=f"engine.generate(backend={backend!r})"))
    cfgs.append(GeneratorConfig(
        name="thundering/ctr-fmix32/xla", expect="pass", kind="engine",
        mode="ctr", deco="fmix32", backend="xla",
        delivery="engine.generate(backend='xla')"))
    for mode in ("ctr", "faithful"):
        cfgs.append(GeneratorConfig(
            name=f"thundering/{mode}/sharded", expect="pass", kind="sharded",
            mode=mode, run_intra=False, run_cross=True,
            delivery="engine.generate_sharded (stream-axis mesh fan-out)"))
    cfgs.append(GeneratorConfig(
        name="thundering/ctr/service", expect="pass", kind="service",
        mode="ctr", backend="xla", run_cross=True,
        delivery="repro.service coalesced frontend (one request per "
                 "tenant, replay parity-checked vs engine.generate)"))
    for spec in DIST_SPECS:
        dist = spec.split("(")[0].split("[")[0]
        for backend in ("ref", "xla", "pallas"):
            # the xla draws for the two analytically-invertible stages
            # also run the cross-battery (the PIT words must stay
            # pairwise independent ACROSS streams, not just uniform
            # within one); ref/pallas draws are bit-identical to xla, so
            # intra coverage there is a parity claim, not extra power
            cfgs.append(GeneratorConfig(
                name=f"dist/{dist}/{backend}", expect="pass", kind="dist",
                mode="ctr", backend=backend, sampler=spec,
                run_cross=(backend == "xla"
                           and dist in ("exponential", "poisson")),
                delivery=f"engine.generate(sampler={spec!r}, "
                         f"backend={backend!r}) -> quality.pit"))
    for kind in ("raw_lcg", "no_deco"):
        cfgs.append(GeneratorConfig(
            name=f"ablation/{kind}", expect="fail", kind=kind,
            mode="-", deco="-", backend="-", run_cross=True,
            delivery="core.baselines.raw_lcg_bits"))
    cfgs.append(GeneratorConfig(
        name="ablation/raw_lcg_pit", expect="fail", kind="raw_lcg_pit",
        mode="-", deco="-", backend="-", sampler="exponential(1.0)",
        run_intra=False, run_cross=True,
        delivery="core.baselines.raw_lcg_bits -> sampler.apply"
                 "('exponential(1.0)') -> quality.pit"))
    return cfgs


def _draw(cfg: GeneratorConfig, seed: int, t: int, s: int) -> np.ndarray:
    if cfg.kind == "engine":
        return _engine_block(seed, t, s, cfg.mode, cfg.deco, cfg.backend)
    if cfg.kind == "leased":
        return _leased_block(seed, t, s, cfg.mode, cfg.deco)
    if cfg.kind == "sharded":
        return _sharded_block(seed, t, s, cfg.mode, cfg.deco)
    if cfg.kind == "service":
        return _service_block(seed, t, s, cfg.deco)
    if cfg.kind == "dist":
        return _dist_block(seed, t, s, cfg.sampler, cfg.mode, cfg.backend)
    if cfg.kind == "raw_lcg_pit":
        return _ablation_pit_block(seed, t, s)
    return _ablation_block(seed, t, s, cfg.kind)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _round_floats(obj, sig: int = 10):
    """Round every float to ``sig`` significant digits so the JSON stays
    byte-identical across BLAS/FFT builds (all test statistics reduce to
    integer counts; only derived tails carry float noise)."""
    if isinstance(obj, float):
        return float(f"{obj:.{sig}g}")
    if isinstance(obj, dict):
        return {k: _round_floats(v, sig) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_round_floats(v, sig) for v in obj]
    return obj


def run_battery(profile: str = "fast", *, seed: int = DEFAULT_SEED,
                generators: Optional[List[str]] = None,
                progress=None) -> Dict:
    """Run the Crush-lite battery and return the report dict.

    ``profile`` is one of ``PROFILES`` (``"fast"`` is the committed /
    CI-checked profile); ``generators`` optionally restricts to a subset
    of config names (used by the benchmark smoke); ``progress`` is an
    optional ``fn(str)`` callback.

    Example:
        >>> from repro.quality import battery
        >>> rep = battery.run_battery(
        ...     "tiny", generators=["thundering/ctr/ref", "ablation/raw_lcg"])
        >>> [g["name"] for g in rep["generators"]]
        ['thundering/ctr/ref', 'ablation/raw_lcg']
        >>> [g["as_expected"] for g in rep["generators"]]
        [True, True]
    """
    prof = PROFILES[profile]
    cfgs = battery_configs()
    if generators is not None:
        wanted = set(generators)
        unknown = wanted - {c.name for c in cfgs}
        if unknown:
            raise ValueError(f"unknown generators {sorted(unknown)}; "
                             f"have {[c.name for c in cfgs]}")
        cfgs = [c for c in cfgs if c.name in wanted]
    gen_reports: List[Dict] = []
    for cfg in cfgs:
        if progress:
            progress(f"battery[{prof.name}] {cfg.name} ...")
        entry: Dict = {"name": cfg.name, "expect": cfg.expect,
                       "delivery": cfg.delivery, "mode": cfg.mode,
                       "deco": cfg.deco, "backend": cfg.backend,
                       "sampler": cfg.sampler,
                       "intra": None, "cross": None}
        if cfg.run_intra:
            block = _draw(cfg, seed, prof.intra_t, prof.intra_s)
            entry["intra"] = run_intra(block)
        if cfg.run_cross:
            block = _draw(cfg, seed, prof.cross_t, prof.cross_s)
            entry["cross"] = cross_mod.run_cross(
                np.ascontiguousarray(block.T), alpha=ALPHA_CROSS,
                hard=HARD_P, max_pairs=prof.max_pairs)
        oks = [part["ok"] for part in (entry["intra"], entry["cross"])
               if part is not None]
        entry["ok"] = all(oks)
        entry["as_expected"] = entry["ok"] == (cfg.expect == "pass")
        gen_reports.append(entry)
    report = {
        "schema": 1,
        "suite": "crush-lite",
        "profile": prof.name,
        "seed": seed,
        "alpha": {"ks": ALPHA_KS, "poisson": ALPHA_POISSON,
                  "cross": ALPHA_CROSS, "hard": HARD_P},
        "sizes": dataclasses.asdict(prof),
        "tests": list(crush.ALL_TESTS)
                 + ["pairwise_sweep"]
                 + [f"interleaved/{n}" for n in sorted(cross_mod.PAIR_TESTS)],
        "generators": gen_reports,
        "ok": all(g["as_expected"] for g in gen_reports),
    }
    return _round_floats(report)


def report_json(report: Dict) -> str:
    """Canonical byte-stable serialization of a battery report."""
    return json.dumps(report, indent=2, sort_keys=True) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--profile", default="fast", choices=sorted(PROFILES))
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED)
    ap.add_argument("--out", default="QUALITY_report.json")
    args = ap.parse_args(argv)
    report = run_battery(args.profile, seed=args.seed, progress=print)
    with open(args.out, "w") as f:
        f.write(report_json(report))
    status = "OK" if report["ok"] else "NOT AS EXPECTED"
    print(f"{args.out}: {status} "
          f"({sum(g['as_expected'] for g in report['generators'])}/"
          f"{len(report['generators'])} generators as expected)")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
