"""Probability-integral-transform reduction: shaped samples -> words.

The Crush-lite battery (``repro.quality.crush``) and the inter-stream
cross-battery (``repro.quality.cross``) consume uint32 word blocks; the
distribution stages (``repro.core.sampler``) emit exponential / Poisson
/ gamma / categorical samples.  This module closes the loop: the PIT
maps each sample through its own CDF back to U[0, 1) — exactly uniform
when the sampler is correct — and packs the result into uint32 words the
existing batteries can test at full discriminating power.

  * **Continuous** stages (exponential, gamma): ``u = F(x)`` in float64,
    quantized to the top 24 bits (the samplers' native uniform
    resolution); the low 8 word bits come from an INDEPENDENT bits draw
    (``v_bits``) so every bit of the word is testable:
    ``word = (floor(u * 2**24) << 8) | (v_bits >> 24)``.
  * **Discrete** stages (poisson, categorical): the randomized PIT of
    Brockwell (2007): ``u = F(k-1) + V * p(k)`` with ``V`` uniform from
    ``v_bits`` — exactly U[0, 1) when the sampled pmf is correct;
    ``word = floor(u * 2**32)``.

A correct sampler therefore yields words indistinguishable from the raw
generator's, and a FLAWED upstream generator (the ``ablation/raw_lcg``
baseline pushed through ``exponential``) still fails the cross-battery
THROUGH the transform — the PIT preserves inter-stream correlation
rather than laundering it.

The gamma CDF needs the regularized lower incomplete gamma function
P(a, x); scipy is not a dependency of this repo, so it is hand-rolled in
vectorized float64 numpy — power series for ``x < a + 1``, modified
Lentz continued fraction for the complement above (Numerical Recipes
6.2) — accurate to ~1e-14, far below the 2**-24 quantization.
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from repro.core import sampler as sampler_mod

#: iteration caps for the incomplete-gamma series / continued fraction
#: (both converge in tens of terms for the battery's shape range k <= ~64)
_ITMAX = 800
_EPS = 1e-15


def _gamma_p_series(a: float, x: np.ndarray) -> np.ndarray:
    """P(a, x) by the power series (valid and fast for x < a + 1)."""
    ap = a
    total = np.full_like(x, 1.0 / a)
    term = total.copy()
    for _ in range(_ITMAX):
        ap += 1.0
        term = term * x / ap
        total = total + term
        if np.all(np.abs(term) < np.abs(total) * _EPS):
            break
    return total * np.exp(-x + a * np.log(x) - math.lgamma(a))


def _gamma_q_lentz(a: float, x: np.ndarray) -> np.ndarray:
    """Q(a, x) = 1 - P(a, x) by modified Lentz continued fraction
    (valid and fast for x >= a + 1)."""
    tiny = 1e-300
    b = x + 1.0 - a
    c = np.full_like(x, 1.0 / tiny)
    d = 1.0 / np.where(b == 0.0, tiny, b)
    h = d.copy()
    for i in range(1, _ITMAX + 1):
        an = -i * (i - a)
        b = b + 2.0
        d = an * d + b
        d = np.where(np.abs(d) < tiny, tiny, d)
        c = b + an / c
        c = np.where(np.abs(c) < tiny, tiny, c)
        d = 1.0 / d
        delta = d * c
        h = h * delta
        if np.all(np.abs(delta - 1.0) < _EPS):
            break
    return h * np.exp(-x + a * np.log(x) - math.lgamma(a))


def regularized_gamma_p(shape: float, x: np.ndarray) -> np.ndarray:
    """Regularized lower incomplete gamma P(shape, x) — the Gamma(shape,
    scale 1) CDF — vectorized float64, no scipy.

    Example:
        >>> import numpy as np
        >>> from repro.quality import pit
        >>> # P(1, x) is the exponential CDF 1 - exp(-x)
        >>> x = np.array([0.5, 2.0, 10.0])
        >>> bool(np.allclose(pit.regularized_gamma_p(1.0, x),
        ...                  -np.expm1(-x), atol=1e-13))
        True
        >>> # median of Gamma(2.5) is near 2.1759
        >>> float(np.round(pit.regularized_gamma_p(2.5,
        ...                np.array([2.17586]))[0], 4))
        0.5
    """
    a = float(shape)
    if not (a > 0.0):
        raise ValueError(f"shape must be > 0, got {shape!r}")
    x = np.asarray(x, dtype=np.float64)
    out = np.zeros(x.shape, dtype=np.float64)
    pos = x > 0.0
    small = pos & (x < a + 1.0)
    large = pos & ~small
    if small.any():
        out[small] = _gamma_p_series(a, x[small])
    if large.any():
        out[large] = 1.0 - _gamma_q_lentz(a, x[large])
    return np.clip(out, 0.0, 1.0)


def continuous_cdf(kind: str, param, x: np.ndarray) -> np.ndarray:
    """Float64 CDF of a continuous distribution stage at ``x``."""
    x = np.asarray(x, dtype=np.float64)
    if kind == "exponential":
        return -np.expm1(-float(param) * np.maximum(x, 0.0))
    if kind == "gamma":
        # two-parameter sugar: Gamma(k, theta) CDF is P(k, x / theta)
        shape, scale = param if isinstance(param, tuple) else (param, 1.0)
        return regularized_gamma_p(float(shape), x / float(scale))
    if kind == "gumbel":
        return np.exp(-np.exp(-x))
    raise ValueError(f"not a continuous stage: {kind!r}")


def discrete_cdf_table(kind: str, param) -> np.ndarray:
    """Cumulative pmf table F(0..K-1) in float64 for a discrete stage.

    For poisson the support is truncated exactly where the sampler's
    threshold ladder stops (``sampler.poisson_thresholds``), then
    renormalized so the randomized PIT of the truncated law is exactly
    uniform — the battery tests the law the kernel actually implements.

    Example:
        >>> from repro.quality import pit
        >>> [round(float(f), 4) for f in pit.discrete_cdf_table(
        ...     "categorical", (1.0, 1.0, 2.0))]
        [0.25, 0.5, 1.0]
    """
    if kind == "poisson":
        rate = float(param)
        n = len(sampler_mod.poisson_thresholds(rate))
        if n == 0:
            return np.array([1.0])
        k = np.arange(n + 1, dtype=np.float64)
        logpmf = k * math.log(rate) - rate - np.array(
            [math.lgamma(v + 1.0) for v in k])
        cdf = np.cumsum(np.exp(logpmf))
        return cdf / cdf[-1]
    if kind == "categorical":
        w = np.asarray(param, dtype=np.float64)
        cdf = np.cumsum(w)
        return cdf / cdf[-1]
    raise ValueError(f"not a discrete stage: {kind!r}")


def pit_words(samples: np.ndarray, spec, v_bits: np.ndarray) -> np.ndarray:
    """Reduce distribution-stage ``samples`` to battery-ready uint32.

    ``spec`` is a sampler spec string or parsed ``(kind, param)`` pair
    from ``sampler.parse``; ``v_bits`` is a same-shape uint32 block from
    an INDEPENDENT draw (a different engine purpose), consumed as the
    randomization of the discrete PIT and as the low 8 bits of the
    continuous words.  Returns a uint32 array of ``samples.shape``.

    Example:
        >>> import numpy as np
        >>> from repro.quality import pit
        >>> x = np.array([0.1, 1.0, 5.0], dtype=np.float32)
        >>> v = np.zeros(3, dtype=np.uint32)
        >>> w = pit.pit_words(x, "exponential(1.0)", v)
        >>> (w.dtype, w.shape)
        (dtype('uint32'), (3,))
        >>> # words order like the CDF: monotone in x
        >>> bool((np.diff(w.astype(np.int64)) > 0).all())
        True
    """
    kind, param = sampler_mod.parse(spec) if isinstance(spec, str) else spec
    if kind not in sampler_mod.DISTRIBUTION_KINDS:
        raise ValueError(
            f"not a distribution stage: {kind!r}; "
            f"have {sampler_mod.DISTRIBUTION_KINDS}")
    x = np.asarray(samples, dtype=np.float64)
    v = np.asarray(v_bits)
    if v.dtype != np.uint32 or v.shape != x.shape:
        raise ValueError(
            f"v_bits must be uint32 of shape {x.shape}, got "
            f"{v.dtype}/{v.shape}")
    if kind in ("exponential", "gamma", "gumbel"):
        u = continuous_cdf(kind, param, x)
        j = np.minimum(np.floor(u * 2.0 ** 24),
                       2.0 ** 24 - 1.0).astype(np.uint32)
        return (j << np.uint32(8)) | (v >> np.uint32(24))
    cdf = discrete_cdf_table(kind, param)
    k = np.clip(np.rint(x).astype(np.int64), 0, len(cdf) - 1)
    lo = np.where(k > 0, cdf[np.maximum(k - 1, 0)], 0.0)
    p = cdf[k] - lo
    vv = v.astype(np.float64) * 2.0 ** -32
    u = lo + vv * p
    return np.minimum(np.floor(u * 2.0 ** 32),
                      2.0 ** 32 - 1.0).astype(np.uint32)
