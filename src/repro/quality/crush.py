"""Crush-lite: the per-block statistical tests of the quality battery.

Each test here is a vectorized numpy implementation of a TestU01
SmallCrush / NIST SP 800-22 style test, scaled to fixed host budgets.
Every function takes one *block* — a 1-D uint32 word sequence (one
stream column of an engine ``(T, S)`` draw) — and returns a first-level
result: either a p-value (chi-square family) or a raw count with its
Poisson mean (counting family), which ``repro.quality.battery``
aggregates across blocks TestU01-style:

  * chi-square family (``gap``, ``serial``, ``matrix_rank``,
    ``spectral``, ``longest_run``): one p-value per block, second level
    = Kolmogorov-Smirnov uniformity of the per-block p-values
    (``statistics.ks_uniform_pvalue``).
  * counting family (``birthday_spacings``, ``collision``): the
    per-block statistic is a small Poisson count whose p-value is too
    discrete for a KS aggregate, so the second level SUMS the counts
    over blocks and takes one two-sided Poisson tail — the same move
    TestU01 makes for its Poisson-distributed statistics.

Test sizes (number of birthdays, urn counts, gap category cut) are pure
functions of the block length, so a profile fixes the whole battery
shape and the report regenerates byte-identically.

References: Marsaglia's birthday spacings / collision (Diehard; Knuth
TAoCP 3.3.2), the NIST SP 800-22 rank / spectral / longest-run tests
with the published class probabilities, and L'Ecuyer & Simard's TestU01
two-level methodology (the Bakiri et al. FPGA survey in PAPERS.md shows
why the F2-linear-sensitive rank test belongs in the battery).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core import statistics as st

# ---------------------------------------------------------------------------
# counting family: first level returns (count, poisson_mean)
# ---------------------------------------------------------------------------

_POISSON_TARGET = 8.0  # per-block Poisson mean the sizes aim for


def birthday_sizes(n_words: int) -> Tuple[int, int]:
    """(num_birthdays m, log2 days) with collision mean m^3/(4d) ~ 8."""
    m = n_words
    # d = 2**b days; pick b so lambda = m^3 / 2**(b+2) lands nearest 8
    b = int(round(3 * np.log2(m) - 2 - np.log2(_POISSON_TARGET)))
    return m, max(8, min(32, b))


def birthday_spacings(words: np.ndarray) -> Tuple[int, float]:
    """Marsaglia birthday spacings: (collision count, Poisson mean).

    m "birthdays" are the top b bits of the words; among the sorted
    spacings, values occurring more than once are collisions, which are
    asymptotically Poisson(m^3 / 4d) for d = 2**b days.
    """
    m, b = birthday_sizes(words.size)
    days = (words[:m] >> np.uint32(32 - b)).astype(np.uint64)
    spacings = np.sort(np.diff(np.sort(days)))
    collisions = int((np.diff(spacings) == 0).sum())
    lam = float(m) ** 3 / (4.0 * 2.0 ** b)
    return collisions, lam


def collision_sizes(n_words: int) -> Tuple[int, int]:
    """(num_throws m, log2 urns) with collision mean m^2/(2d) ~ 8."""
    m = n_words
    b = int(round(2 * np.log2(m) - 1 - np.log2(_POISSON_TARGET)))
    return m, max(8, min(32, b))


def collision(words: np.ndarray) -> Tuple[int, float]:
    """Knuth collision test: throw m balls into d = 2**b urns; the number
    of collisions is asymptotically Poisson(m^2 / 2d) for sparse tables.
    Returns (collision count, Poisson mean)."""
    m, b = collision_sizes(words.size)
    urns = words[:m] >> np.uint32(32 - b)
    collisions = int(m - np.unique(urns).size)
    lam = float(m) ** 2 / (2.0 * 2.0 ** b)
    return collisions, lam


# ---------------------------------------------------------------------------
# chi-square family: first level returns a p-value per block
# ---------------------------------------------------------------------------

def gap(words: np.ndarray, p: float = 0.125) -> float:
    """Knuth gap test: lengths of gaps between visits to [0, p).

    Gap lengths are geometric(p); counts over categories 0..t and >t are
    chi-squared against the exact geometric probabilities, with t set so
    the tail category keeps an expected count >= ~5.
    """
    u = words.astype(np.float64) * 2.0 ** -32
    hits = np.flatnonzero(u < p)
    if hits.size < 2:
        return 1.0  # not enough events for a gap spectrum at this size
    gaps = np.diff(hits) - 1
    n = gaps.size
    # t: geometric tail q**t * n >= 5  =>  t = log(5/n) / log(q)
    q = 1.0 - p
    t = max(1, int(np.log(5.0 / n) / np.log(q)))
    counts = np.bincount(np.minimum(gaps, t), minlength=t + 1)
    probs = p * q ** np.arange(t + 1, dtype=np.float64)
    probs[t] = q ** t  # tail: P(gap >= t)
    expected = probs * n
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    return st.chi2_sf(chi2, t)


def serial(words: np.ndarray) -> float:
    """Serial (overlapping-free) pair test on 4-bit nibbles: chi-square of
    non-overlapping (nibble, nibble) pairs over 256 cells — sensitive to
    sequential dependence that plain frequency tests miss."""
    nib = _nibbles(words)
    pairs = (nib[0::2].astype(np.int32) << 4) | nib[1::2]
    n = pairs.size
    counts = np.bincount(pairs, minlength=256)
    expected = n / 256.0
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    return st.chi2_sf(chi2, 255)


def _nibbles(words: np.ndarray) -> np.ndarray:
    b = np.ascontiguousarray(words).view(np.uint8)
    return np.stack([b >> 4, b & 0x0F], axis=-1).reshape(-1)


# NIST SP 800-22 3.5: rank distribution of random 32x32 GF(2) matrices
_RANK_P32 = 0.2887880950866024   # prod_{j=0..31} (1 - 2**(j-32))
_RANK_P31 = 0.5775761901732048   # 2 * p32 (exact for m = q = 32)
_RANK_PLO = 1.0 - _RANK_P32 - _RANK_P31


def gf2_rank32(rows: np.ndarray) -> int:
    """Rank over GF(2) of one 32x32 bit matrix given as 32 uint32 rows."""
    rows = [int(r) for r in rows]
    rank = 0
    for col in range(31, -1, -1):
        bit = 1 << col
        pivot = next((i for i in range(rank, len(rows)) if rows[i] & bit),
                     None)
        if pivot is None:
            continue
        rows[rank], rows[pivot] = rows[pivot], rows[rank]
        piv = rows[rank]
        for i in range(len(rows)):
            if i != rank and rows[i] & bit:
                rows[i] ^= piv
        rank += 1
        if rank == 32:
            break
    return rank


def matrix_rank(words: np.ndarray) -> float:
    """Binary matrix rank over GF(2): 32 consecutive words form a 32x32
    bit matrix; ranks are chi-squared against the exact asymptotic
    {<=30, 31, 32} distribution.  The battery's F2-linearity detector —
    an undecorrelated xorshift/LFSR output fails it where every weak
    moment test passes (Bakiri et al.)."""
    n_mat = words.size // 32
    if n_mat < 8:
        return 1.0
    mats = words[: n_mat * 32].reshape(n_mat, 32)
    ranks = np.array([gf2_rank32(m) for m in mats])
    counts = np.array([(ranks <= 30).sum(), (ranks == 31).sum(),
                       (ranks == 32).sum()], dtype=np.float64)
    expected = np.array([_RANK_PLO, _RANK_P31, _RANK_P32]) * n_mat
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    return st.chi2_sf(chi2, 2)


def spectral(words: np.ndarray) -> float:
    """NIST discrete Fourier transform test on the bit expansion: the
    fraction of DFT peaks below the 95% threshold should be 0.95; the
    deviation is normally distributed under the null."""
    bits = np.unpackbits(np.ascontiguousarray(words).view(np.uint8))
    x = 2.0 * bits.astype(np.float64) - 1.0
    n = x.size
    mags = np.abs(np.fft.rfft(x))[: n // 2]
    threshold = np.sqrt(np.log(1.0 / 0.05) * n)
    n0 = 0.95 * n / 2.0
    n1 = float((mags < threshold).sum())
    d = (n1 - n0) / np.sqrt(n * 0.95 * 0.05 / 4.0)
    return 2.0 * st.normal_sf(abs(d))


# NIST SP 800-22 3.4: longest-run-of-ones class probabilities for
# M = 128-bit subblocks, classes {<=4, 5, 6, 7, 8, >=9}
_LONGEST_RUN_PI = np.array([0.1174, 0.2430, 0.2493, 0.1752, 0.1027, 0.1124])


def longest_run(words: np.ndarray) -> float:
    """NIST longest-run-of-ones: longest 1-run per 128-bit subblock,
    chi-squared over the published class probabilities."""
    bits = np.unpackbits(np.ascontiguousarray(words).view(np.uint8))
    n_sub = bits.size // 128
    if n_sub < 16:
        return 1.0
    sub = bits[: n_sub * 128].reshape(n_sub, 128)
    cur = np.zeros(n_sub, dtype=np.int32)
    best = np.zeros(n_sub, dtype=np.int32)
    for j in range(128):
        cur = np.where(sub[:, j] == 1, cur + 1, 0)
        best = np.maximum(best, cur)
    classes = np.clip(best, 4, 9) - 4
    counts = np.bincount(classes, minlength=6).astype(np.float64)
    expected = _LONGEST_RUN_PI * n_sub
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    return st.chi2_sf(chi2, 5)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

# name -> (fn, aggregation): "ks" tests return a per-block p-value;
# "poisson" tests return (count, mean) summed over blocks.
CHI2_TESTS: Dict[str, object] = {
    "gap": gap,
    "serial": serial,
    "matrix_rank": matrix_rank,
    "spectral": spectral,
    "longest_run": longest_run,
}

POISSON_TESTS: Dict[str, object] = {
    "birthday_spacings": birthday_spacings,
    "collision": collision,
}

ALL_TESTS = tuple(sorted(CHI2_TESTS)) + tuple(sorted(POISSON_TESTS))
