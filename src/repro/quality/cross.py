"""Inter-stream cross-battery: the decorrelation claim at real power.

The paper's Tables 3/4 argument is that cheap decorrelation makes
*unlimited* streams pairwise independent; a per-stream battery cannot see
the failure mode (each raw-LCG stream looks fine alone — the correlation
lives BETWEEN streams).  Two instruments:

  * **Pairwise-correlation sweep** (Table 3 at power): the full S x S
    Pearson correlation matrix of an (S, T) block via one Gram matmul.
    Under the null each off-diagonal r * sqrt(T) is ~N(0, 1); the
    statistic is max |z| with a Bonferroni-corrected p-value over all
    S(S-1)/2 pairs.  Raw LCG streams show r ~ 0.998 => p ~ 0.
  * **Interleaved-pair battery** (the Li et al. inter-stream method the
    paper adopts, Table 4): adjacent stream pairs are round-robin
    interleaved and each interleave is pushed through a sub-battery
    (serial, longest-run, Hamming-weight-dependency z-test); per-pair
    p-values aggregate by KS uniformity.  Permutation-only ablations
    pass the sweep yet fail here — interleaving exposes the shared-root
    Hamming-weight dependency the permutation cannot remove.

All statistics are numpy over a host block; the battery driver feeds it
blocks drawn through ``engine.generate_sharded``.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import statistics as st
from repro.quality import crush


#: row-block edge of the blocked Gram sweep — 2048 f64-normalized rows
#: per block keep every partial Gram product under ~32 MB, so the sweep
#: scales to the full profile's S = 2**14 without materializing an
#: S x S matrix; for S <= SWEEP_BLOCK the computation is the single
#: full-matrix product, byte-identical to the unblocked form (the
#: committed fast-profile report does not move).
SWEEP_BLOCK = 2048


def _unit_rows(streams: np.ndarray) -> np.ndarray:
    """Center and L2-normalize each row of an (s, T) uint32 block in
    float64 (constant rows normalize to zero => r := 0 for their
    pairs)."""
    u = st.to_unit(streams)
    u -= u.mean(axis=1, keepdims=True)
    norms = np.sqrt((u * u).sum(axis=1))
    norms[norms == 0.0] = 1.0
    u /= norms[:, None]
    return u


def pairwise_sweep(streams: np.ndarray, *,
                   block: int = SWEEP_BLOCK) -> Dict[str, float]:
    """Pairwise Pearson sweep over (S, T) streams via blocked Gram
    products.

    Returns max |r|, its z-score ``|r| * sqrt(T)``, and the
    Bonferroni-corrected two-sided p-value over all pairs (conservative,
    exact enough at the battery's sizes: the null max |z| sits near the
    corrected 5% point by the extreme-value approximation).

    The correlation matrix is swept in ``block x block`` tiles (only the
    upper block triangle, off-diagonal entries only on diagonal tiles),
    tracking the running max |r| — O(S**2 T) flops but O(block * T)
    resident floats, which is what lets the scheduled ``full`` profile
    push S to 2**14.  For ``S <= block`` this is one full-matrix product
    and the result is bit-identical to the historical unblocked sweep.
    """
    s_count, t = streams.shape
    if block < 1:
        raise ValueError(f"block must be >= 1, got {block}")
    n_pairs = s_count * (s_count - 1) // 2
    max_abs_r = 0.0
    # same unit mapping as the Table 3 pairwise functions (power-of-two
    # scale, so the correlations are bit-identical to the raw-shift form)
    for i0 in range(0, s_count, block):
        ui = _unit_rows(streams[i0:i0 + block])
        for j0 in range(i0, s_count, block):
            uj = ui if j0 == i0 else _unit_rows(streams[j0:j0 + block])
            gram = ui @ uj.T
            if j0 == i0:
                iu = np.triu_indices(gram.shape[0], 1)
                tile = gram[iu]
            else:
                tile = gram.ravel()
            if tile.size:
                max_abs_r = max(max_abs_r, float(np.abs(tile).max()))
    z = max_abs_r * np.sqrt(t)
    p = min(1.0, n_pairs * 2.0 * st.normal_sf(z))
    return {"n_pairs": n_pairs, "max_abs_r": max_abs_r, "max_z": float(z),
            "p": float(p)}


def hwd_pvalue(words: np.ndarray) -> float:
    """Hamming-weight dependency as a p-value: correlation of adjacent
    popcounts, z = r * sqrt(n), two-sided normal tail.  The full
    Blackman-Vigna HWD test runs to first anomaly; at fixed budgets the
    z-test is the same detector with a calibrated false-positive rate.
    """
    r = st.hamming_weight_dependency(words)
    n = words.size - 1
    if n < 2:
        return 1.0
    return 2.0 * st.normal_sf(abs(r) * np.sqrt(n))


#: sub-battery applied to each interleaved pair (name -> fn(words) -> p)
PAIR_TESTS = {
    "serial": crush.serial,
    "longest_run": crush.longest_run,
    "hwd": hwd_pvalue,
}


def interleaved_pair_battery(streams: np.ndarray,
                             max_pairs: int = 32) -> Dict[str, Dict]:
    """Interleave adjacent stream pairs (2k, 2k+1) and run ``PAIR_TESTS``
    on each interleave; per-test results carry the per-pair p-values,
    their KS-uniformity aggregate, and the minimum.
    """
    s_count = streams.shape[0]
    n_pairs = min(max_pairs, s_count // 2)
    per_test: Dict[str, list] = {name: [] for name in PAIR_TESTS}
    for k in range(n_pairs):
        inter = st.interleave(streams[2 * k: 2 * k + 2])
        for name, fn in PAIR_TESTS.items():
            per_test[name].append(float(fn(inter)))
    out: Dict[str, Dict] = {}
    for name, ps in per_test.items():
        arr = np.array(ps)
        out[name] = {"n_pairs": n_pairs,
                     "p_ks": st.ks_uniform_pvalue(arr),
                     "p_min": float(arr.min())}
    return out


def run_cross(streams: np.ndarray, *, alpha: float = 1e-4,
              hard: float = 1e-9, max_pairs: int = 32) -> Dict:
    """The full cross-battery on (S, T) streams -> report fragment.

    Fails when the pairwise sweep rejects at ``alpha`` or any
    interleaved-pair test's KS aggregate rejects at ``alpha`` (or shows
    a single-pair p-value below ``hard``).
    """
    sweep = pairwise_sweep(streams)
    pairs = interleaved_pair_battery(streams, max_pairs=max_pairs)
    tests = {"pairwise_sweep": dict(sweep, agg="bonferroni",
                                    ok=sweep["p"] >= alpha)}
    for name, rep in pairs.items():
        ok = rep["p_ks"] >= alpha and rep["p_min"] >= hard
        tests[f"interleaved/{name}"] = dict(rep, agg="ks", ok=ok)
    return {"num_streams": int(streams.shape[0]),
            "num_steps": int(streams.shape[1]),
            "tests": tests,
            "ok": all(t["ok"] for t in tests.values())}
