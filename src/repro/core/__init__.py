"""repro.core — ThundeRiNG MISRN: the paper's contribution as a JAX module.

Public surface:
  * ``ThunderStream`` + ``new_stream``/``derive``/``split``/``advance`` and
    the samplers (``random_bits``/``uniform``/``normal``/``bernoulli``/
    ``gumbel``/``categorical``) — the framework-facing splittable RNG.
  * ``repro.kernels.ops`` — bulk S-streams x T-steps block generation
    (Pallas kernel on TPU, jnp reference elsewhere).
  * ``baselines`` / ``statistics`` / ``golden`` — comparison generators,
    the statistical battery, and the numpy oracle.
"""
from repro.core.stream import (ThunderStream, advance, bernoulli, categorical,
                               derive, gumbel, new_stream, normal, random_bits,
                               split, uniform)

__all__ = [
    "ThunderStream", "new_stream", "derive", "split", "advance",
    "random_bits", "uniform", "normal", "bernoulli", "gumbel", "categorical",
]
