"""Unified RNG engine: one backend-dispatched generation substrate.

The paper's architecture is a *plan*, not an implementation: one shared
root-state generator (RSGU) feeds any number of cheap per-stream output
units (SOU + decorrelator).  This module makes that split explicit in
software.  A ``GenPlan`` describes WHAT to generate —

  (x0, h-table, counter window, (T, S) shape, decorrelator mode,
   sampler output stage)

— and a pluggable backend decides HOW:

  * ``"ref"``     the pure-jnp oracles in ``repro.kernels.ref`` (validated
                  against the numpy golden; slow, simple, always right),
  * ``"xla"``     the engine's own fused elementwise arithmetic (what
                  ``stream.random_bits`` always compiled to),
  * ``"pallas"``  the tiled TPU kernels in ``repro.kernels.thundering_block``
                  (``interpret=True`` on CPU, Mosaic on TPU).

All backends are bit-exact for both decorrelator modes, so the choice is
purely a performance decision; ``select_backend`` picks one from the plan
shape and platform, and every entry point takes a per-call override.

The plan's *sampler* field (``repro.core.sampler``) fuses distribution
shaping into generation — uniform / Box-Muller normal / exact-threshold
bernoulli plus the programmable distribution stages exponential(rate),
poisson(rate), gamma(shape) and categorical[w0,w1,...], float32 or
bfloat16 — applied in-VMEM by the Pallas kernels and as fused
elementwise arithmetic by ref/xla, so raw uint32 blocks never
round-trip through HBM on the way to a float consumer.
``sample(plan, sampler=...)`` is the per-call override.

``generate_sharded`` is the multi-device analogue of the paper's instance
scaling: the (T, S) block is split over a mesh by the stream axis with
``shard_map``.  Because every element is counter-addressable — a pure
function of (x0, h_s, ctr + t) — each device generates its column slice
from the replicated root state with ZERO cross-device communication,
exactly as adding SOU instances on the FPGA costs no extra root-generator
hardware.

This module is the single home of the shared plumbing that used to be
re-implemented by ``core/stream.py``, ``kernels/ops.py`` and the
benchmarks: family/leaf-offset derivation (``family_from_seed``,
``derive_leaf``, ``leaf_table``), root-state/counter-row expansion
(``root_and_ctr_rows``) and the xorshift128 start-state prep for the
faithful decorrelator.

Import layering: ``engine`` sits in ``repro.core`` and imports only the
arithmetic cores (lcg/splitmix/u64/xorshift); the kernel modules are
imported lazily inside backends.  ``stream.py`` and ``kernels/ops.py``
import the engine, never the other way around.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lcg, sampler as sampler_mod, splitmix, u64, xorshift
from repro.core.u64 import U32, U64Pair

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_S = 512

_M64 = (1 << 64) - 1


# ---------------------------------------------------------------------------
# Family / leaf-offset derivation (the ONE copy; stream.derive and
# ops.h_table used to each have their own)
# ---------------------------------------------------------------------------

def family_from_seed(seed: int, purpose: int = 0) -> Tuple[U64Pair, U64Pair]:
    """(x0, h_family) for a python-int seed.

    ``x0`` is the shared root base state (one per family — the paper's
    RSGU seed); ``h_family`` is the family's even leaf offset from which
    per-stream offsets derive.  ``purpose`` selects disjoint h families
    over the same root (e.g. the x/y coordinate streams of the MC apps).
    """
    x0 = splitmix.splitmix64_host(seed & _M64, 0x1234)
    h = (splitmix.splitmix64_host(seed, purpose) << 1) & _M64
    x0_hi, x0_lo = (u64.to_u32(v) for v in u64.const64(x0))
    h_hi, h_lo = (u64.to_u32(v) for v in u64.const64(h))
    return (x0_hi, x0_lo), (h_hi, h_lo)


def derive_leaf(h_parent: U64Pair, tag: U64Pair) -> U64Pair:
    """Child leaf offset: splitmix64(h_parent, tag) forced even (<< 1).

    Even offsets keep the Hull-Dobell full-period condition (lcg.py doc);
    splitmix keeps distinct tags in distinct streams.  ``tag`` limbs may
    be scalars or vectors (broadcast against ``h_parent``).
    """
    return u64.shl64(splitmix.splitmix64(h_parent, tag), 1)


def leaf_table(h_family: U64Pair, num_streams: int) -> U64Pair:
    """(S,) even leaf offsets h_s for streams 0..S-1 of a family."""
    sid = jnp.arange(num_streams, dtype=U32)
    return derive_leaf((jnp.broadcast_to(h_family[0], sid.shape),
                        jnp.broadcast_to(h_family[1], sid.shape)),
                       (jnp.zeros_like(sid), sid))


# ---------------------------------------------------------------------------
# GenPlan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GenPlan:
    """One bulk generation request: a (T, S) uint32 block.

    x0        (hi, lo) scalar root base state (may be traced).
    h         (hi, lo) arrays of shape (S,): per-stream leaf offsets.
    num_steps T, the time extent.
    ctr       (hi, lo) scalar counter start (may be traced).
    offset    the counter start as a static python int when known at
              trace time (enables host-exact xorshift jumps for the
              faithful decorrelator), else None.
    mode      "ctr" (counter decorrelator, pure map) or "faithful"
              (paper's serial xorshift128 decorrelator).
    deco      ctr-mode hash: "splitmix64" (default) or "fmix32".
    sampler   output stage: "bits" (default), "uniform", "normal"
              (Box-Muller over adjacent row pairs; T must be even),
              "bernoulli(p)", or a distribution stage —
              "exponential(rate)", "poisson(rate)", "gamma(shape)",
              "categorical[w0,w1,...]" (all elementwise, any T).
              Grammar in ``repro.core.sampler.SPEC_GRAMMAR``.
    out_dtype "float32" or "bfloat16" for the float samplers (bits is
              always uint32, bernoulli always bool; distribution counts
              and category indices are float-coded exact integers).

    Example:
        >>> from repro.core import engine
        >>> plan = engine.make_plan(seed=7, num_streams=4, num_steps=8)
        >>> plan.shape                    # (T, S), time-major
        (8, 4)
        >>> (plan.mode, plan.deco, plan.sampler)
        ('ctr', 'splitmix64', 'bits')
    """
    x0: U64Pair
    h: U64Pair
    num_steps: int
    ctr: U64Pair
    offset: Optional[int] = 0
    mode: str = "ctr"
    deco: str = "splitmix64"
    sampler: str = "bits"
    out_dtype: str = "float32"

    @property
    def num_streams(self) -> int:
        return int(self.h[0].shape[0])

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.num_steps, self.num_streams)


def make_plan(*, seed: int, num_streams: int, num_steps: int, offset: int = 0,
              purpose: int = 0, mode: str = "ctr",
              deco: str = "splitmix64", sampler: str = "bits",
              out_dtype: str = "float32") -> GenPlan:
    """Plan for a (T, S) block of the family derived from ``seed``."""
    x0, h_fam = family_from_seed(seed, purpose)
    ch, cl = u64.const64(offset)
    return GenPlan(x0=x0, h=leaf_table(h_fam, num_streams),
                   num_steps=num_steps, ctr=(u64.to_u32(ch), u64.to_u32(cl)),
                   offset=offset, mode=mode, deco=deco, sampler=sampler,
                   out_dtype=out_dtype)


def plan_for_stream(stream, num_steps: int, mode: str = "ctr",
                    deco: str = "splitmix64", sampler: str = "bits",
                    out_dtype: str = "float32") -> GenPlan:
    """Plan for ``num_steps`` elements of ONE ThunderStream (S = 1).

    The stream's counter is traced state, so ``offset`` is None; backends
    that need host-exact jumps fall back to traced GF(2) jumps.
    """
    return GenPlan(x0=(stream.x0_hi, stream.x0_lo),
                   h=(jnp.reshape(stream.h_hi, (1,)),
                      jnp.reshape(stream.h_lo, (1,))),
                   num_steps=num_steps,
                   ctr=(stream.ctr_hi, stream.ctr_lo),
                   offset=None, mode=mode, deco=deco, sampler=sampler,
                   out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Shared prep helpers
# ---------------------------------------------------------------------------

def root_and_ctr_rows(x0: U64Pair, ctr: U64Pair, num_steps: int
                      ) -> Tuple[U64Pair, U64Pair]:
    """((T,) root states for ctr+1..ctr+T, (T,) per-row counters ctr+t)."""
    roots = lcg.root_states_vector(x0, ctr, num_steps)
    t_idx = jnp.arange(num_steps, dtype=U32)
    ctr_rows = u64.add64((jnp.broadcast_to(ctr[0], t_idx.shape),
                          jnp.broadcast_to(ctr[1], t_idx.shape)),
                         (jnp.zeros_like(t_idx), t_idx))
    return roots, ctr_rows


def _faithful_start_states(plan: GenPlan) -> jnp.ndarray:
    """(S, 4) xorshift128 states of substreams 0..S-1 advanced to plan.ctr.

    Static offsets use the host-exact GF(2) jump (trace-time constants);
    traced counters use the in-graph jump (bit-identical; see
    tests/test_xorshift.py::test_jump_traced_matches_host).
    """
    S = plan.num_streams
    tbl = xorshift.lane_table(S)
    if plan.offset is not None:
        if plan.offset:
            tbl = xorshift.jump_batch(tbl, plan.offset)
        return jnp.asarray(tbl)
    return xorshift.jump_traced(jnp.asarray(tbl), plan.ctr[0], plan.ctr[1])


def _faithful_tile_states(plan: GenPlan, block_t: int, n_tiles: int,
                          xs0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(n_tiles, 4, S) per-(row-tile, stream) xorshift start states.

    When ``xs0`` is given — (S, 4) states already advanced to plan.ctr,
    carrying GLOBAL substream identity (the sharded case) — tile states
    are derived from it with relative traced jumps instead of rebuilding
    the lane table from local indices.
    """
    S = plan.num_streams
    if xs0 is not None:
        def tile_from(i):
            off = u64.mul32_wide(i, U32(block_t))
            return xorshift.jump_traced(xs0, off[0], off[1])  # (S, 4)

        states = jax.vmap(tile_from)(jnp.arange(n_tiles, dtype=U32))
        return jnp.transpose(states, (0, 2, 1))  # (n_tiles, 4, S)
    if plan.offset is not None:
        # Vectorized GF(2) jumps over the WHOLE lane table: n_tiles batched
        # matvecs instead of an O(S * n_tiles) python-int jump loop
        # (minutes of host work at S = 2**14).
        tbl = xorshift.lane_table(S)
        if plan.offset:
            tbl = xorshift.jump_batch(tbl, plan.offset)
        states = np.empty((n_tiles, 4, S), np.uint32)
        for i in range(n_tiles):
            states[i] = tbl.T
            if i + 1 < n_tiles:
                tbl = xorshift.jump_batch(tbl, block_t)
        return jnp.asarray(states)
    tbl = jnp.asarray(xorshift.lane_table(S))  # (S, 4)

    def tile(i):
        off = u64.add64(plan.ctr, u64.mul32_wide(i, U32(block_t)))
        return xorshift.jump_traced(tbl, off[0], off[1])  # (S, 4)

    states = jax.vmap(tile)(jnp.arange(n_tiles, dtype=U32))
    return jnp.transpose(states, (0, 2, 1))  # (n_tiles, 4, S)


def _faithful_states_at(plan: GenPlan, offsets) -> jnp.ndarray:
    """(K, 4, S) xorshift start states at explicit per-tile offsets.

    ``offsets`` is a non-decreasing list of static python ints, relative
    to ``plan.ctr`` — the generalization of ``_faithful_tile_states``'s
    uniform ``i * block_t`` stride that multi-window tiling needs (tile
    (w, i) sits at ``w * window_len + i * bt``, which is monotone but
    not uniform when the window length is not a tile multiple).
    """
    S = plan.num_streams
    if plan.offset is not None:
        tbl = xorshift.lane_table(S)
        if plan.offset:
            tbl = xorshift.jump_batch(tbl, plan.offset)
        states = np.empty((len(offsets), 4, S), np.uint32)
        at = 0
        for i, off in enumerate(offsets):
            if off != at:
                tbl = xorshift.jump_batch(tbl, off - at)
                at = off
            states[i] = tbl.T
        return jnp.asarray(states)
    tbl = jnp.asarray(xorshift.lane_table(S))  # (S, 4)
    offs = np.array([u64.split64(o) for o in offsets], np.uint32)

    def tile(off_hi, off_lo):
        nh, nl = u64.add64(plan.ctr, (off_hi, off_lo))
        return xorshift.jump_traced(tbl, nh, nl)  # (S, 4)

    states = jax.vmap(tile)(jnp.asarray(offs[:, 0]), jnp.asarray(offs[:, 1]))
    return jnp.transpose(states, (0, 2, 1))  # (K, 4, S)


def _leaf_permuted(roots: U64Pair, h: U64Pair) -> jnp.ndarray:
    """XSH_RR(root_t + h_s): (T,) roots x (S,) offsets -> (T, S) uint32."""
    leaf = u64.add64((roots[0][:, None], roots[1][:, None]),
                     (h[0][None, :], h[1][None, :]))
    return lcg.xsh_rr(leaf)


def _deco_fn(deco: str) -> Callable[[U64Pair, U64Pair], jnp.ndarray]:
    if deco == "splitmix64":
        return splitmix.ctr_decorrelator
    if deco == "fmix32":
        return splitmix.ctr_decorrelator32
    raise ValueError(f"unknown deco {deco!r}")


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

_BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str):
    """Decorator: register fn(plan, *, block_t, block_s, xs0) -> (T, S)."""
    def deco(fn):
        _BACKENDS[name] = fn
        return fn
    return deco


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def use_interpret() -> bool:
    """True when Pallas kernels must run under the interpreter (no TPU)."""
    return jax.default_backend() != "tpu"


@register_backend("ref")
def _ref_backend(plan: GenPlan, *, block_t: int, block_s: int,
                 xs0: Optional[jnp.ndarray]) -> jnp.ndarray:
    from repro.kernels import ref
    if plan.mode == "ctr":
        bits = ref.thundering_block_ctr(plan.x0, plan.h, plan.num_steps,
                                        plan.ctr, deco=plan.deco)
    elif plan.mode == "faithful":
        if xs0 is None:
            xs0 = _faithful_start_states(plan)
        bits = ref.thundering_block_faithful(plan.x0, plan.h, plan.num_steps,
                                             xs0, plan.ctr)
    else:
        raise ValueError(f"unknown mode {plan.mode!r}")
    return sampler_mod.apply(bits, sampler_mod.parse(plan.sampler),
                             plan.out_dtype)


@register_backend("xla")
def _xla_backend(plan: GenPlan, *, block_t: int, block_s: int,
                 xs0: Optional[jnp.ndarray]) -> jnp.ndarray:
    T, S = plan.shape
    roots, ctr_rows = root_and_ctr_rows(plan.x0, plan.ctr, T)
    permuted = _leaf_permuted(roots, plan.h)
    if plan.mode == "ctr":
        dec = _deco_fn(plan.deco)(
            (jnp.broadcast_to(plan.h[0][None, :], (T, S)),
             jnp.broadcast_to(plan.h[1][None, :], (T, S))),
            (jnp.broadcast_to(ctr_rows[0][:, None], (T, S)),
             jnp.broadcast_to(ctr_rows[1][:, None], (T, S))))
        bits = permuted ^ dec
    elif plan.mode == "faithful":
        if xs0 is None:
            xs0 = _faithful_start_states(plan)

        def body(state, perm_row):
            x, y, z, w = (state[..., i] for i in range(4))
            x, y, z, w = xorshift.step_xyzw(x, y, z, w)
            return jnp.stack([x, y, z, w], -1), perm_row ^ w

        _, bits = jax.lax.scan(body, xs0, permuted)
    else:
        raise ValueError(f"unknown mode {plan.mode!r}")
    # XLA fuses the sampler stage into the generation elementwise graph;
    # the barrier only matters for normal's pairing rolls (see sampler).
    return sampler_mod.apply(bits, sampler_mod.parse(plan.sampler),
                             plan.out_dtype, barrier=True)


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@register_backend("pallas")
def _pallas_backend(plan: GenPlan, *, block_t: int, block_s: int,
                    xs0: Optional[jnp.ndarray]) -> jnp.ndarray:
    from repro.kernels import thundering_block as _tb
    T = plan.num_steps
    spec = sampler_mod.parse(plan.sampler)
    roots, ctr_rows = root_and_ctr_rows(plan.x0, plan.ctr, T)
    if plan.mode == "ctr":
        return _tb.block_ctr(roots, ctr_rows, plan.h, block_t=block_t,
                             block_s=block_s, interpret=use_interpret(),
                             deco=plan.deco, sampler=spec,
                             out_dtype=plan.out_dtype)
    if plan.mode == "faithful":
        bt = _tb.tile_t(block_t, T,
                        sampler_mod.result_dtype(spec, plan.out_dtype))
        n_tiles = -(-T // bt)
        states = _faithful_tile_states(plan, bt, n_tiles, xs0)
        return _tb.block_faithful(roots, plan.h, states, block_t=bt,
                                  block_s=block_s,
                                  interpret=use_interpret(),
                                  sampler=spec, out_dtype=plan.out_dtype)
    raise ValueError(f"unknown mode {plan.mode!r}")


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def select_backend(plan: GenPlan) -> str:
    """Pick a backend from the plan shape and the runtime platform.

    On TPU, shapes with at least one VPU tile of work (S >= 128 lanes,
    T >= 8 sublanes) go to the Pallas kernels; everything else — and
    everything off-TPU, where the kernels only run under the interpreter —
    compiles through plain XLA.  ``"ref"`` is never auto-selected; it is
    the oracle, asked for by name.
    """
    T, S = plan.shape
    if jax.default_backend() == "tpu" and S >= 128 and T >= 8:
        return "pallas"
    return "xla"


def _validate_plan(plan: GenPlan) -> None:
    spec = sampler_mod.parse(plan.sampler)          # raises on bad spec
    sampler_mod.result_dtype(spec, plan.out_dtype)  # raises on bad dtype
    if spec[0] == "normal" and plan.num_steps % 2:
        raise ValueError(
            f"sampler='normal' pairs adjacent rows (Box-Muller) and needs "
            f"an even T, got T={plan.num_steps}")


def generate(plan: GenPlan, *, backend: Optional[str] = None,
             block_t: int = DEFAULT_BLOCK_T, block_s: int = DEFAULT_BLOCK_S,
             xs0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(T, S) block for ``plan``, time-major; dtype set by the sampler
    stage (uint32 bits by default, float32/bfloat16 for the float
    samplers, bool for bernoulli).

    ``backend`` overrides ``select_backend``; ``xs0`` optionally supplies
    pre-advanced (S, 4) xorshift start states for faithful mode (used by
    ``generate_sharded``, where substream identity follows the GLOBAL
    stream index, not the local shard).

    Example:
        >>> import numpy as np
        >>> from repro.core import engine
        >>> plan = engine.make_plan(seed=7, num_streams=4, num_steps=8)
        >>> blk = engine.generate(plan, backend="xla")
        >>> (blk.shape, str(blk.dtype))
        ((8, 4), 'uint32')
        >>> oracle = engine.generate(plan, backend="ref")
        >>> bool(np.array_equal(np.asarray(blk), np.asarray(oracle)))
        True
    """
    _validate_plan(plan)
    name = backend or select_backend(plan)
    try:
        fn = _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; have {available_backends()}")
    return fn(plan, block_t=block_t, block_s=block_s, xs0=xs0)


def sample(plan: GenPlan, *, sampler: Optional[str] = None,
           out_dtype: Optional[str] = None, backend: Optional[str] = None,
           block_t: int = DEFAULT_BLOCK_T, block_s: int = DEFAULT_BLOCK_S,
           xs0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """``generate`` with the sampler stage overridden per call.

    ``sample(plan, sampler="uniform")`` draws U[0,1) floats from the plan's
    (T, S) window without materializing the uint32 bits on any backend
    that fuses (xla fuses elementwise; pallas applies the transform
    in-VMEM).  ``sampler=None`` keeps the plan's own stage.

    Example:
        >>> from repro.core import engine
        >>> plan = engine.make_plan(seed=7, num_streams=4, num_steps=8)
        >>> u = engine.sample(plan, sampler="uniform")
        >>> (u.shape, str(u.dtype))
        ((8, 4), 'float32')
        >>> bool((u >= 0).all()) and bool((u < 1).all())
        True
    """
    if sampler is not None or out_dtype is not None:
        plan = dataclasses.replace(
            plan,
            sampler=plan.sampler if sampler is None else sampler,
            out_dtype=plan.out_dtype if out_dtype is None else out_dtype)
    return generate(plan, backend=backend, block_t=block_t, block_s=block_s,
                    xs0=xs0)


def shift_plan(plan: GenPlan, delta: int) -> GenPlan:
    """The same plan ``delta`` counter steps later (window ``[ctr+delta,
    ctr+delta+T)``).  Static offsets stay static; traced counters get a
    traced add — either way the shifted plan is bit-identical to leasing
    the later window directly.
    """
    delta = int(delta)
    d_hi, d_lo = (u64.to_u32(v) for v in u64.const64(delta))
    return dataclasses.replace(
        plan, ctr=u64.add64(plan.ctr, (d_hi, d_lo)),
        offset=None if plan.offset is None else plan.offset + delta)


def generate_windows(plan: GenPlan, num_windows: int, *,
                     backend: Optional[str] = None,
                     block_t: int = DEFAULT_BLOCK_T,
                     block_s: int = DEFAULT_BLOCK_S) -> jnp.ndarray:
    """(W, T, S) stack of W *consecutive* counter windows of ``plan``.

    Window ``w`` covers counter steps ``[ctr + w*T, ctr + (w+1)*T)`` —
    bit-identical on every backend to stacking W ``generate`` calls on
    ``shift_plan(plan, w*T)``, but dispatched as ONE device program:

      * ``"ref"``     literally the stacked loop (the oracle),
      * ``"xla"``     one fused (W*T, S) generation reshaped to windows
                      (counter addressing makes consecutive windows one
                      contiguous block),
      * ``"pallas"``  one ``pallas_call`` whose grid grows a leading
                      window axis — W windows cost one kernel launch
                      (``thundering_block.block_ctr_windows``).

    This is the dispatch-amortization lever of the roofline chase: a
    standing producer that fuses W windows per call pays the per-call
    jit/launch overhead once per W blocks (``BlockProducer(fuse=W)``).

    Example:
        >>> import numpy as np
        >>> from repro.core import engine
        >>> plan = engine.make_plan(seed=7, num_streams=4, num_steps=6)
        >>> stack = engine.generate_windows(plan, 3, backend="xla")
        >>> stack.shape                          # (W, T, S)
        (3, 6, 4)
        >>> w2 = engine.generate(engine.shift_plan(plan, 12), backend="xla")
        >>> bool(np.array_equal(np.asarray(stack[2]), np.asarray(w2)))
        True
    """
    _validate_plan(plan)
    W = int(num_windows)
    if W < 1:
        raise ValueError(f"num_windows must be >= 1, got {num_windows}")
    T, S = plan.shape
    name = backend or select_backend(plan)
    if name not in _BACKENDS:
        raise ValueError(
            f"unknown backend {name!r}; have {available_backends()}")
    if name == "ref":
        return jnp.stack([generate(shift_plan(plan, w * T), backend="ref",
                                   block_t=block_t, block_s=block_s)
                          for w in range(W)])
    if name == "xla":
        wide = dataclasses.replace(plan, num_steps=W * T)
        out = generate(wide, backend="xla", block_t=block_t,
                       block_s=block_s)
        return out.reshape(W, T, S)
    from repro.kernels import thundering_block as _tb
    spec = sampler_mod.parse(plan.sampler)
    roots, ctr_rows = root_and_ctr_rows(plan.x0, plan.ctr, W * T)
    if plan.mode == "ctr":
        return _tb.block_ctr_windows(
            roots, ctr_rows, plan.h, num_windows=W, window_len=T,
            block_t=block_t, block_s=block_s, interpret=use_interpret(),
            deco=plan.deco, sampler=spec, out_dtype=plan.out_dtype)
    if plan.mode == "faithful":
        bt = _tb.tile_t(block_t, T,
                        sampler_mod.result_dtype(spec, plan.out_dtype))
        n_t = -(-_pad_to(T, bt) // bt)
        states = _faithful_states_at(
            plan, [w * T + i * bt for w in range(W) for i in range(n_t)])
        return _tb.block_faithful_windows(
            roots, plan.h, states, num_windows=W, window_len=T,
            block_t=bt, block_s=block_s, interpret=use_interpret(),
            sampler=spec, out_dtype=plan.out_dtype)
    raise ValueError(f"unknown mode {plan.mode!r}")


def generate_flat(plan: GenPlan, *, backend: Optional[str] = None,
                  block_t: int = DEFAULT_BLOCK_T,
                  block_s: int = DEFAULT_BLOCK_S) -> jnp.ndarray:
    """(T,) vector for a single-stream plan (S must be 1); dtype follows
    the plan's sampler stage."""
    if plan.num_streams != 1:
        raise ValueError(f"generate_flat needs S=1, got S={plan.num_streams}")
    return generate(plan, backend=backend, block_t=block_t,
                    block_s=block_s)[:, 0]


# ---------------------------------------------------------------------------
# Multi-device fan-out
# ---------------------------------------------------------------------------

def default_mesh(axis_name: str = "streams") -> jax.sharding.Mesh:
    """1-D mesh over every local device, stream axis last."""
    return jax.sharding.Mesh(np.array(jax.devices()), (axis_name,))


def generate_sharded(plan: GenPlan, *, mesh: Optional[jax.sharding.Mesh] = None,
                     axis_name: str = "streams",
                     axis_names: Optional[Tuple[str, ...]] = None,
                     backend: Optional[str] = None,
                     block_t: int = DEFAULT_BLOCK_T,
                     block_s: int = DEFAULT_BLOCK_S) -> jnp.ndarray:
    """(T, S) block computed with the stream axis sharded over ``mesh``.

    The software analogue of the paper's SOU instance scaling: the root
    state (x0, ctr) is replicated — it is two u32 scalars, the paper's
    "one multiplier" — and each device derives its own column slice by
    counter addressing.  No collective appears in the compiled program;
    the result is bit-identical to ``generate`` on one device.

    ``axis_names`` selects an N-D fan-out: the stream axis is sharded
    over the PRODUCT of the named mesh axes (e.g. ``("hosts", "streams")``
    for the 2-D multi-host layout, or a production mesh's
    ``("data", "model")``).  Because the stream axis carries GLOBAL
    column identity — shard (i, j) of an (H, D) grid owns columns
    ``[(i*D + j) * S_loc, ...)`` — the result stays bit-identical to the
    1-D and single-device paths for any mesh factorization.  When
    ``axis_names`` is None the historical 1-D ``axis_name`` is used.

    S is padded up to a multiple of the total device count and sliced
    back.

    Example:
        >>> import numpy as np
        >>> from repro.core import engine
        >>> plan = engine.make_plan(seed=7, num_streams=6, num_steps=8)
        >>> out = engine.generate_sharded(plan)   # default mesh (1 CPU here)
        >>> direct = engine.generate(plan, backend="xla")
        >>> bool(np.array_equal(np.asarray(out), np.asarray(direct)))
        True
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if axis_names is None:
        axis_names = (axis_name,)
    axes = tuple(axis_names)
    if mesh is None:
        if axes != (axis_name,):
            raise ValueError("axis_names requires an explicit mesh")
        mesh = default_mesh(axis_name)
    for ax in axes:
        if ax not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {ax!r}; has {mesh.axis_names}")
    n_dev = 1
    for ax in axes:
        n_dev *= mesh.shape[ax]
    T, S = plan.shape
    Sp = _pad_to(S, n_dev)

    h_hi = jnp.pad(plan.h[0], (0, Sp - S))
    h_lo = jnp.pad(plan.h[1], (0, Sp - S))
    operands = [h_hi, h_lo]
    in_specs = [P(axes), P(axes)]
    if plan.mode == "faithful":
        # substream identity follows the global stream index: prep the
        # full (Sp, 4) start-state table once, shard it with h.
        padded = dataclasses.replace(plan, h=(h_hi, h_lo))
        xs0 = _faithful_start_states(padded)
        operands.append(xs0)
        in_specs.append(P(axes, None))

    def local(hh, hl, *rest):
        lp = dataclasses.replace(plan, h=(hh, hl))
        lxs0 = rest[0] if rest else None
        return generate(lp, backend=backend or "xla", block_t=block_t,
                        block_s=block_s, xs0=lxs0)

    out = shard_map(local, mesh=mesh, in_specs=tuple(in_specs),
                    out_specs=P(None, axes), check_rep=False)(*operands)
    return out[:, :S]
