"""Unsigned 64-bit arithmetic emulated with uint32 limb pairs.

TPU vector units have no native 64-bit integer multiply (and Pallas/Mosaic
does not lower ``uint64``), so every 64-bit quantity in this codebase is a
pair of ``uint32`` arrays ``(hi, lo)``.  All helpers below are pure jnp and
lower both in regular jitted JAX and inside Pallas kernel bodies.

32x32->64 products are built from 16-bit half-limbs (four partial products),
which is the TPU-native decomposition: each partial product of two 16-bit
values fits a uint32 lane with no overflow.

Convention: a u64 value ``x`` is represented as ``(x_hi, x_lo)`` with
``x = x_hi * 2**32 + x_lo`` and both limbs ``jnp.uint32``.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

# NOTE: numpy (not jnp) scalars — they fold to jaxpr *literals*, which is
# required inside Pallas kernel bodies (captured jax Arrays are rejected).
U32 = np.uint32
MASK16 = U32(0xFFFF)

U64Pair = Tuple[jnp.ndarray, jnp.ndarray]


def to_u32(x) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.uint32)


def split64(value: int) -> Tuple[int, int]:
    """Split a python int (mod 2**64) into (hi, lo) python ints."""
    value &= (1 << 64) - 1
    return (value >> 32) & 0xFFFFFFFF, value & 0xFFFFFFFF


def const64(value: int) -> U64Pair:
    """Python int -> (hi, lo) uint32 numpy scalars (trace-time literals)."""
    hi, lo = split64(value)
    return U32(hi), U32(lo)


def join64(hi, lo) -> int:
    """(hi, lo) numpy/int -> python int. Host-side only (for tests/goldens)."""
    return (int(hi) << 32) | int(lo)


def mul32_wide(a: jnp.ndarray, b: jnp.ndarray) -> U64Pair:
    """Full 32x32 -> 64 bit product via 16-bit half-limbs."""
    a = a.astype(U32)
    b = b.astype(U32)
    a_lo = a & MASK16
    a_hi = a >> 16
    b_lo = b & MASK16
    b_hi = b >> 16
    ll = a_lo * b_lo  # < 2**32, exact
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi
    # bits 16..47 accumulate: upper half of ll plus the low halves of the
    # cross terms; the sum is at most 3*(2**16-1) + (2**16-1) < 2**18 so it
    # fits uint32 without overflow.
    mid = (ll >> 16) + (lh & MASK16) + (hl & MASK16)
    lo = (ll & MASK16) | ((mid & MASK16) << 16)
    hi = hh + (lh >> 16) + (hl >> 16) + (mid >> 16)
    return hi, lo


def add64(a: U64Pair, b: U64Pair) -> U64Pair:
    """(a + b) mod 2**64."""
    ah, al = a
    bh, bl = b
    lo = al + bl
    carry = (lo < al).astype(U32)
    hi = ah + bh + carry
    return hi, lo


def sub64(a: U64Pair, b: U64Pair) -> U64Pair:
    """(a - b) mod 2**64."""
    ah, al = a
    bh, bl = b
    lo = al - bl
    borrow = (al < bl).astype(U32)
    hi = ah - bh - borrow
    return hi, lo


def mul64(a: U64Pair, b: U64Pair) -> U64Pair:
    """(a * b) mod 2**64."""
    ah, al = a
    bh, bl = b
    hi, lo = mul32_wide(al, bl)
    # Cross terms only contribute to the high limb (mod 2**64): wrapping
    # uint32 multiplies are exactly what we need.
    hi = hi + al * bh + ah * bl
    return hi, lo


def xor64(a: U64Pair, b: U64Pair) -> U64Pair:
    return a[0] ^ b[0], a[1] ^ b[1]


def shr64(a: U64Pair, n: int) -> U64Pair:
    """Logical right shift by a static amount 0 <= n < 64."""
    ah, al = a
    if n == 0:
        return ah, al
    if n < 32:
        lo = (al >> n) | (ah << (32 - n))
        hi = ah >> n
    else:
        lo = ah >> (n - 32) if n > 32 else ah
        hi = jnp.zeros_like(ah)
    return hi, lo


def shl64(a: U64Pair, n: int) -> U64Pair:
    """Logical left shift by a static amount 0 <= n < 64."""
    ah, al = a
    if n == 0:
        return ah, al
    if n < 32:
        hi = (ah << n) | (al >> (32 - n))
        lo = al << n
    else:
        hi = al << (n - 32) if n > 32 else al
        lo = jnp.zeros_like(al)
    return hi, lo


def ror32(x: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Rotate right a uint32 by a per-element amount in [0, 31]."""
    x = x.astype(U32)
    r = r.astype(U32) & U32(31)
    return (x >> r) | (x << ((U32(32) - r) & U32(31)))


def eq64(a: U64Pair, b: U64Pair) -> jnp.ndarray:
    return (a[0] == b[0]) & (a[1] == b[1])
