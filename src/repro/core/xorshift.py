"""xorshift128 decorrelator (Marsaglia 2003) with GF(2) jump-ahead.

ThundeRiNG (Sec. 3.2.3) decorrelates the LCG leaf streams by XORing each
with a *substream* of a single xorshift128 generator, substreams spaced
2**64 steps apart so any pair is guaranteed non-overlapping (Sec. 5.1.2).

xorshift128 is F2-linear: the 128-bit state advances by a fixed bit-matrix
``M`` over GF(2).  Jump-ahead by N steps is multiplication by ``M**N``.  We
compute ``M**(2**64)`` once at import (host-side python-int bit tricks —
the paper's "compile time", Sec. 4.2) and derive the i-th substream's start
state with i matrix-vector products (batched for lane tables).

State layout: (x, y, z, w) four uint32 words; output is the new ``w``.
Bit k of the flattened 128-bit state = bit (k % 32) of word (k // 32).
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.u64 import U32

# Default seed from Marsaglia's paper.
DEFAULT_SEED = (123456789, 362436069, 521288629, 88675123)

STATE_WORDS = 4
STATE_BITS = 128


def step_words(x: int, y: int, z: int, w: int) -> Tuple[int, int, int, int]:
    """One xorshift128 step on python ints (host-side golden)."""
    t = (x ^ (x << 11)) & 0xFFFFFFFF
    x, y, z = y, z, w
    w = (w ^ (w >> 19)) ^ (t ^ (t >> 8))
    return x, y, z, w & 0xFFFFFFFF


def step(state: jnp.ndarray) -> jnp.ndarray:
    """One xorshift128 step; state shape (..., 4) uint32. Output = new w."""
    x = state[..., 0]
    y = state[..., 1]
    z = state[..., 2]
    w = state[..., 3]
    t = x ^ (x << U32(11))
    new_w = (w ^ (w >> U32(19))) ^ (t ^ (t >> U32(8)))
    return jnp.stack([y, z, w, new_w], axis=-1)


def step_xyzw(x, y, z, w):
    """One step on four separate uint32 arrays (Pallas-friendly, no stack)."""
    t = x ^ (x << U32(11))
    new_w = (w ^ (w >> U32(19))) ^ (t ^ (t >> U32(8)))
    return y, z, w, new_w


# ----------------------------------------------------------------------------
# GF(2) linear-algebra machinery (host side, exact).
# A 128x128 bit matrix is a list of 128 column ints: column j = M @ e_j,
# encoded as a 128-bit python int.  M @ v = XOR of columns at v's set bits.
# ----------------------------------------------------------------------------

def _state_to_int(words: Tuple[int, int, int, int]) -> int:
    v = 0
    for k, word in enumerate(words):
        v |= (word & 0xFFFFFFFF) << (32 * k)
    return v


def _int_to_state(v: int) -> Tuple[int, int, int, int]:
    return tuple((v >> (32 * k)) & 0xFFFFFFFF for k in range(4))


def _matvec(cols: List[int], v: int) -> int:
    out = 0
    while v:
        lsb = v & -v
        out ^= cols[lsb.bit_length() - 1]
        v ^= lsb
    return out


def _matmul(a_cols: List[int], b_cols: List[int]) -> List[int]:
    """(A @ B): column j of result = A @ (column j of B)."""
    return [_matvec(a_cols, bj) for bj in b_cols]


@functools.lru_cache(maxsize=None)
def step_matrix() -> Tuple[int, ...]:
    """The xorshift128 transition as 128 column ints."""
    cols = []
    for j in range(STATE_BITS):
        basis = _int_to_state(1 << j)
        cols.append(_state_to_int(step_words(*basis)))
    return tuple(cols)


@functools.lru_cache(maxsize=None)
def matrix_pow2(k: int) -> Tuple[int, ...]:
    """M**(2**k) as column ints, by repeated squaring (cached)."""
    if k == 0:
        return step_matrix()
    prev = list(matrix_pow2(k - 1))
    return tuple(_matmul(prev, prev))


def jump(words: Tuple[int, int, int, int], n: int) -> Tuple[int, int, int, int]:
    """Advance a state by n steps via binary decomposition of n (host-side)."""
    v = _state_to_int(words)
    k = 0
    n = int(n)
    while n:
        if n & 1:
            v = _matvec(list(matrix_pow2(k)), v)
        n >>= 1
        k += 1
    return _int_to_state(v)


def substream_state(words: Tuple[int, int, int, int], i: int,
                    log2_spacing: int = 64) -> Tuple[int, int, int, int]:
    """Start state of substream i: base advanced by i * 2**log2_spacing."""
    return jump(words, i << log2_spacing)


@functools.lru_cache(maxsize=None)
def lane_table(num_lanes: int, seed: Tuple[int, int, int, int] = DEFAULT_SEED,
               log2_spacing: int = 64) -> np.ndarray:
    """Start states for lanes 0..num_lanes-1, shape (num_lanes, 4) uint32.

    Lane i = substream i (spaced 2**64 apart).  Computed once host-side
    with a single matvec per lane (J = M**(2**64) applied iteratively).
    """
    J = list(matrix_pow2(log2_spacing))
    out = np.empty((num_lanes, 4), np.uint32)
    v = _state_to_int(seed)
    for i in range(num_lanes):
        out[i] = np.array(_int_to_state(v), np.uint32)
        v = _matvec(J, v)
    return out


@functools.lru_cache(maxsize=None)
def _packed_pow2_matrices(max_log2: int = 64) -> np.ndarray:
    """M**(2**k) for k in [0, max_log2) packed as uint32.

    Shape (max_log2, 128, 4): [k, row, word].  Row r of matrix k packed as
    4 uint32 words, so that output bit r = parity(popcount(row & state)).
    """
    out = np.empty((max_log2, STATE_BITS, STATE_WORDS), np.uint32)
    for k in range(max_log2):
        cols = matrix_pow2(k)
        # convert columns -> rows: row r bit j = column j bit r
        rows = [0] * STATE_BITS
        for j, col in enumerate(cols):
            c = col
            while c:
                lsb = c & -c
                r = lsb.bit_length() - 1
                rows[r] |= 1 << j
                c ^= lsb
        for r in range(STATE_BITS):
            for wd in range(STATE_WORDS):
                out[k, r, wd] = (rows[r] >> (32 * wd)) & 0xFFFFFFFF
    return out


_POPCOUNT8 = np.array([bin(i).count("1") for i in range(256)], np.uint8)


def _popcount_u32(a: np.ndarray) -> np.ndarray:
    if hasattr(np, "bitwise_count"):  # numpy >= 2.0
        return np.bitwise_count(a)
    return _POPCOUNT8[a.view(np.uint8)].reshape(a.shape + (4,)).sum(-1)


def _matvec_batch(mat: np.ndarray, states: np.ndarray) -> np.ndarray:
    """One packed GF(2) matvec over a whole state table.

    mat: (128, 4) uint32 packed rows; states: (S, 4) uint32.  Output bit r
    of each state = parity(popcount(mat[r] & state)).
    """
    acc = mat[None, :, :] & states[:, None, :]            # (S, 128, 4)
    parity = (_popcount_u32(acc).astype(np.uint32).sum(-1) & 1)  # (S, 128)
    bits = parity.reshape(states.shape[0], 4, 32).astype(np.uint32)
    return (bits << np.arange(32, dtype=np.uint32)).sum(-1, dtype=np.uint32)


def jump_batch(states: np.ndarray, n: int) -> np.ndarray:
    """Advance a whole (S, 4) uint32 state table by n steps at once.

    Vectorized numpy version of ``jump``: one packed-matrix matvec per set
    bit of ``n``, over all S lanes simultaneously — O(popcount(n)) numpy
    ops instead of O(S) python-int matvec loops.  Bit-identical to
    per-state ``jump`` (same GF(2) matrices).
    """
    states = np.asarray(states, np.uint32)
    mats = _packed_pow2_matrices(64)
    n = int(n)
    k = 0
    while n:
        if n & 1:
            states = _matvec_batch(mats[k], states)
        n >>= 1
        k += 1
    return states


def jump_traced(state: jnp.ndarray, n_hi: jnp.ndarray, n_lo: jnp.ndarray
                ) -> jnp.ndarray:
    """Traced jump-ahead by a dynamic 64-bit count (n_hi, n_lo).

    ``state``: (..., 4) uint32.  Cost: 64 conditional 128x128 GF(2) matvecs,
    each a (128, 4) & (..., 1, 4) popcount-parity — used once per bulk call,
    never per element.
    """
    mats = jnp.asarray(_packed_pow2_matrices(64))  # (64, 128, 4)

    def matvec(mat, s):
        # mat: (128, 4); s: (..., 4) -> (..., 4)
        acc = jnp.bitwise_and(mat, s[..., None, :])  # (..., 128, 4)
        pc = jax.lax.population_count(acc).astype(U32)
        parity = jnp.sum(pc, axis=-1) & U32(1)  # (..., 128)
        bitpos = jnp.arange(32, dtype=U32)
        bits = parity.reshape(parity.shape[:-1] + (4, 32))
        words = jnp.sum(bits << bitpos, axis=-1, dtype=U32)
        return words

    def body(k, s):
        bit = jnp.where(k < 32, (n_lo >> k.astype(U32)) & U32(1),
                        (n_hi >> (k.astype(U32) - U32(32))) & U32(1))
        jumped = matvec(mats[k], s)
        return jnp.where((bit == 1)[..., None] if bit.ndim else bit == 1,
                         jumped, s)

    return jax.lax.fori_loop(0, 64, body, state)
