"""SplitMix64 in u32-limb form.

Two roles in this codebase:

1. *Stream derivation* — hashing (parent stream id, tag) into fresh leaf
   offsets ``h`` and decorrelator seeds, giving a splittable key tree on top
   of ThundeRiNG's flat stream space (the framework-facing API).

2. *Counter-based decorrelator* ("ctr mode") — the beyond-paper TPU variant:
   the paper's xorshift128 decorrelator is a serial recurrence, which on an
   FPGA costs nothing (an LFSR advances once per cycle) but on a TPU forces
   a sequential fori_loop over time steps.  Replacing it with
   ``splitmix64(h ^ counter)`` keeps both of the paper's theoretical
   constraints from Sec. 3.2.3 — (i) the generator family is completely
   different from (and empirically uncorrelated with) the LCG family, and
   (ii) distinct streams use disjoint input domains so pairwise correlation
   stays weak — while making every output value independently addressable
   (pure map, no serial chain).  See DESIGN.md "Hardware adaptation".
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core import u64
from repro.core.u64 import U32, U64Pair

GAMMA = 0x9E3779B97F4A7C15
MIX1 = 0xBF58476D1CE4E5B9
MIX2 = 0x94D049BB133111EB


def mix64(z: U64Pair) -> U64Pair:
    """The splitmix64 finalizer: z -> mixed 64-bit value."""
    z = u64.xor64(z, u64.shr64(z, 30))
    z = u64.mul64(z, u64.const64(MIX1))
    z = u64.xor64(z, u64.shr64(z, 27))
    z = u64.mul64(z, u64.const64(MIX2))
    z = u64.xor64(z, u64.shr64(z, 31))
    return z


def splitmix64(seed: U64Pair, index: U64Pair) -> U64Pair:
    """mixed = mix64(seed + (index + 1) * GAMMA). Pure counter-addressable."""
    step = u64.mul64(u64.add64(index, u64.const64(1)), u64.const64(GAMMA))
    return mix64(u64.add64(seed, step))


def mix64_host(z: int) -> int:
    """Host-side python-int mirror of mix64 (for goldens/tests)."""
    m = (1 << 64) - 1
    z &= m
    z ^= z >> 30
    z = (z * MIX1) & m
    z ^= z >> 27
    z = (z * MIX2) & m
    z ^= z >> 31
    return z


def splitmix64_host(seed: int, index: int) -> int:
    m = (1 << 64) - 1
    return mix64_host((seed + ((index + 1) * GAMMA)) & m)


def fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer (2 multiplies)."""
    x = x.astype(jnp.uint32) if hasattr(x, "astype") else x
    x = x ^ (x >> U32(16))
    x = x * U32(0x85EBCA6B)
    x = x ^ (x >> U32(13))
    x = x * U32(0xC2B2AE35)
    x = x ^ (x >> U32(16))
    return x


def ctr_decorrelator32(h: U64Pair, counter: U64Pair) -> jnp.ndarray:
    """Cheap 32-bit counter decorrelator (beyond-paper §Perf variant).

    ~18 uint ops/sample vs ~76 for the full splitmix64 path, while keeping
    the paper's Sec. 3.2.3 constraints: (i) multiplicative-xorshift hash
    family, algebraically unrelated to the LCG; (ii) streams occupy
    disjoint input domains via the 64-bit h folded into the seed word.
    Statistical battery results in EXPERIMENTS.md §Perf/H3.
    """
    hh, hl = h
    ch, cl = counter
    seed = (hl ^ ((hh << U32(16)) | (hh >> U32(16))))
    x = seed + cl * U32(0x9E3779B9) + ch * U32(0x85EBCA77)
    return fmix32(x)


def ctr_decorrelator32_host(h: int, counter: int) -> int:
    m32 = 0xFFFFFFFF
    hh, hl = (h >> 32) & m32, h & m32
    ch, cl = (counter >> 32) & m32, counter & m32
    seed = hl ^ (((hh << 16) | (hh >> 16)) & m32)
    x = (seed + cl * 0x9E3779B9 + ch * 0x85EBCA77) & m32
    x ^= x >> 16
    x = (x * 0x85EBCA6B) & m32
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & m32
    x ^= x >> 16
    return x


def ctr_decorrelator(h: U64Pair, counter: U64Pair) -> jnp.ndarray:
    """Counter-mode decorrelator output (32 bits): high word of
    splitmix64(h ^ rotl(counter)).  ``h`` is the leaf offset (unique per
    stream), ``counter`` the element index within the stream."""
    z = splitmix64(u64.xor64(h, u64.const64(0xD1B54A32D192ED03)), counter)
    return z[0] ^ z[1]


def ctr_decorrelator_host(h: int, counter: int) -> int:
    z = splitmix64_host(h ^ 0xD1B54A32D192ED03, counter)
    return ((z >> 32) ^ z) & 0xFFFFFFFF
