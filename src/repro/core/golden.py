"""Numpy uint64 golden model of ThundeRiNG.

This is the oracle every JAX/Pallas implementation is tested against.  It
uses native uint64 arithmetic (independent of the u32-limb code paths) and
mirrors the paper's pipeline exactly:

  root LCG -> leaf add h_i -> XSH-RR permutation -> XOR xorshift128 substream

All functions are intentionally slow and simple.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import lcg as _lcg
from repro.core import xorshift as _xs
from repro.core import splitmix as _sm

M64 = (1 << 64) - 1


def lcg_seq(x0: int, n: int, a: int = _lcg.MULTIPLIER,
            c: int = _lcg.DEFAULT_INCREMENT) -> np.ndarray:
    """Root states x_1..x_n (the state *after* each transition), uint64."""
    out = np.empty(n, np.uint64)
    x = x0 & M64
    for i in range(n):
        x = (a * x + c) & M64
        out[i] = x
    return out


def xsh_rr(state: np.ndarray) -> np.ndarray:
    """PCG XSH-RR 64->32 on a uint64 array."""
    state = state.astype(np.uint64)
    xorshifted = (((state >> np.uint64(18)) ^ state) >> np.uint64(27)).astype(
        np.uint32)
    rot = (state >> np.uint64(59)).astype(np.uint32)
    return (xorshifted >> rot) | (xorshifted << ((np.uint32(32) - rot)
                                                 & np.uint32(31)))


def xorshift_seq(words: Tuple[int, int, int, int], n: int) -> np.ndarray:
    """n successive 32-bit outputs of xorshift128 from the given state."""
    out = np.empty(n, np.uint32)
    x, y, z, w = words
    for i in range(n):
        x, y, z, w = _xs.step_words(x, y, z, w)
        out[i] = w
    return out


def thundering_block(x0: int, h: np.ndarray, n_steps: int,
                     a: int = _lcg.MULTIPLIER,
                     c: int = _lcg.DEFAULT_INCREMENT,
                     mode: str = "faithful",
                     xs_seed: Tuple[int, int, int, int] = _xs.DEFAULT_SEED,
                     offset: int = 0) -> np.ndarray:
    """Golden (num_streams, n_steps) uint32 block.

    mode="faithful": decorrelator = xorshift128 substream per stream
      (substream i spaced 2**64, advanced ``offset`` extra steps).
    mode="ctr": decorrelator = splitmix64(h ^ const, offset + t).
    """
    num_streams = len(h)
    # Root states for steps offset+1 .. offset+n_steps.
    A, C = _lcg.lcg_skip(offset, a, c)
    x_base = (A * (x0 & M64) + C) & M64
    roots = lcg_seq(x_base, n_steps, a, c)

    out = np.empty((num_streams, n_steps), np.uint32)
    for s in range(num_streams):
        leaf = (roots + np.uint64(int(h[s]) & M64)) & np.uint64(M64)
        permuted = xsh_rr(leaf)
        if mode == "faithful":
            st = _xs.substream_state(xs_seed, s)
            if offset:
                st = _xs.jump(st, offset)
            deco = xorshift_seq(st, n_steps)
        elif mode == "ctr":
            deco = np.array(
                [_sm.ctr_decorrelator_host(int(h[s]), offset + t)
                 for t in range(n_steps)], np.uint32)
        else:
            raise ValueError(mode)
        out[s] = permuted ^ deco
    return out


def pcg32_seq(initstate: int, initseq: int, n: int) -> np.ndarray:
    """Reference pcg32 (O'Neill) — used as a known-answer cross-check that
    our LCG + XSH-RR pipeline matches the published algorithm."""
    a = _lcg.MULTIPLIER
    inc = ((initseq << 1) | 1) & M64
    state = 0
    state = (state * a + inc) & M64
    state = (state + initstate) & M64
    state = (state * a + inc) & M64
    out = np.empty(n, np.uint32)
    for i in range(n):
        old = state
        state = (state * a + inc) & M64
        out[i] = xsh_rr(np.array([old], np.uint64))[0]
    return out
