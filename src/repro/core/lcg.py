"""Linear congruential generator core with ThundeRiNG state sharing.

The paper's root/leaf decomposition (Sec. 3.3):

  root transition   x_{n+1} = (a * x_n + c)      mod 2**64      (1 multiply)
  leaf transition   w_n^i   = (x_n + h_i)        mod 2**64      (1 add each)

Each leaf stream i is itself an LCG of the same multiplier with effective
increment ``c_i = (c + h_i - a*h_i) mod 2**64`` (Eq. 21/22).  The
Hull-Dobell maximum-period condition requires ``c_i`` odd; with odd ``a``
and odd ``c`` it suffices to pick EVEN ``h_i`` (Sec. 3.3), which we enforce.

TPU adaptation of the FPGA advance-``i`` trick (Sec. 4.2): the paper runs 6
staggered state generators to hide DSP latency.  On TPU we use the same
jump-ahead algebra (Brown 1994) to express a whole *vector* of future root
states as one fused affine map,

  x_{n+t} = A_t * x_n + C_t,   A_t = a^t,  C_t = c * (a^t - 1) / (a - 1),

with per-lane constants (A_t, C_t) precomputed at trace time.  A block of
``T`` time steps shared over ``S`` leaf streams therefore costs ``T`` vector
multiplies + ``S*T`` adds — the paper's "one multiplier for any number of
instances", reinterpreted for a 8x128-lane VPU.

NOTE on the paper's parameters: Sec. 5.1.2 says ``c = 54``, but an even
``c`` violates the paper's own Hull-Dobell argument in Sec. 3.3 (odd
increment required for full period).  The value 54 is the *stream id* from
O'Neill's pcg32 demo (where the increment becomes ``(54 << 1) | 1``).  We
default
to the PCG64 reference increment and expose ``c`` as a parameter; any odd
``c`` is accepted.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import u64
from repro.core.u64 import U32, U64Pair

# PCG64 / Knuth MMIX multiplier, as used by the paper (Sec. 5.1.2).
MULTIPLIER = 6364136223846793005
# PCG64 reference increment (odd; see module docstring for why not 54).
DEFAULT_INCREMENT = 1442695040888963407
MODULUS_BITS = 64


def lcg_step(state: U64Pair, a: U64Pair, c: U64Pair) -> U64Pair:
    """x -> (a*x + c) mod 2**64, all limb pairs."""
    return u64.add64(u64.mul64(a, state), c)


def leaf_transition(root: U64Pair, h: U64Pair) -> U64Pair:
    """ThundeRiNG leaf: w = (x + h) mod 2**64. h must be even (see module doc)."""
    return u64.add64(root, h)


def effective_increment(a: int, c: int, h: int) -> int:
    """Increment of the leaf stream as an ordinary LCG (Eq. 21)."""
    return (c + h - a * h) % (1 << 64)


def lcg_skip(n: int, a: int = MULTIPLIER, c: int = DEFAULT_INCREMENT) -> Tuple[int, int]:
    """Brown's O(log n) jump-ahead: returns (A, C) with x_{k+n} = A*x_k + C.

    Host-side exact version over python ints (the paper computes these at
    compile time, Sec. 4.2); ``n`` may be any non-negative int (mod 2**64
    period assumed).
    """
    m = 1 << 64
    A, C = 1, 0
    cur_a, cur_c = a % m, c % m
    n = int(n)
    while n > 0:
        if n & 1:
            A = (A * cur_a) % m
            C = (C * cur_a + cur_c) % m
        cur_c = ((cur_a + 1) * cur_c) % m
        cur_a = (cur_a * cur_a) % m
        n >>= 1
    return A, C


def lcg_skip_traced(n: U64Pair, a: int = MULTIPLIER, c: int = DEFAULT_INCREMENT
                    ) -> Tuple[U64Pair, U64Pair]:
    """Traced jump-ahead for dynamic offsets (64-iteration fori_loop).

    ``n`` is a (hi, lo) uint32 pair (possibly vectors).  Returns traced
    (A, C) limb pairs such that x_{k+n} = A*x_k + C elementwise.
    """
    nh, nl = n
    one = (jnp.zeros_like(nh), jnp.ones_like(nl))
    zero = (jnp.zeros_like(nh), jnp.zeros_like(nl))

    a0 = u64.const64(a)
    c0 = u64.const64(c)
    # Broadcast constants against n's shape.
    cur_a = (jnp.broadcast_to(a0[0], nh.shape).astype(U32),
             jnp.broadcast_to(a0[1], nl.shape).astype(U32))
    cur_c = (jnp.broadcast_to(c0[0], nh.shape).astype(U32),
             jnp.broadcast_to(c0[1], nl.shape).astype(U32))

    def body(i, carry):
        A, C, cur_a, cur_c = carry
        # bit i of n: from lo for i < 32 else hi
        bit = jnp.where(i < 32, (nl >> i.astype(U32)) & U32(1),
                        (nh >> (i.astype(U32) - U32(32))) & U32(1)).astype(bool)

        newA = u64.mul64(A, cur_a)
        newC = u64.add64(u64.mul64(C, cur_a), cur_c)
        A = (jnp.where(bit, newA[0], A[0]), jnp.where(bit, newA[1], A[1]))
        C = (jnp.where(bit, newC[0], C[0]), jnp.where(bit, newC[1], C[1]))

        cur_c = u64.mul64(u64.add64(cur_a, one), cur_c)
        cur_a = u64.mul64(cur_a, cur_a)
        return A, C, cur_a, cur_c

    A, C, _, _ = jax.lax.fori_loop(0, 64, body, (one, zero, cur_a, cur_c))
    return A, C


@functools.lru_cache(maxsize=None)
def block_affine_constants(block_len: int, a: int = MULTIPLIER,
                           c: int = DEFAULT_INCREMENT):
    """(A_t, C_t) for t in [0, block_len) as numpy uint32 arrays.

    Used by kernels to expand one scalar root state into ``block_len``
    consecutive root states with a single vector multiply-add — the TPU
    analogue of the paper's six staggered advance-6 generators.

    Returns (A_hi, A_lo, C_hi, C_lo), each shape (block_len,) uint32.
    """
    import numpy as np

    A_hi = np.empty(block_len, np.uint32)
    A_lo = np.empty(block_len, np.uint32)
    C_hi = np.empty(block_len, np.uint32)
    C_lo = np.empty(block_len, np.uint32)
    for t in range(block_len):
        A, C = lcg_skip(t, a, c)
        A_hi[t], A_lo[t] = u64.split64(A)
        C_hi[t], C_lo[t] = u64.split64(C)
    return A_hi, A_lo, C_hi, C_lo


def root_states_vector(x0: U64Pair, ctr: U64Pair, n: int,
                       block: int = 256) -> U64Pair:
    """Root states for positions ctr+1 .. ctr+n as (hi, lo) of shape (n,).

    Two-level jump-ahead (the TPU re-interpretation of the paper's staggered
    advance-6 RSGU): position t = q*block + r.  Block starts are
    jump-computed on a (Q,)-vector (one 64-iteration fori amortized over
    ``block`` elements); within a block the (A_r, C_r) tables are trace-time
    constants, so the per-element cost is a single fused multiply-add — the
    paper's shared-root-multiply, vectorized over VPU lanes.
    """
    import math

    q = -(-n // block)  # ceil
    assert block & (block - 1) == 0, "block must be a power of two"
    # base = x0 advanced by ctr (dynamic): A(ctr) x0 + C(ctr)
    A, C = lcg_skip_traced(ctr)
    base = u64.add64(u64.mul64(A, x0), C)
    # block starts: base advanced by q*block for q = 0..Q-1 (dynamic vector)
    q_idx = jnp.arange(q, dtype=U32)
    shift = int(math.log2(block))
    n_lo = q_idx << shift
    n_hi = q_idx >> (32 - shift)
    Aq, Cq = lcg_skip_traced((n_hi, n_lo))
    starts = u64.add64(u64.mul64(Aq, (jnp.broadcast_to(base[0], (q,)),
                                      jnp.broadcast_to(base[1], (q,)))), Cq)
    # within-block: states[q, r] = A_{r+1} * starts[q] + C_{r+1}
    A_hi, A_lo, C_hi, C_lo = block_affine_constants(block + 1)
    Ar = (jnp.asarray(A_hi[1:]), jnp.asarray(A_lo[1:]))  # advance by r+1
    Cr = (jnp.asarray(C_hi[1:]), jnp.asarray(C_lo[1:]))
    sh = (starts[0][:, None], starts[1][:, None])
    rh = (Ar[0][None, :], Ar[1][None, :])
    states = u64.add64(u64.mul64(rh, sh), (Cr[0][None, :], Cr[1][None, :]))
    hi = states[0].reshape(-1)[:n]
    lo = states[1].reshape(-1)[:n]
    return hi, lo


def xsh_rr(state: U64Pair) -> jnp.ndarray:
    """PCG XSH-RR output permutation (O'Neill 2014), the paper's Sec. 3.4.

    64-bit state -> 32-bit output:
      xorshifted = uint32(((state >> 18) ^ state) >> 27)
      rot        = state >> 59
      out        = ror32(xorshifted, rot)
    """
    sh, sl = state
    x = u64.xor64(u64.shr64(state, 18), state)
    xorshifted = u64.shr64(x, 27)[1]  # low 32 bits after >>27 of a 64-bit value
    rot = sh >> U32(27)  # state >> 59 == hi >> 27
    return u64.ror32(xorshifted, rot)


def truncate_hi(state: U64Pair) -> jnp.ndarray:
    """Plain truncation output (Eq. 4) — the un-permuted baseline."""
    return state[0]
