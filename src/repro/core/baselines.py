"""Baseline PRNGs the paper compares against (Table 1 / 5 / 6), in JAX.

All in u32-limb arithmetic so they run on TPU (and under Pallas interpret
mode) exactly like the ThundeRiNG path:

  * philox4x32-10  (Salmon et al. 2011)    — counter-based, crush-resistant
  * xoroshiro128** (Blackman & Vigna 2018) — sequential, crush-resistant
  * pcg_xsh_rs_64  (O'Neill 2014)          — sequential LCG + XSH-RS
  * raw_lcg        (truncation output only) — the paper's correlation
    strawman (Table 3 "LCG Baseline")

Sequential generators expose a vectorized multi-stream step (one step for S
parallel instances) plus a scan-based block generator; philox is a pure map
over counters.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import lcg, u64
from repro.core.u64 import U32, U64Pair

# ----------------------------------------------------------------------------
# Philox 4x32-10
# ----------------------------------------------------------------------------

_PHILOX_M0 = U32(0xD2511F53)
_PHILOX_M1 = U32(0xCD9E8D57)
_PHILOX_W0 = U32(0x9E3779B9)
_PHILOX_W1 = U32(0xBB67AE85)


def philox4x32(counter: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray],
               key: Tuple[jnp.ndarray, jnp.ndarray],
               rounds: int = 10):
    """Philox4x32 block: 4 uint32 outputs per (counter, key)."""
    c0, c1, c2, c3 = (c.astype(U32) for c in counter)
    k0, k1 = (k.astype(U32) for k in key)
    for _ in range(rounds):
        hi0, lo0 = u64.mul32_wide(_PHILOX_M0, c0)
        hi1, lo1 = u64.mul32_wide(_PHILOX_M1, c2)
        c0, c1, c2, c3 = hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0
        k0 = k0 + _PHILOX_W0
        k1 = k1 + _PHILOX_W1
    return c0, c1, c2, c3


def philox_bits(seed: int, num_streams: int, num_steps: int) -> jnp.ndarray:
    """(num_streams, num_steps) uint32; stream = key, step block = counter."""
    assert num_steps % 4 == 0, "philox emits 4 words per block"
    nblk = num_steps // 4
    sid = jnp.arange(num_streams, dtype=U32)[:, None]
    blk = jnp.arange(nblk, dtype=U32)[None, :]
    zeros = jnp.zeros_like(sid * blk)
    c = (blk + zeros, zeros, zeros, zeros)
    key = (sid + zeros, jnp.full_like(zeros, U32(seed & 0xFFFFFFFF)))
    o0, o1, o2, o3 = philox4x32(c, key)
    out = jnp.stack([o0, o1, o2, o3], axis=-1)
    return out.reshape(num_streams, num_steps)


# ----------------------------------------------------------------------------
# xoroshiro128**
# ----------------------------------------------------------------------------

def _rotl64(x: U64Pair, k: int) -> U64Pair:
    return u64.xor64(u64.shl64(x, k), u64.shr64(x, 64 - k))


def _rotl64_or(x: U64Pair, k: int) -> U64Pair:
    a = u64.shl64(x, k)
    b = u64.shr64(x, 64 - k)
    return a[0] | b[0], a[1] | b[1]


def xoroshiro_step(s0: U64Pair, s1: U64Pair):
    """One xoroshiro128** step -> (new_s0, new_s1, out32).

    out64 = rotl(s0 * 5, 7) * 9; we emit its high 32 bits.
    """
    five = u64.const64(5)
    nine = u64.const64(9)
    r = u64.mul64(_rotl64_or(u64.mul64(s0, five), 7), nine)
    s1x = u64.xor64(s1, s0)
    new_s0 = u64.xor64(u64.xor64(_rotl64_or(s0, 24), s1x), u64.shl64(s1x, 16))
    new_s1 = _rotl64_or(s1x, 37)
    return new_s0, new_s1, r[0]


def xoroshiro_bits(seed: int, num_streams: int, num_steps: int) -> jnp.ndarray:
    """(num_streams, num_steps) via scan; streams seeded by splitmix."""
    from repro.core import splitmix
    sid = jnp.arange(num_streams, dtype=U32)
    seed_pair = u64.const64(seed)
    s0 = splitmix.splitmix64((jnp.broadcast_to(seed_pair[0], sid.shape),
                              jnp.broadcast_to(seed_pair[1], sid.shape)),
                             (jnp.zeros_like(sid), sid))
    s1 = splitmix.splitmix64(s0, (jnp.zeros_like(sid), sid + U32(7)))

    def body(carry, _):
        s0, s1 = carry
        s0, s1, out = xoroshiro_step(s0, s1)
        return (s0, s1), out

    _, outs = jax.lax.scan(body, (s0, s1), None, length=num_steps)
    return outs.T  # (streams, steps)


# ----------------------------------------------------------------------------
# PCG XSH-RS 64/32 (multistream via odd increments)
# ----------------------------------------------------------------------------

def _shr64_dyn32(x: U64Pair, n: jnp.ndarray) -> jnp.ndarray:
    """low 32 bits of (x >> n) for dynamic 0 < n < 32."""
    hi, lo = x
    n = n.astype(U32)
    return (lo >> n) | (hi << (U32(32) - n))


def pcg_xsh_rs_out(state: U64Pair) -> jnp.ndarray:
    """XSH-RS output: uint32((state ^ (state >> 22)) >> (22 + (state >> 61)))."""
    x = u64.xor64(state, u64.shr64(state, 22))
    count = (state[0] >> U32(29)) + U32(22)  # state>>61 == hi>>29
    return _shr64_dyn32(x, count)


def pcg_xsh_rs_bits(seed: int, num_streams: int, num_steps: int) -> jnp.ndarray:
    from repro.core import splitmix
    sid = jnp.arange(num_streams, dtype=U32)
    seed_pair = u64.const64(seed)
    st = splitmix.splitmix64((jnp.broadcast_to(seed_pair[0], sid.shape),
                              jnp.broadcast_to(seed_pair[1], sid.shape)),
                             (jnp.zeros_like(sid), sid))
    # per-stream odd increment (multistream)
    inc = splitmix.splitmix64(st, (jnp.zeros_like(sid), sid ^ U32(0xDECAF)))
    inc = (inc[0], inc[1] | U32(1))
    a = u64.const64(lcg.MULTIPLIER)

    def body(carry, _):
        s = carry
        new = u64.add64(u64.mul64((jnp.broadcast_to(a[0], s[0].shape),
                                   jnp.broadcast_to(a[1], s[1].shape)), s), inc)
        return new, pcg_xsh_rs_out(s)

    _, outs = jax.lax.scan(body, st, None, length=num_steps)
    return outs.T


# ----------------------------------------------------------------------------
# Raw LCG (correlation strawman)
# ----------------------------------------------------------------------------

def raw_lcg_bits(seed: int, num_streams: int, num_steps: int,
                 permute: bool = False, h_mode: str = "adjacent"
                 ) -> jnp.ndarray:
    """Increment-parameterized LCG streams with NO decorrelation (and
    optionally no permutation): the paper's Table 3/4 ablation baselines.

    Streams share the multiplier, differ only in increment/leaf offset.

    ``h_mode``:
      * "adjacent" — h = 2i (tiny adjacent offsets).  The worst case the
        paper's Table 3 "LCG Baseline" column exhibits (Pearson ~0.998):
        truncated outputs are near-identical, and even the permuted outputs
        keep near-perfect Hamming-weight dependency (Table 4's point that
        permutation alone does not decorrelate).
      * "spread" — h derived by splitmix (even), matching ThundeRiNG's own
        offset derivation: isolates the decorrelator's contribution from h
        spacing (the Table 3 "LCG + Permutation" column regime).
    """
    from repro.core import splitmix
    x0 = u64.const64(seed | 1)
    a = u64.const64(lcg.MULTIPLIER)
    c = u64.const64(lcg.DEFAULT_INCREMENT)
    sid = jnp.arange(num_streams, dtype=U32)
    if h_mode == "adjacent":
        h = (sid >> U32(31), sid << U32(1))  # h = 2i, even
    elif h_mode == "spread":
        seed_pair = u64.const64(seed)
        mixed = splitmix.splitmix64(
            (jnp.broadcast_to(seed_pair[0], sid.shape),
             jnp.broadcast_to(seed_pair[1], sid.shape)),
            (jnp.zeros_like(sid), sid))
        h = u64.shl64(mixed, 1)  # even
    else:
        raise ValueError(h_mode)

    def body(carry, _):
        s = carry
        new = u64.add64(u64.mul64((jnp.broadcast_to(a[0], (num_streams,)),
                                   jnp.broadcast_to(a[1], (num_streams,))),
                                  (jnp.broadcast_to(s[0], (num_streams,)),
                                   jnp.broadcast_to(s[1], (num_streams,)))),
                        (jnp.broadcast_to(c[0], (num_streams,)),
                         jnp.broadcast_to(c[1], (num_streams,))))
        # all streams share the root; per-stream leaf add
        leaf = u64.add64(new, h)
        out = lcg.xsh_rr(leaf) if permute else lcg.truncate_hi(leaf)
        return (new[0][0], new[1][0]), out

    (_, _), outs = jax.lax.scan(body, (x0[0], x0[1]), None, length=num_steps)
    return outs.T
