"""Statistical randomness battery (numpy, host-side).

TestU01/PractRand are C suites we cannot link here; this module implements
the *reportable analogues* used by the paper's evaluation tables:

  Table 2 analogue — per-stream battery: monobit, byte chi-square, runs,
                     lag-k serial correlation, spectral DC check.
  Table 3 analogue — inter-stream pairwise Pearson / Spearman / Kendall.
  Table 4 analogue — Hamming-weight dependency (correlation of popcounts of
                     consecutive / cross-stream outputs).

Every function takes uint32 arrays and returns plain floats; thresholds are
chosen for the sample sizes used in tests/benchmarks (see callers).
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def to_unit(x: np.ndarray) -> np.ndarray:
    return (x.astype(np.uint64) >> np.uint64(8)).astype(np.float64) * 2.0 ** -24


def monobit_fraction(bits: np.ndarray) -> float:
    """Fraction of one-bits; ideal 0.5."""
    bits = np.ascontiguousarray(bits)
    pop = np.unpackbits(bits.view(np.uint8))
    return float(pop.mean())


def byte_chi2_pvalue(bits: np.ndarray) -> float:
    """Chi-square uniformity over byte values; returns p-value."""
    from math import lgamma

    counts = np.bincount(np.ascontiguousarray(bits).view(np.uint8),
                         minlength=256)
    n = counts.sum()
    expected = n / 256.0
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    # survival function of chi2 with 255 dof via Wilson-Hilferty approx
    k = 255.0
    z = ((chi2 / k) ** (1.0 / 3.0) - (1 - 2.0 / (9 * k))) / np.sqrt(2.0 / (9 * k))
    from math import erfc, sqrt
    return 0.5 * erfc(z / sqrt(2.0))


def runs_statistic(bits: np.ndarray) -> float:
    """Normalized runs-test z-score on the bit sequence (ideal ~0)."""
    b = np.unpackbits(np.ascontiguousarray(bits).view(np.uint8)).astype(np.int8)
    n = b.size
    pi = b.mean()
    runs = 1 + int((b[1:] != b[:-1]).sum())
    expected = 2 * n * pi * (1 - pi) + 1
    var = 2 * n * pi * (1 - pi) * (2 * n * pi * (1 - pi) - 1) / max(n - 1, 1)
    return float((runs - expected) / np.sqrt(max(var, 1e-12)))


def lag_autocorr(bits: np.ndarray, lag: int = 1) -> float:
    u = to_unit(bits)
    a = u[:-lag] - u[:-lag].mean()
    b = u[lag:] - u[lag:].mean()
    denom = np.sqrt((a * a).sum() * (b * b).sum())
    return float((a * b).sum() / max(denom, 1e-30))


def pearson(x: np.ndarray, y: np.ndarray) -> float:
    a = to_unit(x)
    b = to_unit(y)
    a -= a.mean()
    b -= b.mean()
    denom = np.sqrt((a * a).sum() * (b * b).sum())
    return float((a * b).sum() / max(denom, 1e-30))


def spearman(x: np.ndarray, y: np.ndarray) -> float:
    rx = np.argsort(np.argsort(x, kind="stable")).astype(np.float64)
    ry = np.argsort(np.argsort(y, kind="stable")).astype(np.float64)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = np.sqrt((rx * rx).sum() * (ry * ry).sum())
    return float((rx * ry).sum() / max(denom, 1e-30))


def kendall(x: np.ndarray, y: np.ndarray, max_n: int = 1500) -> float:
    """Kendall tau-a on a subsample (O(n^2))."""
    n = min(len(x), max_n)
    xs = x[:n].astype(np.int64)
    ys = y[:n].astype(np.int64)
    dx = np.sign(xs[:, None] - xs[None, :])
    dy = np.sign(ys[:, None] - ys[None, :])
    iu = np.triu_indices(n, 1)
    concordant = (dx[iu] * dy[iu]).sum()
    total = n * (n - 1) // 2
    return float(concordant / total)


def hamming_weight_dependency(bits: np.ndarray) -> float:
    """Correlation between popcounts of consecutive outputs (HWD-lite).

    The full Blackman-Vigna HWD test counts generated numbers until an
    anomaly; with fixed host budgets we instead report |corr| of adjacent
    popcounts (ideal 0; the paper's LCG-without-decorrelation shows a
    strong positive value here).
    """
    bits = np.ascontiguousarray(bits)
    pc = np.unpackbits(bits.view(np.uint8)).reshape(bits.size, 32).sum(axis=1)
    pc = pc.astype(np.float64)
    a = pc[:-1] - pc[:-1].mean()
    b = pc[1:] - pc[1:].mean()
    denom = np.sqrt((a * a).sum() * (b * b).sum())
    return float((a * b).sum() / max(denom, 1e-30))


def interleave(streams: np.ndarray) -> np.ndarray:
    """Round-robin interleave (num_streams, n) -> (num_streams*n,) — the
    inter-stream testing method of Li et al. adopted by the paper."""
    return streams.T.reshape(-1)


def intra_stream_report(bits: np.ndarray) -> Dict[str, float]:
    return {
        "monobit": monobit_fraction(bits),
        "byte_chi2_p": byte_chi2_pvalue(bits),
        "runs_z": runs_statistic(bits),
        "lag1_autocorr": lag_autocorr(bits, 1),
        "lag7_autocorr": lag_autocorr(bits, 7),
        "hwd": hamming_weight_dependency(bits),
    }


def inter_stream_report(streams: np.ndarray) -> Dict[str, float]:
    """Max pairwise stats over all stream pairs plus interleaved battery."""
    k = streams.shape[0]
    max_p = max_s = max_k = 0.0
    for i in range(k):
        for j in range(i + 1, k):
            max_p = max(max_p, abs(pearson(streams[i], streams[j])))
            max_s = max(max_s, abs(spearman(streams[i], streams[j])))
            max_k = max(max_k, abs(kendall(streams[i], streams[j])))
    inter = interleave(streams)
    rep = {"max_pearson": max_p, "max_spearman": max_s, "max_kendall": max_k,
           "interleaved_hwd": hamming_weight_dependency(inter),
           "interleaved_monobit": monobit_fraction(inter),
           "interleaved_chi2_p": byte_chi2_pvalue(inter)}
    return rep
