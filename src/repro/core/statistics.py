"""Statistical randomness battery (numpy, host-side).

TestU01/PractRand are C suites we cannot link here; this module implements
the *reportable analogues* used by the paper's evaluation tables:

  Table 2 analogue — per-stream battery: monobit, byte chi-square, runs,
                     lag-k serial correlation, spectral DC check.
  Table 3 analogue — inter-stream pairwise Pearson / Spearman / Kendall.
  Table 4 analogue — Hamming-weight dependency (correlation of popcounts of
                     consecutive / cross-stream outputs).

Every function takes uint32 arrays and returns plain floats; thresholds are
chosen for the sample sizes used in tests/benchmarks (see callers).

This module is also the home of the *p-value primitives* shared with the
Crush-lite battery (``repro.quality``): the regularized incomplete gamma
function, exact chi-square / normal / Poisson tail probabilities, and the
Kolmogorov-Smirnov uniformity aggregate used for TestU01-style two-level
testing.  numpy-only — no scipy in this container.
"""
from __future__ import annotations

import math
from typing import Dict

import numpy as np


def to_unit(x: np.ndarray) -> np.ndarray:
    return (x.astype(np.uint64) >> np.uint64(8)).astype(np.float64) * 2.0 ** -24


# ---------------------------------------------------------------------------
# p-value primitives (shared with repro.quality)
# ---------------------------------------------------------------------------

def _gammainc_series_p(a: float, x: float) -> float:
    """P(a, x) by series expansion (valid branch: x < a + 1)."""
    ap, term, total = a, 1.0 / a, 1.0 / a
    for _ in range(1000):
        ap += 1.0
        term *= x / ap
        total += term
        if abs(term) < abs(total) * 1e-16:
            break
    return min(1.0, total * math.exp(-x + a * math.log(x) - math.lgamma(a)))


def _gammainc_cf_q(a: float, x: float) -> float:
    """Q(a, x) by modified-Lentz continued fraction (branch: x >= a + 1)."""
    tiny = 1e-300
    b = x + 1.0 - a
    c = 1.0 / tiny
    d = 1.0 / b
    h = d
    for i in range(1, 1000):
        an = -i * (i - a)
        b += 2.0
        d = an * d + b
        if abs(d) < tiny:
            d = tiny
        c = b + an / c
        if abs(c) < tiny:
            c = tiny
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < 1e-16:
            break
    return min(1.0, math.exp(-x + a * math.log(x) - math.lgamma(a)) * h)


def gammainc_lower(a: float, x: float) -> float:
    """Regularized lower incomplete gamma P(a, x) (Numerical Recipes 6.2).

    Series expansion for x < a + 1, continued fraction otherwise; accurate
    to ~1e-12 over the ranges the battery uses (a up to a few thousand).
    """
    if x < 0 or a <= 0:
        raise ValueError(f"gammainc_lower needs x >= 0, a > 0; got a={a} x={x}")
    if x == 0.0:
        return 0.0
    if x < a + 1.0:
        return _gammainc_series_p(a, x)
    return max(0.0, 1.0 - _gammainc_cf_q(a, x))


def gammainc_upper(a: float, x: float) -> float:
    """Regularized upper incomplete gamma Q(a, x) = 1 - P(a, x).

    Each branch evaluates the representation that is accurate for its
    tail, so Q keeps full relative precision where P saturates at 1.
    """
    if x < a + 1.0:
        return max(0.0, 1.0 - gammainc_lower(a, x))
    return _gammainc_cf_q(a, x)


def chi2_sf(chi2: float, dof: int) -> float:
    """Exact survival function of the chi-square distribution."""
    if dof <= 0:
        raise ValueError(f"chi2_sf needs dof > 0, got {dof}")
    if chi2 <= 0.0:
        return 1.0
    return gammainc_upper(dof / 2.0, chi2 / 2.0)


def normal_sf(z: float) -> float:
    """Survival function of the standard normal, Phi(-z)."""
    return 0.5 * math.erfc(z / math.sqrt(2.0))


def poisson_cdf(k: int, lam: float) -> float:
    """P(X <= k) for X ~ Poisson(lam); Q(k+1, lam) by the gamma identity."""
    if k < 0:
        return 0.0
    return gammainc_upper(k + 1.0, lam)


def poisson_two_sided(k: int, lam: float) -> float:
    """Two-sided Poisson p-value: 2 * min(P(X <= k), P(X >= k)), clipped.

    The aggregate used for the counting tests (birthday spacings,
    collision) where the per-block statistic is a small Poisson count:
    the battery sums counts over blocks so the second level is a single
    Poisson tail instead of a KS over coarsely discrete p-values.
    """
    lo = poisson_cdf(k, lam)
    hi = 1.0 - poisson_cdf(k - 1, lam)
    return float(min(1.0, 2.0 * min(lo, hi)))


def kolmogorov_pvalue(d: float, n: int) -> float:
    """P(D_n >= d) for the one-sample KS statistic (Stephens' correction)."""
    if n <= 0:
        return 1.0
    if d <= 0.0:
        return 1.0
    rn = math.sqrt(n)
    k = (rn + 0.12 + 0.11 / rn) * d
    total = 0.0
    for j in range(1, 101):
        term = 2.0 * (-1.0) ** (j - 1) * math.exp(-2.0 * j * j * k * k)
        total += term
        if abs(term) < 1e-16:
            break
    return float(min(1.0, max(0.0, total)))


def ks_uniform_pvalue(pvalues: np.ndarray) -> float:
    """Second-level TestU01 aggregate: KS test of p-values against U(0,1).

    Given the first-level p-values of one test over many blocks/streams,
    returns the p-value of the hypothesis that they are uniform — small
    when the per-block statistics are collectively biased even if no
    single block fails outright.
    """
    p = np.sort(np.asarray(pvalues, dtype=np.float64))
    n = p.size
    if n == 0:
        return 1.0
    i = np.arange(1, n + 1, dtype=np.float64)
    d_plus = float(np.max(i / n - p))
    d_minus = float(np.max(p - (i - 1.0) / n))
    return kolmogorov_pvalue(max(d_plus, d_minus), n)


def monobit_fraction(bits: np.ndarray) -> float:
    """Fraction of one-bits; ideal 0.5."""
    bits = np.ascontiguousarray(bits)
    pop = np.unpackbits(bits.view(np.uint8))
    return float(pop.mean())


def byte_chi2_pvalue(bits: np.ndarray) -> float:
    """Chi-square uniformity over byte values; returns p-value.

    Empty input returns 1.0 (nothing to reject); short inputs are legal —
    the exact chi-square tail keeps the p-value meaningful (if weak)
    where the old Wilson-Hilferty normal approximation degraded.
    """
    bits = np.ascontiguousarray(bits)
    if bits.size == 0:
        return 1.0
    counts = np.bincount(bits.view(np.uint8), minlength=256)
    n = counts.sum()
    expected = n / 256.0
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    return chi2_sf(chi2, 255)


def runs_statistic(bits: np.ndarray) -> float:
    """Normalized runs-test z-score on the bit sequence (ideal ~0)."""
    b = np.unpackbits(np.ascontiguousarray(bits).view(np.uint8)).astype(np.int8)
    n = b.size
    pi = b.mean()
    runs = 1 + int((b[1:] != b[:-1]).sum())
    expected = 2 * n * pi * (1 - pi) + 1
    var = 2 * n * pi * (1 - pi) * (2 * n * pi * (1 - pi) - 1) / max(n - 1, 1)
    return float((runs - expected) / np.sqrt(max(var, 1e-12)))


def lag_autocorr(bits: np.ndarray, lag: int = 1) -> float:
    u = to_unit(bits)
    a = u[:-lag] - u[:-lag].mean()
    b = u[lag:] - u[lag:].mean()
    denom = np.sqrt((a * a).sum() * (b * b).sum())
    return float((a * b).sum() / max(denom, 1e-30))


def _corr(a: np.ndarray, b: np.ndarray) -> float:
    """Centered correlation with a zero-variance guard: a constant input
    carries no linear relationship, so the correlation is 0.0 (not NaN)."""
    a = a - a.mean()
    b = b - b.mean()
    denom = np.sqrt((a * a).sum() * (b * b).sum())
    if denom == 0.0:
        return 0.0
    return float((a * b).sum() / denom)


def pearson(x: np.ndarray, y: np.ndarray) -> float:
    """Pearson correlation of the unit-mapped values; 0.0 for constant
    input (zero-variance guard)."""
    return _corr(to_unit(x), to_unit(y))


def spearman(x: np.ndarray, y: np.ndarray) -> float:
    """Spearman rank correlation; 0.0 for n < 2 or constant ranks."""
    if min(len(x), len(y)) < 2:
        return 0.0
    rx = np.argsort(np.argsort(x, kind="stable")).astype(np.float64)
    ry = np.argsort(np.argsort(y, kind="stable")).astype(np.float64)
    return _corr(rx, ry)


def kendall(x: np.ndarray, y: np.ndarray, max_n: int = 1500) -> float:
    """Kendall tau-a on a subsample (O(n^2)); 0.0 for n < 2 (no pairs)."""
    n = min(len(x), len(y), max_n)
    if n < 2:
        return 0.0
    xs = x[:n].astype(np.int64)
    ys = y[:n].astype(np.int64)
    dx = np.sign(xs[:, None] - xs[None, :])
    dy = np.sign(ys[:, None] - ys[None, :])
    iu = np.triu_indices(n, 1)
    concordant = (dx[iu] * dy[iu]).sum()
    total = n * (n - 1) // 2
    return float(concordant / total)


def hamming_weight_dependency(bits: np.ndarray) -> float:
    """Correlation between popcounts of consecutive outputs (HWD-lite).

    The full Blackman-Vigna HWD test counts generated numbers until an
    anomaly; with fixed host budgets we instead report |corr| of adjacent
    popcounts (ideal 0; the paper's LCG-without-decorrelation shows a
    strong positive value here).
    """
    bits = np.ascontiguousarray(bits)
    pc = np.unpackbits(bits.view(np.uint8)).reshape(bits.size, 32).sum(axis=1)
    pc = pc.astype(np.float64)
    a = pc[:-1] - pc[:-1].mean()
    b = pc[1:] - pc[1:].mean()
    denom = np.sqrt((a * a).sum() * (b * b).sum())
    return float((a * b).sum() / max(denom, 1e-30))


def interleave(streams: np.ndarray) -> np.ndarray:
    """Round-robin interleave (num_streams, n) -> (num_streams*n,) — the
    inter-stream testing method of Li et al. adopted by the paper."""
    return streams.T.reshape(-1)


def intra_stream_report(bits: np.ndarray) -> Dict[str, float]:
    return {
        "monobit": monobit_fraction(bits),
        "byte_chi2_p": byte_chi2_pvalue(bits),
        "runs_z": runs_statistic(bits),
        "lag1_autocorr": lag_autocorr(bits, 1),
        "lag7_autocorr": lag_autocorr(bits, 7),
        "hwd": hamming_weight_dependency(bits),
    }


def inter_stream_report(streams: np.ndarray) -> Dict[str, float]:
    """Max pairwise stats over all stream pairs plus interleaved battery."""
    k = streams.shape[0]
    max_p = max_s = max_k = 0.0
    for i in range(k):
        for j in range(i + 1, k):
            max_p = max(max_p, abs(pearson(streams[i], streams[j])))
            max_s = max(max_s, abs(spearman(streams[i], streams[j])))
            max_k = max(max_k, abs(kendall(streams[i], streams[j])))
    inter = interleave(streams)
    rep = {"max_pearson": max_p, "max_spearman": max_s, "max_kendall": max_k,
           "interleaved_hwd": hamming_weight_dependency(inter),
           "interleaved_monobit": monobit_fraction(inter),
           "interleaved_chi2_p": byte_chi2_pvalue(inter)}
    return rep
