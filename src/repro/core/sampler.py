"""Sampler output stages: distribution shaping fused into generation.

ThundeRiNG's applications never spill raw random words off-chip — bits
flow through an on-chip FIFO straight into the consumer (Table 7).  The
software analogue: a ``GenPlan`` carries a *sampler* output stage and the
backends apply it where the bits live —

  * ``"ref"`` / ``"xla"``  as fused elementwise jnp on the bit block,
  * ``"pallas"``           in-VMEM inside the generation kernel, so the
                           (T, S) uint32 block never reaches HBM and a
                           bfloat16 output halves bytes/sample.

This module is the single home of the transforms, shared by all three
backends (and the fused Monte-Carlo kernels), which is what makes the
fused outputs bit/value-exact across backends: every path applies the
same jnp ops to the same bits.

Samplers (``GenPlan.sampler`` spec strings):

  "bits"          raw uint32 (default; ``out_dtype`` ignored)
  "uniform"       U[0, 1) from the top 24 bits, float32 or bfloat16
  "normal"        standard normal via Box-Muller over *adjacent row
                  pairs*: rows (2k, 2k+1) of the block supply (u1, u2)
                  and receive (r cos th, r sin th).  Requires even T.
                  u1 is clamped to the smallest positive normal float32,
                  so log(0) can never occur (open-interval guarantee).
  "bernoulli(p)"  bool mask, P(True) = p via the exact host-int
                  threshold round(p * 2**32) (the PR-1 precision rule:
                  p <= 0 / p >= 1 short-circuit to constant masks, the
                  threshold never wraps uint32).

Everything here is pure jnp over uint32/float32 and lowers both in
regular jitted JAX and inside Pallas kernel bodies; kernel callers pass
``roll=pltpu.roll`` so the pairing shuffle stays a Mosaic-native
sublane rotate.
"""
from __future__ import annotations

import re
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lcg, splitmix, u64
from repro.core.u64 import U32, U64Pair

# Smallest positive normal float32: sqrt(-2 ln TINY) ~ 13.2, finite.
TINY_F32 = np.float32(1.1754944e-38)
TWO_PI_F32 = np.float32(2.0 * np.pi)

SamplerSpec = Tuple[str, Optional[float]]

_BERNOULLI_RE = re.compile(r"^bernoulli\(([^)]+)\)$")
FLOAT_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def parse(spec: str) -> SamplerSpec:
    """Sampler spec string -> ("bits"|"uniform"|"normal"|"bernoulli", p)."""
    if spec in ("bits", "uniform", "normal"):
        return (spec, None)
    m = _BERNOULLI_RE.match(spec)
    if m:
        return ("bernoulli", float(m.group(1)))
    raise ValueError(
        f"unknown sampler {spec!r}; expected 'bits', 'uniform', 'normal' "
        f"or 'bernoulli(p)'")


def result_dtype(spec: SamplerSpec, out_dtype: str = "float32"):
    """The jnp dtype a sampler stage emits."""
    kind, _ = spec
    if kind == "bits":
        return jnp.uint32
    if kind == "bernoulli":
        return jnp.bool_
    try:
        return FLOAT_DTYPES[out_dtype]
    except KeyError:
        raise ValueError(f"unknown out_dtype {out_dtype!r}; "
                         f"have {sorted(FLOAT_DTYPES)}")


def bernoulli_threshold(p: float) -> int:
    """Exact uint32 threshold for P(bits < thresh) = p.

    Host-int arithmetic (float32 would wrap or lose low bits near p=1),
    clamped to 2**32 - 1; callers must short-circuit p <= 0 / p >= 1.
    """
    return min(int(round(float(p) * (1 << 32))), (1 << 32) - 1)


# ---------------------------------------------------------------------------
# Generation stage (shared by the ctr-mode kernels)
# ---------------------------------------------------------------------------

def ctr_bits(root: U64Pair, ctr: U64Pair, h: U64Pair,
             deco: str = "splitmix64") -> jnp.ndarray:
    """ThundeRiNG ctr-mode bits: XSH_RR(root + h) ^ deco(h, ctr).

    Operands broadcast, so (BT, 1) roots/counters against (1, BS) leaf
    offsets yield a (BT, BS) tile — the kernel-body form — while (T,)
    against scalars yields the flat form.
    """
    leaf = u64.add64(root, h)
    perm = lcg.xsh_rr(leaf)
    deco_fn = splitmix.ctr_decorrelator if deco == "splitmix64" \
        else splitmix.ctr_decorrelator32
    return perm ^ deco_fn(h, ctr)


# ---------------------------------------------------------------------------
# Output-stage transforms
# ---------------------------------------------------------------------------

def uniform_from_bits(bits: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """U[0, 1) from the top 24 bits (matches stream.uniform exactly).

    Always computed at float32 resolution; bfloat16 output is the f32
    value rounded once at the end (the bandwidth-halving cast).
    """
    u = (bits >> U32(8)).astype(jnp.float32) * np.float32(2.0 ** -24)
    return u if dtype == jnp.float32 else u.astype(dtype)


def box_muller(u1: jnp.ndarray, u2: jnp.ndarray) -> jnp.ndarray:
    """Standard normal from two U[0,1) arrays (cos branch), log(0)-safe."""
    r = jnp.sqrt(np.float32(-2.0) * jnp.log(jnp.maximum(u1, TINY_F32)))
    return r * jnp.cos(TWO_PI_F32 * u2)


def normal_pairs(u: jnp.ndarray, roll: Callable = jnp.roll,
                 barrier: bool = False) -> jnp.ndarray:
    """(T, S) standard normals from (T, S) uniforms, T even.

    Box-Muller over adjacent row pairs: rows (2k, 2k+1) supply (u1, u2)
    and receive (r cos th, r sin th) — both branches, so the output shape
    equals the input shape and no bits are wasted.  Pairing is by row
    parity, so any even-aligned tiling (Pallas bt is a multiple of 8)
    computes identical values; kernel bodies pass ``roll=pltpu.roll``.

    ``barrier=True`` pins ``u`` behind an optimization barrier (a value
    identity): without it XLA:CPU rematerializes the whole generation
    pipeline into each roll consumer's fusion, tripling the work.  The
    Pallas kernel does not need it (the tile is computed once in VMEM).
    """
    if barrier:
        u = jax.lax.optimization_barrier(u)
    even = (jax.lax.broadcasted_iota(jnp.uint32, u.shape, 0)
            & U32(1)) == U32(0)
    # up-shift expressed as a positive roll (pltpu.roll rejects negatives)
    mate = jnp.where(even, roll(u, u.shape[0] - 1, 0), roll(u, 1, 0))
    u1 = jnp.where(even, u, mate)
    u2 = jnp.where(even, mate, u)
    r = jnp.sqrt(np.float32(-2.0) * jnp.log(jnp.maximum(u1, TINY_F32)))
    theta = TWO_PI_F32 * u2
    return r * jnp.where(even, jnp.cos(theta), jnp.sin(theta))


def apply(bits: jnp.ndarray, spec: SamplerSpec, out_dtype: str = "float32",
          roll: Callable = jnp.roll, barrier: bool = False) -> jnp.ndarray:
    """Apply a parsed sampler stage to a uint32 bit block.

    The ONE transform every backend runs — outside the kernel for
    ref/xla, inside VMEM for pallas (with ``roll=pltpu.roll``).
    """
    kind, p = spec
    if kind == "bits":
        return bits
    if kind == "uniform":
        return uniform_from_bits(bits, result_dtype(spec, out_dtype))
    if kind == "normal":
        z = normal_pairs(uniform_from_bits(bits), roll=roll,
                         barrier=barrier)
        dtype = result_dtype(spec, out_dtype)
        return z if dtype == jnp.float32 else z.astype(dtype)
    if kind == "bernoulli":
        if p <= 0.0:
            return jnp.zeros(bits.shape, jnp.bool_)
        if p >= 1.0:
            return jnp.ones(bits.shape, jnp.bool_)
        return bits < U32(bernoulli_threshold(p))
    raise ValueError(f"unknown sampler kind {kind!r}")


def sublane_multiple(dtype) -> int:
    """Minimum sublane tile multiple for a Pallas out dtype (TPU tiling)."""
    if dtype == jnp.bfloat16:
        return 16
    if dtype in (jnp.bool_, jnp.int8, jnp.uint8):
        return 32
    return 8
