"""Sampler output stages: distribution shaping fused into generation.

ThundeRiNG's applications never spill raw random words off-chip — bits
flow through an on-chip FIFO straight into the consumer (Table 7).  The
software analogue: a ``GenPlan`` carries a *sampler* output stage and the
backends apply it where the bits live —

  * ``"ref"`` / ``"xla"``  as fused elementwise jnp on the bit block,
  * ``"pallas"``           in-VMEM inside the generation kernel, so the
                           (T, S) uint32 block never reaches HBM and a
                           bfloat16 output halves bytes/sample.

This module is the single home of the transforms, shared by all three
backends (and the fused Monte-Carlo kernels), which is what makes the
fused outputs bit/value-exact across backends: every path applies the
same jnp ops to the same bits.

Samplers (``GenPlan.sampler`` spec strings):

  "bits"          raw uint32 (default; ``out_dtype`` ignored)
  "uniform"       U[0, 1) from the top 24 bits, float32 or bfloat16
  "normal"        standard normal via Box-Muller over *adjacent row
                  pairs*: rows (2k, 2k+1) of the block supply (u1, u2)
                  and receive (r cos th, r sin th).  Requires even T.
                  u1 is clamped to the smallest positive normal float32,
                  so log(0) can never occur (open-interval guarantee).
  "bernoulli(p)"  bool mask, P(True) = p via the exact host-int
                  threshold round(p * 2**32) (the PR-1 precision rule:
                  p <= 0 / p >= 1 short-circuit to constant masks, the
                  threshold never wraps uint32).

Distribution stages (this PR's programmable-statistics layer — the
software answer to hardware programmable-PRNG statistics):

  "exponential(r)"    Exp(rate r) by inversion, -log(1 - u) / r.
                      1 - u >= 2**-24 > 0, so log(0) is impossible.
  "poisson(r)"        Poisson(rate r), 0 <= r <= POISSON_MAX_RATE, by
                      exact-threshold inversion: the float64 CDF is
                      rounded once to a float32 threshold ladder on the
                      host and the count is the number of thresholds at
                      or below u — one compare+add per ladder rung, no
                      transcendentals at runtime, bit-exact everywhere.
  "gumbel"            standard Gumbel by double-log inversion,
                      -log(-log(u)) with u clamped to TINY_F32, so both
                      logs see strictly positive arguments.  This is the
                      gumbel-max trick's perturbation: adding a gumbel
                      block to logits and taking the argmax samples the
                      softmax — the inference tier's in-kernel
                      bits-to-token stage (``repro.inference``).
  "gamma(k)"          Gamma(shape k >= 1, scale 1) via Marsaglia-Tsang:
                      each element gets GAMMA_RETRY_ROWS candidate
                      (normal, acceptance-uniform) draws derived from
                      its own word by salted fmix32 remixing (the
                      bounded retry-row scheme); the squeeze resolves
                      rejection in-kernel and the first accepted
                      candidate wins.  P(all rejected) < 0.05**6.
                      k == 1 short-circuits to the exact Exp(1) path.
  "gamma(k,theta)"    two-parameter sugar: the gamma(k) stage scaled by
                      theta > 0 — one extra multiply against a host-
                      rounded f32 constant, the final op of the stage
                      (it feeds no add, so no fma_guard is needed).
  "categorical[...]"  draw from weights "categorical[w0,w1,...]" via a
                      packed Walker/Vose alias table: bin = floor(u*K),
                      flip u' < thresh[bin] picks bin or alias[bin].
                      The (thresh, alias) pairs are compile-time f32
                      constants, so the table lives in VMEM with the
                      kernel and the selection is an unrolled K-way
                      where-chain (gather-free, Mosaic-safe).

Counts and category indices are emitted as float32/bfloat16 (lane-width
match with the other stages; exact integers well below 2**24).

Everything here is pure jnp over uint32/float32 and lowers both in
regular jitted JAX and inside Pallas kernel bodies; kernel callers pass
``roll=pltpu.roll`` so the pairing shuffle stays a Mosaic-native
sublane rotate.  The distribution stages are elementwise (no pairing),
so they compose with any tiling.
"""
from __future__ import annotations

import re
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lcg, splitmix, u64
from repro.core.u64 import U32, U64Pair

# Smallest positive normal float32: sqrt(-2 ln TINY) ~ 13.2, finite.
TINY_F32 = np.float32(1.1754944e-38)
TWO_PI_F32 = np.float32(2.0 * np.pi)

# Param slot: None (bits/uniform/normal/gumbel), a float (bernoulli/
# exponential/poisson/gamma) or a tuple of floats (categorical weights;
# gamma's two-parameter (shape, scale) form).  Always hashable — specs
# key functools.partial kernels and jit caches.
SamplerSpec = Tuple[str, Optional[object]]

#: The full sampler spec grammar, quoted verbatim by parse() errors.
SPEC_GRAMMAR = (
    "'bits' | 'uniform' | 'normal' | 'gumbel' | 'bernoulli(p)' | "
    "'exponential(rate)' | 'poisson(rate)' | 'gamma(shape[,scale])' "
    "| 'categorical[w0,w1,...]'")

_SCALAR_RE = re.compile(
    r"^(bernoulli|exponential|poisson|gamma)\(([^)]*)\)$")
_CATEGORICAL_RE = re.compile(r"^categorical\[([^\]]*)\]$")
FLOAT_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}

#: Inversion ladder cap: poisson(rate) must keep rate <= this so the
#: unrolled threshold ladder stays a bounded compile-time constant.
POISSON_MAX_RATE = 32.0
#: Bounded Marsaglia-Tsang retries per element; P(no accept) < 0.05**6.
GAMMA_RETRY_ROWS = 6
#: Alias tables are unrolled K-way where-chains; keep K bounded.
CATEGORICAL_MAX_OUTCOMES = 64


def _parse_float(kind: str, text: str) -> float:
    try:
        value = float(text)
    except ValueError:
        raise ValueError(
            f"unknown sampler parameter {text!r} for {kind}; "
            f"grammar: {SPEC_GRAMMAR}") from None
    if not np.isfinite(value):
        raise ValueError(f"{kind} parameter must be finite, got {text!r}")
    return value


def parse(spec: str) -> SamplerSpec:
    """Sampler spec string -> (kind, param) tuple.

    The param slot is ``None``, a float, or (categorical) a tuple of
    weights, so every parsed spec is hashable and can key jit caches.

    >>> parse("poisson(3.5)")
    ('poisson', 3.5)
    >>> parse("categorical[1, 1, 2]")
    ('categorical', (1.0, 1.0, 2.0))
    >>> parse("gamma(2.5, 0.5)")
    ('gamma', (2.5, 0.5))
    >>> parse("gamma")                 # doctest: +IGNORE_EXCEPTION_DETAIL
    Traceback (most recent call last):
    ValueError: unknown sampler 'gamma'; grammar: ...
    """
    if spec in ("bits", "uniform", "normal", "gumbel"):
        return (spec, None)
    m = _SCALAR_RE.match(spec)
    if m and m.group(1) == "gamma" and "," in m.group(2):
        k_text, _, th_text = m.group(2).partition(",")
        k = _parse_float("gamma", k_text.strip())
        theta = _parse_float("gamma", th_text.strip())
        if k < 1.0:
            raise ValueError(
                f"gamma shape must be >= 1 (Marsaglia-Tsang squeeze "
                f"needs no boost draw), got {k!r}")
        if theta <= 0.0:
            raise ValueError(f"gamma scale must be > 0, got {theta!r}")
        return ("gamma", (k, theta))
    if m:
        kind, p = m.group(1), _parse_float(m.group(1), m.group(2))
        if kind == "exponential" and p <= 0.0:
            raise ValueError(f"exponential rate must be > 0, got {p!r}")
        if kind == "poisson" and not 0.0 <= p <= POISSON_MAX_RATE:
            raise ValueError(f"poisson rate must be in [0, "
                             f"{POISSON_MAX_RATE!r}], got {p!r}")
        if kind == "gamma" and p < 1.0:
            raise ValueError(
                f"gamma shape must be >= 1 (Marsaglia-Tsang squeeze "
                f"needs no boost draw), got {p!r}")
        return (kind, p)
    m = _CATEGORICAL_RE.match(spec)
    if m:
        parts = [s.strip() for s in m.group(1).split(",") if s.strip()]
        weights = tuple(_parse_float("categorical", s) for s in parts)
        if not 1 <= len(weights) <= CATEGORICAL_MAX_OUTCOMES:
            raise ValueError(
                f"categorical needs 1..{CATEGORICAL_MAX_OUTCOMES} "
                f"weights, got {len(weights)}; grammar: {SPEC_GRAMMAR}")
        if min(weights) < 0.0 or sum(weights) <= 0.0:
            raise ValueError(
                f"categorical weights must be >= 0 with positive sum, "
                f"got {weights!r}")
        return ("categorical", weights)
    raise ValueError(f"unknown sampler {spec!r}; grammar: {SPEC_GRAMMAR}")


#: Spec kinds whose outputs are float-coded (see result_dtype).
DISTRIBUTION_KINDS = ("exponential", "poisson", "gamma", "categorical",
                      "gumbel")


def result_dtype(spec: SamplerSpec, out_dtype: str = "float32"):
    """The jnp dtype a sampler stage emits.

    >>> result_dtype(parse("poisson(2.0)"), "bfloat16") == jnp.bfloat16
    True
    """
    kind, _ = spec
    if kind == "bits":
        return jnp.uint32
    if kind == "bernoulli":
        return jnp.bool_
    try:
        return FLOAT_DTYPES[out_dtype]
    except KeyError:
        raise ValueError(f"unknown out_dtype {out_dtype!r}; "
                         f"have {sorted(FLOAT_DTYPES)}")


def bernoulli_threshold(p: float) -> int:
    """Exact uint32 threshold for P(bits < thresh) = p.

    Host-int arithmetic (float32 would wrap or lose low bits near p=1),
    clamped to 2**32 - 1; callers must short-circuit p <= 0 / p >= 1.
    """
    return min(int(round(float(p) * (1 << 32))), (1 << 32) - 1)


# ---------------------------------------------------------------------------
# Generation stage (shared by the ctr-mode kernels)
# ---------------------------------------------------------------------------

def ctr_bits(root: U64Pair, ctr: U64Pair, h: U64Pair,
             deco: str = "splitmix64") -> jnp.ndarray:
    """ThundeRiNG ctr-mode bits: XSH_RR(root + h) ^ deco(h, ctr).

    Operands broadcast, so (BT, 1) roots/counters against (1, BS) leaf
    offsets yield a (BT, BS) tile — the kernel-body form — while (T,)
    against scalars yields the flat form.
    """
    leaf = u64.add64(root, h)
    perm = lcg.xsh_rr(leaf)
    deco_fn = splitmix.ctr_decorrelator if deco == "splitmix64" \
        else splitmix.ctr_decorrelator32
    return perm ^ deco_fn(h, ctr)


# ---------------------------------------------------------------------------
# Output-stage transforms
# ---------------------------------------------------------------------------

def uniform_from_bits(bits: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """U[0, 1) from the top 24 bits (matches stream.uniform exactly).

    Always computed at float32 resolution; bfloat16 output is the f32
    value rounded once at the end (the bandwidth-halving cast).
    """
    u = (bits >> U32(8)).astype(jnp.float32) * np.float32(2.0 ** -24)
    return u if dtype == jnp.float32 else u.astype(dtype)


def box_muller(u1: jnp.ndarray, u2: jnp.ndarray) -> jnp.ndarray:
    """Standard normal from two U[0,1) arrays (cos branch), log(0)-safe."""
    r = jnp.sqrt(np.float32(-2.0) * jnp.log(jnp.maximum(u1, TINY_F32)))
    return r * jnp.cos(TWO_PI_F32 * u2)


def normal_pairs(u: jnp.ndarray, roll: Callable = jnp.roll,
                 barrier: bool = False) -> jnp.ndarray:
    """(T, S) standard normals from (T, S) uniforms, T even.

    Box-Muller over adjacent row pairs: rows (2k, 2k+1) supply (u1, u2)
    and receive (r cos th, r sin th) — both branches, so the output shape
    equals the input shape and no bits are wasted.  Pairing is by row
    parity, so any even-aligned tiling (Pallas bt is a multiple of 8)
    computes identical values; kernel bodies pass ``roll=pltpu.roll``.

    ``barrier=True`` pins ``u`` behind an optimization barrier (a value
    identity): without it XLA:CPU rematerializes the whole generation
    pipeline into each roll consumer's fusion, tripling the work.  The
    Pallas kernel does not need it (the tile is computed once in VMEM).
    """
    if barrier:
        u = jax.lax.optimization_barrier(u)
    even = (jax.lax.broadcasted_iota(jnp.uint32, u.shape, 0)
            & U32(1)) == U32(0)
    # up-shift expressed as a positive roll (pltpu.roll rejects negatives)
    mate = jnp.where(even, roll(u, u.shape[0] - 1, 0), roll(u, 1, 0))
    u1 = jnp.where(even, u, mate)
    u2 = jnp.where(even, mate, u)
    r = jnp.sqrt(np.float32(-2.0) * jnp.log(jnp.maximum(u1, TINY_F32)))
    theta = TWO_PI_F32 * u2
    return r * jnp.where(even, jnp.cos(theta), jnp.sin(theta))


def remix_bits(bits: jnp.ndarray, salt: int) -> jnp.ndarray:
    """Derived word stream #salt from a bit block: fmix32 of a
    golden-ratio-salted copy.

    This is the retry-row primitive: a distribution stage that needs
    more than one uniform per element (gamma candidates, the alias-table
    flip) remixes the element's *own* word instead of widening the
    generator footprint, so shaped outputs stay counter-addressable and
    one-word-per-sample on every backend.
    """
    return splitmix.fmix32(bits + U32((salt * 0x9E3779B9) & 0xFFFFFFFF))


def exponential_from_bits(bits: jnp.ndarray, rate: float) -> jnp.ndarray:
    """Exp(rate) float32 by inversion: x = -log(1 - u) / rate.

    ``1 - u`` is at least 2**-24, so the log argument is strictly
    positive (open-interval guarantee without clamping).  The division
    is a compile-time reciprocal, f32-rounded once on the host so all
    backends multiply by the identical constant.
    """
    u = uniform_from_bits(bits)
    return -jnp.log(np.float32(1.0) - u) * np.float32(1.0 / float(rate))


def gumbel_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Standard Gumbel float32 by double-log inversion, -log(-log(u)).

    ``u`` is clamped to the smallest positive normal float32 before the
    inner log (so it is finite) and the largest representable uniform is
    1 - 2**-24 (so the inner log is strictly negative and the outer log
    sees a positive argument): both logs are open-interval safe without
    the ad-hoc ``+ 1e-20`` epsilons of naive implementations.  The range
    is [-log(log(2**24)), log(-log(TINY_F32))] ~ [-2.81, 4.47] on the
    low side and ~16.6 at u -> TINY, all finite.  No products feed adds,
    so the transform needs no fma_guard and is bit-identical across
    batch shapes on a backend.
    """
    u = uniform_from_bits(bits)
    return -jnp.log(-jnp.log(jnp.maximum(u, TINY_F32)))


def poisson_thresholds(rate: float) -> Tuple[float, ...]:
    """Float32 CDF threshold ladder for exact-inversion Poisson(rate).

    Entry j is the float64 CDF F(j) rounded once to float32; the sampled
    count is ``sum_j [u >= F(j)]``.  The ladder stops at the first entry
    that exceeds the largest representable uniform (1 - 2**-24), past
    which no u can reach, so truncation is exact rather than approximate.

    >>> poisson_thresholds(0.0)
    ()
    >>> len(poisson_thresholds(3.5))
    18
    """
    rate = float(rate)
    if not 0.0 <= rate <= POISSON_MAX_RATE:
        raise ValueError(f"poisson rate must be in [0, {POISSON_MAX_RATE!r}]"
                         f", got {rate!r}")
    u_max = 1.0 - 2.0 ** -24
    out, pmf, cdf = [], np.exp(-rate), 0.0
    for j in range(4096):
        cdf += pmf
        t = float(np.float32(cdf))
        if t > u_max:
            break
        out.append(t)
        pmf *= rate / (j + 1)
    return tuple(out)


# Any finite float32 exceeds this, so jnp.maximum(x, _GUARD_FLOOR) is a
# value identity — but the max survives to codegen as a compare+select,
# which pins the rounded product before it reaches an add.  See
# fma_guard.
_GUARD_FLOOR = np.float32(-1e30)


def fma_guard(x: jnp.ndarray) -> jnp.ndarray:
    """Value-identity that blocks FMA contraction of a product.

    XLA:CPU compiles ``a*b + c`` to a fused multiply-add *shape-
    dependently* (the vectorized loop body contracts, the scalar tail
    may not), so the same elementwise graph can yield ULP-different
    bytes at different batch shapes — fatal for journal replay
    (``repro.service.audit``), which regenerates responses through
    differently-shaped executables, and for cross-backend bit-exactness
    (the Pallas interpreter executes op-by-op, uncontracted).
    ``optimization_barrier`` and bitcast round-trips do NOT stop the
    contraction; a ``maximum`` against a huge negative constant does —
    compares and selects are never contraction fodder — at the cost of
    one vector op.  Wrap any product that feeds an add or subtract on a
    bit-reproducibility-critical path:  ``1 + fma_guard(c * z)``.
    (Exact products — powers of two like ``0.5 * zz`` — never need the
    guard: contracting an exact product cannot change the sum.)
    """
    return jnp.maximum(x, _GUARD_FLOOR)


def gamma_mt_constants(shape: float) -> Tuple[float, float]:
    """Marsaglia-Tsang (d, c) for Gamma(shape >= 1): d = k - 1/3,
    c = 1/sqrt(9 d) (the candidate is v = 1 + c z), each rounded once
    to float32 on the host so all backends use identical constants."""
    d = float(shape) - 1.0 / 3.0
    return (float(np.float32(d)),
            float(np.float32(1.0 / np.sqrt(9.0 * d))))


def gamma_from_bits(bits: jnp.ndarray, shape: float) -> jnp.ndarray:
    """Gamma(shape >= 1, scale 1) float32 via Marsaglia-Tsang with
    bounded retry rows.

    Candidate r derives (u1, u2, u_accept) from remix_bits(bits, 3r..),
    z = box_muller(u1, u2), v = (1 + c z)**3; accept if v > 0 and the
    squeeze 1 - u > 0.0331 z**4 or log u - z**2/2 < d(1 - v**3 + 3 log v).
    The first accepting candidate wins; if all GAMMA_RETRY_ROWS reject
    (probability < 0.05**GAMMA_RETRY_ROWS) the element falls back to the
    central value d (z = 0).  Everything is elementwise, so unlike the
    "normal" stage there is no row pairing and no even-T requirement.

    Bit-reproducibility: the two products that feed adds (``c*z`` and
    ``v**3``) are pinned with ``fma_guard``; every other float op is a
    pure product feeding a compare/select, an exact power-of-two
    product, an add-chain, or a transcendental call — none of which
    XLA can contract.  The transform is therefore bit-identical across
    batch shapes and jit/eager on a given backend (what journal replay
    needs), and across ref/xla everywhere; the pallas interpreter's
    tile padding can shift ``log`` onto a different libm SIMD lane at
    some shapes, giving the same few-ULP slack as the "normal" stage.
    """
    d32, c32 = gamma_mt_constants(shape)
    d, c = np.float32(d32), np.float32(c32)
    out = jnp.full(bits.shape, d, jnp.float32)
    for r in reversed(range(GAMMA_RETRY_ROWS)):
        u1 = uniform_from_bits(remix_bits(bits, 3 * r + 1))
        u2 = uniform_from_bits(remix_bits(bits, 3 * r + 2))
        ua = uniform_from_bits(remix_bits(bits, 3 * r + 3))
        z = box_muller(u1, u2)
        v = np.float32(1.0) + fma_guard(c * z)
        lv = jnp.log(jnp.maximum(v, TINY_F32))
        lv3 = (lv + lv) + lv                    # 3 log v, mul-free
        v3 = v * v * v
        zz = z * z
        squeeze = (np.float32(1.0) - ua) > np.float32(0.0331) * zz * zz
        log_ok = (jnp.log(jnp.maximum(ua, TINY_F32))
                  - np.float32(0.5) * zz) < (
            d * ((np.float32(1.0) - fma_guard(v3)) + lv3))
        accept = (v > np.float32(0.0)) & (squeeze | log_ok)
        out = jnp.where(accept, d * v3, out)
    return out


def alias_table(weights: Tuple[float, ...]) -> Tuple[Tuple[float, int], ...]:
    """Walker/Vose alias table for categorical weights.

    Returns K packed (threshold, alias) pairs: bin j keeps its own index
    with probability ``threshold[j]`` and defers to ``alias[j]``
    otherwise.  Thresholds are float64-constructed then f32-rounded once,
    so every backend compares against identical constants.

    >>> alias_table((1.0,))
    ((1.0, 0),)
    >>> [(round(t, 4), a) for t, a in alias_table((0.5, 0.25, 0.25))]
    [(1.0, 0), (0.75, 0), (0.75, 0)]
    """
    total = float(sum(weights))
    k = len(weights)
    scaled = [w / total * k for w in weights]
    thresh, alias = [0.0] * k, [0] * k
    small = [j for j in range(k) if scaled[j] < 1.0]
    large = [j for j in range(k) if scaled[j] >= 1.0]
    while small and large:
        s, g = small.pop(), large.pop()
        thresh[s], alias[s] = scaled[s], g
        scaled[g] = (scaled[g] + scaled[s]) - 1.0
        (small if scaled[g] < 1.0 else large).append(g)
    for j in large + small:   # numerical leftovers: certainly themselves
        thresh[j], alias[j] = 1.0, j
    return tuple((float(np.float32(t)), a) for t, a in zip(thresh, alias))


def categorical_from_bits(bits: jnp.ndarray,
                          weights: Tuple[float, ...]) -> jnp.ndarray:
    """Category index (float32-coded) from a packed alias table.

    bin = floor(u K) never reaches K: the largest uniform is 1 - 2**-24,
    and K(1 - 2**-24) rounds below K for every K <= 64 (exactly K - K/2**24
    when K is a power of two, and more than half a ULP below K otherwise).
    The flip uniform comes from remix_bits so it is independent of the
    bin-selector bits.  Selection is an unrolled, gather-free where-chain
    over compile-time constants — the packed table rides in VMEM with the
    kernel body.
    """
    table = alias_table(weights)
    k = len(table)
    if k == 1:
        return jnp.zeros(bits.shape, jnp.float32)
    bin_f = jnp.floor(uniform_from_bits(bits) * np.float32(k))
    flip = uniform_from_bits(remix_bits(bits, 0))
    out = jnp.zeros(bits.shape, jnp.float32)
    for j, (t, a) in enumerate(table):
        pick = jnp.where(flip < np.float32(t), np.float32(j), np.float32(a))
        out = jnp.where(bin_f == np.float32(j), pick, out)
    return out


def apply(bits: jnp.ndarray, spec: SamplerSpec, out_dtype: str = "float32",
          roll: Callable = jnp.roll, barrier: bool = False) -> jnp.ndarray:
    """Apply a parsed sampler stage to a uint32 bit block.

    The ONE transform every backend runs — outside the kernel for
    ref/xla, inside VMEM for pallas (with ``roll=pltpu.roll``).

    >>> import numpy as np
    >>> bits = (jnp.arange(8, dtype=jnp.uint32).reshape(2, 4)
    ...         * jnp.uint32(0x9E3779B9))
    >>> x = apply(bits, parse("poisson(3.5)"))
    >>> x.dtype, bool((x >= 0).all())
    (dtype('float32'), True)
    """
    kind, p = spec
    if kind == "bits":
        return bits
    if kind == "uniform":
        return uniform_from_bits(bits, result_dtype(spec, out_dtype))
    if kind == "normal":
        z = normal_pairs(uniform_from_bits(bits), roll=roll,
                         barrier=barrier)
        dtype = result_dtype(spec, out_dtype)
        return z if dtype == jnp.float32 else z.astype(dtype)
    if kind == "bernoulli":
        if p <= 0.0:
            return jnp.zeros(bits.shape, jnp.bool_)
        if p >= 1.0:
            return jnp.ones(bits.shape, jnp.bool_)
        return bits < U32(bernoulli_threshold(p))
    if kind in DISTRIBUTION_KINDS:
        if kind == "exponential":
            x = exponential_from_bits(bits, p)
        elif kind == "poisson":
            u = uniform_from_bits(bits)
            x = jnp.zeros(bits.shape, jnp.float32)
            for t in poisson_thresholds(p):
                x = x + (u >= np.float32(t)).astype(jnp.float32)
        elif kind == "gamma":
            shape, scale = p if isinstance(p, tuple) else (p, None)
            x = exponential_from_bits(bits, 1.0) if shape == 1.0 \
                else gamma_from_bits(bits, shape)
            if scale is not None and scale != 1.0:
                # pure scale multiply: the stage's final op, feeding no
                # add — contraction-safe without a guard
                x = x * np.float32(scale)
        elif kind == "gumbel":
            x = gumbel_from_bits(bits)
        else:
            x = categorical_from_bits(bits, p)
        dtype = result_dtype(spec, out_dtype)
        return x if dtype == jnp.float32 else x.astype(dtype)
    raise ValueError(f"unknown sampler kind {kind!r}")


def sublane_multiple(dtype) -> int:
    """Minimum sublane tile multiple for a Pallas out dtype (TPU tiling)."""
    if dtype == jnp.bfloat16:
        return 16
    if dtype in (jnp.bool_, jnp.int8, jnp.uint8):
        return 32
    return 8
