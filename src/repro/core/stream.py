"""ThunderStream: the framework-facing MISRN API.

A ``ThunderStream`` is one logical random sequence out of ThundeRiNG's
stream space, identified by

  * a shared *root* LCG base state ``x0`` (from the seed — one per family,
    the paper's RSGU), and
  * a per-stream *leaf offset* ``h`` (even, unique — the paper's SOU).

Value ``t`` of stream ``h`` is::

  out_t = XSH_RR( A(t+1)*x0 + C(t+1) + h )  XOR  decorrelator(h, t)

which is exactly the paper's pipeline with the root state reached by
jump-ahead instead of sequential stepping, making every element *counter
addressable*: generation is a pure map over (stream, position) — the
property that lets masses of TPU lanes generate disjoint portions with no
communication, and makes dropout masks deterministic under any re-sharding.

The decorrelator here is the counter-based splitmix variant ("ctr mode",
see splitmix.py).  The paper-faithful serial xorshift128 decorrelator is
available through ``repro.kernels.ops`` for bulk block generation; both are
validated against the numpy golden and the statistical battery.

Derivation (``derive``/``split``) hashes tags into fresh leaf offsets,
giving a jax.random-style splittable tree over the flat MISRN space.

All state fields are uint32 scalars -> a stream is a tiny pytree that can
be carried through scans, checkpoints, and shard_map unchanged.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine, u64
from repro.core.u64 import U32


class ThunderStream(NamedTuple):
    """One ThundeRiNG stream. Fields are uint32 scalars (limb pairs).

    Example:
        >>> from repro.core import stream
        >>> s = stream.new_stream(0)
        >>> (str(s.x0_hi.dtype), int(s.ctr_lo))
        ('uint32', 0)
    """
    x0_hi: jnp.ndarray
    x0_lo: jnp.ndarray
    h_hi: jnp.ndarray
    h_lo: jnp.ndarray
    ctr_hi: jnp.ndarray
    ctr_lo: jnp.ndarray


def new_stream(seed: int, stream_id: int = 0) -> ThunderStream:
    """Create the root stream of a family from a python-int seed.

    Example:
        >>> from repro.core import stream
        >>> s = stream.new_stream(42)
        >>> int(s.ctr_lo)                 # counter starts at 0
        0
    """
    # jnp (not numpy) scalars: stream fields are pytree leaves that flow
    # through jit/scan; numpy-scalar host arithmetic would emit overflow
    # warnings (wrapping is intended).
    (x0_hi, x0_lo), (h_hi, h_lo) = engine.family_from_seed(seed, stream_id)
    zero = jnp.zeros((), U32)
    return ThunderStream(x0_hi, x0_lo, h_hi, h_lo, zero, zero)


def derive(stream: ThunderStream, tag) -> ThunderStream:
    """fold_in: child stream with a fresh (even) leaf offset; counter reset.

    ``tag`` may be a python int or a traced uint32/int32 scalar.

    Example:
        >>> from repro.core import stream
        >>> s = stream.new_stream(42)
        >>> child = stream.derive(s, 3)
        >>> int(child.h_lo) != int(s.h_lo)   # fresh leaf offset
        True
        >>> int(child.h_lo) % 2              # even (Hull-Dobell condition)
        0
    """
    if isinstance(tag, int):
        t_hi, t_lo = (u64.to_u32(v) for v in u64.const64(tag))
    else:
        t_hi = jnp.zeros((), U32)
        t_lo = jnp.asarray(tag).astype(U32)
    h_hi, h_lo = engine.derive_leaf((stream.h_hi, stream.h_lo), (t_hi, t_lo))
    zero = jnp.zeros((), U32)
    return ThunderStream(stream.x0_hi, stream.x0_lo, h_hi, h_lo, zero, zero)


def split(stream: ThunderStream, num: int) -> Sequence[ThunderStream]:
    """``num`` independent child streams (jax.random.split analogue).

    Example:
        >>> from repro.core import stream
        >>> kids = stream.split(stream.new_stream(1), 3)
        >>> len(kids)
        3
        >>> len({int(k.h_lo) for k in kids})  # distinct leaf offsets
        3
    """
    return [derive(stream, i + 0x517CC1B7) for i in range(num)]


def advance(stream: ThunderStream, count: int) -> ThunderStream:
    """Functionally advance the counter by ``count`` elements.

    Counter addressing makes advancing equal slicing:

    Example:
        >>> import numpy as np
        >>> from repro.core import stream
        >>> s = stream.new_stream(7)
        >>> a = stream.random_bits(s, (6,))
        >>> b = stream.random_bits(stream.advance(s, 2), (4,))
        >>> bool(np.array_equal(np.asarray(a)[2:], np.asarray(b)))
        True
    """
    c_hi, c_lo = u64.add64((stream.ctr_hi, stream.ctr_lo), u64.const64(count))
    return stream._replace(ctr_hi=c_hi, ctr_lo=c_lo)


# ----------------------------------------------------------------------------
# Generation
# ----------------------------------------------------------------------------

def random_bits(stream: ThunderStream, shape: Tuple[int, ...]) -> jnp.ndarray:
    """uint32 bits of the given shape, elements ctr..ctr+N-1 of the stream.

    Routed through the unified engine as a (N, 1) single-stream plan; the
    backend is auto-selected (XLA elementwise off-TPU — the arithmetic
    this function always compiled to).

    Example:
        >>> from repro.core import stream
        >>> bits = stream.random_bits(stream.new_stream(7), (4, 8))
        >>> (bits.shape, str(bits.dtype))
        ((4, 8), 'uint32')
    """
    n = int(math.prod(shape)) if shape else 1
    plan = engine.plan_for_stream(stream, n)
    return engine.generate_flat(plan).reshape(shape)


def uniforms(stream: ThunderStream, shape=(), dtype=jnp.float32
             ) -> jnp.ndarray:
    """U[0, 1) samples via the engine's fused uniform sampler stage.

    The bulk convenience API: one engine plan with ``sampler="uniform"``,
    so on TPU the uint32 bits never reach HBM and ``dtype=jnp.bfloat16``
    halves the written bytes.  Element i is the transform of stream
    element ctr + i (same bits as ``random_bits``).

    Example:
        >>> from repro.core import stream
        >>> u = stream.uniforms(stream.new_stream(7), (16,))
        >>> (u.shape, str(u.dtype))
        ((16,), 'float32')
        >>> bool((u >= 0).all()) and bool((u < 1).all())
        True
    """
    n = int(math.prod(shape)) if shape else 1
    plan = engine.plan_for_stream(stream, n, sampler="uniform",
                                  out_dtype=jnp.dtype(dtype).name)
    return engine.generate_flat(plan).reshape(shape)


def normals(stream: ThunderStream, shape=(), dtype=jnp.float32
            ) -> jnp.ndarray:
    """Standard normals via the engine's fused Box-Muller sampler stage.

    Pairs counter-adjacent elements (2k, 2k+1); for odd sample counts one
    extra element is generated and dropped (the pair tail).

    Example:
        >>> from repro.core import stream
        >>> z = stream.normals(stream.new_stream(7), (5,))   # odd N is fine
        >>> (z.shape, str(z.dtype))
        ((5,), 'float32')
    """
    n = int(math.prod(shape)) if shape else 1
    n_even = n + (n & 1)
    plan = engine.plan_for_stream(stream, n_even, sampler="normal",
                                  out_dtype=jnp.dtype(dtype).name)
    return engine.generate_flat(plan)[:n].reshape(shape)


def uniform(stream: ThunderStream, shape=(), dtype=jnp.float32,
            minval=0.0, maxval=1.0) -> jnp.ndarray:
    """U[minval, maxval) floats built from the top 24 bits.

    Example:
        >>> from repro.core import stream
        >>> u = stream.uniform(stream.new_stream(7), (8,), minval=2., maxval=3.)
        >>> bool((u >= 2).all()) and bool((u < 3).all())
        True
    """
    u = uniforms(stream, shape, jnp.float32)
    return (minval + u * (maxval - minval)).astype(dtype)


def normal(stream: ThunderStream, shape=(), dtype=jnp.float32) -> jnp.ndarray:
    """Standard normal via inverse-erf of U(-1, 1) (jax.random's method).

    Example:
        >>> from repro.core import stream
        >>> z = stream.normal(stream.new_stream(7), (4,))
        >>> (z.shape, str(z.dtype))
        ((4,), 'float32')
    """
    u = uniform(stream, shape, jnp.float32, -1.0, 1.0)
    # keep strictly inside (-1, 1)
    tiny = jnp.float32(1e-7)
    u = jnp.clip(u, -1.0 + tiny, 1.0 - tiny)
    return (jnp.sqrt(jnp.float32(2.0)) * jax.lax.erf_inv(u)).astype(dtype)


def bernoulli(stream: ThunderStream, p, shape=()) -> jnp.ndarray:
    """Boolean mask with P(True) = p, from raw 32-bit threshold compare.

    For a host-side ``p`` the threshold round(p * 2**32) is computed with
    exact python-int arithmetic (float32 would wrap or lose the low bits
    for p near 1), with p <= 0 / p >= 1 short-circuiting to constant
    masks.  A traced ``p`` is clamped to [0, 1] and converted at float32
    precision, with the endpoints still exact.

    Example:
        >>> from repro.core import stream
        >>> m = stream.bernoulli(stream.new_stream(3), 1.0, (4,))
        >>> (str(m.dtype), [bool(v) for v in m])
        ('bool', [True, True, True, True])
    """
    if isinstance(p, (bool, int, float)):
        n = int(math.prod(shape)) if shape else 1
        plan = engine.plan_for_stream(stream, n,
                                      sampler=f"bernoulli({float(p)!r})")
        return engine.generate_flat(plan).reshape(shape)
    bits = random_bits(stream, shape)
    p32 = jnp.clip(jnp.asarray(p, jnp.float32), 0.0, 1.0)
    # 4294967040 = 2**32 - 256, the largest float32 below 2**32 (a float32
    # clip bound of 2**32 - 1 would round up and wrap the uint32 cast).
    thresh = jnp.clip(p32 * jnp.float32(2.0 ** 32), 0.0,
                      jnp.float32(4294967040.0)).astype(U32)
    return jnp.where(p32 >= 1.0, True, bits < thresh)


def gumbel(stream: ThunderStream, shape=(), dtype=jnp.float32) -> jnp.ndarray:
    """Standard Gumbel samples (for gumbel-max categorical sampling).

    Example:
        >>> from repro.core import stream
        >>> g = stream.gumbel(stream.new_stream(7), (8,))
        >>> (g.shape, str(g.dtype))
        ((8,), 'float32')
    """
    u = uniform(stream, shape, jnp.float32)
    tiny = jnp.float32(1e-20)
    return (-jnp.log(-jnp.log(u + tiny) + tiny)).astype(dtype)


def categorical(stream: ThunderStream, logits: jnp.ndarray,
                axis: int = -1) -> jnp.ndarray:
    """Gumbel-max sampling along ``axis``.

    Example:
        >>> import jax.numpy as jnp
        >>> from repro.core import stream
        >>> logits = jnp.array([[0.0, 100.0, 0.0]])  # one dominant class
        >>> stream.categorical(stream.new_stream(5), logits).tolist()
        [1]
    """
    g = gumbel(stream, logits.shape, logits.dtype)
    return jnp.argmax(logits + g, axis=axis)
