from repro.runtime.blocks import (BlockProducer, BlockService, Lease,
                                  LeaseError)
from repro.runtime.fault import FaultTolerantLoop, SimulatedFailure

__all__ = ["BlockProducer", "BlockService", "FaultTolerantLoop", "Lease",
           "LeaseError", "SimulatedFailure"]
