from repro.runtime.fault import FaultTolerantLoop, SimulatedFailure

__all__ = ["FaultTolerantLoop", "SimulatedFailure"]
