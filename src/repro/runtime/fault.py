"""Fault-tolerant execution loop: checkpoint/restart, failure containment,
straggler policy — and scripted fault injection for the serving fleet.

At thousand-node scale the failure model is: some step raises (device
lost, preemption, network partition) -> the job controller restarts the
process group -> training must resume bit-exact.  The pieces here:

  * ``FaultTolerantLoop`` — wraps a step function with periodic async
    checkpoints and restart-from-latest semantics.  Because the data
    pipeline and all RNG are counter-addressed (pure functions of
    (seed, step)), resume needs NOTHING beyond (params, opt, step): no
    iterator state, no RNG state files, no replay log.
  * ``SimulatedFailure`` — deterministic fault injection for tests: raise
    at step k, prove the restarted run converges to the same states.
  * ``FaultPlan`` / ``FaultInjector`` — scripted wire-level faults for
    the RandService fleet (``repro.service.fleet``): kill / hang /
    drop-frame / slow-shard at specific request indices, either written
    out explicitly (``FaultPlan.parse("kill@512")``) or drawn from a
    seed (``FaultPlan.seeded``) so adversarial runs replay exactly.
  * Straggler policy (documented): synchronous SPMD cannot drop a slow
    worker mid-step; mitigation is (a) deterministic shards — any
    replacement host recomputes its shard from (seed, step) alone, so
    rescheduling is stateless; (b) checkpoint cadence bounds lost work;
    (c) elastic restore (checkpoint/checkpoint.py) lets the job continue
    on a SMALLER mesh (re-shard on load) rather than wait for repair.
"""
from __future__ import annotations

import dataclasses
import json
import random
import re
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.checkpoint import CheckpointManager


class SimulatedFailure(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# Scripted wire-level fault injection (the fleet's adversary)
# ---------------------------------------------------------------------------

#: fault kinds a shard can inject when the matching request arrives
FAULT_KINDS = ("kill", "hang", "drop", "slow")

_RID_DIGITS = re.compile(r"(\d+)\s*$")


def rid_index(rid: Optional[str]) -> Optional[int]:
    """Request index encoded in a rid's trailing digits (``burst/000512``
    -> 512); ``None`` when the rid carries no index.  Faults key on this
    so "kill at request 512" means the same request in every run,
    regardless of which shard the hash ring routes it to."""
    if not rid:
        return None
    m = _RID_DIGITS.search(rid)
    return int(m.group(1)) if m else None


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault: inject ``kind`` when request ``index`` reaches
    a shard (optionally only shard ``shard``).

    Kinds (what the transport layer does when the spec fires):
      * ``kill`` — ``os._exit`` before serving: SIGKILL semantics, no
        journal write for the triggering request, flock released.
      * ``hang`` — wedge the whole host: this request and every later
        one (including reconnect retries) stalls indefinitely while the
        process stays alive holding its journal flock — the
        live-but-unresponsive shard that fencing (SIGKILL + peer
        adoption) exists for.
      * ``drop`` — serve and journal the request, then close the
        connection without sending the reply frame (torn response; the
        client's retry must be answered by journal replay, bit-identically).
      * ``slow`` — sleep ``seconds`` before serving, then serve normally.
    """
    kind: str
    index: int
    shard: Optional[int] = None
    seconds: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"have {FAULT_KINDS}")
        if self.index < 0:
            raise ValueError(f"fault index must be >= 0, got {self.index}")

    def to_wire(self) -> Dict[str, Any]:
        return {"kind": self.kind, "index": self.index,
                "shard": self.shard, "seconds": self.seconds}

    @classmethod
    def from_wire(cls, d: Dict[str, Any]) -> "FaultSpec":
        return cls(kind=d["kind"], index=int(d["index"]),
                   shard=(None if d.get("shard") is None
                          else int(d["shard"])),
                   seconds=float(d.get("seconds", 0.0)))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An ordered script of :class:`FaultSpec` — the whole adversary of
    one run, serializable so the exact same faults replay in CI.

    Example:
        >>> from repro.runtime.fault import FaultPlan
        >>> plan = FaultPlan.parse("kill@512,slow@600~0.05")
        >>> [s.kind for s in plan.specs]
        ['kill', 'slow']
        >>> FaultPlan.from_json(plan.to_json()) == plan
        True
    """
    specs: Tuple[FaultSpec, ...] = ()

    def __bool__(self) -> bool:
        return bool(self.specs)

    def to_json(self) -> str:
        return json.dumps([s.to_wire() for s in self.specs],
                          sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls(specs=tuple(FaultSpec.from_wire(d)
                               for d in json.loads(text)))

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Mini-DSL: comma-separated ``kind@index[#shard][~seconds]``
        (e.g. ``"kill@512"``, ``"hang@40#1~30"``).  An empty string is
        the empty plan; a string starting with ``[`` is taken as the
        JSON form (what ``--fault-plan`` accepts either way)."""
        text = text.strip()
        if not text:
            return cls()
        if text.startswith("["):
            return cls.from_json(text)
        specs: List[FaultSpec] = []
        for part in text.split(","):
            part = part.strip()
            kind, _, rest = part.partition("@")
            if not rest:
                raise ValueError(f"fault spec {part!r} needs kind@index")
            seconds = 0.0
            if "~" in rest:
                rest, _, sec = rest.partition("~")
                seconds = float(sec)
            shard: Optional[int] = None
            if "#" in rest:
                rest, _, sh = rest.partition("#")
                shard = int(sh)
            specs.append(FaultSpec(kind=kind.strip(), index=int(rest),
                                   shard=shard, seconds=seconds))
        return cls(specs=tuple(specs))

    @classmethod
    def seeded(cls, seed: int, *, burst: int,
               kinds: Tuple[str, ...] = ("kill",), count: int = 1,
               seconds: float = 0.05, lo_frac: float = 0.25,
               hi_frac: float = 0.75) -> "FaultPlan":
        """A replayable random adversary: ``count`` faults of ``kinds``
        at distinct request indices drawn from the middle of a
        ``burst``-request run — a pure function of ``seed``."""
        rng = random.Random(seed ^ 0xFA17)
        lo = int(burst * lo_frac)
        hi = max(lo + 1, int(burst * hi_frac))
        idxs = rng.sample(range(lo, hi), min(count, hi - lo))
        return cls(specs=tuple(
            FaultSpec(kind=rng.choice(list(kinds)), index=i,
                      seconds=seconds)
            for i in sorted(idxs)))


class FaultInjector:
    """Stateful per-process trigger for a :class:`FaultPlan`.

    ``fire(shard, index)`` returns the first not-yet-fired spec matching
    ``(shard, index)`` and marks it fired — each scripted fault happens
    exactly once, so a retried request (same rid, hence same index)
    sails through on its second arrival.  Thread-safe: connection
    handler threads all consult one injector.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._fired: set = set()
        self._lock = threading.Lock()

    def fire(self, shard: int, index: Optional[int]) -> Optional[FaultSpec]:
        if index is None:
            return None
        with self._lock:
            for i, spec in enumerate(self.plan.specs):
                if i in self._fired:
                    continue
                if spec.index != index:
                    continue
                if spec.shard is not None and spec.shard != shard:
                    continue
                self._fired.add(i)
                return spec
        return None


@dataclasses.dataclass
class FaultTolerantLoop:
    ckpt: CheckpointManager
    save_every: int = 50
    max_restarts: int = 3

    def run(self, *, init_state: Callable[[], Any], step_fn, num_steps: int,
            fail_at: Optional[int] = None,
            on_metrics=None,
            extra_state: Optional[Callable[[], Dict[str, Any]]] = None,
            on_restore: Optional[Callable[[Optional[Dict[str, Any]], int],
                                          None]] = None) -> Any:
        """Run ``num_steps`` with checkpoint/restart.

        ``init_state()`` -> (params, opt_state); ``step_fn(params, opt,
        step)`` -> (params, opt, metrics).  ``fail_at``: inject a
        SimulatedFailure the first time that step is reached (tests).

        ``extra_state()`` -> JSON-able dict saved with every checkpoint
        (e.g. the BlockService lease ledger); ``on_restore(extra, step)``
        is called once per (re)start BEFORE stepping — with the restored
        extra dict, or ``None`` on a from-scratch start — so runtime
        state outside (params, opt) rewinds with the model.
        """
        restarts = 0
        failed_once = False
        while True:
            try:
                state, start, extra = self._restore_or_init(init_state)
                params, opt_state = state
                if on_restore is not None:
                    on_restore(extra, start)
                for step in range(start, num_steps):
                    if fail_at is not None and step == fail_at \
                            and not failed_once:
                        failed_once = True
                        raise SimulatedFailure(f"injected at step {step}")
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         step)
                    if on_metrics is not None:
                        on_metrics(step, metrics)
                    done = step + 1
                    if done % self.save_every == 0 or done == num_steps:
                        self.ckpt.save(done, {"params": params,
                                              "opt": _opt_to_tree(opt_state)},
                                       extra=extra_state() if extra_state
                                       else None)
                self.ckpt.wait()
                return params, opt_state
            except SimulatedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                # controller restarts us; loop resumes from latest ckpt

    def _restore_or_init(self, init_state):
        latest = self.ckpt.latest()
        if latest is None:
            return init_state(), 0, None
        tree, step, extra = self.ckpt.restore()
        params = tree["params"]
        opt_state = _opt_from_tree(tree["opt"])
        return (params, opt_state), step, extra


def _opt_to_tree(opt_state) -> Dict[str, Any]:
    return {"step": opt_state.step, "m": opt_state.m, "v": opt_state.v}


def _opt_from_tree(tree):
    from repro.optim.adamw import AdamWState
    import jax.numpy as jnp
    step = jnp.asarray(tree["step"])
    if step.ndim:
        step = step.reshape(())
    return AdamWState(step.astype(jnp.int32), tree["m"], tree["v"])
