"""Fault-tolerant execution loop: checkpoint/restart, failure containment,
straggler policy.

At thousand-node scale the failure model is: some step raises (device
lost, preemption, network partition) -> the job controller restarts the
process group -> training must resume bit-exact.  The pieces here:

  * ``FaultTolerantLoop`` — wraps a step function with periodic async
    checkpoints and restart-from-latest semantics.  Because the data
    pipeline and all RNG are counter-addressed (pure functions of
    (seed, step)), resume needs NOTHING beyond (params, opt, step): no
    iterator state, no RNG state files, no replay log.
  * ``SimulatedFailure`` — deterministic fault injection for tests: raise
    at step k, prove the restarted run converges to the same states.
  * Straggler policy (documented): synchronous SPMD cannot drop a slow
    worker mid-step; mitigation is (a) deterministic shards — any
    replacement host recomputes its shard from (seed, step) alone, so
    rescheduling is stateless; (b) checkpoint cadence bounds lost work;
    (c) elastic restore (checkpoint/checkpoint.py) lets the job continue
    on a SMALLER mesh (re-shard on load) rather than wait for repair.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax

from repro.checkpoint import CheckpointManager


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FaultTolerantLoop:
    ckpt: CheckpointManager
    save_every: int = 50
    max_restarts: int = 3

    def run(self, *, init_state: Callable[[], Any], step_fn, num_steps: int,
            fail_at: Optional[int] = None,
            on_metrics=None,
            extra_state: Optional[Callable[[], Dict[str, Any]]] = None,
            on_restore: Optional[Callable[[Optional[Dict[str, Any]], int],
                                          None]] = None) -> Any:
        """Run ``num_steps`` with checkpoint/restart.

        ``init_state()`` -> (params, opt_state); ``step_fn(params, opt,
        step)`` -> (params, opt, metrics).  ``fail_at``: inject a
        SimulatedFailure the first time that step is reached (tests).

        ``extra_state()`` -> JSON-able dict saved with every checkpoint
        (e.g. the BlockService lease ledger); ``on_restore(extra, step)``
        is called once per (re)start BEFORE stepping — with the restored
        extra dict, or ``None`` on a from-scratch start — so runtime
        state outside (params, opt) rewinds with the model.
        """
        restarts = 0
        failed_once = False
        while True:
            try:
                state, start, extra = self._restore_or_init(init_state)
                params, opt_state = state
                if on_restore is not None:
                    on_restore(extra, start)
                for step in range(start, num_steps):
                    if fail_at is not None and step == fail_at \
                            and not failed_once:
                        failed_once = True
                        raise SimulatedFailure(f"injected at step {step}")
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         step)
                    if on_metrics is not None:
                        on_metrics(step, metrics)
                    done = step + 1
                    if done % self.save_every == 0 or done == num_steps:
                        self.ckpt.save(done, {"params": params,
                                              "opt": _opt_to_tree(opt_state)},
                                       extra=extra_state() if extra_state
                                       else None)
                self.ckpt.wait()
                return params, opt_state
            except SimulatedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                # controller restarts us; loop resumes from latest ckpt

    def _restore_or_init(self, init_state):
        latest = self.ckpt.latest()
        if latest is None:
            return init_state(), 0, None
        tree, step, extra = self.ckpt.restore()
        params = tree["params"]
        opt_state = _opt_from_tree(tree["opt"])
        return (params, opt_state), step, extra


def _opt_to_tree(opt_state) -> Dict[str, Any]:
    return {"step": opt_state.step, "m": opt_state.m, "v": opt_state.v}


def _opt_from_tree(tree):
    from repro.optim.adamw import AdamWState
    import jax.numpy as jnp
    step = jnp.asarray(tree["step"])
    if step.ndim:
        step = step.reshape(())
    return AdamWState(step.astype(jnp.int32), tree["m"], tree["v"])
