"""Block delivery: leased counter windows + double-buffered producers.

The paper's deployment story is not "call the generator" — it is a
standing producer streaming decorrelated blocks through on-chip FIFOs
into application kernels, while SOU instances scale with zero extra
root hardware.  ``BlockService`` is the software analogue of that
delivery layer, sitting ABOVE the engine:

  * **Counter-window leases.**  Every consumer (data pipeline, dropout,
    MC apps, serving sampler) names a *channel* (one MISRN family of the
    service seed) and receives disjoint, checkpointable
    ``(ctr_lo, ctr_hi)`` windows of its counter space.  Double-spending
    randomness becomes structurally impossible — an overlapping lease
    raises ``LeaseError`` — instead of a calling convention.
  * **A two-phase ledger.**  ``lease()`` *reserves* a window (in-memory
    only); ``commit()`` moves it into the durable ledger.
    ``ledger_state()`` snapshots committed windows only, so a snapshot
    taken mid-run describes exactly the randomness consumed so far;
    ``restore_ledger()`` rewinds to a snapshot (dropping reservations),
    after which re-leasing replays the SAME windows — bit-identical
    resume falls out of the accounting.
  * **Double-buffered generation.**  ``producer()`` runs a daemon thread
    that leases window ``k+1`` and *dispatches* its generation while the
    consumer still holds block ``k`` — JAX's async dispatch makes the
    handoff ``block_until_ready``-free: the thread enqueues device work
    and puts the (not yet materialized) array in a depth-bounded queue,
    the software analogue of the paper's FIFO into the application.

Generation itself is one jitted window function per (channel, length,
sampler) with a TRACED counter, so successive leases of equal length
re-use one executable (no per-window retrace) — this covers every
sampler stage including the distribution stages (exponential/poisson/
gamma/categorical), whose parsed specs are hashable compile-time
constants — and the service's mesh —
including the 2-D ``(hosts, streams)`` fan-out of
``engine.generate_sharded`` — rides inside the jit.

Layering: ``runtime`` sits above ``core`` and ``kernels``; nothing in
``core``/``kernels`` imports this module.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import hashlib
import queue
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine, sampler as sampler_mod, stream as tstream, u64

_M64 = (1 << 64) - 1


@functools.lru_cache(maxsize=None)
def donation_supported() -> bool:
    """True if ``jit(..., donate_argnums=...)`` actually aliases here.

    Empirical, not a platform table: donate a buffer into a jitted
    full-overwrite and see whether the runtime deleted the input.  On
    platforms where donation is a no-op jax only warns, the input stays
    live, and the donated producer ring would silently degrade to fresh
    allocations — callers use this to skip/flag rather than pretend.
    """
    import warnings
    probe = jax.jit(
        lambda x: jax.lax.dynamic_update_slice(x, x + jnp.uint32(1), (0,)),
        donate_argnums=(0,))
    x = jnp.zeros((8,), jnp.uint32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        probe(x).block_until_ready()
    return x.is_deleted()


class LeaseError(ValueError):
    """A lease request overlaps randomness that is already spoken for."""


def channel_purpose(name: str) -> int:
    """Deterministic 64-bit purpose tag for a channel name (stable across
    processes — the ledger must mean the same windows after a restart)."""
    return int.from_bytes(
        hashlib.blake2s(name.encode(), digest_size=8).digest(), "little")


# ---------------------------------------------------------------------------
# Lease + ledger
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Lease:
    """One disjoint counter window ``[lo, hi)`` of a channel.

    Units are whatever the channel's window function counts: engine
    plan channels count counter steps along the T axis (x ``num_streams``
    elements per step); the data-pipeline channel counts optimizer steps.

    Example:
        >>> from repro.runtime.blocks import BlockService
        >>> svc = BlockService(seed=11)
        >>> _ = svc.open("docs/demo", num_streams=2)
        >>> lease = svc.lease("docs/demo", 4)
        >>> (lease.lo, lease.hi, lease.length)
        (0, 4, 4)
        >>> lease.commit()                     # window becomes durable
        >>> svc.lease("docs/demo", 4).lo       # next window is disjoint
        4
    """
    channel: str
    lo: int
    hi: int
    service: "BlockService" = dataclasses.field(repr=False, compare=False)

    @property
    def length(self) -> int:
        return self.hi - self.lo

    def plan(self, **overrides) -> engine.GenPlan:
        """The engine plan for this window (plan channels only)."""
        return self.service.plan_for(self, **overrides)

    def stream(self, column: int = 0) -> tstream.ThunderStream:
        """ThunderStream for one column of the window, advanced to ``lo``.

        Bit-parity with the bulk block is the engine's shared-derivation
        guarantee: ``random_bits(lease.stream(s), (L,))`` equals column
        ``s`` of ``service.generate(lease)`` for a bits channel.
        """
        return self.service.stream_for(self, column)

    def commit(self) -> None:
        self.service.commit(self)

    def release(self) -> None:
        self.service.release(self)


class _Ledger:
    """Disjoint-interval bookkeeping for one channel.

    ``committed`` is a sorted list of disjoint ``[lo, hi)`` windows
    (adjacent windows merge); ``reserved`` holds in-flight leases.  The
    sequential high-water ``next`` is ``max(floor, every hi)`` so plain
    ``lease(n)`` calls hand out consecutive windows.
    """

    def __init__(self) -> None:
        self.committed: List[Tuple[int, int]] = []
        self.reserved: List[Tuple[int, int]] = []
        self.floor = 0

    @property
    def next(self) -> int:
        hi = self.floor
        if self.committed:
            hi = max(hi, self.committed[-1][1])
        for _, h in self.reserved:
            hi = max(hi, h)
        return hi

    def _overlaps(self, lo: int, hi: int) -> Optional[Tuple[int, int]]:
        i = bisect.bisect_left(self.committed, (lo, lo)) - 1
        for j in (i, i + 1):
            if 0 <= j < len(self.committed):
                clo, chi = self.committed[j]
                if clo < hi and lo < chi:
                    return (clo, chi)
        for rlo, rhi in self.reserved:
            if rlo < hi and lo < rhi:
                return (rlo, rhi)
        return None

    def reserve(self, lo: int, hi: int) -> None:
        if lo < self.floor:
            raise LeaseError(
                f"window [{lo}, {hi}) starts below the fenced floor "
                f"{self.floor} (counters below the floor may already "
                f"have been served by a previous owner)")
        clash = self._overlaps(lo, hi)
        if clash is not None:
            raise LeaseError(
                f"window [{lo}, {hi}) overlaps existing lease "
                f"[{clash[0]}, {clash[1]})")
        self.reserved.append((lo, hi))

    def commit(self, lo: int, hi: int) -> None:
        try:
            self.reserved.remove((lo, hi))
        except ValueError:
            raise LeaseError(f"window [{lo}, {hi}) is not reserved")
        bisect.insort(self.committed, (lo, hi))
        # merge touching neighbours (overlap is impossible by reserve())
        merged: List[Tuple[int, int]] = []
        for w in self.committed:
            if merged and merged[-1][1] >= w[0]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], w[1]))
            else:
                merged.append(w)
        self.committed = merged

    def release(self, lo: int, hi: int) -> None:
        try:
            self.reserved.remove((lo, hi))
        except ValueError:
            raise LeaseError(f"window [{lo}, {hi}) is not reserved")

    def state(self) -> Dict[str, Any]:
        return {"committed": [[lo, hi] for lo, hi in self.committed],
                "floor": self.floor}

    @classmethod
    def from_state(cls, state: Dict[str, Any]) -> "_Ledger":
        led = cls()
        led.committed = sorted((int(lo), int(hi))
                               for lo, hi in state.get("committed", []))
        led.floor = int(state.get("floor", 0))
        return led


# ---------------------------------------------------------------------------
# Channels
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Channel:
    """One named consumer of the service's MISRN space.

    A *plan channel* (``window_fn is None``) generates ``(L, S)`` engine
    blocks for each leased window; a *custom channel* delegates to
    ``window_fn(lo, hi)`` (e.g. the data pipeline's batch function) and
    uses the ledger for accounting only.
    """
    name: str
    purpose: int
    num_streams: int = 1
    mode: str = "ctr"
    deco: str = "splitmix64"
    sampler: str = "bits"
    out_dtype: str = "float32"
    window_fn: Optional[Callable[[int, int], Any]] = None


class BlockService:
    """Leased-window block delivery over one seed's MISRN stream space.

    ``mesh``/``axis_names`` route every plan-channel window through
    ``engine.generate_sharded`` — 1-D or the 2-D ``(hosts, streams)``
    fan-out — with the root state replicated and zero collectives, so
    adding devices to the service is the paper's "add SOU instances"
    move.  Without a mesh, plans go through ``engine.generate`` with the
    service's backend override (auto-selected when None).

    Example:
        >>> from repro.runtime.blocks import BlockService
        >>> svc = BlockService(seed=11)
        >>> _ = svc.open("docs/demo", num_streams=4)
        >>> blk = svc.take("docs/demo", 8)     # lease + generate + commit
        >>> (blk.shape, str(blk.dtype))
        ((8, 4), 'uint32')
        >>> svc.ledger_state()["channels"]["docs/demo"]["committed"]
        [[0, 8]]
    """

    def __init__(self, seed: int = 0, *,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 axis_names: Optional[Tuple[str, ...]] = None,
                 backend: Optional[str] = None,
                 block_t: int = engine.DEFAULT_BLOCK_T,
                 block_s: int = engine.DEFAULT_BLOCK_S):
        self.seed = seed
        self.mesh = mesh
        self.axis_names = (tuple(axis_names) if axis_names is not None
                           else (tuple(mesh.axis_names) if mesh is not None
                                 else None))
        self.backend = backend
        self.block_t = block_t
        self.block_s = block_s
        self._channels: Dict[str, Channel] = {}
        self._ledgers: Dict[str, _Ledger] = {}
        self._window_fns: Dict[Tuple, Callable] = {}
        self._lock = threading.Lock()

    # -- channels ----------------------------------------------------------

    def open(self, name: str, *, num_streams: int = 1,
             purpose: Optional[int] = None, mode: str = "ctr",
             deco: str = "splitmix64", sampler: str = "bits",
             out_dtype: str = "float32",
             window_fn: Optional[Callable[[int, int], Any]] = None
             ) -> Channel:
        """Open (or return the already-open) channel ``name``."""
        with self._lock:
            if name in self._channels:
                return self._channels[name]
            ch = Channel(name=name,
                         purpose=(channel_purpose(name) if purpose is None
                                  else purpose),
                         num_streams=num_streams, mode=mode, deco=deco,
                         sampler=sampler, out_dtype=out_dtype,
                         window_fn=window_fn)
            self._channels[name] = ch
            self._ledgers.setdefault(name, _Ledger())
            return ch

    def channel(self, name: str) -> Channel:
        return self._channels[name]

    # -- leases ------------------------------------------------------------

    def lease(self, name: str, length: int, *,
              at: Optional[int] = None) -> Lease:
        """Reserve the next (or an explicit) disjoint window of a channel.

        ``at=None`` takes ``length`` units at the channel's high-water
        mark; an explicit ``at`` claims ``[at, at + length)`` and raises
        ``LeaseError`` if ANY part of it is already reserved or
        committed.
        """
        if length <= 0:
            raise ValueError(f"lease length must be positive, got {length}")
        if name not in self._channels:
            raise KeyError(f"channel {name!r} is not open; "
                           f"have {sorted(self._channels)}")
        with self._lock:
            led = self._ledgers[name]
            lo = led.next if at is None else int(at)
            hi = lo + length
            if hi > _M64:
                raise LeaseError(f"window [{lo}, {hi}) exceeds the u64 "
                                 f"counter space")
            led.reserve(lo, hi)
        return Lease(channel=name, lo=lo, hi=hi, service=self)

    def lease_many(self, name: str, length: int, n: int, *,
                   at: Optional[int] = None) -> List[Lease]:
        """``n`` CONTIGUOUS equal-length windows, reserved atomically.

        All-or-nothing under one lock acquisition: either every window
        ``[lo0 + i*length, lo0 + (i+1)*length)`` is reserved or none is
        (an explicit ``at`` that clashes partway rolls back and raises).
        This is the fused producer's lease shape — one
        ``generate_windows`` dispatch covers all ``n`` windows, but each
        window keeps its own lease so commit-at-handoff accounting stays
        per-block exact.
        """
        if n < 1:
            raise ValueError(f"n must be >= 1, got {n}")
        if length <= 0:
            raise ValueError(f"lease length must be positive, got {length}")
        if name not in self._channels:
            raise KeyError(f"channel {name!r} is not open; "
                           f"have {sorted(self._channels)}")
        with self._lock:
            led = self._ledgers[name]
            lo0 = led.next if at is None else int(at)
            if lo0 + n * length > _M64:
                raise LeaseError(f"window [{lo0}, {lo0 + n * length}) "
                                 f"exceeds the u64 counter space")
            done: List[Tuple[int, int]] = []
            try:
                for i in range(n):
                    lo = lo0 + i * length
                    led.reserve(lo, lo + length)
                    done.append((lo, lo + length))
            except LeaseError:
                for lo, hi in done:
                    led.release(lo, hi)
                raise
        return [Lease(channel=name, lo=lo, hi=hi, service=self)
                for lo, hi in done]

    def commit(self, lease: Lease) -> None:
        """Move a reserved window into the durable (checkpointable) ledger."""
        with self._lock:
            self._ledgers[lease.channel].commit(lease.lo, lease.hi)

    def release(self, lease) -> None:
        """Drop an unconsumed reservation — or retire a whole channel.

        With a :class:`Lease`, drops that reservation (its window may be
        re-leased).  With a channel NAME (str), retires the channel —
        the slot-churn primitive the inference tier's slot pool uses
        when a sequence finishes:

          * the channel's lease floor is fenced at its current
            high-water mark, so when a later occupant re-opens the same
            name (``open`` preserves the ledger of a retired channel)
            every window it leases is strictly beyond anything the
            previous occupant consumed — a retired-and-reused region can
            never overlap a lease that was ever live;
          * the ``Channel`` entry and its cached window executables are
            dropped, so churn over many short-lived consumers does not
            grow the channel table or the jit cache without bound;
          * outstanding reservations refuse the retire (``LeaseError``)
            — a live producer must be closed before its channel dies.
        """
        if isinstance(lease, str):
            return self._release_channel(lease)
        with self._lock:
            self._ledgers[lease.channel].release(lease.lo, lease.hi)

    def _release_channel(self, name: str) -> int:
        with self._lock:
            if name not in self._channels:
                raise KeyError(f"channel {name!r} is not open; "
                               f"have {sorted(self._channels)}")
            led = self._ledgers[name]
            if led.reserved:
                raise LeaseError(
                    f"channel {name!r} has {len(led.reserved)} live "
                    f"reservation(s); close its producers before release")
            led.floor = led.next
            del self._channels[name]
            for key in [k for k in self._window_fns if k[0] == name]:
                del self._window_fns[key]
            return led.floor

    # -- ledger checkpointing ---------------------------------------------

    def ledger_state(self) -> Dict[str, Any]:
        """JSON-able snapshot of COMMITTED windows per channel.

        Reservations are deliberately excluded: a snapshot describes the
        randomness actually handed to consumers, so restoring it and
        re-leasing replays in-flight windows bit-identically.
        """
        with self._lock:
            return {"channels": {name: led.state()
                                 for name, led in self._ledgers.items()}}

    def restore_ledger(self, state: Optional[Dict[str, Any]]) -> None:
        """Rewind the ledger to a snapshot (or clear it with ``None``/{}).

        All reservations vanish — producers running at snapshot-restore
        time must be closed first (``BlockProducer.close``).
        """
        chans = (state or {}).get("channels", {})
        with self._lock:
            self._ledgers = {name: _Ledger.from_state(s)
                             for name, s in chans.items()}
            for name in self._channels:
                self._ledgers.setdefault(name, _Ledger())

    def fence(self, name: str, floor: int) -> int:
        """Raise channel ``name``'s lease floor to at least ``floor``.

        Every future lease — including an explicit ``lease(at=...)``
        into a gap between old committed windows — starts at or past
        the floor.  This is the failover primitive: a peer adopting a
        dead shard's journal fences each channel at its journaled
        high-water mark, so no counter the dead shard *might* have
        handed out can ever be re-leased.  Returns the new floor.
        """
        with self._lock:
            led = self._ledgers.setdefault(name, _Ledger())
            led.floor = max(led.floor, int(floor))
            return led.floor

    # -- generation --------------------------------------------------------

    def plan_for(self, lease: Lease, *, sampler: Optional[str] = None,
                 out_dtype: Optional[str] = None) -> engine.GenPlan:
        """Static-offset engine plan for a leased window (plan channels)."""
        ch = self._channels[lease.channel]
        if ch.window_fn is not None:
            raise ValueError(f"channel {lease.channel!r} has a custom "
                             f"window_fn; it has no engine plan")
        return engine.make_plan(
            seed=self.seed, num_streams=ch.num_streams,
            num_steps=lease.length, offset=lease.lo, purpose=ch.purpose,
            mode=ch.mode, deco=ch.deco,
            sampler=ch.sampler if sampler is None else sampler,
            out_dtype=ch.out_dtype if out_dtype is None else out_dtype)

    def stream_for(self, lease: Lease, column: int = 0
                   ) -> tstream.ThunderStream:
        ch = self._channels[lease.channel]
        fam = tstream.new_stream(self.seed, ch.purpose)
        return tstream.advance(tstream.derive(fam, column), lease.lo)

    def _window_fn(self, ch: Channel, length: int, sampler: str,
                   out_dtype: str, *, fuse: int = 1,
                   donate: bool = False) -> Callable:
        """One jitted window executable per (channel, shape, variant).

        The counter is TRACED (plan.offset=None), so every equal-length
        lease of a channel reuses one executable; traced and static
        counters are bit-identical by the engine's parity tests.

        Variants (cache-keyed alongside the shape):

          * ``fuse=1, donate=False`` — ``fn(hi, lo) -> (L, S)``.
          * ``fuse=W``               — ``fn(hi, lo) -> (W, L, S)``, one
            ``engine.generate_windows`` dispatch for W windows.
          * ``donate=True``          — ``fn(hi, lo, retired)`` with
            ``donate_argnums=(2,)``: the retiring block is overwritten
            in place (``dynamic_update_slice`` over the full shape, so
            the values are exactly the fresh block's) and XLA reuses its
            allocation instead of allocating per window.  The donated
            arg MUST participate in the computation or XLA prunes it
            and silently drops the aliasing — hence update, not ignore.

        ``fuse>1``/``donate`` require ``mesh=None`` (the sharded path
        manages its own output layout).
        """
        fuse = int(fuse)
        if fuse < 1:
            raise ValueError(f"fuse must be >= 1, got {fuse}")
        if (fuse > 1 or donate) and self.mesh is not None:
            raise ValueError("fused/donated window functions require "
                             "mesh=None; sharded delivery manages its own "
                             "output buffers")
        key = (ch.name, length, sampler, out_dtype, fuse, donate)
        fn = self._window_fns.get(key)
        if fn is not None:
            return fn
        x0, h_fam = engine.family_from_seed(self.seed, ch.purpose)
        h = engine.leaf_table(h_fam, ch.num_streams)
        mesh, axes, backend = self.mesh, self.axis_names, self.backend
        block_t, block_s = self.block_t, self.block_s
        mode, deco = ch.mode, ch.deco

        def compute(ctr_hi, ctr_lo):
            plan = engine.GenPlan(
                x0=x0, h=h, num_steps=length, ctr=(ctr_hi, ctr_lo),
                offset=None, mode=mode, deco=deco, sampler=sampler,
                out_dtype=out_dtype)
            if fuse > 1:
                return engine.generate_windows(
                    plan, fuse, backend=backend, block_t=block_t,
                    block_s=block_s)
            if mesh is not None:
                return engine.generate_sharded(
                    plan, mesh=mesh, axis_names=axes, backend=backend,
                    block_t=block_t, block_s=block_s)
            return engine.generate(plan, backend=backend, block_t=block_t,
                                   block_s=block_s)

        if donate:
            @functools.partial(jax.jit, donate_argnums=(2,))
            def window(ctr_hi, ctr_lo, retired):
                block = compute(ctr_hi, ctr_lo)
                return jax.lax.dynamic_update_slice(
                    retired, block, (0,) * block.ndim)
        else:
            window = jax.jit(compute)

        self._window_fns[key] = window
        return window

    def _ctr_args(self, lo: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        c_hi, c_lo = (u64.to_u32(v) for v in u64.const64(lo))
        return jnp.asarray(c_hi), jnp.asarray(c_lo)

    def generate(self, lease: Lease, *, sampler: Optional[str] = None,
                 out_dtype: Optional[str] = None,
                 retired: Any = None) -> Any:
        """The block for a leased window (dispatched, not waited on).

        Plan channels return the ``(length, S)`` engine block with the
        channel's (or overridden) sampler stage; custom channels return
        ``window_fn(lo, hi)``.  Passing ``retired`` — a live jax array
        of the output's exact shape and dtype, typically the block the
        consumer just finished with — DONATES it: the result is
        bit-identical but reuses the retired block's allocation, and
        the retired array is deleted (donated producer ring).
        """
        ch = self._channels[lease.channel]
        if ch.window_fn is not None:
            if retired is not None:
                raise ValueError(f"channel {lease.channel!r} has a custom "
                                 f"window_fn; donation needs a plan channel")
            return ch.window_fn(lease.lo, lease.hi)
        s = ch.sampler if sampler is None else sampler
        d = ch.out_dtype if out_dtype is None else out_dtype
        fn = self._window_fn(ch, lease.length, s, d,
                             donate=retired is not None)
        args = self._ctr_args(lease.lo)
        if retired is not None:
            return fn(*args, retired)
        return fn(*args)

    def generate_many(self, leases: List[Lease], *,
                      sampler: Optional[str] = None,
                      out_dtype: Optional[str] = None,
                      retired: Any = None) -> Any:
        """(W, L, S) stack for W contiguous leases — ONE fused dispatch.

        The leases must be what ``lease_many`` hands out: same plan
        channel, equal length, back-to-back windows.  The stack is
        bit-identical to per-lease ``generate`` calls (the engine's
        ``generate_windows`` parity guarantee) but pays the dispatch
        path once.  ``retired`` donates a (W, L, S) stack as in
        ``generate``.
        """
        if not leases:
            raise ValueError("generate_many needs at least one lease")
        ch = self._channels[leases[0].channel]
        if ch.window_fn is not None:
            raise ValueError(f"channel {leases[0].channel!r} has a custom "
                             f"window_fn; fused generation needs a plan "
                             f"channel")
        L = leases[0].length
        for a, b in zip(leases, leases[1:]):
            if b.channel != a.channel or b.length != L or b.lo != a.hi:
                raise ValueError(
                    "generate_many needs contiguous equal-length leases of "
                    f"one channel; got [{a.lo},{a.hi}) then [{b.lo},{b.hi}) "
                    f"on {a.channel!r}/{b.channel!r}")
        s = ch.sampler if sampler is None else sampler
        d = ch.out_dtype if out_dtype is None else out_dtype
        if len(leases) == 1:
            # the fuse=1 window fn emits (L, S); keep the documented
            # (W, L, S) contract.  Donation of a 1-window stack would
            # alias the wrong shape — the plain path covers it.
            if retired is not None:
                raise ValueError("donating into a single-window stack is "
                                 "not supported; use generate(lease, "
                                 "retired=...) for W=1")
            return self.generate(leases[0], sampler=s, out_dtype=d)[None]
        fn = self._window_fn(ch, L, s, d, fuse=len(leases),
                             donate=retired is not None)
        args = self._ctr_args(leases[0].lo)
        if retired is not None:
            return fn(*args, retired)
        return fn(*args)

    def regenerate(self, name: str, lo: int, length: int, *,
                   sampler: Optional[str] = None,
                   out_dtype: Optional[str] = None) -> Any:
        """The block for an ALREADY-durable window — no lease, no ledger.

        Restart/failover re-enters the middle of a journaled window
        (e.g. a standing pool's current block) through this: the window
        is already committed (and fenced) from the journal, so the new
        owner regenerates its bytes — bit-identical by counter
        addressing — without touching the accounting.  Leasing it again
        would (correctly) raise ``LeaseError``; that refusal is exactly
        why this path must not lease.
        """
        ch = self._channels[name]
        if ch.window_fn is not None:
            return ch.window_fn(lo, lo + length)
        s = ch.sampler if sampler is None else sampler
        d = ch.out_dtype if out_dtype is None else out_dtype
        fn = self._window_fn(ch, length, s, d)
        return fn(*self._ctr_args(lo))

    def take(self, name: str, length: int, **kw) -> Any:
        """lease + generate + commit in one call (synchronous consumers)."""
        lease = self.lease(name, length)
        try:
            block = self.generate(lease, **kw)
        except Exception:
            self.release(lease)
            raise
        self.commit(lease)
        return block

    def producer(self, name: str, block_len: int, *, depth: int = 1,
                 count: Optional[int] = None, start: Optional[int] = None,
                 donate: bool = False, fuse: int = 1,
                 check_ring: bool = False, **gen_kw) -> "BlockProducer":
        """Double-buffered producer over successive leased windows.

        ``start`` pins the first window to ``[start, start + block_len)``
        (explicit ``at=`` leases) — the repositioning hook for resume:
        windows already committed beyond ``start`` raise ``LeaseError``
        unless the ledger was rewound first.

        ``donate=True`` runs the allocation-free steady state: blocks
        cycle through a fixed ring of pre-allocated buffers (see
        ``BlockProducer``).  ``fuse=W`` generates W windows per device
        dispatch via ``generate_windows``.  ``check_ring=True`` asserts
        every donated block's ``unsafe_buffer_pointer()`` stays inside
        the ring (debug aid — forces a sync per block).
        """
        return BlockProducer(self, name, block_len, depth=depth,
                             count=count, start=start, donate=donate,
                             fuse=fuse, check_ring=check_ring, **gen_kw)


# ---------------------------------------------------------------------------
# Double-buffered producer
# ---------------------------------------------------------------------------

class BlockProducer:
    """Standing producer thread: block ``k+1`` is leased and dispatched
    while the consumer holds block ``k`` (the paper's FIFO-into-
    application pipeline).

    The queue holds (lease, block) pairs where ``block`` is a live jax
    array whose computation was *dispatched* by the producer thread —
    never waited on (``block_until_ready``-free handoff); the consumer's
    own ops simply enqueue behind it.  Iterating yields the block and
    COMMITS its lease (consumed randomness enters the durable ledger at
    handoff, so a ledger snapshot between iterations is exact).

    Two roofline levers ride on top of the base pipeline:

      * ``donate=True`` — the allocation-free steady state.  The
        producer pre-allocates a ring of ``depth + 2`` buffers (queue
        depth + the consumer's live block + the one being generated)
        and every window is generated INTO a retiring ring buffer via a
        donated jit (``donate_argnums``), so XLA reuses the allocation
        instead of allocating per window.  Bit-identity with the
        non-donated path is structural (the donated fn full-overwrites
        the retired buffer with the fresh block).  The contract: a
        yielded block is valid only until the NEXT ``__next__`` call —
        fetching block ``k+1`` retires block ``k`` into the ring (copy
        out with ``np.array`` if you need it longer).
      * ``fuse=W`` — W windows per dispatch.  The thread leases W
        contiguous windows atomically (``lease_many``), generates their
        ``(W, L, S)`` stack with ONE fused ``generate_windows`` call,
        and enqueues per-window slices; commit stays per-block at
        handoff.  With ``donate=True`` the stacks alternate through a
        producer-local two-buffer ring (the enqueued slices are fresh
        arrays, so the consumer never touches ring memory and no
        validity window applies).

    Example:
        >>> from repro.runtime.blocks import BlockService
        >>> svc = BlockService(seed=11)
        >>> _ = svc.open("docs/demo", num_streams=2)
        >>> with svc.producer("docs/demo", 4, count=2) as prod:
        ...     shapes = [blk.shape for _, blk in prod]
        >>> shapes
        [(4, 2), (4, 2)]
        >>> with svc.producer("docs/demo", 4, count=4, fuse=2) as prod:
        ...     lows = [lease.lo for lease, _ in prod]
        >>> lows                               # fused leases stay per-window
        [8, 12, 16, 20]
    """

    def __init__(self, service: BlockService, name: str, block_len: int, *,
                 depth: int = 1, count: Optional[int] = None,
                 start: Optional[int] = None, donate: bool = False,
                 fuse: int = 1, check_ring: bool = False, **gen_kw):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        fuse = int(fuse)
        if fuse < 1:
            raise ValueError(f"fuse must be >= 1, got {fuse}")
        if (donate or fuse > 1) and service.mesh is not None:
            raise ValueError("donate/fuse producers require a mesh-less "
                             "service; sharded delivery manages its own "
                             "buffers")
        if donate and not donation_supported():
            raise ValueError(
                f"buffer donation is a no-op on backend "
                f"{jax.default_backend()!r}; run without donate=True")
        self._service = service
        self._name = name
        self._block_len = block_len
        self._count = count
        self._pos = start
        self._donate = donate
        self._fuse = fuse
        self._check_ring = check_ring
        self._gen_kw = gen_kw
        self._queue: "queue.Queue" = queue.Queue(maxsize=depth)
        self._recycle: "queue.Queue" = queue.Queue()
        self._ring_ptrs: set = set()
        self._held: Any = None
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._produced = 0
        if donate:
            ch = service.channel(name)
            if ch.window_fn is not None:
                raise ValueError(f"channel {name!r} has a custom window_fn; "
                                 f"donation needs a plan channel")
            s = gen_kw.get("sampler") or ch.sampler
            d = gen_kw.get("out_dtype") or ch.out_dtype
            dtype = sampler_mod.result_dtype(sampler_mod.parse(s), d)
            shape = ((block_len, ch.num_streams) if fuse == 1
                     else (fuse, block_len, ch.num_streams))
            # fuse>1: stacks never leave the thread -> 2 buffers alternate;
            # fuse=1: queue depth + consumer's live block + in-flight gen.
            for _ in range(2 if fuse > 1 else depth + 2):
                buf = jnp.zeros(shape, dtype)
                if check_ring:  # pointer reads sync; debug mode only
                    self._ring_ptrs.add(buf.unsafe_buffer_pointer())
                self._recycle.put(buf)
        self._thread = threading.Thread(
            target=self._work, name=f"blocks:{name}", daemon=True)
        self._thread.start()

    def _get_retired(self) -> Any:
        """Next free ring buffer (None once stop is requested)."""
        while not self._stop.is_set():
            try:
                return self._recycle.get(timeout=0.1)
            except queue.Empty:
                continue
        return None

    def _put(self, item) -> bool:
        """queue.put with stop-polling; False once stop is requested."""
        while not self._stop.is_set():
            try:
                self._queue.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _work(self) -> None:
        try:
            while not self._stop.is_set():
                if self._count is not None and self._produced >= self._count:
                    break
                n = self._fuse
                if self._count is not None:
                    n = min(n, self._count - self._produced)
                leases = self._service.lease_many(
                    self._name, self._block_len, n, at=self._pos)
                if self._pos is not None:
                    self._pos += n * self._block_len
                # A short tail (n < fuse) has the wrong stack shape for
                # the ring -> generate it undonated.
                retired = None
                if self._donate and n == self._fuse:
                    retired = self._get_retired()
                    if retired is None:  # stopping
                        for lease in leases:
                            self._service.release(lease)
                        break
                try:
                    if n == 1 and self._fuse == 1:
                        block = self._service.generate(
                            leases[0], retired=retired, **self._gen_kw)
                        pairs = [(leases[0], block)]
                        ring_out = block
                    else:
                        stack = self._service.generate_many(
                            leases, retired=retired, **self._gen_kw)
                        pairs = [(leases[w], stack[w]) for w in range(n)]
                        ring_out = stack
                        if retired is not None:
                            # slices are fresh arrays; the stack cycles
                            # producer-locally
                            self._recycle.put(stack)
                except BaseException:
                    if retired is not None and not retired.is_deleted():
                        self._recycle.put(retired)
                    for lease in leases:
                        self._service.release(lease)
                    raise
                if self._check_ring and retired is not None:
                    ptr = ring_out.unsafe_buffer_pointer()
                    if ptr not in self._ring_ptrs:
                        raise AssertionError(
                            f"donated block escaped the buffer ring: "
                            f"{ptr:#x} not in "
                            f"{sorted(map(hex, self._ring_ptrs))}")
                self._produced += n
                stopped = False
                for idx, pair in enumerate(pairs):
                    if not self._put(pair):
                        for lease, _ in pairs[idx:]:
                            self._service.release(lease)
                        stopped = True
                        break
                if stopped:
                    break
        except BaseException as e:  # surface in the consumer thread
            self._error = e
        finally:
            while not self._stop.is_set():
                try:
                    self._queue.put(None, timeout=0.1)  # end-of-stream
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> "BlockProducer":
        return self

    def __next__(self) -> Tuple[Lease, Any]:
        while True:
            if self._error is not None and self._queue.empty():
                err, self._error = self._error, None
                raise err
            try:
                item = self._queue.get(timeout=0.1)
            except queue.Empty:
                continue
            if item is None:
                if self._error is not None:
                    err, self._error = self._error, None
                    raise err
                raise StopIteration
            lease, block = item
            self._service.commit(lease)
            if self._donate and self._fuse == 1:
                if self._held is not None:
                    self._recycle.put(self._held)  # retire block k
                self._held = block
            return lease, block

    def close(self) -> None:
        """Stop the thread and release every unconsumed reservation."""
        self._stop.set()
        self._thread.join(timeout=10.0)
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is not None:
                self._service.release(item[0])

    def __enter__(self) -> "BlockProducer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Leased Monte-Carlo app entry points (paper Sec. 6 consumers)
# ---------------------------------------------------------------------------

def _leased_app(service: BlockService, channel: str, num_streams: int,
                length: int, fn: Callable[[Lease], Any]) -> Any:
    """open + lease + run + commit (release on failure) — the shared
    lifecycle of every synchronous leased consumer."""
    service.open(channel, num_streams=num_streams)
    lease = service.lease(channel, length)
    try:
        result = fn(lease)
    except Exception:
        service.release(lease)
        raise
    service.commit(lease)
    return result


def estimate_pi(service: BlockService, *, num_lanes: int,
                draws_per_lane: int, **kw) -> Any:
    """MC pi over a leased draw window: repeated calls consume fresh,
    disjoint randomness of the service family (window units = draws per
    lane; the x/y coordinate purposes share the window)."""
    from repro.kernels import ops
    return _leased_app(
        service, "mc/pi", num_lanes, draws_per_lane,
        lambda lease: ops.estimate_pi(
            seed=service.seed, num_lanes=num_lanes,
            draws_per_lane=draws_per_lane, offset=lease.lo, **kw))


def price_option(service: BlockService, *, num_lanes: int,
                 draws_per_lane: int, **kw) -> Any:
    """Leased-window Black-Scholes MC (see ``estimate_pi``)."""
    from repro.kernels import ops
    return _leased_app(
        service, "mc/option", num_lanes, draws_per_lane,
        lambda lease: ops.price_option(
            seed=service.seed, num_lanes=num_lanes,
            draws_per_lane=draws_per_lane, offset=lease.lo, **kw))
