"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run entry point forces 512 host
platform devices BEFORE first jax init.
"""
from __future__ import annotations

import jax


def make_mesh_auto(shape, axes):
    """jax.make_mesh with Auto axis types where the jax version has them.

    ``jax.sharding.AxisType`` only exists on newer jax; on older versions
    plain ``make_mesh`` already defaults every axis to Auto semantics.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod, or (2, 16, 16) pod x data x model."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the locally available devices (tests / examples)."""
    n = len(jax.devices())
    if model < 1 or n % model:
        raise ValueError(
            f"make_host_mesh(model={model}): {n} local device(s) cannot "
            f"split into (data={n}/{model}, model={model}); pick a model "
            f"axis that divides the device count")
    return make_mesh_auto((n // model, model), ("data", "model"))


def rng_axes(mesh) -> tuple:
    """Mesh axes for the RNG block fan-out: ALL of them.

    ``engine.generate_sharded(..., axis_names=rng_axes(mesh))`` shards
    the stream axis over every device of a production mesh — the
    (host, stream) 2-D layout (or 3-D with the pod axis).  Generation is
    collective-free regardless of how the model otherwise uses the axes,
    because every column is counter-addressed from the replicated root.
    """
    return tuple(mesh.axis_names)
