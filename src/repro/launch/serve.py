"""Batched serving driver: prefill a batch of prompts, then step-decode.

Sampling randomness comes from the randomness-as-a-service layer: with
``temperature > 0`` the server is RandService's first in-process client
— each decode step requests a ``(batch, vocab)`` uniform block for the
``launch/serve`` tenant and samples by gumbel-max.  Every draw is
therefore tenant-attributed, quota-metered, ledger-fenced and (with a
journal) replayable to bit-identical tokens; the token sampler shares
its generation substrate with every other tenant of the service.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --smoke \\
      --batch 4 --prompt-len 32 --gen 16 --temperature 0.8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLMPipeline
from repro.launch.train import pipeline_for, smoke_config
from repro.models import registry
from repro.service import RandServer, ServerConfig

SAMPLER_TENANT = "launch/serve"


def _pick(logits, rand: RandServer, temperature: float):
    """Greedy at temperature 0; else gumbel-max over one service request."""
    if temperature <= 0.0 or rand is None:
        return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    u = rand.request(SAMPLER_TENANT, logits.shape, sampler="uniform")
    tiny = np.float32(1e-20)
    g = -np.log(-np.log(u + tiny) + tiny)
    tok = jnp.argmax(logits.astype(jnp.float32) / temperature + g, -1)
    return tok[:, None].astype(jnp.int32)


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          temperature: float = 0.0, rand: RandServer = None):
    model = registry.build(cfg)
    params, _ = model.init(seed)
    pipe = pipeline_for(cfg, batch, max(prompt_len, 2), seed)
    b = pipe.batch_at(0)
    prompts = {k: (v[:, :prompt_len] if k in ("tokens", "labels") else v)
               for k, v in b.items()}
    prompts.pop("labels", None)

    own_rand = False
    if temperature > 0.0 and rand is None:
        # single in-process client: flush every request immediately
        rand = RandServer(seed, config=ServerConfig(max_batch=1))
        own_rand = True

    total_ctx = prompt_len + gen
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode)

    t0 = time.time()
    logits, pcache = prefill(params, prompts)
    # copy prefix kv into a full-length cache (attention families); ssm
    # caches are position-free and carry over directly
    cache = model.init_cache(batch, total_ctx)
    cache = _graft(cfg, cache, pcache, prompt_len)
    t_prefill = time.time() - t0

    try:
        tok = _pick(logits, rand, temperature)
        out = [np.asarray(tok)]
        t1 = time.time()
        for i in range(gen - 1):
            logits, cache = decode(params, cache, tok,
                                   jnp.int32(prompt_len + i))
            tok = _pick(logits, rand, temperature)
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.time() - t1
    finally:
        if own_rand:
            rand.shutdown()      # drain the in-process sampler service
    toks = np.concatenate(out, axis=1)
    return toks, {"prefill_s": t_prefill, "decode_s": t_decode,
                  "decode_tok_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def _graft(cfg, cache, pcache, prompt_len):
    """Copy prefill results into the zeroed full-length decode cache."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        k, v = cache
        pk, pv = pcache
        return (jax.lax.dynamic_update_slice_in_dim(k, pk.astype(k.dtype), 0, 2),
                jax.lax.dynamic_update_slice_in_dim(v, pv.astype(v.dtype), 0, 2))
    if fam == "encdec":
        sk, sv, _, _ = cache
        pk, pv, ck, cv = pcache
        return (jax.lax.dynamic_update_slice_in_dim(sk, pk.astype(sk.dtype), 0, 2),
                jax.lax.dynamic_update_slice_in_dim(sv, pv.astype(sv.dtype), 0, 2),
                ck, cv)
    if fam == "ssm":
        return pcache  # state-based: prefill cache IS the decode cache
    if fam == "hybrid":
        kc, vc = cache[0], cache[1]
        pkc, pvc = pcache[0], pcache[1]
        return (jax.lax.dynamic_update_slice_in_dim(kc, pkc.astype(kc.dtype), 0, 2),
                jax.lax.dynamic_update_slice_in_dim(vc, pvc.astype(vc.dtype), 0, 2),
                *pcache[2:])
    raise ValueError(fam)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples via per-step RandService "
                         "uniform requests (tenant-attributed, journaled, "
                         "replayable)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    toks, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen=args.gen, temperature=args.temperature)
    print("generated shape:", toks.shape)
    print({k: round(v, 4) for k, v in stats.items()})


if __name__ == "__main__":
    main()
