"""Batched serving driver: prefill a batch of prompts, then step-decode.

Token sampling is delegated to the inference tier
(``repro.inference.GumbelMaxSampler``): each decode row is a tenant
sequence (``launch/serve/seq/<b>``), and with ``temperature > 0`` every
decode step draws its gumbel noise from ONE leased counter window of a
standalone sampler service — tenant-attributed, ledger-fenced, and
(through the fused path) sampled in-kernel from counter bits to token
ids without a noise block in HBM.  ``temperature 0`` stays the pure
greedy argmax and consumes no randomness at all.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --smoke \\
      --batch 4 --prompt-len 32 --gen 16 --temperature 0.8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.train import pipeline_for, smoke_config
from repro.models import registry
from repro.inference import ActiveSeq, GumbelMaxSampler, SamplingSpec

SAMPLER_TENANT = "launch/serve"


class TokenPicker:
    """Per-step token selection over the inference tier's sampler.

    Greedy (``temperature <= 0``) is the pure argmax — bit-identical to
    sampling-free serving, no service, no leases.  Stochastic picking
    builds one :class:`GumbelMaxSampler` (its own BlockService seeded
    with the serve seed) and registers each batch row as the tenant
    ``launch/serve/seq/<b>``; step ``i`` consumes counter window
    ``[i * vocab, (i+1) * vocab)`` — replayable from (seed, step) alone.
    """

    def __init__(self, *, seed: int, batch: int, vocab: int,
                 temperature: float, path: str = "fused"):
        self.batch = batch
        self.greedy = temperature <= 0.0
        self.sampler = None
        self._active = []
        if not self.greedy:
            self.sampler = GumbelMaxSampler.standalone(
                seed=seed, vocab=vocab, capacity=batch,
                spec=SamplingSpec(temperature=temperature), path=path)
            for b in range(batch):
                sid = f"{SAMPLER_TENANT}/seq/{b}"
                tenant = self.sampler.registry.register(sid)
                self._active.append((sid, tenant.tag(0)))

    def pick(self, step: int, logits) -> jnp.ndarray:
        """(batch, 1) int32 next tokens for decode step ``step``."""
        if self.greedy:
            return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        active = [ActiveSeq(slot=b, seq_id=sid, tenant_id=sid, tag=tag,
                            position=step)
                  for b, (sid, tag) in enumerate(self._active)]
        flat = jnp.asarray(logits).reshape(self.batch, -1)
        toks = self.sampler.sample_step(step, flat, active)
        return jnp.asarray(toks)[:, None]


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          temperature: float = 0.0, sampler_path: str = "fused"):
    model = registry.build(cfg)
    params, _ = model.init(seed)
    pipe = pipeline_for(cfg, batch, max(prompt_len, 2), seed)
    b = pipe.batch_at(0)
    prompts = {k: (v[:, :prompt_len] if k in ("tokens", "labels") else v)
               for k, v in b.items()}
    prompts.pop("labels", None)

    total_ctx = prompt_len + gen
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode)

    t0 = time.time()
    logits, pcache = prefill(params, prompts)
    # copy prefix kv into a full-length cache (attention families); ssm
    # caches are position-free and carry over directly
    cache = model.init_cache(batch, total_ctx)
    cache = _graft(cfg, cache, pcache, prompt_len)
    t_prefill = time.time() - t0

    picker = TokenPicker(seed=seed, batch=batch,
                         vocab=int(logits.shape[-1]),
                         temperature=temperature, path=sampler_path)
    tok = picker.pick(0, logits)
    out = [np.asarray(tok)]
    t1 = time.time()
    for i in range(gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.int32(prompt_len + i))
        tok = picker.pick(i + 1, logits)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    toks = np.concatenate(out, axis=1)
    stats = {"prefill_s": t_prefill, "decode_s": t_decode,
             "decode_tok_s": batch * (gen - 1) / max(t_decode, 1e-9)}
    if picker.sampler is not None:
        stats["sampler_calls_per_step"] = (
            picker.sampler.stats()["calls_per_step"])
    return toks, stats


def _graft(cfg, cache, pcache, prompt_len):
    """Copy prefill results into the zeroed full-length decode cache."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        k, v = cache
        pk, pv = pcache
        return (jax.lax.dynamic_update_slice_in_dim(k, pk.astype(k.dtype), 0, 2),
                jax.lax.dynamic_update_slice_in_dim(v, pv.astype(v.dtype), 0, 2))
    if fam == "encdec":
        sk, sv, _, _ = cache
        pk, pv, ck, cv = pcache
        return (jax.lax.dynamic_update_slice_in_dim(sk, pk.astype(sk.dtype), 0, 2),
                jax.lax.dynamic_update_slice_in_dim(sv, pv.astype(sv.dtype), 0, 2),
                ck, cv)
    if fam == "ssm":
        return pcache  # state-based: prefill cache IS the decode cache
    if fam == "hybrid":
        kc, vc = cache[0], cache[1]
        pkc, pvc = pcache[0], pcache[1]
        return (jax.lax.dynamic_update_slice_in_dim(kc, pkc.astype(kc.dtype), 0, 2),
                jax.lax.dynamic_update_slice_in_dim(vc, pvc.astype(vc.dtype), 0, 2),
                *pcache[2:])
    raise ValueError(fam)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples via the inference "
                         "tier's fused gumbel-max sampler (tenant-"
                         "attributed, ledger-fenced, replayable)")
    ap.add_argument("--sampler-path", choices=("fused", "xla", "ref"),
                    default="fused")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    toks, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen=args.gen, temperature=args.temperature,
                        sampler_path=args.sampler_path)
    print("generated shape:", toks.shape)
    print({k: round(v, 4) for k, v in stats.items()})


if __name__ == "__main__":
    main()
