"""Batched serving driver: prefill a batch of prompts, then step-decode.

Sampling randomness comes through the block-delivery layer: with
``temperature > 0`` the server opens a ``BlockService`` sampler channel
and leases ONE counter window covering the whole generation
(``gen * batch * vocab`` gumbel draws); decode step ``i`` reads the
window slice at ``i * batch * vocab``.  Sampling is therefore
counter-addressed (replayable from the lease alone) and the ledger makes
re-spending a window across requests a structural error.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --smoke \\
      --batch 4 --prompt-len 32 --gen 16 --temperature 0.8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import stream as tstream
from repro.data import SyntheticLMPipeline
from repro.launch.train import pipeline_for, smoke_config
from repro.models import registry
from repro.runtime import BlockService

SAMPLER_CHANNEL = "serve/sampler"


def _pick(logits, sample_stream, temperature: float, draws_per_step: int):
    """Greedy at temperature 0; else gumbel-max over one window slice."""
    if temperature <= 0.0 or sample_stream is None:
        return jnp.argmax(logits, -1)[:, None].astype(jnp.int32), \
            sample_stream
    tok = tstream.categorical(sample_stream,
                              logits.astype(jnp.float32) / temperature)
    return tok[:, None].astype(jnp.int32), \
        tstream.advance(sample_stream, draws_per_step)


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          temperature: float = 0.0, service: BlockService = None):
    model = registry.build(cfg)
    params, _ = model.init(seed)
    pipe = pipeline_for(cfg, batch, max(prompt_len, 2), seed)
    b = pipe.batch_at(0)
    prompts = {k: (v[:, :prompt_len] if k in ("tokens", "labels") else v)
               for k, v in b.items()}
    prompts.pop("labels", None)

    sample_stream = None
    lease = None
    if temperature > 0.0:
        service = service or BlockService(seed)
        service.open(SAMPLER_CHANNEL)
        lease = service.lease(SAMPLER_CHANNEL, gen * batch * cfg.vocab)
        sample_stream = lease.stream()
    draws_per_step = batch * cfg.vocab

    total_ctx = prompt_len + gen
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode)

    t0 = time.time()
    logits, pcache = prefill(params, prompts)
    # copy prefix kv into a full-length cache (attention families); ssm
    # caches are position-free and carry over directly
    cache = model.init_cache(batch, total_ctx)
    cache = _graft(cfg, cache, pcache, prompt_len)
    t_prefill = time.time() - t0

    try:
        tok, sample_stream = _pick(logits, sample_stream, temperature,
                                   draws_per_step)
        out = [np.asarray(tok)]
        t1 = time.time()
        for i in range(gen - 1):
            logits, cache = decode(params, cache, tok,
                                   jnp.int32(prompt_len + i))
            tok, sample_stream = _pick(logits, sample_stream, temperature,
                                       draws_per_step)
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.time() - t1
    except Exception:
        if lease is not None:
            lease.release()      # failed request: window may be re-leased
        raise
    if lease is not None:
        lease.commit()
    toks = np.concatenate(out, axis=1)
    return toks, {"prefill_s": t_prefill, "decode_s": t_decode,
                  "decode_tok_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def _graft(cfg, cache, pcache, prompt_len):
    """Copy prefill results into the zeroed full-length decode cache."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        k, v = cache
        pk, pv = pcache
        return (jax.lax.dynamic_update_slice_in_dim(k, pk.astype(k.dtype), 0, 2),
                jax.lax.dynamic_update_slice_in_dim(v, pv.astype(v.dtype), 0, 2))
    if fam == "encdec":
        sk, sv, _, _ = cache
        pk, pv, ck, cv = pcache
        return (jax.lax.dynamic_update_slice_in_dim(sk, pk.astype(sk.dtype), 0, 2),
                jax.lax.dynamic_update_slice_in_dim(sv, pv.astype(sv.dtype), 0, 2),
                ck, cv)
    if fam == "ssm":
        return pcache  # state-based: prefill cache IS the decode cache
    if fam == "hybrid":
        kc, vc = cache[0], cache[1]
        pkc, pvc = pcache[0], pcache[1]
        return (jax.lax.dynamic_update_slice_in_dim(kc, pkc.astype(kc.dtype), 0, 2),
                jax.lax.dynamic_update_slice_in_dim(vc, pvc.astype(vc.dtype), 0, 2),
                *pcache[2:])
    raise ValueError(fam)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples via a leased gumbel "
                         "window (counter-addressed, replayable)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    toks, stats = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen=args.gen, temperature=args.temperature)
    print("generated shape:", toks.shape)
    print({k: round(v, 4) for k, v in stats.items()})


if __name__ == "__main__":
    main()
