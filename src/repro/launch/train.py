"""End-to-end training driver.

Runs on whatever devices exist (1 CPU here; the same code path jits under
the production mesh on TPU).  Integrates: ThunderStream-initialized model,
deterministic ThundeRiNG data pipeline fed through the BlockService
delivery layer (leased step windows, double-buffered batch dispatch,
ledger checkpointed with the model), sharded AdamW, fault-tolerant loop
with async checkpoints.

  PYTHONPATH=src python -m repro.launch.train --arch glm4_9b --smoke \\
      --steps 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import LeasedBatchFeeder, SyntheticLMPipeline
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.models.common import ArchConfig
from repro.optim import adamw_init
from repro.runtime import BlockService, FaultTolerantLoop

SMOKE_OVERRIDES = dict(n_layers=2, d_model=128, d_ff=256, vocab=512,
                       q_chunk=64, loss_chunks=4)


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    over = dict(SMOKE_OVERRIDES)
    if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
        over.update(n_heads=4, n_kv_heads=min(4, max(cfg.n_kv_heads, 1)),
                    head_dim=32)
    if cfg.family == "moe":
        over.update(n_experts=8, top_k=2, d_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        over.update(ssm_state=16, ssm_head_dim=16)
    if cfg.family == "hybrid":
        over.update(n_layers=4, attn_every=2)
    if cfg.family == "encdec":
        over.update(enc_layers=2, enc_ctx=64)
    return cfg.scaled(**over)


def pipeline_for(cfg: ArchConfig, global_batch: int, seq_len: int,
                 seed: int) -> SyntheticLMPipeline:
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = (cfg.vision_prefix, cfg.d_model)
    if cfg.family == "encdec":
        extras["frames"] = (cfg.enc_ctx, cfg.d_model)
    return SyntheticLMPipeline(seed, cfg.vocab, global_batch, seq_len,
                               extras=extras or None)


def train(cfg: ArchConfig, *, steps: int, global_batch: int, seq_len: int,
          ckpt_dir: str, seed: int = 0, save_every: int = 50,
          fail_at=None, log_every: int = 10, compress=None,
          use_service: bool = True):
    """Train ``steps`` steps; returns (params, opt_state, logged losses).

    ``use_service=True`` (default) feeds batches through the
    ``BlockService`` delivery layer: one leased step window per batch,
    batch ``s+1`` dispatched by a producer thread while step ``s``
    computes, and the lease ledger saved/restored with every checkpoint
    (exact mid-epoch resume, double-spend structurally rejected).
    ``use_service=False`` keeps the historical path that fuses
    ``batch_at`` into the jitted step — the batch bits and losses are
    BIT-IDENTICAL either way (the batch function is the same pure
    function of (seed, step); see tests/test_blocks.py).
    """
    model = registry.build(cfg)
    pipe = pipeline_for(cfg, global_batch, seq_len, seed)
    train_step = steps_mod.make_train_step(model, seed=seed,
                                           total_steps=max(steps, 2),
                                           compress=compress)

    jit_step = jax.jit(train_step)

    @jax.jit
    def fused_step(params, opt_state, step):
        batch = pipe.batch_at(step)           # data gen fused into the step
        return train_step(params, opt_state, batch, step)

    mgr = CheckpointManager(ckpt_dir, async_save=True)
    loop = FaultTolerantLoop(mgr, save_every=save_every)

    service = feeder = None
    extra_state = on_restore = None
    if use_service:
        service = BlockService(seed)
        feeder = LeasedBatchFeeder(pipe, service)

        def step_fn(p, o, s):
            batch = feeder.batch_for(s)
            return jit_step(p, o, batch, jnp.int32(s))

        def extra_state():
            return {"rng_ledger": service.ledger_state()}

        def on_restore(extra, start):
            feeder.reset()
            service.restore_ledger((extra or {}).get("rng_ledger"))
    else:
        def step_fn(p, o, s):
            return fused_step(p, o, jnp.int32(s))

    def init_state():
        params, _ = model.init(seed)
        return params, adamw_init(params)

    losses = []

    def on_metrics(step, metrics):
        if step % log_every == 0 or step < 3:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            print(f"step {step:5d} loss {loss:.4f}", flush=True)

    t0 = time.time()
    try:
        params, opt_state = loop.run(
            init_state=init_state, step_fn=step_fn,
            num_steps=steps, fail_at=fail_at, on_metrics=on_metrics,
            extra_state=extra_state, on_restore=on_restore)
    finally:
        if feeder is not None:
            feeder.reset()
    dt = time.time() - t0
    tokens = steps * global_batch * seq_len
    print(f"done: {steps} steps, {tokens} tokens, {dt:.1f}s "
          f"({tokens / dt:.0f} tok/s)", flush=True)
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced dims for CPU execution")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--compress", default=None, choices=[None, "bf16"])
    ap.add_argument("--no-service", action="store_true",
                    help="legacy path: fuse batch_at into the jitted step "
                         "instead of the BlockService delivery layer")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    train(cfg, steps=args.steps, global_batch=args.global_batch,
          seq_len=args.seq_len, ckpt_dir=args.ckpt_dir, seed=args.seed,
          save_every=args.save_every, compress=args.compress,
          use_service=not args.no_service)


if __name__ == "__main__":
    main()
