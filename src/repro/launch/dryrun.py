import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-touching import: jax locks the device count at
# first init.  512 host devices back both the 16x16 single-pod mesh and
# the 2x16x16 multi-pod mesh.  Only this entry point does this — tests,
# benchmarks and examples see the real single CPU device.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, get_config, input_specs,
                           shape_skipped)  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh, rng_axes  # noqa: E402
from repro.models import registry  # noqa: E402
from repro.models.common import flatten  # noqa: E402
from repro.optim import adamw_init  # noqa: E402

from repro.launch.analysis import (  # noqa: E402
    HBM_BW, ICI_BW, PEAK_FLOPS, _DTYPE_BYTES, _shape_bytes, collective_bytes)


def _mem_report(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    rep = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            rep[attr] = int(v)
    rep["total_bytes_per_device"] = (
        rep.get("argument_size_in_bytes", 0)
        + rep.get("output_size_in_bytes", 0)
        + rep.get("temp_size_in_bytes", 0)
        - rep.get("alias_size_in_bytes", 0))
    return rep


def _cost_report(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "transcendentals") or "bytes" in k)}


def count_params(shapes_tree) -> int:
    total = 0
    for x in jax.tree.leaves(shapes_tree):
        n = 1
        for d in x.shape:
            n *= int(d)
        total += n
    return total


def active_params(cfg, params_shapes) -> int:
    """MoE-aware active parameter count for MODEL_FLOPS = 6*N_active*D."""
    total = 0
    for path, leaf in flatten(params_shapes).items():
        n = 1
        for d in leaf.shape:
            n *= d
        if "moe_" in path and cfg.n_experts:
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return total


def np_prod(t) -> int:
    out = 1
    for v in t:
        out *= int(v)
    return out


def model_flops_from_counts(cfg, n_active: int, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N MoE-active."""
    spec = SHAPES[shape_name]
    if spec.kind == "train":
        return 6.0 * n_active * spec.global_batch * spec.seq_len
    if spec.kind == "prefill":
        return 2.0 * n_active * spec.global_batch * spec.seq_len
    return 2.0 * n_active * spec.global_batch  # decode: 1 token/sequence


def _compile_cell(cfg, shape_name: str, mesh,
                  param_dtype: Optional[str] = None) -> Dict[str, Any]:
    """Lower + compile one step for one concrete cfg; return compiled +
    timing + params info."""
    model = registry.build(cfg)
    spec = SHAPES[shape_name]
    holder = {}

    def initf():
        p, s = model.init(0)
        holder["specs"] = s
        return p

    t0 = time.time()
    params_shapes = jax.eval_shape(initf)
    specs = holder["specs"]

    mode = "train" if spec.kind == "train" else "serve"
    if mode == "serve":
        # serving weights are bf16 (training keeps fp32 masters)
        params_shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if s.dtype == jnp.float32 else s, params_shapes)
    pshard, _ = steps_mod.param_sharding_tree(model, params_shapes, specs,
                                              mesh, mode)
    batch_specs = input_specs(cfg, shape_name, model)
    bshard = steps_mod.batch_sharding(cfg, batch_specs, mesh)

    with mesh:
        if spec.kind == "train":
            opt_shapes = jax.eval_shape(adamw_init, params_shapes)
            oshard = steps_mod.opt_sharding_like(pshard, mesh)
            # gradient accumulation for the big archs: per-microbatch
            # activations must fit 16 GB/chip alongside FSDP param shards
            n_params = count_params(params_shapes)
            micro = 8 if n_params > 5e10 else (2 if n_params > 2e10 else 1)
            if cfg.scan_unroll:
                micro = 1  # cost-fit compiles measure the whole batch once
            micro = int(os.environ.get("REPRO_MICROBATCHES", micro))
            train_step = steps_mod.make_train_step(model, microbatches=micro,
                                                   param_dtype=param_dtype)
            step_spec = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                train_step,
                in_shardings=(pshard, oshard, bshard,
                              NamedSharding(mesh, P())),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1))  # params/opt update in place
            lowered = jitted.lower(params_shapes, opt_shapes, batch_specs,
                                   step_spec)
        elif spec.kind == "prefill":
            prefill_step, _ = steps_mod.make_serve_fns(model)
            # prefill OUTPUT cache must come out sharded (kv/ctx over
            # model, batch over data) — explicit, not inferred
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(spec.global_batch, spec.seq_len))
            from repro.models import sharding as shd_mod
            cache_pspec = shd_mod.cache_pspecs(cfg, cache_shapes, mesh)
            cache_shard = jax.tree.map(
                lambda s: NamedSharding(mesh, s), cache_pspec,
                is_leaf=lambda x: isinstance(x, P))
            jitted = jax.jit(prefill_step, in_shardings=(pshard, bshard),
                             out_shardings=(None, cache_shard))
            lowered = jitted.lower(params_shapes, batch_specs)
        else:  # decode
            _, decode_step = steps_mod.make_serve_fns(model)
            jitted = jax.jit(
                decode_step,
                in_shardings=(pshard, bshard["cache"], bshard["token"],
                              bshard["pos"]),
                out_shardings=(None, bshard["cache"]),
                donate_argnums=(1,))  # KV cache updates in place
            lowered = jitted.lower(params_shapes, batch_specs["cache"],
                                   batch_specs["token"], batch_specs["pos"])
        lower_s = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = round(time.time() - t1, 2)
    return {"compiled": compiled, "lower_s": lower_s,
            "compile_s": compile_s, "params_shapes": params_shapes}


def _fit_layers(cfg):
    """(L1, L2) reduced depths for the cost-fit compiles."""
    if cfg.family == "hybrid":
        return cfg.attn_every, 2 * cfg.attn_every
    return 1, 2


def _fit_cfg(cfg, L, shape_name: str):
    over = dict(n_layers=L, scan_unroll=True, loss_chunks=1,
                q_chunk=SHAPES[shape_name].seq_len)
    if cfg.family == "encdec":
        over["enc_layers"] = L
    return cfg.scaled(**over)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               fit_costs: bool = True,
               overrides: Optional[Dict[str, Any]] = None,
               param_dtype: Optional[str] = None) -> Dict[str, Any]:
    """Lower + compile one (arch x shape x mesh) cell; return the report.

    Protocol (XLA's HloCostAnalysis counts while-loop bodies ONCE, so the
    scanned full-depth program under-reports flops/bytes/collectives):
      1. FULL-depth scanned compile  -> memory_analysis (peak is real)
         + proof that the production program compiles on this mesh.
      2. Two reduced-depth compiles with layer scans UNROLLED (L1, L2)
         -> per-layer linear fit of flops / bytes / collective bytes,
         extrapolated to the full depth.  Known residual: loops whose
         trip count is layer-independent (the 16-chunk xent scan and the
         SSD inter-chunk scan) stay counted once in the fit compiles too;
         they are made loop-free there (loss_chunks=1, q_chunk=seq), which
         preserves total flops and, to first order, total bytes.
    """
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    skip = shape_skipped(cfg, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "skipped": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np_prod(mesh.devices.shape))
    spec = SHAPES[shape_name]
    report: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "kind": spec.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": n_chips,
    }

    # --- 1. full-depth compile: memory + shardability proof --------------
    full = _compile_cell(cfg, shape_name, mesh, param_dtype=param_dtype)
    report["lower_s"] = full["lower_s"]
    report["compile_s"] = full["compile_s"]
    report["n_params"] = count_params(full["params_shapes"])
    report["n_params_active"] = active_params(cfg, full["params_shapes"])
    report["memory"] = _mem_report(full["compiled"])
    report["cost_raw"] = _cost_report(full["compiled"])
    try:
        hlo = full["compiled"].as_text()
        report["collectives_raw"] = collective_bytes(hlo)
        report["hlo_lines"] = hlo.count("\n")
    except Exception as e:  # pragma: no cover
        report["collectives_raw"] = {"error": str(e)}
    del full

    # --- 2. reduced-depth unrolled compiles: linear layer fit ------------
    if fit_costs:
        L1, L2 = _fit_layers(cfg)
        fit = {}
        for L in (L1, L2):
            c = _compile_cell(_fit_cfg(cfg, L, shape_name), shape_name, mesh,
                              param_dtype=param_dtype)
            cost = _cost_report(c["compiled"])
            coll = collective_bytes(c["compiled"].as_text())
            fit[L] = {"flops": cost.get("flops", 0.0),
                      "bytes": cost.get("bytes accessed", 0.0),
                      "coll": float(coll.get("total", 0)),
                      "coll_by_op": coll,
                      "compile_s": c["compile_s"]}
            del c
        Lf = cfg.n_layers

        def extrap(key):
            y1, y2 = fit[L1][key], fit[L2][key]
            return y1 + (y2 - y1) * (Lf - L1) / (L2 - L1)

        flops = extrap("flops")
        bytes_acc = extrap("bytes")
        coll = extrap("coll")
        report["cost_fit"] = {
            "flops": flops, "bytes_accessed": bytes_acc,
            "collective_bytes": coll,
            "fit_points": {str(L): fit[L] for L in (L1, L2)},
        }
    else:
        flops = report["cost_raw"].get("flops", 0.0)
        bytes_acc = report["cost_raw"].get("bytes accessed", 0.0)
        coll = report["collectives_raw"].get("total", 0)

    # --- roofline terms (per-device program values) -----------------------
    mf = model_flops_from_counts(cfg, report["n_params_active"], shape_name)
    # NOTE: cost_analysis/HLO values are PER-DEVICE (the SPMD program), so
    # each term divides by per-chip peak only.  The spec's
    # "collective_bytes / (chips x link_bw)" assumes GLOBAL collective
    # bytes; ours are per-device, so the chips factor cancels.
    report["roofline"] = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll / ICI_BW,
        "model_flops_total": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops if flops else 0.0,
    }
    terms = {k: report["roofline"][k]
             for k in ("compute_s", "memory_s", "collective_s")}
    report["roofline"]["bottleneck"] = max(terms, key=terms.get)
    return report


def rng_fanout_cell(*, multi_pod: bool = False, num_streams: int = 2 ** 14,
                    num_steps: int = 256) -> Dict[str, Any]:
    """Lower + compile the RNG block fan-out on the production mesh.

    The 2-D/3-D ``(host, stream)`` layout of ``engine.generate_sharded``
    over ALL mesh axes: proves the (T, S) block shards over the full
    production device grid with ZERO collectives (counter addressing —
    the paper's "no extra root hardware per instance" claim, verified on
    the compiled HLO) and reports the per-device memory footprint.
    """
    from repro.core import engine

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = rng_axes(mesh)
    n_chips = int(np_prod(mesh.devices.shape))
    report: Dict[str, Any] = {
        "kind": "rng_fanout",
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "axes": list(axes), "chips": n_chips,
        "num_streams": num_streams, "num_steps": num_steps,
    }
    for sampler, out_dtype in (("bits", "float32"), ("uniform", "bfloat16")):
        plan = engine.make_plan(seed=7, num_streams=num_streams,
                                num_steps=num_steps, sampler=sampler,
                                out_dtype=out_dtype)
        t0 = time.time()
        lowered = jax.jit(lambda: engine.generate_sharded(
            plan, mesh=mesh, axis_names=axes)).lower()
        compiled = lowered.compile()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        report[sampler] = {
            "compile_s": round(time.time() - t0, 2),
            "collective_bytes": coll,
            "memory": _mem_report(compiled),
            "hlo_lines": hlo.count("\n"),
        }
    return report


def service_cell(*, burst: int = 192, tenants: int = 96,
                 seed: int = 11) -> Dict[str, Any]:
    """In-process RandService burst on the forced host platform.

    The serving analogue of ``rng_fanout_cell``: fires a deterministic
    mixed (shape, sampler, dtype) burst through the coalescing frontend
    + standing pool, then asserts the acceptance properties — zero
    counter-window overlap (ledger-verified on both the live service
    and the journal) and bit-identical journal replay — and reports
    requests/s, p50/p99 latency and the coalescing factor.
    """
    from repro.service import (Journal, RandServer, ServerConfig, replay,
                               verify_ledger_disjoint)
    from repro.service.audit import response_digest
    from repro.service.burst import make_requests, run_burst

    journal = Journal()
    server = RandServer(seed, config=ServerConfig(
        max_batch=64, max_delay_s=0.25,
        hot_classes=(("uniform", "float32"),)), journal=journal)
    t0 = time.time()
    responses = run_burst(server, make_requests(
        burst=burst, tenants=tenants, seed=seed))
    wall_s = time.time() - t0
    stats = server.stats()
    windows = verify_ledger_disjoint(server.block_service)
    verify_ledger_disjoint(journal)
    digest = response_digest(responses)
    replay_ok = response_digest(replay(journal, seed=seed)) == digest
    server.shutdown()
    return {
        "kind": "service", "burst": burst, "tenants": tenants,
        "seed": seed, "wall_s": round(wall_s, 3), "digest": digest,
        "replay_ok": replay_ok, "ledger_windows": windows,
        "stats": {k: (round(v, 4) if isinstance(v, float) else v)
                  for k, v in stats.items()},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (hillclimb variants)")
    ap.add_argument("--param-dtype", default=None, choices=[None, "bf16"])
    ap.add_argument("--tag", default="",
                    help="suffix for output json names")
    ap.add_argument("--rng-fanout", action="store_true",
                    help="compile the RNG (host, stream) block fan-out on "
                         "the production mesh(es) and report collective "
                         "bytes (expected 0) + memory")
    ap.add_argument("--service", action="store_true",
                    help="run an in-process RandService mixed burst and "
                         "report requests/s, latency, coalescing factor, "
                         "ledger disjointness and replay bit-identity")
    args = ap.parse_args()

    if args.service:
        os.makedirs(args.out, exist_ok=True)
        rep = service_cell()
        with open(os.path.join(args.out, "service.json"), "w") as f:
            json.dump(rep, f, indent=2)
        s = rep["stats"]
        status = "OK" if rep["replay_ok"] else "FAIL"
        print(f"[{status}] service burst={rep['burst']} "
              f"tenants={s['tenants']} req/s={s['requests_per_s']:.0f} "
              f"p50={s['latency_p50_ms']:.1f}ms "
              f"p99={s['latency_p99_ms']:.1f}ms "
              f"calls/req={s['calls_per_request']:.3f} "
              f"replay={'bit-identical' if rep['replay_ok'] else 'MISMATCH'}",
              flush=True)
        if not rep["replay_ok"]:
            raise SystemExit("service replay mismatch")
        return

    if args.rng_fanout:
        os.makedirs(args.out, exist_ok=True)
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            rep = rng_fanout_cell(multi_pod=mp)
            tag = f"rng_fanout__{'multipod' if mp else 'pod'}"
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rep, f, indent=2)
            coll = {s: rep[s]["collective_bytes"]["total"]
                    for s in ("bits", "uniform")}
            print(f"[OK] {tag} mesh={rep['mesh']} chips={rep['chips']} "
                  f"collective_bytes={coll}", flush=True)
        return

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'multipod' if mp else 'pod'}"
        if args.tag:
            tag += "__" + args.tag
        try:
            # roofline fit only on the single-pod mesh; the multi-pod pass
            # proves the "pod" axis shards (memory + compile success)
            rep = lower_cell(arch, shape, multi_pod=mp, fit_costs=not mp,
                             overrides=overrides or None,
                             param_dtype=args.param_dtype)
        except Exception as e:
            rep = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rep, f, indent=2)
        status = ("SKIP" if rep.get("skipped") else
                  "FAIL" if rep.get("error") else "OK")
        extra = ""
        if status == "OK":
            r = rep["roofline"]
            extra = (f" mem/dev={rep['memory'].get('total_bytes_per_device', 0)/2**30:.2f}GiB"
                     f" compute={r['compute_s']*1e3:.2f}ms"
                     f" memory={r['memory_s']*1e3:.2f}ms"
                     f" coll={r['collective_s']*1e3:.2f}ms"
                     f" bottleneck={r['bottleneck']}"
                     f" compile={rep['compile_s']}s")
        elif status == "FAIL":
            extra = " " + rep["error"][:200]
        print(f"[{status}] {tag}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
