"""Step functions (train / prefill / decode) + their sharding specs.

These are the units the dry-run lowers and the trainer/server jit:
  * train_step: fwd + bwd + AdamW update (+ per-step ThundeRiNG substream
    derivation: rng = derive(root, step) — deterministic, mesh-independent)
  * prefill_step / decode_step: serving path.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import stream as tstream
from repro.models import registry, sharding
from repro.models.common import ArchConfig, flatten, unflatten
from repro.optim import adamw_init, adamw_update, cosine_schedule


def make_train_step(model: registry.Model, *, seed: int = 0,
                    peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000,
                    compress: Optional[str] = None,
                    microbatches: int = 1,
                    param_dtype: Optional[str] = None):
    """fwd + bwd + AdamW.  ``microbatches`` > 1 = gradient accumulation:
    the global batch is processed in M sequential slices (lax.scan), so
    live activation memory scales with B/M while the optics (loss, grads,
    update) are identical to the monolithic step.

    ``param_dtype="bf16"`` (mixed precision): the fwd/bwd runs against a
    bf16 cast of the fp32 masters, made ONCE per step before the FSDP
    all-gathers — weight-gather AND gradient-reduce bytes halve; AdamW
    still updates fp32 masters.  (Beyond-paper distributed-optimization
    lever; see EXPERIMENTS.md §Perf.)"""
    cfg = model.cfg
    lr = cosine_schedule(peak_lr, warmup, total_steps)
    root = tstream.new_stream(seed, 0xD07)

    def grads_of(params, batch, rng):
        if param_dtype == "bf16":
            p16 = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p, params)

            def loss16(p):
                loss, metrics = model.loss(p, batch, rng)
                return loss, metrics

            (val, metrics), g16 = jax.value_and_grad(
                loss16, has_aux=True)(p16)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), g16)
            return (val, metrics), grads

        def loss_fn(p):
            loss, metrics = model.loss(p, batch, rng)
            return loss, metrics
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch, step):
        rng = tstream.derive(root, step.astype(jnp.uint32))
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch, rng)
        else:
            M = microbatches
            mb = jax.tree.map(
                lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mbatch):
                (l, m), g = grads_of(params, mbatch, rng)
                return jax.tree.map(jnp.add, acc, g), (l, m)

            grads, (losses, ms) = jax.lax.scan(body, zeros, mb)
            grads = jax.tree.map(lambda g: g / M, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, ms)
        params, opt_state = adamw_update(grads, opt_state, params, lr=lr,
                                         compress=compress)
        metrics = dict(metrics, loss=loss, step=step + 1)
        return params, opt_state, metrics

    return train_step


def make_serve_fns(model: registry.Model):
    cfg = model.cfg

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    def decode_step(params, cache, token, pos):
        return model.decode(params, cache, token, pos)

    return prefill_step, decode_step


# ---------------------------------------------------------------------------
# sharding plumbing
# ---------------------------------------------------------------------------

def param_sharding_tree(model: registry.Model, params, specs, mesh: Mesh,
                        mode: str = "train"):
    flat = flatten(params)
    pspecs = sharding.param_pspecs(specs, flat, mesh, mode)
    tree = unflatten({k: NamedSharding(mesh, v) for k, v in pspecs.items()})
    return tree, unflatten(dict(pspecs))


def batch_sharding(cfg: ArchConfig, batch_specs: Dict[str, Any], mesh: Mesh):
    out = {}
    for name, spec in batch_specs.items():
        if name == "cache":
            pspec = sharding.cache_pspecs(cfg, spec, mesh)
            out[name] = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                                     is_leaf=lambda x: isinstance(x, P))
        elif name == "pos":
            out[name] = NamedSharding(mesh, P())
        else:
            bspec = sharding.batch_pspec(mesh, spec.shape[0])
            extra = (None,) * (len(spec.shape) - 1)
            out[name] = NamedSharding(mesh, P(*(tuple(bspec) + extra)))
    return out


def opt_sharding_like(param_shardings, mesh: Mesh):
    """AdamWState sharding: step replicated; m/v like params."""
    from repro.optim.adamw import AdamWState
    return AdamWState(NamedSharding(mesh, P()), param_shardings,
                      param_shardings)
