"""Roofline analysis helpers: HLO collective parsing, hardware model.

Import-safe (no jax device-state side effects) — ``dryrun.py`` (which
forces the 512-device host platform) imports THIS module, never the
other way round.
"""
from __future__ import annotations

import re
from typing import Any, Dict

# TPU v5e hardware model for the roofline (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every TYPE[dims] group in an HLO result type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind bytes moved (result-shape convention), from optimized
    post-SPMD HLO.  'start' variants counted; 'done' variants skipped so
    async pairs are not double counted."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.*?) (\w[\w\-]*)\(", line)
        if not m:
            continue
        result_type, opname = m.groups()
        base = opname.replace("-start", "")
        if opname.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            out[base] += _shape_bytes(result_type)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out
