"""Sharded AdamW with global-norm clipping and an optional gradient-
compression hook.

Optimizer state is a pytree of the same structure/sharding as the params
(m, v per leaf), so FSDP param sharding gives ZeRO-style optimizer-state
sharding for free: each device updates only its own shard.

``compress="bf16"`` rounds gradients to bf16 before the update — the
distributed-optimization trick of halving gradient all-reduce bytes (the
reduction itself is inserted by SPMD from the batch-sharded loss); the
fp32 master params keep the update numerically stable.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # () int32
    m: Any                     # pytree like params
    v: Any                     # pytree like params


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0,
                 compress: Optional[str] = None) -> Tuple[Any, AdamWState]:
    if compress == "bf16":
        grads = jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    t = step.astype(jnp.float32)
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    c1 = 1.0 - jnp.power(b1, t)
    c2 = 1.0 - jnp.power(b2, t)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_); new_m.append(nm); new_v.append(nv)
    return (jax.tree.unflatten(treedef, new_p),
            AdamWState(step, jax.tree.unflatten(treedef, new_m),
                       jax.tree.unflatten(treedef, new_v)))
