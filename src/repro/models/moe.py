"""Mixture-of-Experts MLP: GShard/Switch-style dense dispatch with capacity.

Tokens are grouped (group dim shards over the data axes), routed top-k with
optional ThundeRiNG jitter, and dispatched to (E, C) expert slots via
one-hot einsums — collective-light and fully SPMD-partitionable; experts
shard over the "model" mesh axis (EP) when E divides it, otherwise the
expert FFN dim shards (TP inside each expert).

Aux losses: load-balance (Switch) + router z-loss, returned per layer.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stream as tstream
from repro.models import layers as L
from repro.models.common import ArchConfig


def _group_size(n: int, want: int = 2048, min_groups: int = 32) -> int:
    """Largest divisor of n that is <= want and (if possible) keeps
    n/gs >= min_groups so the group dim stays shardable over data axes."""
    best = 1
    for gs in range(1, min(want, n) + 1):
        if n % gs == 0:
            if n // gs >= min_groups:
                best = gs
            elif best == 1:
                best = gs
    return best


def router_probs(x, router_w, rng: Optional[tstream.ThunderStream],
                 jitter: float = 1e-2):
    """x: (G, gs, D) -> router probabilities (G, gs, E) fp32."""
    if rng is not None and jitter > 0:
        bits = L.dropout_bits((rng.h_hi, rng.h_lo), (rng.ctr_hi, rng.ctr_lo),
                              x.shape)
        u = (bits >> np.uint32(8)).astype(jnp.float32) * np.float32(2.0 ** -24)
        x = x * (1.0 + jitter * (2.0 * u - 1.0)).astype(x.dtype)
    logits = jnp.einsum("gsd,de->gse", x, router_w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return jax.nn.softmax(logits, axis=-1), logits


def moe_mlp(cfg: ArchConfig, h: jnp.ndarray, router_w, wg, wi, wo,
            rng: Optional[tstream.ThunderStream]
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h: (B, S, D) -> (B, S, D), aux scalar loss.

    wg/wi: (E, D, F); wo: (E, F, D).
    """
    from repro.models import sharding as shd
    B, S, D = h.shape
    E, k = cfg.n_experts, cfg.top_k
    # gather the SP'd sequence before routing: the (B,S,D) activation is
    # far smaller than the (E,D,F) expert weights XLA would otherwise
    # gather to resolve the S-vs-E model-axis conflict (§Perf/H1)
    h = shd.gather_seq_hint(h)
    N = B * S
    gs = _group_size(N, want=cfg.moe_group)
    G = N // gs
    x = h.reshape(G, gs, D)

    probs, logits = router_probs(x, router_w, rng)
    top_w, top_idx = jax.lax.top_k(probs, k)                  # (G, gs, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    C = max(1, int(np.ceil(cfg.capacity_factor * k * gs / E)))

    # slot assignment: for each of the k choices in priority order, position
    # within the chosen expert = running count of prior tokens routed there.
    dispatch = jnp.zeros((G, gs, E, C), jnp.bfloat16)
    combine = jnp.zeros((G, gs, E, C), jnp.float32)
    counts = jnp.zeros((G, E), jnp.int32)
    for j in range(k):
        idx_j = top_idx[..., j]                               # (G, gs)
        onehot = jax.nn.one_hot(idx_j, E, dtype=jnp.int32)    # (G, gs, E)
        pos_in_e = jnp.cumsum(onehot, axis=1) - onehot + counts[:, None, :]
        pos_j = jnp.sum(pos_in_e * onehot, axis=-1)           # (G, gs)
        keep = pos_j < C
        slot = jax.nn.one_hot(jnp.where(keep, pos_j, C), C + 1,
                              dtype=jnp.float32)[..., :C]     # (G, gs, C)
        d_j = onehot.astype(jnp.float32)[..., None] * slot[..., None, :]
        dispatch = dispatch + d_j.astype(jnp.bfloat16)
        combine = combine + d_j * top_w[..., j][..., None, None]
        counts = counts + jnp.sum(onehot, axis=1)

    # dispatch tokens -> (G, E, C, D)
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, x)
    # expert FFN (E sharded over model when divisible)
    gate = jnp.einsum("gecd,edf->gecf", xe, wg.astype(xe.dtype))
    up = jnp.einsum("gecd,edf->gecf", xe, wi.astype(xe.dtype))
    act = jax.nn.silu(gate) * up
    ye = jnp.einsum("gecf,efd->gecd", act, wo.astype(xe.dtype))
    # combine back
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(ye.dtype), ye)

    # Switch load-balance loss + router z-loss
    density = jnp.mean(probs, axis=1)                         # (G, E)
    top1 = jax.nn.one_hot(top_idx[..., 0], E, dtype=jnp.float32)
    frac = jnp.mean(top1, axis=1)                             # (G, E)
    lb = E * jnp.mean(jnp.sum(density * frac, axis=-1))
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = lb + 1e-3 * z
    return y.reshape(B, S, D), aux
