"""Model stack: composable families (dense/moe/vlm/ssm/hybrid/encdec)
with scan-over-layers, ThundeRiNG-stream init & dropout, and logical-axis
sharding specs.  Entry point: ``repro.models.registry.build(cfg)``."""
