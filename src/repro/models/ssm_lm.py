"""Mamba2 decoder-only LM (attention-free) — family "ssm"."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import stream as tstream
from repro.models import layers as L
from repro.models import mamba2
from repro.models import sharding as shd
from repro.models.common import ArchConfig, ParamFactory, unflatten


def init_ssm_lm(cfg: ArchConfig, seed: int):
    pf = ParamFactory(seed)
    D, V = cfg.d_model, cfg.vocab
    flat = {"embed": pf.normal("embed", (V, D), 0.02, ("vocab", "embed")),
            "final_norm": pf.zeros("final_norm", (D,), ("embed",))}
    flat.update(mamba2.mamba_layer_params(pf, cfg, "layers", cfg.n_layers))
    return unflatten(flat), dict(pf.specs)


def _scan(cfg, h, params, body):
    idx = jnp.arange(cfg.n_layers)
    if cfg.remat == "full":
        body = jax.checkpoint(body)
    return jax.lax.scan(body, (h,), (params["layers"], idx),
                        unroll=True if cfg.scan_unroll else 1)


def ssm_forward(cfg: ArchConfig, params, tokens, *, rng=None,
                return_hidden: bool = False):
    h = shd.activation_hint(L.embed(tokens, params["embed"]))

    def body(carry, xs):
        (h,) = carry
        lp, li = xs
        lrng = tstream.derive(rng, li) if rng is not None else None
        h, _ = mamba2.mamba_block(cfg, lp, h, lrng)
        return (h,), ()

    (h,), _ = _scan(cfg, h, params, body)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return h, jnp.zeros((), jnp.float32)
    table = params["embed"] if cfg.tie_embeddings else params.get(
        "unembed", params["embed"])
    return L.unembed(h, table), jnp.zeros((), jnp.float32)


def ssm_prefill(cfg: ArchConfig, params, tokens):
    """Returns (last logits, cache = (ssm_states, conv tails x3))."""
    h = shd.activation_hint(L.embed(tokens, params["embed"]))

    def body(carry, xs):
        (h,) = carry
        lp, li = xs
        h, (state, tails) = mamba2.mamba_block(cfg, lp, h)
        return (h,), (state, tails[0], tails[1], tails[2])

    (h,), caches = _scan(cfg, h, params, body)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(h[:, -1:], params["embed"])[:, 0]
    return logits, caches


def ssm_decode(cfg: ArchConfig, params, cache, token, pos):
    """One token step; ``pos`` unused (state-based), kept for API parity."""
    states, tx, tb, tc = cache
    h = L.embed(token, params["embed"])

    def body(carry, xs):
        (h,) = carry
        lp, li, st, x_, b_, c_ = xs
        h, st, (x_, b_, c_) = mamba2.mamba_decode_step(
            cfg, lp, h, st, (x_, b_, c_))
        return (h,), (st, x_, b_, c_)

    idx = jnp.arange(cfg.n_layers)
    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    (h,), new_cache = jax.lax.scan(
        body_fn, (h,), (params["layers"], idx, states, tx, tb, tc),
        unroll=True if cfg.scan_unroll else 1)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return L.unembed(h, params["embed"])[:, 0], new_cache


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    """Zeroed decode cache (ssm_states, conv tails)."""
    Lc, H, N, P = cfg.n_layers, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    ck = cfg.ssm_conv
    return (jnp.zeros((Lc, batch, H, N, P), jnp.float32),
            jnp.zeros((Lc, batch, ck - 1, cfg.d_inner), L.COMPUTE_DTYPE),
            jnp.zeros((Lc, batch, ck - 1, cfg.ssm_state), L.COMPUTE_DTYPE),
            jnp.zeros((Lc, batch, ck - 1, cfg.ssm_state), L.COMPUTE_DTYPE))
