"""Logical-axis -> mesh PartitionSpec resolution (DP/FSDP/TP/EP/SP).

Per-param assignment (not a single global map) so indivisible dims fall
back gracefully per-tensor:

  TP ("model" axis): first divisible axis in priority order
      experts > kv_heads > q_rep > f > ssm_inner > ssm_heads > vocab
      > embed (>=2-D params only — the row-parallel fallback for archs
      like qwen1.5-32b whose 40 heads don't divide a 16-way model axis).
  FSDP (train only; "data" [+ "pod"] axes): first remaining divisible
      axis in order embed > vocab > f > ssm_inner > head — ZeRO-3-style
      parameter + optimizer-state sharding.

Serve mode skips FSDP (weights TP-only, batch over data) and shards KV
caches: kv_heads over model when divisible, else the *context* axis over
model (flash-decoding); batch over data when divisible, else context over
data too (the long_500k single-sequence case).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig

TP_PRIORITY = ("experts", "kv_heads", "q_rep", "f", "ssm_inner",
               "ssm_heads", "vocab")
TP_FALLBACK = ("embed",)
FSDP_PRIORITY = ("embed", "vocab", "f", "ssm_inner", "head")


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return fsdp_axes(mesh)


def _axsize(mesh: Mesh, axes) -> int:
    sizes = mesh_axis_sizes(mesh)
    if isinstance(axes, str):
        return sizes[axes]
    return int(np.prod([sizes[a] for a in axes])) if axes else 1


def param_pspec(axes: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
                mode: str = "train") -> P:
    """PartitionSpec for one param given its logical axes + shape."""
    model_sz = _axsize(mesh, "model")
    assign: list = [None] * len(axes)

    def try_assign(names, mesh_axis, mesh_sz, skip_1d=False):
        for name in names:
            if name in axes:
                i = axes.index(name)
                if assign[i] is None and shape[i] % mesh_sz == 0 \
                        and shape[i] > 0:
                    if skip_1d and sum(s > 1 for s in shape) < 2:
                        continue
                    assign[i] = mesh_axis
                    return True
        return False

    ok = try_assign(TP_PRIORITY, "model", model_sz)
    if not ok:
        # (Measured, kept: removing the row-parallel fallback from
        # unshardable-head attention params cut collectives only 2% while
        # adding 7 GiB of full-head k/v transients — refuted hypothesis,
        # see EXPERIMENTS.md §Perf/H2.)
        try_assign(TP_FALLBACK, "model", model_sz, skip_1d=True)
    # Embedding/unembedding tables stay TP-only: FSDP-sharding their
    # d_model axis makes the gather/scatter backward reshard the (B,S,D)
    # cotangent to a batch-replicated fp32 layout (multi-GiB per buffer).
    if mode == "train" and "vocab" not in axes:
        fa = fsdp_axes(mesh)
        if fa:
            fsz = _axsize(mesh, fa)
            remaining = [n for n in FSDP_PRIORITY
                         if n in axes and assign[axes.index(n)] is None]
            try_assign(remaining, fa if len(fa) > 1 else fa[0], fsz)
    return P(*assign)


def param_pspecs(specs: Dict[str, Tuple[str, ...]], params_flat,
                 mesh: Mesh, mode: str = "train") -> Dict[str, P]:
    out = {}
    for path, axes in specs.items():
        out[path] = param_pspec(axes, tuple(params_flat[path].shape), mesh,
                                mode)
    return out


def batch_pspec(mesh: Mesh, batch_size: int) -> P:
    da = data_axes(mesh)
    if da and batch_size % _axsize(mesh, da) == 0:
        return P(da if len(da) > 1 else da[0])
    return P(None)


def _cache_kv_pspec(mesh: Mesh, shape, kv_idx: int, ctx_idx: int,
                    batch_idx: int = 1) -> P:
    """(L/napps, B, T, K, hd) attention-cache spec."""
    sizes = mesh_axis_sizes(mesh)
    assign: list = [None] * len(shape)
    da = data_axes(mesh)
    dsz = _axsize(mesh, da) if da else 1
    if shape[kv_idx] % sizes["model"] == 0:
        assign[kv_idx] = "model"
    elif shape[ctx_idx] % sizes["model"] == 0:
        assign[ctx_idx] = "model"
    if da:
        if shape[batch_idx] % dsz == 0:
            assign[batch_idx] = da if len(da) > 1 else da[0]
        elif assign[ctx_idx] is None and shape[ctx_idx] % dsz == 0:
            assign[ctx_idx] = da if len(da) > 1 else da[0]
        elif assign[ctx_idx] == "model" and \
                shape[ctx_idx] % (dsz * sizes["model"]) == 0:
            assign[ctx_idx] = (*da, "model")
    return P(*assign)


def cache_pspecs(cfg: ArchConfig, cache, mesh: Mesh):
    """PartitionSpecs matching Model.init_cache's pytree structure."""
    sizes = mesh_axis_sizes(mesh)
    da = data_axes(mesh)
    dsz = _axsize(mesh, da) if da else 1

    def b_axis(b):
        if da and b % dsz == 0:
            return da if len(da) > 1 else da[0]
        return None

    def feat_axis(n):
        return "model" if n % sizes["model"] == 0 else None

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        k, v = cache
        spec = _cache_kv_pspec(mesh, k.shape, kv_idx=3, ctx_idx=2)
        return (spec, spec)
    if fam == "encdec":
        sk, sv, ck, cv = cache
        s_spec = _cache_kv_pspec(mesh, sk.shape, kv_idx=3, ctx_idx=2)
        c_spec = _cache_kv_pspec(mesh, ck.shape, kv_idx=3, ctx_idx=2)
        return (s_spec, s_spec, c_spec, c_spec)
    if fam == "ssm":
        st, tx, tb, tc = cache
        return (P(None, b_axis(st.shape[1]), feat_axis(st.shape[2]), None, None),
                P(None, b_axis(tx.shape[1]), None, feat_axis(tx.shape[3])),
                P(None, b_axis(tb.shape[1]), None, None),
                P(None, b_axis(tc.shape[1]), None, None))
    if fam == "hybrid":
        kc, vc, st, tx, tb, tc = cache
        kv_spec = _cache_kv_pspec(mesh, kc.shape, kv_idx=3, ctx_idx=2)
        return (kv_spec, kv_spec,
                P(None, b_axis(st.shape[1]), feat_axis(st.shape[2]), None, None),
                P(None, b_axis(tx.shape[1]), None, feat_axis(tx.shape[3])),
                P(None, b_axis(tb.shape[1]), None, None),
                P(None, b_axis(tc.shape[1]), None, None))
    raise ValueError(fam)


def tree_shardings(mesh: Mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def context_parallel_attention(mesh_or_none, n_kv: int, n_rep: int) -> bool:
    """True when neither kv heads nor query repeats divide the model axis
    (e.g. qwen1.5-32b's 40 MHA heads on a 16-way axis): attention then runs
    context-parallel — q stays sequence-sharded, k/v are gathered."""
    m = mesh_or_none or ambient_mesh()
    if m is None or "model" not in m.axis_names:
        return False
    ms = mesh_axis_sizes(m)["model"]
    return (n_kv % ms != 0) and (n_rep % ms != 0)


def ambient_mesh() -> Optional[Mesh]:
    """The mesh installed by ``with mesh:`` (legacy thread resources), or
    None outside any mesh context (e.g. single-device tests)."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def prefer_seq_gather(cfg, batch: int, seq: int) -> bool:
    """Resolve the SP-carry-vs-TP-weight einsum conflict by napkin math:
    inside a layer, EITHER the (B,S,D) activation's sequence axis or the
    (D,F)/head weight's model axis must be gathered.  Gather whichever is
    smaller: activations win for big-F archs once microbatching shrinks
    B_local (qwen2-vl-72b, granite-34b); weights win for glm4-class."""
    m = ambient_mesh()
    if m is None:
        return False
    sizes = mesh_axis_sizes(m)
    if "model" not in sizes or seq <= 1 or seq % sizes["model"]:
        return False
    da = data_axes(m)
    dsz = _axsize(m, da) if da else 1
    b_loc = batch // dsz if (da and batch % dsz == 0) else batch
    act_bytes = b_loc * seq * cfg.d_model * 2 * 2   # bf16, gather+scatter
    n_mats = 3 if cfg.act in ("silu", "geglu") else 2
    w_bytes = cfg.d_model * cfg.d_ff * 4 * n_mats
    # 2x margin: XLA's default (weight-gather) also keeps remat cheaper,
    # so only force activation-gather on a clear win (measured: granite-34b
    # regresses at ~1.3x, qwen2-vl-72b wins at ~10x)
    return act_bytes * 2 < w_bytes


def gather_seq_hint(x):
    """Constraint (batch over data, seq REPLICATED): applied at the input
    of head-/f-sharded einsums so XLA gathers the SP'd sequence instead of
    'involuntarily' replicating the much larger head/f dimension."""
    m = ambient_mesh()
    if m is None:
        return x
    da = data_axes(m)
    spec: list = [None] * x.ndim
    if da and x.shape[0] % _axsize(m, da) == 0:
        spec[0] = da if len(da) > 1 else da[0]
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, P(*spec)))


def activation_hint(x, *, seq_axis: Optional[int] = 1):
    """Sequence-parallel sharding constraint for a (B, S, ...) activation.

    Applied to the scan-over-layers carry: the *saved* per-layer tensor is
    (batch over data axes) x (seq over model axis); the full-sequence /
    full-head tensors inside a layer are transient and rematerialized.
    This is what lets 72B-class train_4k activations fit 16 GB/chip.
    No-op outside a mesh context or when dims don't divide.
    """
    m = ambient_mesh()
    if m is None:
        return x
    sizes = mesh_axis_sizes(m)
    da = data_axes(m)
    spec: list = [None] * x.ndim
    if da and x.shape[0] % _axsize(m, da) == 0:
        spec[0] = da if len(da) > 1 else da[0]
    if (seq_axis is not None and "model" in sizes and x.ndim > seq_axis
            and x.shape[seq_axis] % sizes["model"] == 0
            and x.shape[seq_axis] > 1):
        spec[seq_axis] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, P(*spec)))
