"""Transformer building blocks (pure functions, pjit/SPMD-friendly).

Conventions:
  * params are fp32; compute casts to bf16 with fp32 softmax/norm accums.
  * attention heads carry split (K, R) dims — K = kv heads, R = query
    repeats (H = K*R) — so EITHER dim can take the "model" mesh axis
    (GQA with many kv heads shards K; MQA shards R with K replicated).
  * memory-efficient attention: lax.scan over query chunks with full-key
    logits per chunk (peak q_chunk x T per head) — no S x S materialization.
  * dropout is counter-addressable ThundeRiNG bits (the decorrelator member
    of the family): mask(b,s,d) depends only on (leaf h, flat element
    index), so it is bitwise identical under any sharding or re-sharding.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import splitmix, u64
from repro.core import stream as tstream
from repro.core.u64 import U32

COMPUTE_DTYPE = jnp.bfloat16


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, np.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., S, K, R, hd) or (..., S, K, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    # insert singleton head dims between S and hd so angles rank-matches x
    extra = x.ndim - angles.ndim
    for _ in range(extra):
        angles = angles[..., None, :]
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoid_positions(n_pos: int, d_model: int) -> np.ndarray:
    """Whisper-style sinusoidal embeddings, (n_pos, d_model) f32."""
    log_timescale = math.log(10000.0) / (d_model // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(d_model // 2, dtype=np.float32))
    ang = np.arange(n_pos, dtype=np.float32)[:, None] * inv[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1)


# ---------------------------------------------------------------------------
# ThundeRiNG dropout (counter-addressable, partition-friendly)
# ---------------------------------------------------------------------------

def dropout_bits(h: Tuple[jnp.ndarray, jnp.ndarray],
                 ctr0: Tuple[jnp.ndarray, jnp.ndarray],
                 shape: Tuple[int, ...]) -> jnp.ndarray:
    """uint32 bits for elements ctr0 .. ctr0+prod(shape)-1, laid out row-
    major over ``shape`` — computed elementwise from broadcasted iotas (no
    flat intermediate), so XLA partitions it like any elementwise op."""
    sizes = list(shape)
    flat_hi = jnp.zeros(shape, U32)
    flat_lo = jnp.zeros(shape, U32)
    stride = 1
    for d in reversed(range(len(sizes))):
        idx = jax.lax.broadcasted_iota(U32, tuple(shape), d)
        # flat += idx * stride (64-bit accumulate)
        shi, slo = u64.mul32_wide(idx, U32(stride & 0xFFFFFFFF))
        shi = shi + idx * U32((stride >> 32) & 0xFFFFFFFF)
        flat_hi, flat_lo = u64.add64((flat_hi, flat_lo), (shi, slo))
        stride *= sizes[d]
    ctr = u64.add64((jnp.broadcast_to(ctr0[0], shape),
                     jnp.broadcast_to(ctr0[1], shape)),
                    (flat_hi, flat_lo))
    hh = (jnp.broadcast_to(h[0], shape), jnp.broadcast_to(h[1], shape))
    return splitmix.ctr_decorrelator(hh, ctr)


def dropout(x: jnp.ndarray, stream: Optional[tstream.ThunderStream],
            rate: float) -> jnp.ndarray:
    if rate <= 0.0 or stream is None:
        return x
    bits = dropout_bits((stream.h_hi, stream.h_lo),
                        (stream.ctr_hi, stream.ctr_lo), x.shape)
    thresh = U32(int(round((1.0 - rate) * (1 << 32))) & 0xFFFFFFFF)
    keep = bits < thresh
    scale = x.dtype.type(1.0 / (1.0 - rate))
    return jnp.where(keep, x * scale, jnp.zeros_like(x))


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _attn_logits(q, k, scale):
    # q: (B, S, K, R, d); k: (B, T, K, d) -> (B, K, R, S, T) fp32
    return jnp.einsum("bqkrd,btkd->bkrqt", q, k,
                      preferred_element_type=jnp.float32) * scale


def _attn_combine(w, v):
    # w: (B, K, R, S, T) f32; v: (B, T, K, d) -> (B, S, K, R, d)
    return jnp.einsum("bkrqt,btkd->bqkrd", w.astype(v.dtype), v)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool, q_chunk: int = 512,
              q_offset: int = 0) -> jnp.ndarray:
    """Memory-efficient attention.

    q: (B, S, K, R, d); k/v: (B, T, K, d).  Returns (B, S, K, R, d).
    ``q_offset``: absolute position of q[0] (for causal masking in
    prefill-with-cache scenarios).
    """
    B, S, K, R, d = q.shape
    T = k.shape[1]
    scale = np.float32(1.0 / math.sqrt(d))
    qc = min(q_chunk, S)
    while S % qc:
        qc -= 1
    nq = S // qc

    def chunk(qi, start):
        logits = _attn_logits(qi, k, scale)  # (B, K, R, qc, T)
        if causal:
            qpos = start + jax.lax.broadcasted_iota(jnp.int32, (qc, T), 0) \
                + q_offset
            tpos = jax.lax.broadcasted_iota(jnp.int32, (qc, T), 1)
            mask = (tpos <= qpos)[None, None, None]
            logits = jnp.where(mask, logits, np.float32(-1e30))
        w = jax.nn.softmax(logits, axis=-1)
        return _attn_combine(w, v)

    if nq == 1:
        return chunk(q, 0)

    qs = q.reshape(B, nq, qc, K, R, d).transpose(1, 0, 2, 3, 4, 5)

    # checkpoint each chunk: without it the scan SAVES every chunk's fp32
    # logits for the backward pass — O(S^2) residuals per layer.
    @jax.checkpoint
    def body(_, inp):
        i, qi = inp
        return None, chunk(qi, i * qc)

    _, out = jax.lax.scan(body, None, (jnp.arange(nq), qs))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, K, R, d)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """One-token attention against a (B, T, K, d) cache, masked to <= pos.

    q: (B, 1, K, R, d).  With the cache's T (or K) dim sharded over the
    model axis this is the flash-decoding pattern: XLA turns the softmax
    reductions into per-shard partials + all-reduce.
    """
    B, _, K, R, d = q.shape
    T = k_cache.shape[1]
    if k_cache.dtype != q.dtype:   # e.g. f8 storage -> bf16 compute
        k_cache = k_cache.astype(q.dtype)
        v_cache = v_cache.astype(q.dtype)
    scale = np.float32(1.0 / math.sqrt(d))
    logits = _attn_logits(q, k_cache, scale)  # (B, K, R, 1, T)
    tpos = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)
    mask = (tpos <= pos.astype(jnp.int32))[None, None, None]
    logits = jnp.where(mask, logits, np.float32(-1e30))
    w = jax.nn.softmax(logits, axis=-1)
    return _attn_combine(w, v_cache)


def qkv_split(x: jnp.ndarray, wq, wk, wv, bq=None, bk=None, bv=None):
    """x: (B, S, D); wq: (D, K, R, d); wk/wv: (D, K, d)."""
    q = jnp.einsum("bsd,dkrh->bskrh", x, wq.astype(x.dtype))
    k = jnp.einsum("bsd,dkh->bskh", x, wk.astype(x.dtype))
    v = jnp.einsum("bsd,dkh->bskh", x, wv.astype(x.dtype))
    if bq is not None:
        q = q + bq.astype(x.dtype)
        k = k + bk.astype(x.dtype)
        v = v + bv.astype(x.dtype)
    return q, k, v


def attn_out(o: jnp.ndarray, wo) -> jnp.ndarray:
    """o: (B, S, K, R, d); wo: (K, R, d, D)."""
    return jnp.einsum("bskrh,krhd->bsd", o, wo.astype(o.dtype))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def _act(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu" or kind == "geglu_silu":
        return jax.nn.silu(x)
    if kind == "geglu" or kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def mlp(x: jnp.ndarray, wi, wo, act: str, wg=None) -> jnp.ndarray:
    """Gated (wg != None) or plain MLP.  wi/wg: (D, F); wo: (F, D)."""
    up = jnp.einsum("bsd,df->bsf", x, wi.astype(x.dtype))
    if wg is not None:
        gate = jnp.einsum("bsd,df->bsf", x, wg.astype(x.dtype))
        up = _act(gate, act) * up
    else:
        up = _act(up, act)
    return jnp.einsum("bsf,fd->bsd", up, wo.astype(x.dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------

def embed(tokens: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table.astype(COMPUTE_DTYPE), tokens, axis=0)


def unembed(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """(B, S, D) x (V, D) -> (B, S, V) fp32 logits."""
    return jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype),
                      preferred_element_type=jnp.float32)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy; logits fp32 (B, S, V), labels (B, S)."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def softmax_xent_chunked(h: jnp.ndarray, table: jnp.ndarray,
                         labels: jnp.ndarray, n_chunks: int = 16
                         ) -> jnp.ndarray:
    """Vocab-memory-bounded cross-entropy: unembed + xent evaluated one
    sequence chunk at a time under a remat'd scan, so the (B, S, V) logits
    tensor is never materialized (peak = one (B, S/nc, V) chunk).

    h: (B, S, D) hidden states; table: (V, D); labels: (B, S) int32.
    """
    B, S, D = h.shape
    nc = min(n_chunks, S)
    while S % nc:
        nc -= 1
    sc = S // nc
    hc = h.reshape(B, nc, sc, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, sc).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, xs):
        hx, lx = xs
        logits = unembed(hx, table)                     # (B, sc, V) fp32
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (B * S)
