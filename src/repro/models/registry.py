"""Uniform model API over all families.

``build(cfg)`` returns a ``Model`` with:
  init(seed)                  -> (params, flat path->logical-axes specs)
  loss(params, batch, rng)    -> (scalar loss, metrics dict)
  forward(params, batch, rng) -> (logits, aux)
  prefill(params, batch)      -> (last logits, cache)
  decode(params, cache, token, pos) -> (logits, cache)
  init_cache(batch, ctx)      -> zeroed decode cache pytree
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import stream as tstream
from repro.models import hybrid as hybrid_mod
from repro.models import layers as L
from repro.models import ssm_lm
from repro.models import transformer as tf
from repro.models.common import ArchConfig

AUX_WEIGHT = 0.01  # MoE aux-loss weight


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    init: Callable
    forward: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable


def _xent_loss(cfg, forward, table_fn):
    """Loss via hidden states + vocab-chunked xent (the (B,S,V) logits
    tensor is never materialized; see layers.softmax_xent_chunked)."""
    def loss(params, batch, rng: Optional[tstream.ThunderStream] = None):
        h, aux = forward(params, batch, rng, return_hidden=True)
        nll = L.softmax_xent_chunked(h, table_fn(params), batch["labels"],
                                     n_chunks=cfg.loss_chunks)
        total = nll + AUX_WEIGHT * aux
        return total, {"nll": nll, "aux": aux}
    return loss


def _lm_table(cfg):
    def table_fn(params):
        if cfg.tie_embeddings or "unembed" not in params:
            return params["embed"]
        return params["unembed"]
    return table_fn


def _kv_dt(cfg):
    return jnp.float8_e4m3fn if cfg.kv_dtype == "f8" else L.COMPUTE_DTYPE


def build(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def forward(params, batch, rng=None, return_hidden=False):
            return tf.lm_forward(cfg, params, batch["tokens"],
                                 patches=batch.get("patches"), rng=rng,
                                 return_hidden=return_hidden)

        def prefill(params, batch):
            return tf.lm_prefill(cfg, params, batch["tokens"],
                                 patches=batch.get("patches"))

        def decode(params, cache, token, pos):
            return tf.lm_decode(cfg, params, cache, token, pos)

        def init_cache(batch, ctx):
            K = cfg.n_kv_heads
            hd = cfg.resolved_head_dim
            shape = (cfg.n_layers, batch, ctx, K, hd)
            return (jnp.zeros(shape, _kv_dt(cfg)),
                    jnp.zeros(shape, _kv_dt(cfg)))

        return Model(cfg, lambda seed: tf.init_lm(cfg, seed), forward,
                     _xent_loss(cfg, forward, _lm_table(cfg)), prefill, decode,
                     init_cache)

    if fam == "encdec":
        def forward(params, batch, rng=None, return_hidden=False):
            return tf.encdec_forward(cfg, params, batch["frames"],
                                     batch["tokens"], rng=rng,
                                     return_hidden=return_hidden)

        def prefill(params, batch):
            return tf.encdec_prefill(cfg, params, batch["frames"],
                                     batch["tokens"])

        def decode(params, cache, token, pos):
            return tf.encdec_decode(cfg, params, cache, token, pos)

        def init_cache(batch, ctx):
            K = cfg.n_kv_heads
            hd = cfg.resolved_head_dim
            self_shape = (cfg.n_layers, batch, ctx, K, hd)
            cross_shape = (cfg.n_layers, batch, cfg.enc_ctx, K, hd)
            return (jnp.zeros(self_shape, L.COMPUTE_DTYPE),
                    jnp.zeros(self_shape, L.COMPUTE_DTYPE),
                    jnp.zeros(cross_shape, L.COMPUTE_DTYPE),
                    jnp.zeros(cross_shape, L.COMPUTE_DTYPE))

        return Model(cfg, lambda seed: tf.init_encdec(cfg, seed), forward,
                     _xent_loss(cfg, forward, lambda p: p["embed"]), prefill,
                     decode, init_cache)

    if fam == "ssm":
        def forward(params, batch, rng=None, return_hidden=False):
            return ssm_lm.ssm_forward(cfg, params, batch["tokens"], rng=rng,
                                      return_hidden=return_hidden)

        def prefill(params, batch):
            return ssm_lm.ssm_prefill(cfg, params, batch["tokens"])

        def decode(params, cache, token, pos):
            return ssm_lm.ssm_decode(cfg, params, cache, token, pos)

        def init_cache(batch, ctx):
            return ssm_lm.init_ssm_cache(cfg, batch)

        return Model(cfg, lambda seed: ssm_lm.init_ssm_lm(cfg, seed),
                     forward, _xent_loss(cfg, forward, _lm_table(cfg)), prefill,
                     decode, init_cache)

    if fam == "hybrid":
        def forward(params, batch, rng=None, return_hidden=False):
            return hybrid_mod.hybrid_forward(cfg, params, batch["tokens"],
                                             rng=rng,
                                             return_hidden=return_hidden)

        def prefill(params, batch):
            return hybrid_mod.hybrid_prefill(cfg, params, batch["tokens"])

        def decode(params, cache, token, pos):
            return hybrid_mod.hybrid_decode(cfg, params, cache, token, pos)

        def init_cache(batch, ctx):
            return hybrid_mod.init_hybrid_cache(cfg, batch, ctx)

        return Model(cfg, lambda seed: hybrid_mod.init_hybrid(cfg, seed),
                     forward, _xent_loss(cfg, forward, lambda p: p["embed"]),
                     prefill, decode, init_cache)

    raise ValueError(f"unknown family {fam}")
