"""ArchConfig + parameter initialization driven by ThundeRiNG streams.

Every weight tensor is drawn from a named ``ThunderStream`` leaf derived
from (seed, parameter path), so initialization is a pure function of the
seed — identical across any mesh shape or host count (the MISRN guarantee
applied to init).  Logical sharding axes ride along with each param and are
mapped to physical mesh axes in ``models/sharding.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stream as tstream


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0                 # 0 for attn-free
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 32000
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    act: str = "silu"                # silu | geglu | gelu
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dropout_rate: float = 0.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 2048            # router group size (tokens)
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    # hybrid (zamba2): shared attention block applied every k mamba blocks
    attn_every: int = 0
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_ctx: int = 0                 # encoder positions (audio frames)
    # vlm: number of prefix patch-embedding positions in input_specs
    vision_prefix: int = 0
    # attention chunking for long prefill (memory-efficient attention)
    q_chunk: int = 512
    # remat policy for the layer scan: "full" | "none"
    remat: str = "full"
    # KV-cache storage dtype: "bf16" | "f8" (float8_e4m3; for archs whose
    # full-precision cache cannot fit the pod, e.g. qwen1.5-32b's 40-head
    # MHA at 32k x 128)
    kv_dtype: str = "bf16"
    # sequence chunks for the vocab-chunked xent loss
    loss_chunks: int = 16
    # unroll layer scans (cost-analysis mode: XLA counts while bodies once,
    # so roofline-fit compiles unroll a reduced-depth model; see dryrun)
    scan_unroll: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def scaled(self, **overrides) -> "ArchConfig":
        """A reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)


def param_stream(seed: int, path: str) -> tstream.ThunderStream:
    """The ThunderStream leaf for one named parameter."""
    s = tstream.new_stream(seed, 0)
    # fold the path string into successive derives (stable across runs)
    for token in path.split("/"):
        tag = int.from_bytes(token.encode()[:8].ljust(8, b"\0"), "little")
        s = tstream.derive(s, tag & 0x7FFFFFFF)
    return s


def trunc_normal(s: tstream.ThunderStream, shape, std: float,
                 dtype=jnp.float32) -> jnp.ndarray:
    x = tstream.normal(s, shape, jnp.float32)
    x = jnp.clip(x, -3.0, 3.0) * jnp.float32(std)
    return x.astype(dtype)


class ParamFactory:
    """Collects (path -> array, logical axes) during model init."""

    def __init__(self, seed: int, dtype=jnp.float32):
        self.seed = seed
        self.dtype = dtype
        self.specs: Dict[str, Tuple[str, ...]] = {}

    def normal(self, path: str, shape, std: float, axes: Tuple[str, ...]):
        assert len(shape) == len(axes), (path, shape, axes)
        self.specs[path] = axes
        return trunc_normal(param_stream(self.seed, path), shape, std,
                            self.dtype)

    def zeros(self, path: str, shape, axes: Tuple[str, ...]):
        assert len(shape) == len(axes), (path, shape, axes)
        self.specs[path] = axes
        return jnp.zeros(shape, self.dtype)

    def ones(self, path: str, shape, axes: Tuple[str, ...]):
        assert len(shape) == len(axes), (path, shape, axes)
        self.specs[path] = axes
        return jnp.ones(shape, self.dtype)

    def const(self, path: str, value: jnp.ndarray, axes: Tuple[str, ...]):
        assert value.ndim == len(axes), (path, value.shape, axes)
        self.specs[path] = axes
        return value.astype(self.dtype)


def unflatten(flat: Dict[str, Any]) -> Dict[str, Any]:
    """'a/b/c' keyed dict -> nested dicts."""
    tree: Dict[str, Any] = {}
    for k, v in flat.items():
        parts = k.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def flatten(tree: Dict[str, Any], prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for k, v in tree.items():
        key = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(flatten(v, key))
        else:
            out[key] = v
    return out
