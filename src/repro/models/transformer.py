"""Decoder-only LM (dense / MoE / VLM-backbone) and encoder-decoder
(whisper-family) models as pure functions with scan-over-layers.

Parameter layout: every per-layer tensor is stacked on a leading "layer"
axis and the layer body runs under ``jax.lax.scan`` (+ optional remat), so
HLO size and compile time are O(1) in depth — required for the 64..88-layer
assigned configs to compile on the CPU dry-run.

All randomness (init, dropout) comes from named ThundeRiNG streams.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stream as tstream
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import sharding as shd
from repro.models.common import ArchConfig, ParamFactory, flatten, unflatten

CD = L.COMPUTE_DTYPE


def _kr(cfg: ArchConfig) -> Tuple[int, int]:
    K = cfg.n_kv_heads
    R = cfg.n_heads // max(K, 1)
    return K, R


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_params(pf: ParamFactory, cfg: ArchConfig, prefix: str,
                  n_layers: int, cross: bool = False,
                  moe: bool = False) -> Dict[str, Any]:
    D = cfg.d_model
    K, R = _kr(cfg)
    hd = cfg.resolved_head_dim
    F = cfg.d_ff
    std = 0.02
    std_out = std / np.sqrt(2.0 * max(cfg.n_layers, 1))
    p = {}
    Lx = ("layer",)
    p[f"{prefix}/attn_norm"] = pf.zeros(f"{prefix}/attn_norm",
                                        (n_layers, D), Lx + ("embed",))
    p[f"{prefix}/wq"] = pf.normal(f"{prefix}/wq", (n_layers, D, K, R, hd),
                                  std, Lx + ("embed", "kv_heads", "q_rep", "head"))
    p[f"{prefix}/wk"] = pf.normal(f"{prefix}/wk", (n_layers, D, K, hd), std,
                                  Lx + ("embed", "kv_heads", "head"))
    p[f"{prefix}/wv"] = pf.normal(f"{prefix}/wv", (n_layers, D, K, hd), std,
                                  Lx + ("embed", "kv_heads", "head"))
    p[f"{prefix}/wo"] = pf.normal(f"{prefix}/wo", (n_layers, K, R, hd, D),
                                  std_out, Lx + ("kv_heads", "q_rep", "head", "embed"))
    if cfg.qkv_bias:
        p[f"{prefix}/bq"] = pf.zeros(f"{prefix}/bq", (n_layers, K, R, hd),
                                     Lx + ("kv_heads", "q_rep", "head"))
        p[f"{prefix}/bk"] = pf.zeros(f"{prefix}/bk", (n_layers, K, hd),
                                     Lx + ("kv_heads", "head"))
        p[f"{prefix}/bv"] = pf.zeros(f"{prefix}/bv", (n_layers, K, hd),
                                     Lx + ("kv_heads", "head"))
    if cross:
        p[f"{prefix}/xattn_norm"] = pf.zeros(f"{prefix}/xattn_norm",
                                             (n_layers, D), Lx + ("embed",))
        p[f"{prefix}/xwq"] = pf.normal(f"{prefix}/xwq", (n_layers, D, K, R, hd),
                                       std, Lx + ("embed", "kv_heads", "q_rep", "head"))
        p[f"{prefix}/xwk"] = pf.normal(f"{prefix}/xwk", (n_layers, D, K, hd),
                                       std, Lx + ("embed", "kv_heads", "head"))
        p[f"{prefix}/xwv"] = pf.normal(f"{prefix}/xwv", (n_layers, D, K, hd),
                                       std, Lx + ("embed", "kv_heads", "head"))
        p[f"{prefix}/xwo"] = pf.normal(f"{prefix}/xwo", (n_layers, K, R, hd, D),
                                       std_out, Lx + ("kv_heads", "q_rep", "head", "embed"))
    p[f"{prefix}/mlp_norm"] = pf.zeros(f"{prefix}/mlp_norm", (n_layers, D),
                                       Lx + ("embed",))
    if moe:
        E = cfg.n_experts
        p[f"{prefix}/router"] = pf.normal(f"{prefix}/router", (n_layers, D, E),
                                          std, Lx + ("embed", "experts"))
        p[f"{prefix}/moe_wg"] = pf.normal(f"{prefix}/moe_wg", (n_layers, E, D, F),
                                          std, Lx + ("experts", "embed", "f"))
        p[f"{prefix}/moe_wi"] = pf.normal(f"{prefix}/moe_wi", (n_layers, E, D, F),
                                          std, Lx + ("experts", "embed", "f"))
        p[f"{prefix}/moe_wo"] = pf.normal(f"{prefix}/moe_wo", (n_layers, E, F, D),
                                          std_out, Lx + ("experts", "f", "embed"))
    else:
        gated = cfg.act in ("silu", "geglu")
        if gated:
            p[f"{prefix}/wg"] = pf.normal(f"{prefix}/wg", (n_layers, D, F), std,
                                          Lx + ("embed", "f"))
        p[f"{prefix}/wi"] = pf.normal(f"{prefix}/wi", (n_layers, D, F), std,
                                      Lx + ("embed", "f"))
        p[f"{prefix}/wo_mlp"] = pf.normal(f"{prefix}/wo_mlp", (n_layers, F, D),
                                          std_out, Lx + ("f", "embed"))
    return p


def init_lm(cfg: ArchConfig, seed: int):
    """Decoder-only LM params. Returns (nested params, flat path->axes)."""
    pf = ParamFactory(seed)
    D, V = cfg.d_model, cfg.vocab
    flat = {"embed": pf.normal("embed", (V, D), 0.02, ("vocab", "embed")),
            "final_norm": pf.zeros("final_norm", (D,), ("embed",))}
    if not cfg.tie_embeddings:
        flat["unembed"] = pf.normal("unembed", (V, D), 0.02,
                                    ("vocab", "embed"))
    flat.update(_layer_params(pf, cfg, "layers", cfg.n_layers,
                              moe=cfg.family == "moe"))
    return unflatten(flat), dict(pf.specs)


def init_encdec(cfg: ArchConfig, seed: int):
    pf = ParamFactory(seed)
    D, V = cfg.d_model, cfg.vocab
    flat = {"embed": pf.normal("embed", (V, D), 0.02, ("vocab", "embed")),
            "enc_final_norm_w": pf.ones("enc_final_norm_w", (D,), ("embed",)),
            "enc_final_norm_b": pf.zeros("enc_final_norm_b", (D,), ("embed",)),
            "final_norm_w": pf.ones("final_norm_w", (D,), ("embed",)),
            "final_norm_b": pf.zeros("final_norm_b", (D,), ("embed",))}
    flat.update(_layer_params(pf, cfg, "enc_layers", cfg.enc_layers))
    flat.update(_layer_params(pf, cfg, "dec_layers", cfg.n_layers, cross=True))
    return unflatten(flat), dict(pf.specs)


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _norm(cfg, x, w, b=None):
    if cfg.family == "encdec":
        return L.layer_norm(x, w, b, cfg.norm_eps)
    return L.rms_norm(x, w, cfg.norm_eps)


def _self_attention(cfg, lp, h, positions, *, causal, kv_cache=None,
                    pos=None, prefix=""):
    """Returns (attn_out, (k, v)) — k/v for cache building in prefill."""
    bq = lp.get(f"{prefix}bq")
    q, k, v = L.qkv_split(h, lp[f"{prefix}wq"], lp[f"{prefix}wk"],
                          lp[f"{prefix}wv"], bq,
                          lp.get(f"{prefix}bk"), lp.get(f"{prefix}bv"))
    if cfg.rope_theta > 0 and cfg.family != "encdec":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    if kv_cache is not None:
        k_cache, v_cache = kv_cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), pos, axis=1)
        o = L.decode_attention(q, k_cache, v_cache, pos)
        return L.attn_out(o, lp[f"{prefix}wo"]), (k_cache, v_cache)
    o = L.attention(q, k, v, causal=causal, q_chunk=cfg.q_chunk)
    return L.attn_out(o, lp[f"{prefix}wo"]), (k, v)


def _mlp_block(cfg, lp, h, rng, moe: bool):
    if moe:
        return moe_mod.moe_mlp(cfg, h, lp["router"], lp["moe_wg"],
                               lp["moe_wi"], lp["moe_wo"], rng)
    gated = cfg.act in ("silu", "geglu")
    out = L.mlp(h, lp["wi"], lp["wo_mlp"], cfg.act,
                lp.get("wg") if gated else None)
    return out, jnp.zeros((), jnp.float32)


def _decoder_layer(cfg: ArchConfig, h, lp, positions, rng, *,
                   kv_cache=None, pos=None, enc_out=None, causal=True):
    """One decoder layer. Returns (h, new_kv, aux_loss)."""
    moe = cfg.family == "moe"
    is_ln = cfg.family == "encdec"
    nrm = lambda x, base: _norm(cfg, x, lp[base],
                                lp.get(base + "_b")) if not is_ln else \
        L.layer_norm(x, 1.0 + lp[base], jnp.zeros_like(lp[base]), cfg.norm_eps)
    seq_gather = kv_cache is None and shd.prefer_seq_gather(
        cfg, h.shape[0], h.shape[1])
    a_in = nrm(h, "attn_norm")
    if seq_gather and not shd.context_parallel_attention(
            None, max(cfg.n_kv_heads, 1),
            cfg.n_heads // max(cfg.n_kv_heads, 1)):
        a_in = shd.gather_seq_hint(a_in)
    attn, new_kv = _self_attention(cfg, lp, a_in, positions, causal=causal,
                                   kv_cache=kv_cache, pos=pos)
    attn = L.dropout(attn, rng, cfg.dropout_rate)
    h = h + attn
    if enc_out is not None:
        x_in = nrm(h, "xattn_norm")
        xq = jnp.einsum("bsd,dkrh->bskrh", x_in, lp["xwq"].astype(x_in.dtype))
        xo = L.attention(xq, enc_out[0], enc_out[1], causal=False,
                         q_chunk=cfg.q_chunk) if pos is None else \
            L.decode_attention(xq, enc_out[0], enc_out[1],
                               jnp.asarray(enc_out[0].shape[1], jnp.int32))
        h = h + L.attn_out(xo, lp["xwo"])
    m_in = nrm(h, "mlp_norm")
    if seq_gather:
        m_in = shd.gather_seq_hint(m_in)
    mlp_rng = tstream.derive(rng, 0x4D4C50) if rng is not None else None
    out, aux = _mlp_block(cfg, lp, m_in, mlp_rng, moe)
    out = L.dropout(out, rng, cfg.dropout_rate)
    # sequence-parallel carry: the saved inter-layer activation is
    # (batch over data) x (seq over model); see sharding.activation_hint
    return shd.activation_hint(h + out), new_kv, aux


# ---------------------------------------------------------------------------
# decoder-only forward / prefill / decode
# ---------------------------------------------------------------------------

def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


def _unroll(cfg):
    return True if cfg.scan_unroll else 1


def _scan_layers(cfg, h, stacked, body):
    idx = jnp.arange(cfg.n_layers)
    body = _maybe_remat(cfg, body)
    (h, *rest), outs = jax.lax.scan(body, (h,), (stacked, idx),
                                    unroll=_unroll(cfg))
    return h, outs


def lm_forward(cfg: ArchConfig, params, tokens, *, patches=None,
               rng: Optional[tstream.ThunderStream] = None,
               return_hidden: bool = False):
    """Full forward. tokens (B, S) int32 -> (logits fp32 (B, S, V), aux);
    with ``return_hidden`` the final-norm hidden states replace logits
    (for the chunked-xent loss path that never materializes logits)."""
    h = L.embed(tokens, params["embed"])
    if cfg.family == "vlm" and patches is not None:
        # pad+add (not slice+concat): elementwise, so the SP'd sequence
        # sharding survives — slicing a model-sharded dim forces XLA into
        # involuntary replication
        P = patches.shape[1]
        pad = jnp.pad(patches.astype(h.dtype),
                      ((0, 0), (0, h.shape[1] - P), (0, 0)))
        h = h + pad
    h = shd.activation_hint(h)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, xs):
        (h,) = carry
        lp, li = xs
        lrng = tstream.derive(rng, li) if rng is not None else None
        h, _, aux = _decoder_layer(cfg, h, lp, positions, lrng)
        return (h,), aux

    h, auxes = _scan_layers(cfg, h, params["layers"], body)
    h = _norm(cfg, h, params["final_norm"])
    if return_hidden:
        return h, jnp.mean(auxes)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(h, table), jnp.mean(auxes)


def lm_prefill(cfg: ArchConfig, params, tokens, *, patches=None):
    """Forward over S tokens building the KV cache.

    Returns (last-position logits (B, V), cache (k, v) each
    (L, B, S, K, hd))."""
    h = L.embed(tokens, params["embed"])
    if cfg.family == "vlm" and patches is not None:
        # pad+add (not slice+concat): elementwise, so the SP'd sequence
        # sharding survives — slicing a model-sharded dim forces XLA into
        # involuntary replication
        P = patches.shape[1]
        pad = jnp.pad(patches.astype(h.dtype),
                      ((0, 0), (0, h.shape[1] - P), (0, 0)))
        h = h + pad
    h = shd.activation_hint(h)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, xs):
        (h,) = carry
        lp, li = xs
        h, kv, _ = _decoder_layer(cfg, h, lp, positions, None)
        return (h,), kv

    h, caches = _scan_layers(cfg, h, params["layers"], body)
    h = _norm(cfg, h, params["final_norm"])
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(h[:, -1:], table)[:, 0]
    return logits, caches


def lm_decode(cfg: ArchConfig, params, cache, token, pos):
    """One decode step. token (B, 1) int32; cache (k, v) stacked (L, ...);
    pos: scalar int32 (current length).  Returns (logits (B, V), cache).

    The cache rides in the scan CARRY (not xs/ys): carry buffers alias
    across iterations, so with donated inputs the multi-GiB KV cache is
    updated IN PLACE — a stacked-ys formulation doubles peak memory
    (measured: gemma-7b decode_32k 27.6 -> ~15 GiB/chip)."""
    h = L.embed(token, params["embed"])
    B = token.shape[0]
    positions = jnp.broadcast_to(pos[None], (B, 1)).astype(jnp.int32)

    def body(carry, xs):
        h, kc_all, vc_all = carry
        lp, li = xs
        kc = jax.lax.dynamic_index_in_dim(kc_all, li, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vc_all, li, 0, keepdims=False)
        h, (kc, vc), _ = _decoder_layer(cfg, h, lp, positions, None,
                                        kv_cache=(kc, vc), pos=pos)
        kc_all = jax.lax.dynamic_update_index_in_dim(kc_all, kc, li, 0)
        vc_all = jax.lax.dynamic_update_index_in_dim(vc_all, vc, li, 0)
        return (h, kc_all, vc_all), ()

    idx = jnp.arange(cfg.n_layers)
    body = _maybe_remat(cfg, body)
    (h, kc_all, vc_all), _ = jax.lax.scan(
        body, (h, cache[0], cache[1]), (params["layers"], idx),
        unroll=_unroll(cfg))
    h = _norm(cfg, h, params["final_norm"])
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(h, table)[:, 0], (kc_all, vc_all)


# ---------------------------------------------------------------------------
# encoder-decoder (whisper-family)
# ---------------------------------------------------------------------------

def encode(cfg: ArchConfig, params, frames):
    """frames: (B, enc_ctx, D) precomputed conv-frontend output (stub)."""
    B, T, D = frames.shape
    pos = jnp.asarray(L.sinusoid_positions(T, D))
    h = (frames + pos[None]).astype(CD)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(carry, xs):
        (h,) = carry
        lp, li = xs
        h, _, _ = _decoder_layer(cfg, h, lp, positions, None, causal=False)
        return (h,), ()

    idx = jnp.arange(cfg.enc_layers)
    bodyr = _maybe_remat(cfg, body)
    (h,), _ = jax.lax.scan(bodyr, (h,), (params["enc_layers"], idx),
                           unroll=_unroll(cfg))
    return L.layer_norm(h, params["enc_final_norm_w"],
                        params["enc_final_norm_b"], cfg.norm_eps)


def _dec_positions(cfg, tokens):
    B, S = tokens.shape
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))


def _cross_kv(cfg, params, enc_out):
    """Precompute per-decoder-layer cross K/V: (L, B, T, K, hd) x2."""
    def body(_, lp):
        k = jnp.einsum("btd,dkh->btkh", enc_out,
                       lp["xwk"].astype(enc_out.dtype))
        v = jnp.einsum("btd,dkh->btkh", enc_out,
                       lp["xwv"].astype(enc_out.dtype))
        return None, (k, v)

    _, kv = jax.lax.scan(body, None, params["dec_layers"])
    return kv


def encdec_forward(cfg: ArchConfig, params, frames, tokens, *,
                   rng: Optional[tstream.ThunderStream] = None,
                   return_hidden: bool = False):
    """Training forward: (B, T, D) frames + (B, S) tokens -> logits."""
    enc_out = encode(cfg, params, frames)
    h = L.embed(tokens, params["embed"])
    B, S = tokens.shape
    pos_table = jnp.asarray(L.sinusoid_positions(S, cfg.d_model))
    h = shd.activation_hint(h + pos_table[None].astype(h.dtype))
    positions = _dec_positions(cfg, tokens)

    def body(carry, xs):
        (h,) = carry
        lp, li = xs
        lrng = tstream.derive(rng, li) if rng is not None else None
        xk = jnp.einsum("btd,dkh->btkh", enc_out, lp["xwk"].astype(enc_out.dtype))
        xv = jnp.einsum("btd,dkh->btkh", enc_out, lp["xwv"].astype(enc_out.dtype))
        h, _, _ = _decoder_layer(cfg, h, lp, positions, lrng,
                                 enc_out=(xk, xv))
        return (h,), ()

    idx = jnp.arange(cfg.n_layers)
    bodyr = _maybe_remat(cfg, body)
    (h,), _ = jax.lax.scan(bodyr, (h,), (params["dec_layers"], idx),
                           unroll=_unroll(cfg))
    h = L.layer_norm(h, params["final_norm_w"], params["final_norm_b"],
                     cfg.norm_eps)
    if return_hidden:
        return h, jnp.zeros((), jnp.float32)
    return L.unembed(h, params["embed"]), jnp.zeros((), jnp.float32)


def encdec_prefill(cfg: ArchConfig, params, frames, tokens):
    """Returns (last logits, (self_k, self_v, cross_k, cross_v))."""
    enc_out = encode(cfg, params, frames)
    cross = _cross_kv(cfg, params, enc_out)
    h = L.embed(tokens, params["embed"])
    B, S = tokens.shape
    pos_table = jnp.asarray(L.sinusoid_positions(S, cfg.d_model))
    h = h + pos_table[None].astype(h.dtype)
    positions = _dec_positions(cfg, tokens)

    def body(carry, xs):
        (h,) = carry
        lp, li, xk, xv = xs
        h, kv, _ = _decoder_layer(cfg, h, lp, positions, None,
                                  enc_out=(xk, xv))
        return (h,), kv

    idx = jnp.arange(cfg.n_layers)
    bodyr = _maybe_remat(cfg, body)
    (h,), self_kv = jax.lax.scan(
        bodyr, (h,), (params["dec_layers"], idx, cross[0], cross[1]),
        unroll=_unroll(cfg))
    h = L.layer_norm(h, params["final_norm_w"], params["final_norm_b"],
                     cfg.norm_eps)
    logits = L.unembed(h[:, -1:], params["embed"])[:, 0]
    return logits, (self_kv[0], self_kv[1], cross[0], cross[1])


def encdec_decode(cfg: ArchConfig, params, cache, token, pos):
    self_k, self_v, cross_k, cross_v = cache
    h = L.embed(token, params["embed"])
    B = token.shape[0]
    # sinusoid at position pos
    pos_row = jnp.asarray(L.sinusoid_positions(self_k.shape[2], cfg.d_model))
    h = h + jax.lax.dynamic_slice_in_dim(pos_row, pos, 1, 0)[None].astype(h.dtype)
    positions = jnp.broadcast_to(pos[None], (B, 1)).astype(jnp.int32)

    def body(carry, xs):
        h, sk_all, sv_all = carry
        lp, li, xk, xv = xs
        kc = jax.lax.dynamic_index_in_dim(sk_all, li, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(sv_all, li, 0, keepdims=False)
        h, (kc, vc), _ = _decoder_layer(cfg, h, lp, positions, None,
                                        kv_cache=(kc, vc), pos=pos,
                                        enc_out=(xk, xv))
        sk_all = jax.lax.dynamic_update_index_in_dim(sk_all, kc, li, 0)
        sv_all = jax.lax.dynamic_update_index_in_dim(sv_all, vc, li, 0)
        return (h, sk_all, sv_all), ()

    idx = jnp.arange(cfg.n_layers)
    bodyr = _maybe_remat(cfg, body)
    (h, self_k, self_v), _ = jax.lax.scan(
        bodyr, (h, self_k, self_v),
        (params["dec_layers"], idx, cross_k, cross_v), unroll=_unroll(cfg))
    h = L.layer_norm(h, params["final_norm_w"], params["final_norm_b"],
                     cfg.norm_eps)
    logits = L.unembed(h, params["embed"])[:, 0]
    return logits, (self_k, self_v, cross_k, cross_v)
