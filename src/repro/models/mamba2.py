"""Mamba2 (SSD — state-space duality) blocks, chunked-parallel training
form + O(1)-state decode form.  arXiv:2405.21060.

Chunked SSD: sequence split into chunks of Q; within-chunk the quadratic
(Q x Q) "attention-like" form runs on the MXU; across chunks a linear
recurrence over the (H, N, P) states runs in a lax.scan.  Sub-quadratic in
S (O(S*Q + S*N*P)) — this is why the ssm/hybrid archs run the long_500k
shape that full-attention archs skip.

Single group (G=1) for B/C as in the assigned configs.  The depthwise
causal conv runs as three separate convs (x / B / C) so the d_inner part
shards over the model axis while the small B/C parts stay replicated.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import sharding as shd
from repro.models.common import ArchConfig, ParamFactory

CD = L.COMPUTE_DTYPE


def mamba_layer_params(pf: ParamFactory, cfg: ArchConfig, prefix: str,
                       n_layers: int) -> Dict[str, jnp.ndarray]:
    D = cfg.d_model
    DI = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    ck = cfg.ssm_conv
    std = 0.02
    std_out = std / np.sqrt(2.0 * max(cfg.n_layers, 1))
    Lx = ("layer",)
    p = {}
    p[f"{prefix}/norm"] = pf.zeros(f"{prefix}/norm", (n_layers, D),
                                   Lx + ("embed",))
    p[f"{prefix}/wz"] = pf.normal(f"{prefix}/wz", (n_layers, D, DI), std,
                                  Lx + ("embed", "ssm_inner"))
    p[f"{prefix}/wx"] = pf.normal(f"{prefix}/wx", (n_layers, D, DI), std,
                                  Lx + ("embed", "ssm_inner"))
    p[f"{prefix}/wB"] = pf.normal(f"{prefix}/wB", (n_layers, D, N), std,
                                  Lx + ("embed", "ssm_state"))
    p[f"{prefix}/wC"] = pf.normal(f"{prefix}/wC", (n_layers, D, N), std,
                                  Lx + ("embed", "ssm_state"))
    p[f"{prefix}/wdt"] = pf.normal(f"{prefix}/wdt", (n_layers, D, H), std,
                                   Lx + ("embed", "ssm_heads"))
    # dt bias: softplus^-1 of log-spaced dt in [1e-3, 1e-1]
    dts = np.exp(np.linspace(np.log(1e-3), np.log(1e-1), H,
                             dtype=np.float32))
    dtb = np.log(np.expm1(dts))
    p[f"{prefix}/dt_bias"] = pf.const(
        f"{prefix}/dt_bias", jnp.broadcast_to(jnp.asarray(dtb), (n_layers, H)),
        Lx + ("ssm_heads",))
    a_init = np.log(np.linspace(1.0, 16.0, H, dtype=np.float32))
    p[f"{prefix}/a_log"] = pf.const(
        f"{prefix}/a_log", jnp.broadcast_to(jnp.asarray(a_init), (n_layers, H)),
        Lx + ("ssm_heads",))
    p[f"{prefix}/d_skip"] = pf.ones(f"{prefix}/d_skip", (n_layers, H),
                                    Lx + ("ssm_heads",))
    p[f"{prefix}/conv_x_w"] = pf.normal(f"{prefix}/conv_x_w",
                                        (n_layers, ck, DI), 0.1,
                                        Lx + ("conv_k", "ssm_inner"))
    p[f"{prefix}/conv_x_b"] = pf.zeros(f"{prefix}/conv_x_b", (n_layers, DI),
                                       Lx + ("ssm_inner",))
    p[f"{prefix}/conv_B_w"] = pf.normal(f"{prefix}/conv_B_w",
                                        (n_layers, ck, N), 0.1,
                                        Lx + ("conv_k", "ssm_state"))
    p[f"{prefix}/conv_B_b"] = pf.zeros(f"{prefix}/conv_B_b", (n_layers, N),
                                       Lx + ("ssm_state",))
    p[f"{prefix}/conv_C_w"] = pf.normal(f"{prefix}/conv_C_w",
                                        (n_layers, ck, N), 0.1,
                                        Lx + ("conv_k", "ssm_state"))
    p[f"{prefix}/conv_C_b"] = pf.zeros(f"{prefix}/conv_C_b", (n_layers, N),
                                       Lx + ("ssm_state",))
    p[f"{prefix}/gnorm"] = pf.zeros(f"{prefix}/gnorm", (n_layers, DI),
                                    Lx + ("ssm_inner",))
    p[f"{prefix}/out_proj"] = pf.normal(f"{prefix}/out_proj",
                                        (n_layers, DI, D), std_out,
                                        Lx + ("ssm_inner", "embed"))
    return p


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv over seq: x (B, S, C), w (ck, C), b (C,).

    ``tail``: (B, ck-1, C) carry-in from previous segment (decode/prefill
    continuation); zeros when None.
    """
    ck = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (ck - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(ck):
        out = out + xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
    return jax.nn.silu(out + b.astype(x.dtype))


def _ssd_chunked(x, dt, A, B_, C_, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) fp32; A: (H,) fp32 (negative);
    B_/C_: (B, S, N).  Returns (y (B, S, H, P), final state (B, H, N, P)).
    """
    B, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q
    xb = x.reshape(B, nc, Q, H, P)
    dtb = dt.reshape(B, nc, Q, H)
    Bb = B_.reshape(B, nc, Q, N).astype(jnp.float32)
    Cb = C_.reshape(B, nc, Q, N).astype(jnp.float32)

    dA = dtb * A                                    # (B, nc, Q, H) fp32, <=0
    cum = jnp.cumsum(dA, axis=2)
    # within-chunk decay L[i, j] = exp(cum_i - cum_j), i >= j
    cumT = cum.transpose(0, 1, 3, 2)                # (B, nc, H, Q)
    seg = cumT[..., :, None] - cumT[..., None, :]   # (B, nc, H, Q, Q)
    tri = np.tril(np.ones((Q, Q), np.bool_))
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cb, Bb)
    M = scores[:, :, None] * Lmat                   # (B, nc, H, Q, Q)
    xdt = (xb.astype(jnp.float32) * dtb[..., None])
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, xdt)

    # chunk-boundary states
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)    # (B, nc, Q, H)
    states = jnp.einsum("bcjn,bcjhp->bchnp", Bb, xdt * decay_end[..., None])
    chunk_decay = jnp.exp(cum[:, :, -1, :])         # (B, nc, H)

    def scan_body(hprev, inp):
        cd, st = inp                                # (B, H), (B, H, N, P)
        hnew = cd[..., None, None] * hprev + st
        return hnew, hprev

    init = jnp.zeros((B, H, N, P), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    final, prevs = jax.lax.scan(
        scan_body, init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prevs = prevs.transpose(1, 0, 2, 3, 4)          # (B, nc, H, N, P)

    decay_start = jnp.exp(cum)                      # (B, nc, Q, H)
    y_off = jnp.einsum("bcin,bchnp->bcihp", Cb, prevs) * \
        decay_start.transpose(0, 1, 2, 3)[..., None]
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y.astype(x.dtype), final


def mamba_block(cfg: ArchConfig, lp: Dict[str, jnp.ndarray], h: jnp.ndarray,
                rng=None, conv_tails=None, h0=None):
    """Full-sequence mamba2 block.  h: (B, S, D).

    Returns (out (B, S, D), (final ssm state, conv tails))."""
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    ck = cfg.ssm_conv
    x_in = L.rms_norm(h, lp["norm"], cfg.norm_eps)
    z = jnp.einsum("bsd,di->bsi", x_in, lp["wz"].astype(x_in.dtype))
    xr = jnp.einsum("bsd,di->bsi", x_in, lp["wx"].astype(x_in.dtype))
    Br = jnp.einsum("bsd,dn->bsn", x_in, lp["wB"].astype(x_in.dtype))
    Cr = jnp.einsum("bsd,dn->bsn", x_in, lp["wC"].astype(x_in.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x_in.astype(jnp.float32),
                        lp["wdt"].astype(jnp.float32)) + \
        lp["dt_bias"].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw)                     # (B, S, H) fp32

    t_x = t_B = t_C = None
    if conv_tails is not None:
        t_x, t_B, t_C = conv_tails
    xc = _causal_conv(xr, lp["conv_x_w"], lp["conv_x_b"], t_x)
    Bc = _causal_conv(Br, lp["conv_B_w"], lp["conv_B_b"], t_B)
    Cc = _causal_conv(Cr, lp["conv_C_w"], lp["conv_C_b"], t_C)

    A = -jnp.exp(lp["a_log"].astype(jnp.float32))    # (H,)
    xh = xc.reshape(*xc.shape[:2], H, P)
    y, final = _ssd_chunked(xh, dt, A, Bc, Cc, chunk=128, h0=h0)
    y = y + xh * lp["d_skip"].astype(xh.dtype)[:, None]
    y = y.reshape(*y.shape[:2], DI)
    y = L.rms_norm(y * jax.nn.silu(z), lp["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, lp["out_proj"].astype(y.dtype))
    if rng is not None:
        out = L.dropout(out, rng, cfg.dropout_rate)
    new_tails = (_tail_of(t_x, xr, ck), _tail_of(t_B, Br, ck),
                 _tail_of(t_C, Cr, ck))
    return shd.activation_hint(h + out), (final, new_tails)


def _tail_of(prev_tail, seq, ck):
    """Last ck-1 raw conv inputs (using the carry-in when seq is short)."""
    need = ck - 1
    if seq.shape[1] >= need:
        return seq[:, -need:]
    if prev_tail is None:
        pad = jnp.zeros((seq.shape[0], need - seq.shape[1], seq.shape[2]),
                        seq.dtype)
        return jnp.concatenate([pad, seq], axis=1)
    keep = need - seq.shape[1]
    return jnp.concatenate([prev_tail[:, -keep:].astype(seq.dtype), seq],
                           axis=1)


def mamba_decode_step(cfg: ArchConfig, lp, h: jnp.ndarray, state, tails):
    """One-token step.  h: (B, 1, D); state (B, H, N, P) fp32;
    tails: 3x (B, ck-1, C).  Returns (out (B, 1, D), state, tails)."""
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    ck = cfg.ssm_conv
    t_x, t_B, t_C = tails
    x_in = L.rms_norm(h, lp["norm"], cfg.norm_eps)
    z = jnp.einsum("bsd,di->bsi", x_in, lp["wz"].astype(x_in.dtype))
    xr = jnp.einsum("bsd,di->bsi", x_in, lp["wx"].astype(x_in.dtype))
    Br = jnp.einsum("bsd,dn->bsn", x_in, lp["wB"].astype(x_in.dtype))
    Cr = jnp.einsum("bsd,dn->bsn", x_in, lp["wC"].astype(x_in.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x_in.astype(jnp.float32),
                        lp["wdt"].astype(jnp.float32)) + \
        lp["dt_bias"].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw)[:, 0]               # (B, H)

    xc = _causal_conv(xr, lp["conv_x_w"], lp["conv_x_b"], t_x)[:, 0]
    Bc = _causal_conv(Br, lp["conv_B_w"], lp["conv_B_b"], t_B)[:, 0]
    Cc = _causal_conv(Cr, lp["conv_C_w"], lp["conv_C_b"], t_C)[:, 0]
    new_tails = (_tail_of(t_x, xr, ck), _tail_of(t_B, Br, ck),
                 _tail_of(t_C, Cr, ck))

    A = -jnp.exp(lp["a_log"].astype(jnp.float32))
    xh = xc.reshape(-1, H, P).astype(jnp.float32)    # (B, H, P)
    dA = jnp.exp(dt * A)                             # (B, H)
    contrib = jnp.einsum("bn,bh,bhp->bhnp", Bc.astype(jnp.float32), dt, xh)
    state = dA[..., None, None] * state + contrib
    y = jnp.einsum("bn,bhnp->bhp", Cc.astype(jnp.float32), state)
    y = y + xh * lp["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(-1, 1, DI).astype(h.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), lp["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, lp["out_proj"].astype(y.dtype))
    return h + out, state, new_tails
