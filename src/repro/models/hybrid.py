"""Zamba2-style hybrid: mamba2 backbone + ONE shared attention+MLP block
applied every ``attn_every`` mamba layers (weights shared across all
applications; per-application LoRA adapters of the reference model are
omitted — see DESIGN.md).  KV cache exists only for the shared-block
applications: (n_apps, B, T, K, hd)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import stream as tstream
from repro.models import layers as L
from repro.models import mamba2
from repro.models import sharding as shd
from repro.models.common import ArchConfig, ParamFactory, unflatten


def n_apps(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def init_hybrid(cfg: ArchConfig, seed: int):
    pf = ParamFactory(seed)
    D, V = cfg.d_model, cfg.vocab
    K = cfg.n_kv_heads
    R = cfg.n_heads // K
    hd = cfg.resolved_head_dim
    F = cfg.d_ff
    std = 0.02
    flat = {"embed": pf.normal("embed", (V, D), 0.02, ("vocab", "embed")),
            "final_norm": pf.zeros("final_norm", (D,), ("embed",))}
    flat.update(mamba2.mamba_layer_params(pf, cfg, "layers", cfg.n_layers))
    # shared attention + MLP block (single copy)
    flat["shared/attn_norm"] = pf.zeros("shared/attn_norm", (D,), ("embed",))
    flat["shared/wq"] = pf.normal("shared/wq", (D, K, R, hd), std,
                                  ("embed", "kv_heads", "q_rep", "head"))
    flat["shared/wk"] = pf.normal("shared/wk", (D, K, hd), std,
                                  ("embed", "kv_heads", "head"))
    flat["shared/wv"] = pf.normal("shared/wv", (D, K, hd), std,
                                  ("embed", "kv_heads", "head"))
    flat["shared/wo"] = pf.normal("shared/wo", (K, R, hd, D), std,
                                  ("kv_heads", "q_rep", "head", "embed"))
    flat["shared/mlp_norm"] = pf.zeros("shared/mlp_norm", (D,), ("embed",))
    flat["shared/wg"] = pf.normal("shared/wg", (D, F), std, ("embed", "f"))
    flat["shared/wi"] = pf.normal("shared/wi", (D, F), std, ("embed", "f"))
    flat["shared/wo_mlp"] = pf.normal("shared/wo_mlp", (F, D), std,
                                      ("f", "embed"))
    return unflatten(flat), dict(pf.specs)


def _shared_block(cfg, sp, h, positions, kv_cache=None, pos=None):
    a_in = L.rms_norm(h, sp["attn_norm"], cfg.norm_eps)
    q, k, v = L.qkv_split(a_in, sp["wq"], sp["wk"], sp["wv"])
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if kv_cache is not None:
        kc, vc = kv_cache
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                                 pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                                 pos, axis=1)
        o = L.decode_attention(q, kc, vc, pos)
        new_kv = (kc, vc)
    else:
        o = L.attention(q, k, v, causal=True, q_chunk=cfg.q_chunk)
        new_kv = (k, v)
    h = h + L.attn_out(o, sp["wo"])
    m_in = L.rms_norm(h, sp["mlp_norm"], cfg.norm_eps)
    h = shd.activation_hint(h + L.mlp(m_in, sp["wi"], sp["wo_mlp"], "silu",
                                      sp["wg"]))
    return h, new_kv


def _mamba_group(cfg, params, h, g0, g1, rng):
    """Scan mamba layers [g0, g1) (static bounds)."""
    sub = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, g0, g1, axis=0),
                       params["layers"])

    def body(carry, xs):
        (h,) = carry
        lp, li = xs
        lrng = tstream.derive(rng, li) if rng is not None else None
        h, _ = mamba2.mamba_block(cfg, lp, h, lrng)
        return (h,), ()

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    (h,), _ = jax.lax.scan(body_fn, (h,), (sub, jnp.arange(g0, g1)),
                           unroll=True if cfg.scan_unroll else 1)
    return h


def hybrid_forward(cfg: ArchConfig, params, tokens, *, rng=None,
                   return_hidden: bool = False):
    h = shd.activation_hint(L.embed(tokens, params["embed"]))
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ae = cfg.attn_every
    napps = n_apps(cfg)
    lo = 0
    for g in range(napps):
        h, _ = _shared_block(cfg, params["shared"], h, positions)
        h = _mamba_group(cfg, params, h, lo, lo + ae, rng)
        lo += ae
    if lo < cfg.n_layers:
        h = _mamba_group(cfg, params, h, lo, cfg.n_layers, rng)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return h, jnp.zeros((), jnp.float32)
    return L.unembed(h, params["embed"]), jnp.zeros((), jnp.float32)


def hybrid_prefill(cfg: ArchConfig, params, tokens):
    h = shd.activation_hint(L.embed(tokens, params["embed"]))
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ae = cfg.attn_every
    napps = n_apps(cfg)
    kvs, sstates, tx, tb, tc = [], [], [], [], []
    lo = 0

    def group_prefill(h, g0, g1):
        sub = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, g0, g1, axis=0),
                           params["layers"])

        def body(carry, xs):
            (h,) = carry
            lp, li = xs
            h, (st, tails) = mamba2.mamba_block(cfg, lp, h)
            return (h,), (st, tails[0], tails[1], tails[2])

        body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
        (h,), caches = jax.lax.scan(body_fn, (h,), (sub, jnp.arange(g0, g1)),
                                    unroll=True if cfg.scan_unroll else 1)
        return h, caches

    for g in range(napps):
        h, kv = _shared_block(cfg, params["shared"], h, positions)
        kvs.append(kv)
        h, caches = group_prefill(h, lo, lo + ae)
        sstates.append(caches[0])
        tx.append(caches[1]); tb.append(caches[2]); tc.append(caches[3])
        lo += ae
    if lo < cfg.n_layers:
        h, caches = group_prefill(h, lo, cfg.n_layers)
        sstates.append(caches[0])
        tx.append(caches[1]); tb.append(caches[2]); tc.append(caches[3])
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(h[:, -1:], params["embed"])[:, 0]
    cache = (jnp.stack([kv[0] for kv in kvs]),
             jnp.stack([kv[1] for kv in kvs]),
             jnp.concatenate(sstates, 0), jnp.concatenate(tx, 0),
             jnp.concatenate(tb, 0), jnp.concatenate(tc, 0))
    return logits, cache


def hybrid_decode(cfg: ArchConfig, params, cache, token, pos):
    kc_all, vc_all, sstates, tx, tb, tc = cache
    h = L.embed(token, params["embed"])
    B = token.shape[0]
    positions = jnp.broadcast_to(pos[None], (B, 1)).astype(jnp.int32)
    ae = cfg.attn_every
    napps = n_apps(cfg)
    new_kc, new_vc = [], []
    new_caches = []
    lo = 0

    def group_decode(h, g0, g1):
        sub = jax.tree.map(lambda a: jax.lax.slice_in_dim(a, g0, g1, axis=0),
                           params["layers"])
        sl = lambda a: jax.lax.slice_in_dim(a, g0, g1, axis=0)

        def body(carry, xs):
            (h,) = carry
            lp, li, st, x_, b_, c_ = xs
            h, st, (x_, b_, c_) = mamba2.mamba_decode_step(
                cfg, lp, h, st, (x_, b_, c_))
            return (h,), (st, x_, b_, c_)

        body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
        (h,), caches = jax.lax.scan(
            body_fn, (h,),
            (sub, jnp.arange(g0, g1), sl(sstates), sl(tx), sl(tb), sl(tc)),
            unroll=True if cfg.scan_unroll else 1)
        return h, caches

    for g in range(napps):
        h, kv = _shared_block(cfg, params["shared"], h, positions,
                              kv_cache=(kc_all[g], vc_all[g]), pos=pos)
        new_kc.append(kv[0]); new_vc.append(kv[1])
        h, caches = group_decode(h, lo, lo + ae)
        new_caches.append(caches)
        lo += ae
    if lo < cfg.n_layers:
        h, caches = group_decode(h, lo, cfg.n_layers)
        new_caches.append(caches)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(h, params["embed"])[:, 0]
    cache = (jnp.stack(new_kc), jnp.stack(new_vc),
             jnp.concatenate([c[0] for c in new_caches], 0),
             jnp.concatenate([c[1] for c in new_caches], 0),
             jnp.concatenate([c[2] for c in new_caches], 0),
             jnp.concatenate([c[3] for c in new_caches], 0))
    return logits, cache


def init_hybrid_cache(cfg: ArchConfig, batch: int, ctx: int):
    Lc, H, N, P = cfg.n_layers, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    K = cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ck = cfg.ssm_conv
    na = n_apps(cfg)
    return (jnp.zeros((na, batch, ctx, K, hd), L.COMPUTE_DTYPE),
            jnp.zeros((na, batch, ctx, K, hd), L.COMPUTE_DTYPE),
            jnp.zeros((Lc, batch, H, N, P), jnp.float32),
            jnp.zeros((Lc, batch, ck - 1, cfg.d_inner), L.COMPUTE_DTYPE),
            jnp.zeros((Lc, batch, ck - 1, N), L.COMPUTE_DTYPE),
            jnp.zeros((Lc, batch, ck - 1, N), L.COMPUTE_DTYPE))
