"""Sharded, atomic, elastic checkpointing.

Format: one directory per step, one ``.npy`` file per flattened pytree
leaf plus a ``manifest.json`` (paths, shapes, dtypes, step, pipeline
state).  Writes go to ``<dir>.tmp`` then ``os.replace`` — a crash mid-save
never corrupts the latest checkpoint (atomic rename), which together with
the deterministic data pipeline gives exact crash/restart semantics.

Elasticity: leaves are saved as full (host-gathered) arrays, so a restore
may target ANY mesh/sharding — the trainer re-shards on load (device_put
against the new sharding).  This is how a job resumes on a different pod
count after hardware failures.

Async: ``CheckpointManager(async_save=True)`` snapshots to host then
writes on a background thread — the train loop continues immediately
(compute/IO overlap).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.models.common import flatten, unflatten

_MANIFEST = "manifest.json"


def _sanitize(path: str) -> str:
    return path.replace("/", "__")


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Write atomically to <directory>/step_<n>; returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = flatten(tree) if isinstance(tree, dict) else \
        dict(enumerate_tree(tree))
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for path, leaf in flat.items():
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if logical_dtype == "bfloat16":      # numpy can't persist bf16
            arr = arr.view(np.uint16)
        fname = _sanitize(path) + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][path] = {"file": fname,
                                    "shape": list(arr.shape),
                                    "dtype": logical_dtype}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def enumerate_tree(tree):
    leaves, _ = jax.tree.flatten(tree)
    return {f"leaf_{i}": l for i, l in enumerate(leaves)}


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name, _MANIFEST)):
            steps.append(int(name[5:]))
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: Optional[int] = None,
                    shardings: Any = None):
    """Load (tree, step, extra).  ``shardings``: optional pytree of
    NamedSharding to place leaves onto (elastic re-shard)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    flat = {}
    for lpath, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(path, meta["file"]))
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        flat[lpath] = arr
    tree = unflatten(flat)
    if shardings is not None:
        flat_sh = flatten(shardings) if isinstance(shardings, dict) else None
        if flat_sh:
            placed = {k: jax.device_put(v, flat_sh[k])
                      for k, v in flat.items()}
            tree = unflatten(placed)
    return tree, manifest["step"], manifest.get("extra", {})


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async background save."""

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None):
        # snapshot to host memory NOW (cheap); write possibly async
        host = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            save_checkpoint(self.directory, step, host, extra)
            self._gc()

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, shardings: Any = None, step: Optional[int] = None):
        self.wait()
        return load_checkpoint(self.directory, step, shardings)

    def latest(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(s for s in (latest_step(self.directory),) if s is not None)
        all_steps = sorted(
            int(n[5:]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in all_steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
