"""CLI entry: ``python -m repro.inference`` (see harness.main)."""
from repro.inference.harness import main

raise SystemExit(main())
