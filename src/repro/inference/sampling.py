"""Token sampling over leased counter windows: the decode tier's ONE
randomness consumer.

``GumbelMaxSampler`` turns a ``(capacity, vocab)`` logit block into
``(capacity,)`` token ids, drawing all of a decode step's randomness
from ONE leased counter window of one per-class channel — the
continuous batcher's "one coalesced per-class request per decode step"
contract, metered here as ``engine_calls / steps`` (the CI gate).

Per decode step ``d`` the sampler consumes window
``[d * vocab, (d+1) * vocab)`` of its class channel; each live
sequence's noise column is the engine leaf at the sequence-tenant's
region tag.  The (channel, window, tags) triple is journaled per step
as an atomic batch record — ``repro.service.audit`` can regenerate any
sequence's per-step noise from the record alone, and a restarted run
replays journaled steps through ``lease-or-regenerate``: an explicit
``lease(at=d * vocab)`` that collides with a restored (fenced) window
is the replay signal, and the step regenerates bit-identically instead
of double-spending counters.

Sampling paths (all bit-compatible on real entries):

  * ``"fused"``  — the Pallas kernel (``inference.kernels``): one
    pallas_call, bits -> token ids, nothing intermediate in HBM.
  * ``"xla"`` / ``"ref"`` — the two-pass oracle: engine-generated
    ``"gumbel"`` noise block + the same masked first-argmax.

Greedy decode (``temperature <= 0``) takes the pure argmax and consumes
NO randomness — no lease, no journal record, zero engine calls.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, u64
from repro.inference import kernels as kern
from repro.runtime import blocks
from repro.service import frontend, tenants

PATHS = ("fused", "xla", "ref")


def class_channel(sampler: str = "gumbel",
                  out_dtype: str = "float32") -> str:
    """Channel name for one inference sampling class (cf.
    ``service.frontend.class_channel`` — same convention, own prefix)."""
    return f"inference/class/{sampler}/{out_dtype}"


@dataclasses.dataclass(frozen=True)
class SamplingSpec:
    """Host-side sampling parameters (compile-time, not per-step).

    ``temperature <= 0`` means greedy argmax (no randomness).
    ``top_k == 0`` disables the top-k filter; ``top_k = k`` keeps the k
    largest logits per sequence.  ``inv_temp`` is rounded once to f32 on
    the host so every backend scales by the identical constant.
    """
    temperature: float = 1.0
    top_k: int = 0

    def __post_init__(self):
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    @property
    def inv_temp(self) -> np.float32:
        return np.float32(1.0 / float(self.temperature))


@dataclasses.dataclass(frozen=True)
class ActiveSeq:
    """One live sequence's view of a decode step (slot pool row)."""
    slot: int           # slot index = logits row = noise column
    seq_id: str
    tenant_id: str
    tag: int            # absolute leaf tag (tenant region slot 0)
    position: int       # tokens generated so far = decode position

    @property
    def rid(self) -> str:
        return f"{self.seq_id}/t{self.position:06d}"


class GumbelMaxSampler:
    """Slot-batched gumbel-max token sampler over a BlockService channel.

    One instance serves a fixed ``(capacity, vocab)`` decode shape; the
    per-step executable is jitted once per path with TRACED tags and
    counter, so slot churn (different tag vectors every step) never
    retraces.
    """

    def __init__(self, service: blocks.BlockService,
                 registry: Optional[tenants.TenantRegistry] = None, *,
                 vocab: int, capacity: int,
                 spec: SamplingSpec = SamplingSpec(),
                 path: str = "fused", journal=None,
                 channel: Optional[str] = None,
                 deco: str = "splitmix64"):
        if path not in PATHS:
            raise ValueError(f"unknown sampling path {path!r}; have {PATHS}")
        if vocab < 1 or capacity < 1:
            raise ValueError(f"need vocab >= 1 and capacity >= 1, got "
                             f"vocab={vocab} capacity={capacity}")
        if spec.top_k > vocab:
            raise ValueError(f"top_k={spec.top_k} exceeds vocab={vocab}")
        self.service = service
        self.registry = registry
        self.vocab = int(vocab)
        self.capacity = int(capacity)
        self.spec = spec
        self.path = path
        self.journal = journal
        self.deco = deco
        self.channel = channel or class_channel()
        service.open(self.channel, num_streams=capacity, sampler="gumbel",
                     out_dtype="float32", deco=deco)
        self.steps = 0
        self.engine_calls = 0
        self.replayed_steps = 0
        self._jitted: Dict[str, Callable] = {}
        self._greedy_fn = jax.jit(
            lambda l: jnp.argmax(l, -1).astype(jnp.int32))

    @classmethod
    def standalone(cls, *, seed: int, vocab: int, capacity: int,
                   spec: SamplingSpec = SamplingSpec(),
                   path: str = "fused", journal=None) -> "GumbelMaxSampler":
        """Self-contained sampler over a fresh BlockService + registry
        (the thin-client entry ``launch/serve.py`` uses)."""
        return cls(blocks.BlockService(seed=seed),
                   tenants.TenantRegistry(), vocab=vocab,
                   capacity=capacity, spec=spec, path=path, journal=journal)

    # -- per-path executables ---------------------------------------------

    def jitted(self, path: Optional[str] = None) -> Callable:
        """The jitted step function for ``path`` (tests introspect the
        fused path's jaxpr through this)."""
        path = path or self.path
        fn = self._jitted.get(path)
        if fn is None:
            fn = self._build(path)
            self._jitted[path] = fn
        return fn

    def _build(self, path: str) -> Callable:
        V, B = self.vocab, self.capacity
        purpose = self.service.channel(self.channel).purpose
        x0, h_fam = engine.family_from_seed(self.service.seed, purpose)
        inv_temp = self.spec.inv_temp
        top_k = int(self.spec.top_k)
        deco = self.deco
        block_t, block_s = self.service.block_t, self.service.block_s

        def fn(logits, tag_hi, tag_lo, ctr_hi, ctr_lo):
            lf = logits.astype(jnp.float32).reshape(B, V)
            if top_k > 0:
                thresh = jax.lax.top_k(lf, top_k)[0][:, -1]
            else:
                thresh = jnp.full((B,), -jnp.inf, jnp.float32)
            h = engine.derive_leaf(
                (jnp.broadcast_to(jnp.asarray(h_fam[0]), tag_hi.shape),
                 jnp.broadcast_to(jnp.asarray(h_fam[1]), tag_lo.shape)),
                (tag_hi, tag_lo))
            lt = lf.T                                    # (V, B) vocab-major
            if path == "fused":
                roots, ctr_rows = engine.root_and_ctr_rows(
                    x0, (ctr_hi, ctr_lo), V)
                return kern.fused_argmax(
                    lt, h, roots, ctr_rows, thresh, inv_temp=inv_temp,
                    deco=deco, block_v=block_t, block_b=block_s,
                    interpret=engine.use_interpret())
            plan = engine.GenPlan(
                x0=x0, h=h, num_steps=V, ctr=(ctr_hi, ctr_lo), offset=None,
                mode="ctr", deco=deco, sampler="gumbel",
                out_dtype="float32")
            noise = engine.generate(plan, backend=path, block_t=block_t,
                                    block_s=block_s)
            return kern.twopass_argmax(lt, noise, thresh,
                                       inv_temp=inv_temp)

        return jax.jit(fn)

    # -- the decode step ---------------------------------------------------

    def sample_step(self, step: int, logits,
                    active: Sequence[ActiveSeq] = ()) -> np.ndarray:
        """(capacity,) int32 tokens for decode step ``step``.

        ``logits``: (capacity, vocab) — inactive slots' rows are ignored
        (their tokens are garbage; callers only read active slots).
        ``active``: the live sequences; their tags select the noise
        columns, their rids label the journal record.
        """
        self.steps += 1
        if self.spec.greedy:
            # pure argmax: consumes no randomness, journals nothing
            return np.asarray(self._greedy_fn(jnp.asarray(logits)))

        V = self.vocab
        lo = step * V
        lease = None
        try:
            lease = self.service.lease(self.channel, V, at=lo)
        except blocks.LeaseError:
            pass  # journaled window from a previous owner: regenerate

        tags = np.zeros(self.capacity, dtype=np.uint64)
        for a in active:
            tags[a.slot] = np.uint64(a.tag)
        c_hi, c_lo = u64.const64(lo)
        toks = self.jitted(self.path)(
            jnp.asarray(logits),
            jnp.asarray((tags >> np.uint64(32)).astype(np.uint32)),
            jnp.asarray(tags.astype(np.uint32)),
            jnp.asarray(c_hi), jnp.asarray(c_lo))
        self.engine_calls += 1
        toks = np.asarray(toks)

        if self.registry is not None:
            for a in active:
                self.registry.charge(a.tenant_id, V)
        if lease is not None:
            lease.commit()
            if self.journal is not None:
                assignments = [frontend.Assignment(
                    rid=a.rid, tenant_id=a.tenant_id, sampler="gumbel",
                    out_dtype="float32", shape=(V,), channel=self.channel,
                    lo=lo, rows=V, tags=(a.tag,), deco=self.deco)
                    for a in active]
                self.journal.append_batch(
                    assignments, [(self.channel, lo, lo + V)])
                self.journal.flush()
        else:
            self.replayed_steps += 1
        return toks

    def stats(self) -> Dict[str, float]:
        steps = max(1, self.steps)
        return {"steps": self.steps,
                "engine_calls": self.engine_calls,
                "replayed_steps": self.replayed_steps,
                "calls_per_step": self.engine_calls / steps,
                "path": self.path,
                "greedy": self.spec.greedy}
