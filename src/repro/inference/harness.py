"""Offline-inference benchmark harness: run, measure, verify, report.

``run_offline`` executes one :class:`ContinuousBatcher` schedule and
reduces it to the serving metrics the EXPERIMENTS table and the CI
gates read:

  * throughput — sampled tokens per second of decode wall time;
  * latency   — p50/p99 per-token latency, where one token's latency is
    its decode step's wall time (all live sequences' tokens in a step
    share the step; this is the standard continuous-batching
    accounting, and is what makes p99 an admission/churn tail metric
    rather than a kernel metric);
  * occupancy — mean live slots / capacity over decode steps;
  * calls/step — sampling-engine calls per decode step per class (the
    coalescing gate: one fused call serves the whole batch, so the
    meter is 1.0; the CI bound 1.25 leaves headroom for future
    multi-class schedules).

``--parity`` re-runs the identical schedule on the two-pass xla path
and asserts transcript-digest equality — the fused kernel's token
streams are thereby checked against engine-generated noise on every CI
run, not just in unit tests.  ``--fault-plan kill@K`` arms the scripted
adversary (the process dies at decode step K); re-running with the same
``--journal`` replays the journaled prefix bit-identically and the
digest must equal a fault-free run's (the crash-replay acceptance
check).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, Optional

import numpy as np

from repro.runtime import fault
from repro.service import audit
from repro.inference.scheduler import (ContinuousBatcher, RunResult,
                                       ScheduleConfig)


@dataclasses.dataclass
class OfflineReport:
    """JSON-able summary of one offline serving run."""
    config: ScheduleConfig
    result: RunResult
    wall_seconds: float
    parity_digest: Optional[str] = None   # xla-path digest when checked

    @property
    def tokens_per_s(self) -> float:
        decode = sum(self.result.step_seconds)
        return self.result.total_tokens / decode if decode else 0.0

    def to_json(self) -> Dict:
        lat = self.result.latency_percentiles()
        r = self.result
        return {
            "config": dataclasses.asdict(self.config),
            "decode_steps": r.decode_steps,
            "total_tokens": r.total_tokens,
            "admitted": r.admitted,
            "retired": r.retired,
            "occupancy": round(r.occupancy, 4),
            "tokens_per_s": round(self.tokens_per_s, 1),
            "p50_ms": round(lat["p50_ms"], 3),
            "p99_ms": round(lat["p99_ms"], 3),
            "calls_per_step": r.sampler_stats["calls_per_step"],
            "replayed_steps": r.sampler_stats["replayed_steps"],
            "digest": r.digest,
            "parity_digest": self.parity_digest,
            "wall_seconds": round(self.wall_seconds, 3),
        }


def run_offline(config: ScheduleConfig, *,
                journal_path: Optional[str] = None,
                fault_plan: Optional[fault.FaultPlan] = None,
                parity: bool = False) -> OfflineReport:
    """One offline continuous-batching run (+ optional parity re-run).

    ``journal_path`` arms the audit journal: a fresh path records the
    run; an existing one restores-and-replays it (the kill-and-restart
    flow is two calls with the same path).  ``parity=True`` re-runs the
    schedule on the ``"xla"`` two-pass path and asserts the transcript
    digests match (skipped when the primary path IS xla/ref).
    """
    journal = audit.Journal(journal_path) if journal_path else None
    try:
        t0 = time.perf_counter()
        result = ContinuousBatcher(config, journal=journal,
                                   fault_plan=fault_plan).run()
        wall = time.perf_counter() - t0
    finally:
        if journal is not None:
            journal.close()

    parity_digest = None
    if parity and config.path == "fused":
        twopass = dataclasses.replace(config, path="xla")
        ref = ContinuousBatcher(twopass).run()
        parity_digest = ref.digest
        if ref.digest != result.digest:
            raise AssertionError(
                f"fused vs two-pass transcript digest mismatch: "
                f"{result.digest} != {ref.digest}")
    return OfflineReport(config=config, result=result, wall_seconds=wall,
                         parity_digest=parity_digest)


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        prog="python -m repro.inference",
        description="offline continuous-batching serving harness")
    p.add_argument("--batch", type=int, default=64,
                   help="slot capacity (decode batch)")
    p.add_argument("--vocab", type=int, default=512)
    p.add_argument("--sequences", type=int, default=128,
                   help="total sequences to serve")
    p.add_argument("--rate", type=float, default=8.0,
                   help="Poisson arrival rate (sequences per decode step)")
    p.add_argument("--min-len", type=int, default=4)
    p.add_argument("--len-spread", type=int, default=29)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--temperature", type=float, default=1.0)
    p.add_argument("--top-k", type=int, default=0)
    p.add_argument("--path", choices=("fused", "xla", "ref"),
                   default="fused")
    p.add_argument("--max-steps", type=int, default=100_000)
    p.add_argument("--journal", default=None,
                   help="audit journal path (existing = restore + replay)")
    p.add_argument("--fault-plan", default="",
                   help='scripted faults, e.g. "kill@12" (decode-step '
                        'indexed)')
    p.add_argument("--digest-out", default=None,
                   help="write the transcript digest to this file")
    p.add_argument("--parity", action="store_true",
                   help="re-run on the xla path and assert digest parity")
    p.add_argument("--json", action="store_true",
                   help="print the full JSON report")
    args = p.parse_args(argv)

    config = ScheduleConfig(
        capacity=args.batch, vocab=args.vocab, sequences=args.sequences,
        rate=args.rate, min_len=args.min_len, len_spread=args.len_spread,
        seed=args.seed, temperature=args.temperature, top_k=args.top_k,
        path=args.path, max_steps=args.max_steps)
    plan = fault.FaultPlan.parse(args.fault_plan)
    report = run_offline(config, journal_path=args.journal,
                         fault_plan=plan or None, parity=args.parity)
    j = report.to_json()
    if args.digest_out:
        with open(args.digest_out, "w") as f:
            f.write(j["digest"] + "\n")
    if args.json:
        print(json.dumps(j, indent=2, sort_keys=True))
    else:
        print(f"served {j['retired']}/{j['admitted']} sequences, "
              f"{j['total_tokens']} tokens in {j['decode_steps']} steps | "
              f"{j['tokens_per_s']} tok/s | occupancy {j['occupancy']} | "
              f"p50 {j['p50_ms']}ms p99 {j['p99_ms']}ms | "
              f"calls/step {j['calls_per_step']:.2f} | "
              f"digest {j['digest'][:16]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
