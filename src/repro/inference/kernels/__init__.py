"""Inference-tier Pallas kernels (bits -> sampled token ids)."""
from repro.inference.kernels.gumbel_argmax import (  # noqa: F401
    argmax_first, fused_argmax, gumbel_scores, twopass_argmax)
